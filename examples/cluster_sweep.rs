//! Cluster sweep: scaling study across DP/TP sizes and the model family
//! (the workloads behind paper Figs. 8 and 9), on the simulator.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{simulate_iteration, Scenario};
use canzona::util::stats::load_balance_ratio;
use canzona::util::table::Table;

fn main() {
    // DP scaling at fixed TP (paper Fig. 8a).
    let mut t = Table::new("DP scaling — Qwen3-32B, TP=4, Muon",
                           &["DP", "GPUs", "ASC opt", "LB-ASC opt", "LB ratio (ASC)", "LB ratio (ours)"]);
    for dp in [8, 16, 32, 64, 128] {
        let asc = simulate_iteration(
            &Scenario::new(Qwen3Size::S32B, dp, 4, 1, OptimKind::Muon, DpStrategy::Asc));
        let lb = simulate_iteration(
            &Scenario::new(Qwen3Size::S32B, dp, 4, 1, OptimKind::Muon, DpStrategy::LbAsc));
        t.row(vec![
            dp.to_string(),
            (dp * 4).to_string(),
            format!("{:.3}s", asc.optimizer_s),
            format!("{:.3}s", lb.optimizer_s),
            format!("{:.2}x", load_balance_ratio(&asc.dp_loads_flops)),
            format!("{:.2}x", load_balance_ratio(&lb.dp_loads_flops)),
        ]);
    }
    t.print();

    // Model-size scaling at fixed grid (paper Fig. 9).
    let mut t2 = Table::new("Model scaling — DP=16, TP=4, Muon",
                            &["model", "ASC LB ratio", "ours LB ratio", "ours opt"]);
    for size in Qwen3Size::all() {
        let asc = simulate_iteration(
            &Scenario::new(size, 16, 4, 1, OptimKind::Muon, DpStrategy::Asc));
        let lb = simulate_iteration(
            &Scenario::new(size, 16, 4, 1, OptimKind::Muon, DpStrategy::LbAsc));
        t2.row(vec![
            size.label().into(),
            format!("{:.2}x", load_balance_ratio(&asc.dp_loads_flops)),
            format!("{:.2}x", load_balance_ratio(&lb.dp_loads_flops)),
            format!("{:.3}s", lb.optimizer_s),
        ]);
    }
    t2.print();

    // Optimizer generality (paper Figs. 10-12 flavour).
    let mut t3 = Table::new("Optimizer generality — Qwen3-14B, DP=32, TP=4, PP=2",
                            &["optimizer", "SC opt", "LB-ASC opt", "speedup"]);
    for opt in [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap] {
        let sc = simulate_iteration(
            &Scenario::new(Qwen3Size::S14B, 32, 4, 2, opt, DpStrategy::Sc));
        let lb = simulate_iteration(
            &Scenario::new(Qwen3Size::S14B, 32, 4, 2, opt, DpStrategy::LbAsc));
        t3.row(vec![
            opt.label().into(),
            format!("{:.3}s", sc.optimizer_s),
            format!("{:.3}s", lb.optimizer_s),
            format!("{:.1}x", sc.optimizer_s / lb.optimizer_s),
        ]);
    }
    t3.print();
}
