//! Cluster sweep: scaling study across DP/TP sizes and the model family
//! (the workloads behind paper Figs. 8 and 9), evaluated as one batch on
//! the plan-cached, work-stealing sweep engine.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::Scenario;
use canzona::sweep::SweepEngine;
use canzona::util::stats::load_balance_ratio;
use canzona::util::table::Table;

fn main() {
    let engine = SweepEngine::global();

    // DP scaling at fixed TP (paper Fig. 8a).
    let dps = [8usize, 16, 32, 64, 128];
    let mut scens: Vec<Scenario> = Vec::new();
    for &dp in &dps {
        scens.push(Scenario::new(Qwen3Size::S32B, dp, 4, 1, OptimKind::Muon, DpStrategy::Asc));
        scens.push(Scenario::new(Qwen3Size::S32B, dp, 4, 1, OptimKind::Muon, DpStrategy::LbAsc));
    }
    let res = engine.eval(&scens);
    let mut t = Table::new("DP scaling — Qwen3-32B, TP=4, Muon",
                           &["DP", "GPUs", "ASC opt", "LB-ASC opt", "LB ratio (ASC)", "LB ratio (ours)"]);
    for (i, &dp) in dps.iter().enumerate() {
        let (asc, lb) = (&res[2 * i], &res[2 * i + 1]);
        t.row(vec![
            dp.to_string(),
            (dp * 4).to_string(),
            format!("{:.3}s", asc.optimizer_s),
            format!("{:.3}s", lb.optimizer_s),
            format!("{:.2}x", load_balance_ratio(&asc.dp_loads_flops)),
            format!("{:.2}x", load_balance_ratio(&lb.dp_loads_flops)),
        ]);
    }
    t.print();

    // Model-size scaling at fixed grid (paper Fig. 9).
    let sizes = Qwen3Size::all();
    let mut scens2: Vec<Scenario> = Vec::new();
    for &size in &sizes {
        scens2.push(Scenario::new(size, 16, 4, 1, OptimKind::Muon, DpStrategy::Asc));
        scens2.push(Scenario::new(size, 16, 4, 1, OptimKind::Muon, DpStrategy::LbAsc));
    }
    let res2 = engine.eval(&scens2);
    let mut t2 = Table::new("Model scaling — DP=16, TP=4, Muon",
                            &["model", "ASC LB ratio", "ours LB ratio", "ours opt"]);
    for (i, size) in sizes.iter().enumerate() {
        let (asc, lb) = (&res2[2 * i], &res2[2 * i + 1]);
        t2.row(vec![
            size.label().into(),
            format!("{:.2}x", load_balance_ratio(&asc.dp_loads_flops)),
            format!("{:.2}x", load_balance_ratio(&lb.dp_loads_flops)),
            format!("{:.3}s", lb.optimizer_s),
        ]);
    }
    t2.print();

    // Optimizer generality (paper Figs. 10-12 flavour).
    let optims = [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap];
    let mut scens3: Vec<Scenario> = Vec::new();
    for &opt in &optims {
        scens3.push(Scenario::new(Qwen3Size::S14B, 32, 4, 2, opt, DpStrategy::Sc));
        scens3.push(Scenario::new(Qwen3Size::S14B, 32, 4, 2, opt, DpStrategy::LbAsc));
    }
    let res3 = engine.eval(&scens3);
    let mut t3 = Table::new("Optimizer generality — Qwen3-14B, DP=32, TP=4, PP=2",
                            &["optimizer", "SC opt", "LB-ASC opt", "speedup"]);
    for (i, opt) in optims.iter().enumerate() {
        let (sc, lb) = (&res3[2 * i], &res3[2 * i + 1]);
        t3.row(vec![
            opt.label().into(),
            format!("{:.3}s", sc.optimizer_s),
            format!("{:.3}s", lb.optimizer_s),
            format!("{:.1}x", sc.optimizer_s / lb.optimizer_s),
        ]);
    }
    t3.print();

    let stats = engine.cache_stats();
    println!("\nplan cache: {} hits / {} solves on {} threads",
             stats.hits, stats.solves, engine.threads());
}
