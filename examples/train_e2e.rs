//! End-to-end driver: train a real transformer LM with Muon under the
//! Canzona LB-ASC execution plan, on 4 thread ranks, through the full
//! three-layer stack (Pallas kernels -> JAX fwd/bwd -> AOT HLO -> Rust
//! coordinator + PJRT). Logs the loss curve and verifies SC parity on
//! the first steps.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e -- \
//!     [--steps 300] [--ranks 4] [--preset e2e] [--parity-steps 5]
//! ```

use canzona::partition::DpStrategy;
use canzona::train::{train, TrainConfig};
use canzona::util::cli::Args;

fn main() -> canzona::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let preset = args.get_or("preset", "e2e").to_string();
    let steps = args.get_usize("steps", 300)?;
    let ranks = args.get_usize("ranks", 4)?;
    let parity_steps = args.get_usize("parity-steps", 5)?;

    let mut cfg = TrainConfig::new(&preset);
    cfg.ranks = ranks;
    cfg.steps = steps;
    cfg.strategy = DpStrategy::LbAsc;
    cfg.log_every = 10;

    // Phase 1: precision verification (paper Fig. 5) on a short prefix.
    if parity_steps > 0 {
        println!("== parity check: SC vs LB-ASC, {parity_steps} steps ==");
        let mut short = cfg.clone();
        short.steps = parity_steps;
        short.log_every = 0;
        let lb = train(&short)?;
        short.strategy = DpStrategy::Sc;
        let sc = train(&short)?;
        assert_eq!(sc.losses, lb.losses, "loss trajectories diverged!");
        assert_eq!(sc.params_hash, lb.params_hash, "parameters diverged!");
        println!("bitwise parity OK over {parity_steps} steps (hash {:016x})\n",
                 lb.params_hash);
    }

    // Phase 2: the real run.
    println!("== training preset={preset} ranks={ranks} steps={steps} (LB-ASC, Muon) ==");
    let r = train(&cfg)?;
    let first = *r.losses.first().unwrap();
    let last = *r.losses.last().unwrap();
    println!("\nloss: {first:.4} -> {last:.4} over {} steps", r.losses.len());
    println!("mean step {:.3}s | mean optimizer phase {:.3}s | comm {:.1} MB",
             canzona::util::stats::mean(&r.step_times),
             canzona::util::stats::mean(&r.opt_times),
             r.comm_bytes as f64 / 1e6);

    // Persist the loss curve for EXPERIMENTS.md.
    let mut csv = String::from("step,loss\n");
    for (i, l) in r.losses.iter().enumerate() {
        csv += &format!("{},{l}\n", i + 1);
    }
    let out = format!("e2e_loss_{preset}.csv");
    std::fs::write(&out, csv)?;
    println!("wrote {out}");

    canzona::ensure!(last < first, "loss did not decrease");
    Ok(())
}
