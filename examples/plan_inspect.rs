//! Plan inspector: dump the per-bucket cut structure of every DP
//! strategy side by side, plus the TP micro-group schedule — useful for
//! understanding exactly how Algorithm 1 shifts boundaries.
//!
//! ```bash
//! cargo run --release --example plan_inspect -- [--model 1.7b] [--dp 8] [--tp 8]
//! ```

use canzona::buffer::FlatBuffer;
use canzona::cost::optim::{CostMetric, OptimCost, OptimKind};
use canzona::model::qwen3::{qwen3, Qwen3Size};
use canzona::model::tp::{fragmented_matrix_params, tp_split};
use canzona::partition::{alpha_balanced, equal_chunk, naive_atomic};
use canzona::schedule::microgroup::{build_micro_groups, tasks_from_shards};
use canzona::util::cli::Args;

fn main() -> canzona::util::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let size = Qwen3Size::parse(args.get_or("model", "1.7b"))
        .ok_or_else(|| canzona::err!("unknown model"))?;
    let dp = args.get_usize("dp", 8)?;
    let tp = args.get_usize("tp", 8)?;

    let census = qwen3(size);
    let fb = FlatBuffer::build(&census, 40_000_000);
    let w = |p: &canzona::buffer::PlacedParam| p.numel() as f64;

    println!("{} | {} tensors | {} buckets | DP={dp}\n", size.label(),
             fb.params.len(), fb.buckets.len());

    let plans = [
        ("equal-chunk (ZeRO-1)", equal_chunk(&fb, dp)),
        ("naive atomic (Eq. 1)", naive_atomic(&fb, dp)),
        ("α-balanced (Alg. 1)", alpha_balanced(&fb, dp, 1.0, true, w)),
    ];
    for (name, plan) in &plans {
        let loads = plan.rank_loads(&fb, w);
        let max = loads.iter().cloned().fold(0.0, f64::max);
        let avg = loads.iter().sum::<f64>() / dp as f64;
        println!("== {name}: Max/Avg = {:.3} ==", max / avg);
        // Show bucket 0's cuts.
        let c = &plan.cuts[0];
        let pretty: Vec<String> = c.iter().map(|x| format!("{:.1}M", *x as f64 / 1e6)).collect();
        println!("   bucket 0 cuts: {}", pretty.join(" | "));
        let bars: Vec<String> = loads
            .iter()
            .map(|l| format!("{:>4.0}%", 100.0 * l / max))
            .collect();
        println!("   per-rank load (% of max): {}\n", bars.join(" "));
    }

    // TP micro-groups.
    let shards = tp_split(&census, tp);
    let frag = fragmented_matrix_params(&shards, tp);
    let optim = OptimCost::new(OptimKind::Muon);
    let tasks = tasks_from_shards(&frag, &optim, CostMetric::Numel);
    let plan = build_micro_groups(tasks, tp, 512e6 / 2.0);
    println!("== TP micro-groups (TP={tp}, C_max=512MB) ==");
    println!("   {} fragmented tensors -> {} groups", plan.tasks.len(), plan.groups.len());
    for (i, g) in plan.groups.iter().enumerate().take(5) {
        println!("   group {i}: {} tasks, makespan {:.1}M cost, {:.0} MB fused all-to-all",
                 g.assignments.len(), g.max_load / 1e6, g.comm_bytes / 1e6);
    }
    if plan.groups.len() > 5 {
        println!("   ... ({} more)", plan.groups.len() - 5);
    }
    Ok(())
}
