//! Quickstart: plan + simulate the paper's main configuration.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the Qwen3-32B census, partitions it with α-Balanced Greedy LPT
//! (paper Alg. 1), schedules the TP plane into micro-groups (Algs. 2-4),
//! and simulates one training iteration for every strategy the paper
//! compares.

use canzona::buffer::FlatBuffer;
use canzona::model::qwen3::{qwen3, total_params, Qwen3Size};
use canzona::partition::{alpha_balanced, naive_atomic, DpStrategy};
use canzona::sim::{simulate_iteration, Scenario};
use canzona::util::stats::load_balance_ratio;

fn main() {
    // 1. The model census: the shape inventory drives everything.
    let census = qwen3(Qwen3Size::S32B);
    println!("Qwen3-32B census: {} tensors, {:.2}B parameters\n",
             census.len(), total_params(&census) as f64 / 1e9);

    // 2. The Megatron-style flat buffer and two DP partitions of it.
    let fb = FlatBuffer::build(&census, 40_000_000);
    let w = |p: &canzona::buffer::PlacedParam| p.numel() as f64;
    let naive = naive_atomic(&fb, 32);
    let balanced = alpha_balanced(&fb, 32, 1.0, true, w);
    println!("DP partition over 32 ranks ({} buckets):", fb.buckets.len());
    println!("  naive stride rule (Eq. 1):  Max/Avg = {:.2}x",
             load_balance_ratio(&naive.rank_loads(&fb, w)));
    println!("  α-balanced LPT   (Alg. 1):  Max/Avg = {:.2}x\n",
             load_balance_ratio(&balanced.rank_loads(&fb, w)));

    // 3. One simulated iteration per strategy (paper Figs. 3a/4).
    println!("{:<14} {:>9} {:>10} {:>9}", "strategy", "fwd-bwd", "optimizer", "total");
    for strat in [DpStrategy::Sc, DpStrategy::NvLayerwise, DpStrategy::Asc,
                  DpStrategy::LbAsc] {
        let b = simulate_iteration(&Scenario::paper_default().with_strategy(strat));
        println!("{:<14} {:>8.3}s {:>9.3}s {:>8.3}s",
                 strat.label(), b.fwd_bwd_s, b.optimizer_s, b.total_s);
    }
    println!("\nNext: `canzona experiment all` reproduces every paper figure;");
    println!("`cargo run --release --example train_e2e` runs real training.");
}
