"""L2: optimizer steps lowered to per-shape HLO artifacts.

Each function here is an *atomic optimizer task* in the Canzona sense:
it consumes a whole (unfragmented) gradient matrix plus locally-resident
states and produces the new weight/states. `aot.py` lowers one executable
per distinct parameter shape; the Rust coordinator schedules these tasks
onto rank threads according to the α-balanced / micro-group plans.

Matrix roots: the exact Shampoo step needs A^{-1/4}. `jnp.linalg.eigh`
lowers to a LAPACK custom-call that a bare PJRT-CPU client cannot execute,
so the artifact path uses the *coupled Newton iteration* (as in Anil et
al.'s distributed Shampoo) — pure matmuls, verified against the eigh
oracle in pytest. SOAP fundamentally requires the eigen*basis*, so its
artifact path keeps eigh; pytest covers its math and the cluster
simulator covers its scheduling (see DESIGN.md substitution table).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import newton_schulz as ns
from .kernels import ref
from .kernels.adamw import adamw_update  # re-export: the 1-D artifact  # noqa: F401
from .kernels.newton_schulz import muon_update  # re-export: the 2-D artifact  # noqa: F401


def inv_pth_root_newton(a: jax.Array, p: int, iters: int = 25,
                        ridge: float = 1e-6) -> jax.Array:
    """A^{-1/p} for symmetric PSD A via the coupled Newton iteration.

    M_0 = z*A, X_0 = z^{1/p} I with z = (1+p)/(2*||A||_F);
    T_k = ((1+1/p) I - (1/p) M_k); X_{k+1} = X_k T_k; M_{k+1} = T_k^p M_k.
    Matmul-only, hence lowerable to any PJRT backend.
    """
    n = a.shape[0]
    eye = jnp.eye(n, dtype=jnp.float32)
    a = a.astype(jnp.float32)
    a = a + (ridge * jnp.trace(a) / n + 1e-30) * eye
    z = (1.0 + p) / (2.0 * jnp.linalg.norm(a))
    m = z * a
    x = (z ** (1.0 / p)) * eye
    alpha = 1.0 / p

    def body(_, carry):
        x, m = carry
        t = (1.0 + alpha) * eye - alpha * m
        x = ns.matmul(x, t)
        t2 = ns.matmul(t, t)
        tp = ns.matmul(t2, t2) if p == 4 else (t2 if p == 2 else ns.matmul(t2, t))
        m = ns.matmul(tp, m)
        return x, m

    # Unrolled python loop: `iters` is static at lowering time.
    for i in range(iters):
        x, m = body(i, (x, m))
    return x


def shampoo_update(w, g, l_stat, r_stat, lr, beta=0.95, eps=1e-6,
                   root_iters: int = 25):
    """One exact Shampoo step (Newton roots, Pallas gram kernels).

    Returns (new_w, new_l, new_r). Matches `ref.shampoo_update_ref` up to
    the root-solver tolerance (checked in pytest).
    """
    g32 = g.astype(jnp.float32)
    l_new = beta * l_stat + (1.0 - beta) * ns.gram(g, "l")
    r_new = beta * r_stat + (1.0 - beta) * ns.gram(g, "r")
    pl_ = inv_pth_root_newton(l_new, 4, iters=root_iters, ridge=eps)
    pr_ = inv_pth_root_newton(r_new, 4, iters=root_iters, ridge=eps)
    precond = ns.matmul(ns.matmul(pl_, g32), pr_)
    gn = jnp.linalg.norm(g32) / (jnp.linalg.norm(precond) + 1e-12)
    w_new = w - lr * gn * precond.astype(w.dtype)
    return w_new, l_new, r_new


def soap_update(w, g, l_stat, r_stat, m, v, t, lr, beta=0.95,
                beta1=0.9, beta2=0.95, eps=1e-8):
    """One SOAP step (eigh-based; identical math to the ref oracle)."""
    return ref.soap_update_ref(w, g, l_stat, r_stat, m, v, t, lr,
                               beta=beta, beta1=beta1, beta2=beta2, eps=eps)


# ---------------------------------------------------------------------------
# Default hyper-parameters shared with the Rust side through the manifest.
# ---------------------------------------------------------------------------
HYPERS = {
    "muon": {"lr": 0.02, "beta": 0.95, "weight_decay": 0.0, "ns_steps": 5},
    "adamw": {"lr": 3e-3, "beta1": 0.9, "beta2": 0.95, "eps": 1e-8,
              "weight_decay": 0.0},
    "shampoo": {"lr": 0.05, "beta": 0.95, "eps": 1e-6, "root_iters": 25},
    "soap": {"lr": 3e-3, "beta": 0.95, "beta1": 0.9, "beta2": 0.95,
             "eps": 1e-8},
}


def reference_train_step(params, tokens, targets, states, step, cfg,
                         hypers=None):
    """Single-process Muon+AdamW training step in pure JAX.

    Used by pytest to validate that the distributed Rust execution of the
    same artifacts reproduces identical loss trajectories (paper Fig. 5).
    """
    from . import model as M

    hypers = hypers or HYPERS
    loss, grads = M.fwd_bwd(params, tokens, targets, cfg)
    new_params, new_states = {}, {}
    for name, shape, kind in M.param_spec(cfg):
        w, g = params[name], grads[name]
        if kind == M.KIND_MATRIX:
            mom = states[name]["mom"]
            h = hypers["muon"]
            w_new, mom_new = ref.muon_update_ref(
                w, g, mom, h["lr"], h["beta"], h["weight_decay"], h["ns_steps"])
            new_params[name] = w_new
            new_states[name] = {"mom": mom_new}
        else:
            st = states[name]
            h = hypers["adamw"]
            wf, gf = w.reshape(-1), g.reshape(-1)
            w_new, m_new, v_new = ref.adamw_update_ref(
                wf, gf, st["m"], st["v"], jnp.float32(step), h["lr"],
                h["beta1"], h["beta2"], h["eps"], h["weight_decay"])
            new_params[name] = w_new.reshape(w.shape)
            new_states[name] = {"m": m_new, "v": v_new}
    return loss, new_params, new_states


def init_states(params, cfg):
    """Zero-initialized optimizer states matching `reference_train_step`."""
    from . import model as M

    states = {}
    for name, shape, kind in M.param_spec(cfg):
        if kind == M.KIND_MATRIX:
            states[name] = {"mom": jnp.zeros(shape, jnp.float32)}
        else:
            n = int(functools.reduce(lambda a, b: a * b, shape, 1))
            states[name] = {"m": jnp.zeros(n, jnp.float32),
                            "v": jnp.zeros(n, jnp.float32)}
    return states
