"""AOT pipeline: lower L2/L1 to HLO **text** artifacts + manifest.

Python runs exactly once (`make artifacts`); the Rust coordinator is
self-contained afterwards. Interchange is HLO text — NOT a serialized
HloModuleProto — because jax >= 0.5 emits protos with 64-bit instruction
ids that xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Emitted per preset:
  fwd_bwd__<preset>.hlo.txt          loss + flat grads for the LM
  muon_<m>x<n>.hlo.txt               one per distinct 2-D matrix shape
  adamw_<numel>.hlo.txt              one per distinct AdamW tensor size
  shampoo_<m>x<n>.hlo.txt            (tiny always; larger presets opt-in)
  manifest__<preset>.json            parameter census, artifact map, hypers
"""

import argparse
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim as O


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _write(out_dir: str, name: str, text: str) -> str:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")
    return name


def lower_fwd_bwd(cfg: M.ModelConfig, out_dir: str) -> str:
    spec = M.param_spec(cfg)
    args = [_f32(shape) for _, shape, _ in spec]
    args += [_i32((cfg.batch, cfg.seq_len)), _i32((cfg.batch, cfg.seq_len))]
    lowered = jax.jit(M.flat_fwd_bwd(cfg)).lower(*args)
    return _write(out_dir, f"fwd_bwd__{cfg.name}.hlo.txt", to_hlo_text(lowered))


def lower_muon(shape, out_dir: str) -> str:
    m, n = shape
    h = O.HYPERS["muon"]

    def fn(w, g, mom, lr, beta):
        return O.muon_update(w, g, mom, lr, beta,
                             weight_decay=h["weight_decay"],
                             steps=h["ns_steps"])

    lowered = jax.jit(fn).lower(_f32(shape), _f32(shape), _f32(shape),
                                _f32(()), _f32(()))
    return _write(out_dir, f"muon_{m}x{n}.hlo.txt", to_hlo_text(lowered))


def lower_adamw(numel: int, out_dir: str) -> str:
    h = O.HYPERS["adamw"]

    def fn(w, g, m, v, t, lr):
        return O.adamw_update(w, g, m, v, t, lr, beta1=h["beta1"],
                              beta2=h["beta2"], eps=h["eps"],
                              weight_decay=h["weight_decay"])

    s = _f32((numel,))
    lowered = jax.jit(fn).lower(s, s, s, s, _f32(()), _f32(()))
    return _write(out_dir, f"adamw_{numel}.hlo.txt", to_hlo_text(lowered))


def lower_shampoo(shape, out_dir: str) -> str:
    m, n = shape
    h = O.HYPERS["shampoo"]

    def fn(w, g, l_stat, r_stat, lr):
        return O.shampoo_update(w, g, l_stat, r_stat, lr, beta=h["beta"],
                                eps=h["eps"], root_iters=h["root_iters"])

    lowered = jax.jit(fn).lower(_f32(shape), _f32(shape), _f32((m, m)),
                                _f32((n, n)), _f32(()))
    return _write(out_dir, f"shampoo_{m}x{n}.hlo.txt", to_hlo_text(lowered))


def build_manifest(cfg: M.ModelConfig, artifacts: Dict[str, str],
                   with_shampoo: bool) -> dict:
    params = []
    for name, shape, kind in M.param_spec(cfg):
        numel = 1
        for d in shape:
            numel *= d
        if kind == M.KIND_MATRIX:
            optim, artifact = "muon", f"muon_{shape[0]}x{shape[1]}"
        else:
            optim, artifact = "adamw", f"adamw_{numel}"
        params.append({
            "name": name,
            "shape": list(shape),
            "kind": kind,
            "numel": numel,
            "optim": optim,
            "artifact": artifact,
            "init_std": M.init_std(name, shape, kind, cfg),
        })
    return {
        "preset": cfg.name,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len, "batch": cfg.batch,
        },
        "params": params,
        "artifacts": artifacts,
        "hypers": O.HYPERS,
        "with_shampoo": with_shampoo,
    }


def build(preset: str, out_dir: str, with_shampoo: bool) -> None:
    cfg = M.PRESETS[preset]
    print(f"[aot] preset={preset} ({cfg})")
    os.makedirs(out_dir, exist_ok=True)
    artifacts: Dict[str, str] = {}
    artifacts["fwd_bwd"] = lower_fwd_bwd(cfg, out_dir)

    matrix_shapes = sorted({shape for _, shape, kind in M.param_spec(cfg)
                            if kind == M.KIND_MATRIX})
    adamw_sizes = sorted({
        int(jnp.prod(jnp.array(shape))) for _, shape, kind in M.param_spec(cfg)
        if kind != M.KIND_MATRIX})
    for shape in matrix_shapes:
        artifacts[f"muon_{shape[0]}x{shape[1]}"] = lower_muon(shape, out_dir)
    for numel in adamw_sizes:
        artifacts[f"adamw_{numel}"] = lower_adamw(numel, out_dir)
    if with_shampoo:
        for shape in matrix_shapes:
            artifacts[f"shampoo_{shape[0]}x{shape[1]}"] = lower_shampoo(shape, out_dir)

    manifest = build_manifest(cfg, artifacts, with_shampoo)
    path = os.path.join(out_dir, f"manifest__{preset}.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest__{preset}.json "
          f"({sum(p['numel'] for p in manifest['params']) / 1e6:.1f}M params)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="e2e", choices=sorted(M.PRESETS))
    ap.add_argument("--with-shampoo", action="store_true",
                    help="also lower Shampoo executables for this preset")
    args = ap.parse_args()
    # tiny always ships (fast tests depend on it), with Shampoo included.
    build("tiny", args.out_dir, with_shampoo=True)
    if args.preset != "tiny":
        build(args.preset, args.out_dir, with_shampoo=args.with_shampoo)


if __name__ == "__main__":
    main()
