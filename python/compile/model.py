"""L2: JAX transformer LM (fwd/bwd) — the compute graph Canzona trains.

A Qwen3-flavoured decoder-only LM (RMSNorm, SwiGLU MLP, causal MHA,
untied LM head). The parameter inventory deliberately mirrors the shape
census in `rust/src/model/qwen3.rs`: the same mix of large 2-D matrices
(Muon-updated) and 1-D norms / embedding-class tensors (AdamW-updated)
that drives the paper's load-balancing problem.

Only build-time code lives here: `aot.py` lowers `fwd_bwd` to HLO text
once, and the Rust coordinator executes the artifact on the request path.
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Presets. `tiny` drives fast tests, `e2e` is the recorded end-to-end run,
# `m100` is the ~100M-parameter configuration (same code path, heavier).
PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
                        d_ff=128, seq_len=32, batch=2),
    "e2e": ModelConfig("e2e", vocab=8192, d_model=384, n_layers=6, n_heads=6,
                       d_ff=1152, seq_len=128, batch=4),
    "m100": ModelConfig("m100", vocab=32000, d_model=640, n_layers=10,
                        n_heads=10, d_ff=1920, seq_len=256, batch=2),
}

# Parameter kinds: decide optimizer routing + init scale.
KIND_MATRIX = "matrix"  # 2-D, Muon
KIND_EMBED = "embed"    # 2-D but embedding-class -> AdamW (standard Muon practice)
KIND_VECTOR = "vector"  # 1-D -> AdamW


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Ordered (name, shape, kind) inventory. The order is the canonical
    flattening order shared with the Rust side via the manifest."""
    spec: List[Tuple[str, Tuple[int, ...], str]] = []
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    spec.append(("embed.weight", (v, d), KIND_EMBED))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        spec.append((p + "attn_norm.weight", (d,), KIND_VECTOR))
        spec.append((p + "attn.wq", (d, d), KIND_MATRIX))
        spec.append((p + "attn.wk", (d, d), KIND_MATRIX))
        spec.append((p + "attn.wv", (d, d), KIND_MATRIX))
        spec.append((p + "attn.wo", (d, d), KIND_MATRIX))
        spec.append((p + "mlp_norm.weight", (d,), KIND_VECTOR))
        spec.append((p + "mlp.gate", (d, ff), KIND_MATRIX))
        spec.append((p + "mlp.up", (d, ff), KIND_MATRIX))
        spec.append((p + "mlp.down", (ff, d), KIND_MATRIX))
    spec.append(("final_norm.weight", (d,), KIND_VECTOR))
    spec.append(("lm_head.weight", (v, d), KIND_EMBED))
    return spec


def init_std(name: str, shape: Tuple[int, ...], kind: str, cfg: ModelConfig) -> float:
    """Init scale per parameter (norm vectors start at exactly 1.0)."""
    if kind == KIND_VECTOR:
        return 0.0
    if kind == KIND_EMBED:
        return 0.02
    fan_in, fan_out = shape[0], shape[1]
    std = (2.0 / (fan_in + fan_out)) ** 0.5
    if name.endswith(("attn.wo", "mlp.down")):
        std /= (2.0 * cfg.n_layers) ** 0.5  # GPT-2-style residual scaling
    return std


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    params = {}
    for name, shape, kind in param_spec(cfg):
        key, sub = jax.random.split(key)
        if kind == KIND_VECTOR:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = init_std(name, shape, kind, cfg)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def attention(x: jax.Array, p: Dict[str, jax.Array], prefix: str,
              cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p[prefix + "attn.wq"]).reshape(b, s, h, hd)
    k = (x @ p[prefix + "attn.wk"]).reshape(b, s, h, hd)
    v = (x @ p[prefix + "attn.wv"]).reshape(b, s, h, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
    return out @ p[prefix + "attn.wo"]


def mlp(x: jax.Array, p: Dict[str, jax.Array], prefix: str) -> jax.Array:
    gate = jax.nn.silu(x @ p[prefix + "mlp.gate"])
    up = x @ p[prefix + "mlp.up"]
    return (gate * up) @ p[prefix + "mlp.down"]


def forward(params: Dict[str, jax.Array], tokens: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """tokens i32[B, S] -> logits f32[B, S, V]."""
    x = params["embed.weight"][tokens]
    for i in range(cfg.n_layers):
        prefix = f"layers.{i}."
        x = x + attention(rmsnorm(x, params[prefix + "attn_norm.weight"]), params, prefix, cfg)
        x = x + mlp(rmsnorm(x, params[prefix + "mlp_norm.weight"]), params, prefix)
    x = rmsnorm(x, params["final_norm.weight"])
    return x @ params["lm_head.weight"].T


def loss_fn(params: Dict[str, jax.Array], tokens: jax.Array,
            targets: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Mean next-token cross-entropy."""
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def fwd_bwd(params: Dict[str, jax.Array], tokens: jax.Array,
            targets: jax.Array, cfg: ModelConfig):
    """(loss, grads-dict) — the function AOT-lowered for the Rust trainer."""
    return jax.value_and_grad(lambda p: loss_fn(p, tokens, targets, cfg))(params)


def flat_fwd_bwd(cfg: ModelConfig):
    """Return fn(*flat_params, tokens, targets) -> (loss, *flat_grads)
    with the canonical `param_spec` ordering — the AOT entry point."""
    spec = param_spec(cfg)
    names = [n for n, _, _ in spec]

    def fn(*args):
        flat, tokens, targets = args[:-2], args[-2], args[-1]
        params = dict(zip(names, flat))
        loss, grads = fwd_bwd(params, tokens, targets, cfg)
        return (loss, *[grads[n] for n in names])

    return fn
