"""L1 Pallas kernel: element-wise AdamW update.

AdamW is the element-wise baseline in the paper (and the optimizer Muon
delegates 1-D parameters — embeddings, norms, biases — to). The kernel is a
1-D blocked element-wise pipeline: each grid step streams a VMEM-sized
chunk of (w, g, m, v) through the update math. `interpret=True` as always
on this CPU-PJRT environment.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64k f32 elements = 256 KiB per operand chunk; 4 inputs + 3 outputs keeps
# the VMEM working set < 2 MiB with pipeline double-buffering.
DEFAULT_CHUNK = 65536


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _adamw_kernel(beta1, beta2, eps, weight_decay,
                  w_ref, g_ref, m_ref, v_ref, t_ref, lr_ref,
                  ow_ref, om_ref, ov_ref):
    w, g, m, v = w_ref[...], g_ref[...], m_ref[...], v_ref[...]
    t, lr = t_ref[0], lr_ref[0]
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    ow_ref[...] = w * (1.0 - lr * weight_decay) - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    om_ref[...] = m_new
    ov_ref[...] = v_new


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps", "weight_decay", "chunk"))
def adamw_update(w, g, m, v, t, lr, *, beta1=0.9, beta2=0.95, eps=1e-8,
                 weight_decay=0.0, chunk=DEFAULT_CHUNK):
    """One AdamW step on a 1-D tensor. Returns (new_w, new_m, new_v).

    `t` (step, f32) and `lr` are traced scalars so a single lowered HLO
    serves the whole training run.
    """
    (n,) = w.shape
    c = min(chunk, n) or 1
    npad = _cdiv(n, c) * c
    pad = lambda a: jnp.pad(a, (0, npad - n)) if npad != n else a
    w_, g_, m_, v_ = pad(w), pad(g), pad(m), pad(v)
    # v is padded with zeros => sqrt(0)+eps in the pad region is fine.
    t_arr = jnp.reshape(t.astype(jnp.float32), (1,))
    lr_arr = jnp.reshape(lr.astype(jnp.float32), (1,))
    kernel = functools.partial(_adamw_kernel, beta1, beta2, eps, weight_decay)
    shape = jax.ShapeDtypeStruct((npad,), w.dtype)
    ow, om, ov = pl.pallas_call(
        kernel,
        grid=(npad // c,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_shape=[shape, shape, shape],
        interpret=True,
    )(w_, g_, m_, v_, t_arr, lr_arr)
    if npad != n:
        ow, om, ov = ow[:n], om[:n], ov[:n]
    return ow, om, ov
