"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here.
The pytest suite (python/tests/) sweeps shapes/dtypes with hypothesis and
asserts `assert_allclose(kernel(...), ref(...))`.

The math follows the optimizers the Canzona paper schedules:
  * Muon       — momentum + Newton-Schulz-5 orthogonalization (Jordan et al.)
  * Shampoo    — Kronecker preconditioners L, R with inverse 4th roots
  * SOAP       — Adam in the eigenbasis of the Shampoo preconditioners
  * AdamW      — element-wise baseline (Loshchilov & Hutter)
"""

import jax
import jax.numpy as jnp

# Quintic Newton-Schulz coefficients used by Muon (Jordan et al., 2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5
NS_EPS = 1e-7


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain matmul oracle (f32 accumulation)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def newton_schulz_ref(g: jax.Array, steps: int = NS_STEPS) -> jax.Array:
    """Quintic Newton-Schulz orthogonalization of a 2-D gradient.

    Returns an approximation of U V^T where g = U S V^T — the "zeroth power"
    of g. Operates on the smaller Gram side (transposes when m > n) exactly
    like the reference Muon implementation.
    """
    assert g.ndim == 2
    a, b, c = NS_COEFFS
    x = g.astype(jnp.float32)
    transposed = x.shape[0] > x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.linalg.norm(x) + NS_EPS)
    for _ in range(steps):
        gram = x @ x.T
        poly = b * gram + c * (gram @ gram)
        x = a * x + poly @ x
    if transposed:
        x = x.T
    return x.astype(g.dtype)


def muon_update_ref(w, g, mom, lr, beta, weight_decay=0.0, steps: int = NS_STEPS):
    """One Muon step: nesterov momentum -> NS5 -> scaled orthogonal update.

    Returns (new_w, new_mom). `lr`/`beta` are scalars (static or traced).
    """
    mom_new = beta * mom + g
    upd = g + beta * mom_new  # nesterov
    ortho = newton_schulz_ref(upd, steps=steps)
    m, n = w.shape
    scale = jnp.sqrt(jnp.maximum(1.0, m / n))
    w_new = w * (1.0 - lr * weight_decay) - lr * scale * ortho
    return w_new, mom_new


def adamw_update_ref(w, g, m, v, t, lr, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.0):
    """One AdamW step on a flat tensor. Returns (new_w, new_m, new_v)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    w_new = w * (1.0 - lr * weight_decay) - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return w_new, m_new, v_new


def gram_ref(g: jax.Array, side: str) -> jax.Array:
    """Shampoo statistic: G G^T (side='l') or G^T G (side='r')."""
    g = g.astype(jnp.float32)
    return g @ g.T if side == "l" else g.T @ g


def matrix_inv_pth_root_ref(a: jax.Array, p: int, eps: float = 1e-6) -> jax.Array:
    """A^{-1/p} for a symmetric PSD matrix via eigendecomposition."""
    a = a.astype(jnp.float32)
    ridge = eps * jnp.trace(a) / a.shape[0] + 1e-30
    vals, vecs = jnp.linalg.eigh(a + ridge * jnp.eye(a.shape[0], dtype=a.dtype))
    vals = jnp.maximum(vals, eps * jnp.max(vals))
    return (vecs * (vals ** (-1.0 / p))) @ vecs.T


def shampoo_update_ref(w, g, l_stat, r_stat, lr, beta=0.95, eps=1e-6):
    """One (full-matrix, exact) Shampoo step.

    Returns (new_w, new_l, new_r). Preconditioned grad = L^{-1/4} G R^{-1/4}.
    """
    l_new = beta * l_stat + (1.0 - beta) * gram_ref(g, "l")
    r_new = beta * r_stat + (1.0 - beta) * gram_ref(g, "r")
    pl_ = matrix_inv_pth_root_ref(l_new, 4, eps)
    pr_ = matrix_inv_pth_root_ref(r_new, 4, eps)
    precond = pl_ @ g.astype(jnp.float32) @ pr_
    # Grafting to the gradient norm keeps step sizes sane (standard practice).
    gn = jnp.linalg.norm(g) / (jnp.linalg.norm(precond) + 1e-12)
    w_new = w - lr * gn * precond.astype(w.dtype)
    return w_new, l_new, r_new


def soap_update_ref(w, g, l_stat, r_stat, m, v, t, lr, beta=0.95,
                    beta1=0.9, beta2=0.95, eps=1e-8):
    """One SOAP step: Adam in the eigenbasis of the Shampoo preconditioners.

    Returns (new_w, new_l, new_r, new_m, new_v). m/v live in the rotated
    basis (as in Vyas et al., 2024, with per-step eigendecomposition —
    the paper amortizes it; exactness is what Canzona preserves).
    """
    g32 = g.astype(jnp.float32)
    l_new = beta * l_stat + (1.0 - beta) * gram_ref(g, "l")
    r_new = beta * r_stat + (1.0 - beta) * gram_ref(g, "r")
    _, ql = jnp.linalg.eigh(l_new)
    _, qr = jnp.linalg.eigh(r_new)
    g_rot = ql.T @ g32 @ qr
    m_new = beta1 * m + (1.0 - beta1) * g_rot
    v_new = beta2 * v + (1.0 - beta2) * g_rot * g_rot
    m_hat = m_new / (1.0 - beta1 ** t)
    v_hat = v_new / (1.0 - beta2 ** t)
    upd_rot = m_hat / (jnp.sqrt(v_hat) + eps)
    upd = ql @ upd_rot @ qr.T
    w_new = w - lr * upd.astype(w.dtype)
    return w_new, l_new, r_new, m_new, v_new
