"""L1 Pallas kernels: blocked matmul + Newton-Schulz-5 orthogonalization.

This is the compute hot-spot of the Muon optimizer the Canzona paper
schedules. The GPU reference implementations stage tiles through shared
memory with threadblocks; here the same insight is expressed for the
TPU memory hierarchy:

  * `BlockSpec` describes the HBM->VMEM schedule: (bm, bk) x (bk, bn)
    tiles stream into VMEM, the MXU-shaped (128, 128) output tile is
    accumulated in-place across the K grid dimension (the innermost,
    sequential grid axis), so each output tile is resident in VMEM for
    the whole K loop — the double-buffering of the input tiles is done
    by the Pallas pipeline itself.
  * f32 accumulation with `preferred_element_type` targets the MXU's
    native accumulation width.

`interpret=True` is mandatory in this environment: real-TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute. The
BlockSpec structure is unchanged between the two paths, so the VMEM /
MXU-utilization analysis in DESIGN.md applies to the real-TPU build.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NS_COEFFS, NS_EPS, NS_STEPS

# MXU-aligned default tile. 128x128 f32 = 64 KiB per tile; the working set
# (x-tile + y-tile + out-tile + pipeline double buffers) stays well under
# the ~16 MiB VMEM budget of a TPU core (see DESIGN.md "Hardware adaptation").
DEFAULT_BLOCK = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = DEFAULT_BLOCK,
           bn: int = DEFAULT_BLOCK, bk: int = DEFAULT_BLOCK) -> jax.Array:
    """Blocked Pallas matmul: (m, k) @ (k, n) -> (m, n).

    Shapes need not be multiples of the block sizes; inputs are zero-padded
    (zeros are absorbing for matmul accumulation) and the result is sliced
    back. Padding happens at trace time so the AOT-lowered HLO carries the
    padded grid only when needed.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bn, bk = min(bm, m) or 1, min(bn, n) or 1, min(bk, k) or 1
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else y
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n] if (mp, np_) != (m, n) else out


def newton_schulz(g: jax.Array, steps: int = NS_STEPS) -> jax.Array:
    """Quintic Newton-Schulz orthogonalization with Pallas matmuls.

    Mirrors `ref.newton_schulz_ref` exactly; the three matmuls per
    iteration (gram, gram^2, poly @ x) run through the blocked kernel.
    """
    assert g.ndim == 2
    a, b, c = NS_COEFFS
    x = g.astype(jnp.float32)
    transposed = x.shape[0] > x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.linalg.norm(x) + NS_EPS)
    for _ in range(steps):
        gram = matmul(x, x.T)
        poly = b * gram + c * matmul(gram, gram)
        x = a * x + matmul(poly, x)
    if transposed:
        x = x.T
    return x.astype(g.dtype)


def muon_update(w, g, mom, lr, beta, weight_decay=0.0, steps: int = NS_STEPS):
    """One Muon step (Pallas NS core). Returns (new_w, new_mom).

    Matches `ref.muon_update_ref`; this is the function `aot.py` lowers to
    one HLO artifact per distinct 2-D parameter shape.
    """
    mom_new = beta * mom + g
    upd = g + beta * mom_new
    ortho = newton_schulz(upd, steps=steps)
    m, n = w.shape
    scale = jnp.sqrt(jnp.maximum(1.0, m / n))
    w_new = w * (1.0 - lr * weight_decay) - lr * scale * ortho
    return w_new, mom_new


def gram(g: jax.Array, side: str) -> jax.Array:
    """Shampoo statistic G G^T / G^T G through the Pallas matmul."""
    g32 = g.astype(jnp.float32)
    return matmul(g32, g32.T) if side == "l" else matmul(g32.T, g32)
