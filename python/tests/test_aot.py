"""AOT pipeline tests: HLO text well-formedness + manifest integrity.

Requires `make artifacts` (the tiny preset) to have run; skips otherwise.
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest__tiny.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _manifest():
    with open(os.path.join(ART, "manifest__tiny.json")) as f:
        return json.load(f)


def test_manifest_parses():
    m = _manifest()
    assert m["preset"] == "tiny"
    assert m["model"]["vocab"] == 256
    assert len(m["params"]) == 3 + m["model"]["n_layers"] * 9


def test_all_artifacts_exist_and_are_hlo():
    m = _manifest()
    for key, fname in m["artifacts"].items():
        path = os.path.join(ART, fname)
        assert os.path.exists(path), fname
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{fname} is not HLO text"
        assert "ENTRY" in open(path).read(), fname


def test_param_artifact_mapping_complete():
    m = _manifest()
    for p in m["params"]:
        assert p["artifact"] in m["artifacts"], p["name"]
        if p["optim"] == "muon":
            assert p["kind"] == "matrix"
            assert p["artifact"] == f"muon_{p['shape'][0]}x{p['shape'][1]}"
        else:
            assert p["artifact"] == f"adamw_{p['numel']}"


def test_numel_consistent():
    m = _manifest()
    for p in m["params"]:
        n = 1
        for d in p["shape"]:
            n *= d
        assert n == p["numel"]


def test_fwd_bwd_parameter_count():
    """fwd_bwd must expose P+2 parameters and 1+P tuple outputs."""
    m = _manifest()
    path = os.path.join(ART, m["artifacts"]["fwd_bwd"])
    text = open(path).read()
    # Nested fusion computations also contain parameter instructions;
    # only the ENTRY computation reflects the artifact's call signature.
    entry = text[text.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == len(m["params"]) + 2, n_params


def test_hypers_present():
    m = _manifest()
    for opt in ("muon", "adamw", "shampoo", "soap"):
        assert opt in m["hypers"]
    assert 0.0 < m["hypers"]["muon"]["lr"] < 1.0
