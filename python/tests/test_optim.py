"""L2 optimizer math: Shampoo/SOAP vs oracles, Newton root solver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import optim as O
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _psd(key, n):
    a = _rand(key, (n, n))
    return a @ a.T + 0.1 * jnp.eye(n)


# --------------------------------------------------- newton inverse root ---
@given(n=st.integers(2, 48), seed=st.integers(0, 2**31 - 1),
       p=st.sampled_from([2, 4]))
def test_inv_pth_root_matches_eigh(n, seed, p):
    a = _psd(seed, n)
    got = O.inv_pth_root_newton(a, p, iters=40)
    want = ref.matrix_inv_pth_root_ref(a, p)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_inv_4th_root_defining_property():
    """(A^{-1/4})^4 A ~ I."""
    a = _psd(5, 24)
    x = O.inv_pth_root_newton(a, 4, iters=40)
    x4 = x @ x @ x @ x
    np.testing.assert_allclose(x4 @ a, jnp.eye(24), rtol=0.05, atol=0.05)


def test_inv_root_identity():
    eye = jnp.eye(16)
    got = O.inv_pth_root_newton(eye, 4, iters=30)
    np.testing.assert_allclose(got, eye, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------- shampoo ---
@given(m=st.integers(2, 40), n=st.integers(2, 40),
       seed=st.integers(0, 2**31 - 1))
def test_shampoo_matches_ref(m, n, seed):
    w = _rand(seed, (m, n))
    g = _rand(seed + 1, (m, n))
    l_stat = _psd(seed + 2, m) * 0.1
    r_stat = _psd(seed + 3, n) * 0.1
    got = O.shampoo_update(w, g, l_stat, r_stat, jnp.float32(0.01), root_iters=40)
    want = ref.shampoo_update_ref(w, g, l_stat, r_stat, 0.01)
    # Statistics must match tightly; preconditioned weight loosely
    # (Newton root vs eigh root tolerance).
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got[0], want[0], rtol=0.1, atol=0.1)


def test_shampoo_descends_quadratic():
    """Shampoo on f(W)=||W||_F^2/2 must decrease the objective."""
    w = _rand(7, (16, 12))
    l_stat = jnp.zeros((16, 16))
    r_stat = jnp.zeros((12, 12))
    f0 = float(jnp.sum(w * w))
    for _ in range(15):
        w, l_stat, r_stat = O.shampoo_update(w, w, l_stat, r_stat, jnp.float32(0.05))
    assert float(jnp.sum(w * w)) < f0


# ------------------------------------------------------------------ soap ---
def test_soap_matches_ref():
    w = _rand(9, (12, 20))
    g = _rand(10, (12, 20))
    l_stat = _psd(11, 12) * 0.1
    r_stat = _psd(12, 20) * 0.1
    m = jnp.zeros((12, 20))
    v = jnp.zeros((12, 20))
    got = O.soap_update(w, g, l_stat, r_stat, m, v, jnp.float32(1), jnp.float32(1e-3))
    # Traced f32 scalars vs python-float bias correction differ in the
    # last ulp; everything else is the identical code path.
    want = ref.soap_update_ref(w, g, l_stat, r_stat, m, v, 1.0, 1e-3)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_soap_descends_quadratic():
    w = _rand(13, (10, 14))
    l_stat = jnp.zeros((10, 10))
    r_stat = jnp.zeros((14, 14))
    m = jnp.zeros((10, 14))
    v = jnp.zeros((10, 14))
    f0 = float(jnp.sum(w * w))
    for t in range(1, 20):
        w, l_stat, r_stat, m, v = O.soap_update(
            w, w, l_stat, r_stat, m, v, jnp.float32(t), jnp.float32(0.05))
    assert float(jnp.sum(w * w)) < f0


# ------------------------------------------------------- reference steps ---
def test_reference_train_step_decreases_loss():
    """The pure-jax Muon+AdamW step must learn a trivial corpus."""
    from compile import model as M

    cfg = M.PRESETS["tiny"]
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    states = O.init_states(params, cfg)
    tok = jnp.tile(jnp.arange(cfg.seq_len, dtype=jnp.int32) % 17,
                   (cfg.batch, 1))
    tgt = jnp.roll(tok, -1, axis=1)
    first = None
    for step in range(1, 26):
        loss, params, states = O.reference_train_step(
            params, tok, tgt, states, step, cfg)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.7, (first, float(loss))
