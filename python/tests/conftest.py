"""Test-session setup for the offline build environment.

Two fixes for fresh checkouts:

* ``python/`` is put on ``sys.path`` so ``from compile import ...``
  resolves without an editable install.
* Modules that depend on optional dev packages (``hypothesis``) are
  skipped at collection time instead of erroring, so ``python -m pytest
  python/tests -q`` is green wherever only the base stack (jax, numpy,
  pytest) is available.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_kernels.py", "test_optim.py"]
