"""L2 model tests: shapes, grads, spec/manifest consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


def _data(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (cfg.batch, cfg.seq_len), 0, cfg.vocab,
                             dtype=jnp.int32)
    tgt = jnp.roll(tok, -1, axis=1)
    return tok, tgt


def test_param_spec_counts():
    cfg = M.PRESETS["tiny"]
    spec = M.param_spec(cfg)
    # embed + head + final norm + per-layer (2 norms + 7 matrices)
    assert len(spec) == 3 + cfg.n_layers * 9
    names = [n for n, _, _ in spec]
    assert len(set(names)) == len(names), "duplicate parameter names"


def test_param_spec_kinds():
    cfg = M.PRESETS["tiny"]
    for name, shape, kind in M.param_spec(cfg):
        if kind == M.KIND_VECTOR:
            assert len(shape) == 1
        else:
            assert len(shape) == 2
        if kind == M.KIND_MATRIX:
            assert "embed" not in name and "lm_head" not in name


def test_forward_shapes():
    cfg = M.PRESETS["tiny"]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tok, _ = _data(cfg)
    logits = M.forward(params, tok, cfg)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    """Fresh model => loss ~ ln(vocab)."""
    cfg = M.PRESETS["tiny"]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tok, tgt = _data(cfg)
    loss = float(M.loss_fn(params, tok, tgt, cfg))
    assert abs(loss - np.log(cfg.vocab)) < 0.5, loss


def test_grads_finite_and_complete():
    cfg = M.PRESETS["tiny"]
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    tok, tgt = _data(cfg, 1)
    loss, grads = M.fwd_bwd(params, tok, tgt, cfg)
    assert jnp.isfinite(loss)
    assert set(grads) == set(params)
    for name, g in grads.items():
        assert g.shape == params[name].shape
        assert bool(jnp.all(jnp.isfinite(g))), name


def test_causality():
    """Future tokens must not influence current logits."""
    cfg = M.PRESETS["tiny"]
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    tok, _ = _data(cfg, 2)
    logits1 = M.forward(params, tok, cfg)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % cfg.vocab)
    logits2 = M.forward(params, tok2, cfg)
    np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1],
                               rtol=1e-5, atol=1e-5)


def test_flat_fwd_bwd_order_matches_spec():
    cfg = M.PRESETS["tiny"]
    spec = M.param_spec(cfg)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    flat = [params[n] for n, _, _ in spec]
    tok, tgt = _data(cfg, 3)
    out = M.flat_fwd_bwd(cfg)(*flat, tok, tgt)
    assert len(out) == 1 + len(spec)
    loss, grads_dict = M.fwd_bwd(params, tok, tgt, cfg)
    np.testing.assert_allclose(out[0], loss, rtol=0, atol=0)
    for (name, _, _), g in zip(spec, out[1:]):
        np.testing.assert_allclose(g, grads_dict[name], rtol=0, atol=0)


def test_init_std_values():
    cfg = M.PRESETS["tiny"]
    for name, shape, kind in M.param_spec(cfg):
        std = M.init_std(name, shape, kind, cfg)
        if kind == M.KIND_VECTOR:
            assert std == 0.0
        elif kind == M.KIND_EMBED:
            assert std == 0.02
        else:
            assert 0.0 < std < 0.25
