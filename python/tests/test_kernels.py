"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-block-multiple and degenerate
sizes) and block configurations; every case asserts allclose against
`kernels.ref`. This is the core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adamw as AW
from compile.kernels import newton_schulz as NS
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

dims = st.integers(min_value=1, max_value=200)
small_dims = st.integers(min_value=1, max_value=96)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- matmul ---
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    got = NS.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(m=small_dims, k=small_dims, n=small_dims,
       bm=st.sampled_from([8, 32, 128]), bn=st.sampled_from([8, 32, 128]),
       bk=st.sampled_from([8, 32, 128]))
def test_matmul_block_invariance(m, k, n, bm, bn, bk):
    """Result must not depend on the BlockSpec tiling."""
    x = _rand(7, (m, k))
    y = _rand(8, (k, n))
    got = NS.matmul(x, y, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_matmul_identity():
    x = _rand(3, (64, 64))
    np.testing.assert_allclose(NS.matmul(x, jnp.eye(64)), x, rtol=1e-6, atol=1e-6)


def test_matmul_zeros():
    x = jnp.zeros((33, 45), jnp.float32)
    y = _rand(4, (45, 17))
    assert float(jnp.abs(NS.matmul(x, y)).max()) == 0.0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand(5, (40, 50)).astype(dtype)
    y = _rand(6, (50, 30)).astype(dtype)
    got = NS.matmul(x, y)
    assert got.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               ref.matmul_ref(x, y).astype(jnp.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------- newton-schulz ---
@given(m=st.integers(2, 150), n=st.integers(2, 150),
       seed=st.integers(0, 2**31 - 1))
def test_newton_schulz_matches_ref(m, n, seed):
    g = _rand(seed, (m, n))
    got = NS.newton_schulz(g)
    want = ref.newton_schulz_ref(g)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_newton_schulz_orthogonalizes():
    """Singular values of NS5(G) must concentrate near 1 (Muon property)."""
    g = _rand(11, (64, 96))
    o = np.asarray(NS.newton_schulz(g))
    s = np.linalg.svd(o, compute_uv=False)
    assert s.max() < 1.6 and s.min() > 0.4


def test_newton_schulz_transpose_consistency():
    """Tall and wide inputs take the transposed path; both must be valid."""
    g = _rand(12, (96, 48))
    o_tall = np.asarray(NS.newton_schulz(g))
    o_wide = np.asarray(NS.newton_schulz(g.T))
    # NS(G)^T approximates NS(G^T) exactly (same iteration, transposed).
    np.testing.assert_allclose(o_tall.T, o_wide, rtol=1e-5, atol=1e-5)


def test_newton_schulz_scale_invariance():
    """NS orthogonalization is invariant to positive scaling of G."""
    g = _rand(13, (32, 64))
    o1 = NS.newton_schulz(g)
    o2 = NS.newton_schulz(17.0 * g)
    np.testing.assert_allclose(o1, o2, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ muon ---
@given(m=st.integers(2, 100), n=st.integers(2, 100),
       seed=st.integers(0, 2**31 - 1),
       lr=st.floats(1e-4, 0.1), beta=st.floats(0.0, 0.99))
def test_muon_update_matches_ref(m, n, seed, lr, beta):
    w = _rand(seed, (m, n))
    g = _rand(seed + 1, (m, n))
    mom = _rand(seed + 2, (m, n)) * 0.1
    got_w, got_m = NS.muon_update(w, g, mom, jnp.float32(lr), jnp.float32(beta))
    want_w, want_m = ref.muon_update_ref(w, g, mom, lr, beta)
    np.testing.assert_allclose(got_w, want_w, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)


def test_muon_momentum_accumulates():
    w = _rand(1, (16, 16))
    g = _rand(2, (16, 16))
    _, m1 = NS.muon_update(w, g, jnp.zeros_like(w), jnp.float32(0.01), jnp.float32(0.9))
    np.testing.assert_allclose(m1, g, rtol=1e-6, atol=1e-7)


# ----------------------------------------------------------------- adamw ---
@given(n=st.integers(1, 300_000), seed=st.integers(0, 2**31 - 1),
       t=st.integers(1, 1000))
def test_adamw_matches_ref(n, seed, t):
    w = _rand(seed, (n,))
    g = _rand(seed + 1, (n,))
    m = _rand(seed + 2, (n,)) * 0.01
    v = jnp.abs(_rand(seed + 3, (n,))) * 0.01
    got = AW.adamw_update(w, g, m, v, jnp.float32(t), jnp.float32(1e-3))
    want = ref.adamw_update_ref(w, g, m, v, float(t), 1e-3)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adamw_chunk_invariance():
    """Result must not depend on the pipeline chunk size."""
    n = 10_001
    w, g = _rand(20, (n,)), _rand(21, (n,))
    m, v = jnp.zeros(n), jnp.zeros(n)
    a = AW.adamw_update(w, g, m, v, jnp.float32(1), jnp.float32(1e-3), chunk=256)
    b = AW.adamw_update(w, g, m, v, jnp.float32(1), jnp.float32(1e-3), chunk=65536)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=0, atol=0)


def test_adamw_descends_quadratic():
    """AdamW on f(w)=||w||^2/2 must shrink the iterate."""
    w = _rand(22, (512,))
    m = v = jnp.zeros(512)
    for t in range(1, 30):
        w2, m, v = AW.adamw_update(w, w, m, v, jnp.float32(t), jnp.float32(0.05))
        w = w2
    assert float(jnp.linalg.norm(w)) < float(jnp.linalg.norm(_rand(22, (512,))))
