//! Simulation scenario: everything a paper experiment varies.

use crate::cost::hardware::Hardware;
use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::{qwen3, Qwen3Size};
use crate::model::shapes::Param;
use crate::partition::DpStrategy;

/// One simulated configuration (a single bar/point in a paper figure).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Full model census (unsharded).
    pub census: Vec<Param>,
    /// Family member the census was derived from — the `Copy` model id
    /// the plan-cache keys use (no string clone on the warm path).
    pub size: Qwen3Size,
    pub label: String,
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub optim: OptimKind,
    pub strategy: DpStrategy,
    /// α of Algorithm 1 (LB-ASC only).
    pub alpha: f64,
    /// Micro-group capacity C_max in *bytes* of fused gradient buffer
    /// (per host rank). `None` disables fusion (the Fig. 14 "No-Fuse").
    pub c_max_bytes: Option<f64>,
    /// Balancing metric (paper default Numel; Fig. 16 ablates Flops).
    pub metric: CostMetric,
    pub hw: Hardware,
    pub seq_len: usize,
    pub batch_per_dp: usize,
    /// Bucket size of the flat buffer, in elements (Megatron default 40M).
    pub bucket_elems: usize,
}

impl Scenario {
    /// The paper's default main-results configuration:
    /// Qwen3-32B, 256 GPUs as DP=32 x TP=8, Muon, seq 4096, mbs 1.
    pub fn paper_default() -> Scenario {
        Scenario::new(Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    pub fn new(size: Qwen3Size, dp: usize, tp: usize, pp: usize,
               optim: OptimKind, strategy: DpStrategy) -> Scenario {
        Scenario {
            census: qwen3(size),
            size,
            label: size.label().to_string(),
            dp,
            tp,
            pp,
            optim,
            strategy,
            alpha: 1.0,
            c_max_bytes: Some(512e6),
            metric: CostMetric::Numel,
            hw: Hardware::h800(),
            seq_len: 4096,
            batch_per_dp: 1,
            bucket_elems: 40_000_000,
        }
    }

    pub fn gpus(&self) -> usize {
        self.dp * self.tp * self.pp
    }

    /// Tokens processed per DP rank per iteration.
    pub fn tokens(&self) -> usize {
        self.seq_len * self.batch_per_dp
    }

    pub fn with_strategy(mut self, s: DpStrategy) -> Scenario {
        self.strategy = s;
        self
    }

    pub fn with_alpha(mut self, a: f64) -> Scenario {
        self.alpha = a;
        self
    }

    pub fn with_optim(mut self, o: OptimKind) -> Scenario {
        self.optim = o;
        self
    }

    pub fn with_c_max(mut self, c: Option<f64>) -> Scenario {
        self.c_max_bytes = c;
        self
    }

    pub fn with_metric(mut self, m: CostMetric) -> Scenario {
        self.metric = m;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_grid() {
        let s = Scenario::paper_default();
        assert_eq!(s.gpus(), 256);
        assert_eq!(s.tokens(), 4096);
        assert_eq!(s.strategy, DpStrategy::LbAsc);
    }

    #[test]
    fn builders() {
        let s = Scenario::paper_default()
            .with_strategy(DpStrategy::Sc)
            .with_alpha(0.5)
            .with_optim(OptimKind::Shampoo)
            .with_c_max(None);
        assert_eq!(s.strategy, DpStrategy::Sc);
        assert_eq!(s.alpha, 0.5);
        assert_eq!(s.optim, OptimKind::Shampoo);
        assert!(s.c_max_bytes.is_none());
    }
}
