//! Simulation scenario: everything a paper experiment varies.

use crate::cost::hardware::Hardware;
use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::{qwen3, Qwen3Size};
use crate::model::shapes::Param;
use crate::partition::DpStrategy;

use super::timeline::PipelineSchedule;

/// One simulated configuration (a single bar/point in a paper figure).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Full model census (unsharded).
    pub census: Vec<Param>,
    /// Family member the census was derived from — the `Copy` model id
    /// the plan-cache keys use (no string clone on the warm path).
    pub size: Qwen3Size,
    pub label: String,
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub optim: OptimKind,
    pub strategy: DpStrategy,
    /// α of Algorithm 1 (LB-ASC only).
    pub alpha: f64,
    /// Micro-group capacity C_max in *bytes* of fused gradient buffer
    /// (per host rank). `None` disables fusion (the Fig. 14 "No-Fuse").
    pub c_max_bytes: Option<f64>,
    /// Balancing metric (paper default Numel; Fig. 16 ablates Flops).
    pub metric: CostMetric,
    pub hw: Hardware,
    pub seq_len: usize,
    pub batch_per_dp: usize,
    /// Bucket size of the flat buffer, in elements (Megatron default 40M).
    pub bucket_elems: usize,
    /// Micro-batches per iteration (each processes [`Scenario::tokens`]
    /// tokens). `> 1` or `pp > 1` routes through the event-driven
    /// timeline engine; `1` with `pp == 1` keeps the closed-form fast
    /// path.
    pub micro_batches: usize,
    /// Pipeline schedule for `pp > 1` (1F1B default; GPipe available).
    pub schedule: PipelineSchedule,
    /// Straggler factor: the last PP stage's compute/HBM throughput is
    /// derated by this multiplier (`1.0` = homogeneous hardware;
    /// `1.2` = that stage's GPUs are 20% slower). Values `!= 1.0` route
    /// through the timeline engine even at `pp == 1`.
    pub straggler: f64,
    /// Transformer depth (highest census layer index + 1), cached at
    /// construction so plan-cache key builds never re-scan the census.
    /// Derived from `census`; don't set independently.
    pub n_layers: usize,
}

impl Scenario {
    /// The paper's default main-results configuration:
    /// Qwen3-32B, 256 GPUs as DP=32 x TP=8, Muon, seq 4096, mbs 1.
    pub fn paper_default() -> Scenario {
        Scenario::new(Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    pub fn new(size: Qwen3Size, dp: usize, tp: usize, pp: usize,
               optim: OptimKind, strategy: DpStrategy) -> Scenario {
        let census = qwen3(size);
        let n_layers = census
            .iter()
            .filter_map(|p| p.layer)
            .max()
            .map(|l| l + 1)
            .unwrap_or(0);
        Scenario {
            census,
            size,
            label: size.label().to_string(),
            dp,
            tp,
            // pp = 0 is meaningless (there is always at least one
            // stage); clamp so library callers can't route a zero into
            // the stage split. The CLI/grid parsers reject it outright.
            pp: pp.max(1),
            optim,
            strategy,
            alpha: 1.0,
            c_max_bytes: Some(512e6),
            metric: CostMetric::Numel,
            hw: Hardware::h800(),
            seq_len: 4096,
            batch_per_dp: 1,
            bucket_elems: 40_000_000,
            micro_batches: 1,
            schedule: PipelineSchedule::OneFOneB,
            straggler: 1.0,
            n_layers,
        }
    }

    pub fn gpus(&self) -> usize {
        self.dp * self.tp * self.pp
    }

    /// Tokens processed per DP rank per iteration.
    pub fn tokens(&self) -> usize {
        self.seq_len * self.batch_per_dp
    }

    pub fn with_strategy(mut self, s: DpStrategy) -> Scenario {
        self.strategy = s;
        self
    }

    pub fn with_alpha(mut self, a: f64) -> Scenario {
        self.alpha = a;
        self
    }

    pub fn with_optim(mut self, o: OptimKind) -> Scenario {
        self.optim = o;
        self
    }

    pub fn with_c_max(mut self, c: Option<f64>) -> Scenario {
        self.c_max_bytes = c;
        self
    }

    pub fn with_metric(mut self, m: CostMetric) -> Scenario {
        self.metric = m;
        self
    }

    pub fn with_micro_batches(mut self, m: usize) -> Scenario {
        self.micro_batches = m.max(1);
        self
    }

    pub fn with_schedule(mut self, sched: PipelineSchedule) -> Scenario {
        self.schedule = sched;
        self
    }

    /// Set the last-stage straggler factor, normalized like
    /// [`Scenario::with_micro_batches`] clamps its input: non-finite
    /// values fall back to 1.0 (homogeneous) and factors below 1.0 are
    /// clamped up — a "straggler" can only be slower, and `derate(0.0)`
    /// would otherwise produce infinite throughput. The CLI/grid
    /// parsers reject such inputs with an error instead.
    pub fn with_straggler(mut self, f: f64) -> Scenario {
        self.straggler = if f.is_finite() { f.max(1.0) } else { 1.0 };
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_grid() {
        let s = Scenario::paper_default();
        assert_eq!(s.gpus(), 256);
        assert_eq!(s.tokens(), 4096);
        assert_eq!(s.strategy, DpStrategy::LbAsc);
    }

    #[test]
    fn builders() {
        let s = Scenario::paper_default()
            .with_strategy(DpStrategy::Sc)
            .with_alpha(0.5)
            .with_optim(OptimKind::Shampoo)
            .with_c_max(None)
            .with_micro_batches(8)
            .with_schedule(PipelineSchedule::GPipe)
            .with_straggler(1.5);
        assert_eq!(s.strategy, DpStrategy::Sc);
        assert_eq!(s.alpha, 0.5);
        assert_eq!(s.optim, OptimKind::Shampoo);
        assert!(s.c_max_bytes.is_none());
        assert_eq!(s.micro_batches, 8);
        assert_eq!(s.schedule, PipelineSchedule::GPipe);
        assert_eq!(s.straggler, 1.5);
        // Defaults keep the closed-form fast path.
        let d = Scenario::paper_default();
        assert_eq!(d.micro_batches, 1);
        assert_eq!(d.schedule, PipelineSchedule::OneFOneB);
        assert_eq!(d.straggler, 1.0);
        assert_eq!(d.n_layers, 64); // Qwen3-32B depth, cached at construction
        // Builder/constructor normalization: invalid inputs clamp.
        let c = Scenario::new(Qwen3Size::S1_7B, 4, 2, 0, OptimKind::Muon, DpStrategy::LbAsc)
            .with_straggler(0.5)
            .with_micro_batches(0);
        assert_eq!(c.pp, 1);
        assert_eq!(c.straggler, 1.0);
        assert_eq!(c.micro_batches, 1);
        assert_eq!(
            Scenario::paper_default().with_straggler(f64::NAN).straggler,
            1.0,
        );
    }
}
