//! Simulation scenario: everything a paper experiment varies.

use crate::bail;
use crate::cost::hardware::Hardware;
use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::{qwen3, Qwen3Size};
use crate::model::shapes::Param;
use crate::partition::DpStrategy;
use crate::util::error::Result;

use super::faults::{FailSpec, HeteroSpec};
use super::timeline::PipelineSchedule;

/// One simulated configuration (a single bar/point in a paper figure).
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Full model census (unsharded).
    pub census: Vec<Param>,
    /// Family member the census was derived from — the `Copy` model id
    /// the plan-cache keys use (no string clone on the warm path).
    pub size: Qwen3Size,
    pub label: String,
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub optim: OptimKind,
    pub strategy: DpStrategy,
    /// α of Algorithm 1 (LB-ASC only).
    pub alpha: f64,
    /// Micro-group capacity C_max in *bytes* of fused gradient buffer
    /// (per host rank). `None` disables fusion (the Fig. 14 "No-Fuse").
    pub c_max_bytes: Option<f64>,
    /// Balancing metric (paper default Numel; Fig. 16 ablates Flops).
    pub metric: CostMetric,
    pub hw: Hardware,
    pub seq_len: usize,
    pub batch_per_dp: usize,
    /// Bucket size of the flat buffer, in elements (Megatron default 40M).
    pub bucket_elems: usize,
    /// Micro-batches per iteration (each processes [`Scenario::tokens`]
    /// tokens). `> 1` or `pp > 1` routes through the event-driven
    /// timeline engine; `1` with `pp == 1` keeps the closed-form fast
    /// path.
    pub micro_batches: usize,
    /// Pipeline schedule for `pp > 1` (1F1B default; GPipe available).
    pub schedule: PipelineSchedule,
    /// Straggler factor: the last PP stage's compute/HBM throughput is
    /// derated by this multiplier (`1.0` = homogeneous hardware;
    /// `1.2` = that stage's GPUs are 20% slower). Values `!= 1.0` route
    /// through the timeline engine even at `pp == 1`.
    pub straggler: f64,
    /// Per-rank hardware heterogeneity ([`HeteroSpec::None`] =
    /// homogeneous, bit-identical to pre-fault artifacts). Anything
    /// else routes through the timeline engine, which derates each
    /// stage by the *max* derate among its ranks and prices DP
    /// collectives against the slowest participating link.
    pub hetero: HeteroSpec,
    /// Seed of the per-rank fault/heterogeneity draws (deterministic:
    /// the same seed yields byte-identical artifacts).
    pub fault_seed: u64,
    /// Deterministic rank-failure injection (`--fail-rank r@frac`).
    pub fail_rank: Option<FailSpec>,
    /// Mean time to failure (s); charges the *expected* per-iteration
    /// recovery cost instead of a single injected event.
    pub mttf_s: Option<f64>,
    /// Checkpoint interval in iterations (`1` = every iteration); a
    /// failure redoes the work since the last checkpoint.
    pub ckpt_interval: usize,
    /// Transformer depth (highest census layer index + 1), cached at
    /// construction so plan-cache key builds never re-scan the census.
    /// Derived from `census`; don't set independently.
    pub n_layers: usize,
}

impl Scenario {
    /// The paper's default main-results configuration:
    /// Qwen3-32B, 256 GPUs as DP=32 x TP=8, Muon, seq 4096, mbs 1.
    pub fn paper_default() -> Scenario {
        Scenario::new(Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    pub fn new(size: Qwen3Size, dp: usize, tp: usize, pp: usize,
               optim: OptimKind, strategy: DpStrategy) -> Scenario {
        let census = qwen3(size);
        let n_layers = census
            .iter()
            .filter_map(|p| p.layer)
            .max()
            .map(|l| l + 1)
            .unwrap_or(0);
        Scenario {
            census,
            size,
            label: size.label().to_string(),
            dp,
            tp,
            // pp = 0 is meaningless (there is always at least one
            // stage); clamp so library callers can't route a zero into
            // the stage split. The CLI/grid parsers reject it outright.
            pp: pp.max(1),
            optim,
            strategy,
            alpha: 1.0,
            c_max_bytes: Some(512e6),
            metric: CostMetric::Numel,
            hw: Hardware::h800(),
            seq_len: 4096,
            batch_per_dp: 1,
            bucket_elems: 40_000_000,
            micro_batches: 1,
            schedule: PipelineSchedule::OneFOneB,
            straggler: 1.0,
            hetero: HeteroSpec::None,
            fault_seed: 0,
            fail_rank: None,
            mttf_s: None,
            ckpt_interval: 1,
            n_layers,
        }
    }

    /// Does any fault/heterogeneity knob deviate from the homogeneous,
    /// never-failing default? Faulted scenarios route through the
    /// timeline engine (and the batch tier rejects them — see
    /// [`crate::sim::batch`]). `fault_seed` and `ckpt_interval` alone
    /// are inert: without a spec or an event they change nothing.
    pub fn faulted(&self) -> bool {
        self.hetero != HeteroSpec::None || self.fail_rank.is_some() || self.mttf_s.is_some()
    }

    pub fn gpus(&self) -> usize {
        self.dp * self.tp * self.pp
    }

    /// Tokens processed per DP rank per iteration.
    pub fn tokens(&self) -> usize {
        self.seq_len * self.batch_per_dp
    }

    pub fn with_strategy(mut self, s: DpStrategy) -> Scenario {
        self.strategy = s;
        self
    }

    pub fn with_alpha(mut self, a: f64) -> Scenario {
        self.alpha = a;
        self
    }

    pub fn with_optim(mut self, o: OptimKind) -> Scenario {
        self.optim = o;
        self
    }

    pub fn with_c_max(mut self, c: Option<f64>) -> Scenario {
        self.c_max_bytes = c;
        self
    }

    pub fn with_metric(mut self, m: CostMetric) -> Scenario {
        self.metric = m;
        self
    }

    pub fn with_micro_batches(mut self, m: usize) -> Scenario {
        self.micro_batches = m.max(1);
        self
    }

    pub fn with_schedule(mut self, sched: PipelineSchedule) -> Scenario {
        self.schedule = sched;
        self
    }

    /// Set the last-stage straggler factor, normalized like
    /// [`Scenario::with_micro_batches`] clamps its input: non-finite
    /// values fall back to 1.0 (homogeneous) and factors below 1.0 are
    /// clamped up — a "straggler" can only be slower, and `derate(0.0)`
    /// would otherwise produce infinite throughput. The CLI/grid
    /// parsers reject such inputs with an error instead.
    pub fn with_straggler(mut self, f: f64) -> Scenario {
        self.straggler = if f.is_finite() { f.max(1.0) } else { 1.0 };
        self
    }

    /// Set the per-rank heterogeneity spec (see [`HeteroSpec`]).
    pub fn with_hetero(mut self, h: HeteroSpec) -> Scenario {
        self.hetero = h;
        self
    }

    /// Set the fault/heterogeneity draw seed.
    pub fn with_fault_seed(mut self, seed: u64) -> Scenario {
        self.fault_seed = seed;
        self
    }

    /// Inject a deterministic rank failure (see [`FailSpec`]).
    pub fn with_fail_rank(mut self, f: Option<FailSpec>) -> Scenario {
        self.fail_rank = f;
        self
    }

    /// Set the mean-time-to-failure rate (s); `None` disables it.
    pub fn with_mttf(mut self, mttf_s: Option<f64>) -> Scenario {
        self.mttf_s = mttf_s;
        self
    }

    /// Set the checkpoint interval (iterations), clamped to `>= 1`
    /// like [`Scenario::with_micro_batches`].
    pub fn with_ckpt_interval(mut self, k: usize) -> Scenario {
        self.ckpt_interval = k.max(1);
        self
    }

    /// Reject knob combinations that would poison the arithmetic
    /// downstream: a zero bandwidth or zero `gpu_flops` divides to
    /// `inf`, a non-positive straggler multiplies to `inf`/`NaN`, and
    /// the `total_cmp`-hardened sort paths then rank such rows instead
    /// of crashing — garbage ordered confidently. Every parse-time
    /// entry (the `simulate`/`plan` CLI, `SweepGrid::parse`, batch-lane
    /// construction) calls this so invalid knobs never enter a grid;
    /// library callers mutating the pub fields directly can call it
    /// themselves. Errors are prefixed `invalid scenario:` so the named
    /// failure is greppable at any entry point.
    pub fn validate(&self) -> Result<()> {
        if self.dp < 1 || self.tp < 1 || self.pp < 1 {
            bail!(
                "invalid scenario: dp/tp/pp must be >= 1 (got dp={} tp={} pp={})",
                self.dp, self.tp, self.pp
            );
        }
        if self.micro_batches < 1 {
            bail!("invalid scenario: micro_batches must be >= 1");
        }
        if self.seq_len < 1 || self.batch_per_dp < 1 || self.bucket_elems < 1 {
            bail!(
                "invalid scenario: seq_len/batch_per_dp/bucket_elems must be >= 1 \
                 (got {}/{}/{})",
                self.seq_len, self.batch_per_dp, self.bucket_elems
            );
        }
        if !self.straggler.is_finite() || self.straggler < 1.0 {
            bail!(
                "invalid scenario: straggler expects a finite factor >= 1.0, got {}",
                self.straggler
            );
        }
        // The fault/heterogeneity knobs, each with a named error
        // (mirroring the batch tier's per-lane `LaneKnobs::validate`).
        self.hetero.validate()?;
        if let Some(f) = &self.fail_rank {
            f.validate(self.gpus())?;
        }
        if let Some(mttf) = self.mttf_s {
            if !mttf.is_finite() || mttf <= 0.0 {
                bail!("invalid scenario: mttf expects a finite rate > 0 s, got {mttf}");
            }
        }
        if self.ckpt_interval < 1 {
            bail!(
                "invalid scenario: ckpt_interval must be >= 1, got {}",
                self.ckpt_interval
            );
        }
        if !self.alpha.is_finite() || !(0.0..=1.0).contains(&self.alpha) {
            bail!("invalid scenario: alpha must be in [0, 1], got {}", self.alpha);
        }
        if let Some(cb) = self.c_max_bytes {
            if !cb.is_finite() || cb <= 0.0 {
                bail!(
                    "invalid scenario: c_max_bytes must be finite and > 0 \
                     (use None for No-Fuse), got {cb}"
                );
            }
        }
        let hw = &self.hw;
        for (name, v) in [
            ("gpu_flops", hw.gpu_flops),
            ("hbm_bw", hw.hbm_bw),
            ("nvlink_bw", hw.nvlink_bw),
            ("ib_bw", hw.ib_bw),
        ] {
            if !v.is_finite() || v <= 0.0 {
                bail!("invalid scenario: hardware {name} must be finite and > 0, got {v}");
            }
        }
        for (name, v) in [
            ("nvlink_lat", hw.nvlink_lat),
            ("ib_lat", hw.ib_lat),
            ("launch_overhead", hw.launch_overhead),
        ] {
            if !v.is_finite() || v < 0.0 {
                bail!("invalid scenario: hardware {name} must be finite and >= 0, got {v}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_grid() {
        let s = Scenario::paper_default();
        assert_eq!(s.gpus(), 256);
        assert_eq!(s.tokens(), 4096);
        assert_eq!(s.strategy, DpStrategy::LbAsc);
    }

    #[test]
    fn builders() {
        let s = Scenario::paper_default()
            .with_strategy(DpStrategy::Sc)
            .with_alpha(0.5)
            .with_optim(OptimKind::Shampoo)
            .with_c_max(None)
            .with_micro_batches(8)
            .with_schedule(PipelineSchedule::GPipe)
            .with_straggler(1.5);
        assert_eq!(s.strategy, DpStrategy::Sc);
        assert_eq!(s.alpha, 0.5);
        assert_eq!(s.optim, OptimKind::Shampoo);
        assert!(s.c_max_bytes.is_none());
        assert_eq!(s.micro_batches, 8);
        assert_eq!(s.schedule, PipelineSchedule::GPipe);
        assert_eq!(s.straggler, 1.5);
        // Defaults keep the closed-form fast path.
        let d = Scenario::paper_default();
        assert_eq!(d.micro_batches, 1);
        assert_eq!(d.schedule, PipelineSchedule::OneFOneB);
        assert_eq!(d.straggler, 1.0);
        assert_eq!(d.n_layers, 64); // Qwen3-32B depth, cached at construction
        // Builder/constructor normalization: invalid inputs clamp.
        let c = Scenario::new(Qwen3Size::S1_7B, 4, 2, 0, OptimKind::Muon, DpStrategy::LbAsc)
            .with_straggler(0.5)
            .with_micro_batches(0);
        assert_eq!(c.pp, 1);
        assert_eq!(c.straggler, 1.0);
        assert_eq!(c.micro_batches, 1);
        assert_eq!(
            Scenario::paper_default().with_straggler(f64::NAN).straggler,
            1.0,
        );
        // Fault-layer builders and the `faulted()` dispatch predicate.
        let d = Scenario::paper_default();
        assert!(!d.faulted(), "defaults must keep the closed-form path");
        assert!(!d.clone().with_fault_seed(7).with_ckpt_interval(4).faulted(),
                "seed/ckpt alone are inert");
        let f = d
            .clone()
            .with_hetero(HeteroSpec::LastStage { factor: 1.5 })
            .with_fault_seed(7)
            .with_fail_rank(Some(FailSpec { rank: 3, at: 0.25 }))
            .with_mttf(Some(3600.0))
            .with_ckpt_interval(0); // clamps like with_micro_batches
        assert!(f.faulted());
        assert_eq!(f.hetero, HeteroSpec::LastStage { factor: 1.5 });
        assert_eq!(f.fault_seed, 7);
        assert_eq!(f.fail_rank, Some(FailSpec { rank: 3, at: 0.25 }));
        assert_eq!(f.mttf_s, Some(3600.0));
        assert_eq!(f.ckpt_interval, 1);
        assert!(d.clone().with_mttf(Some(600.0)).faulted());
        assert!(d.with_fail_rank(Some(FailSpec { rank: 0, at: 0.5 })).faulted());
    }

    #[test]
    fn validate_accepts_defaults_and_paper_knobs() {
        assert!(Scenario::paper_default().validate().is_ok());
        let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Shampoo, DpStrategy::Sc)
            .with_c_max(None)
            .with_alpha(0.5)
            .with_straggler(1.5)
            .with_micro_batches(8);
        assert!(s.validate().is_ok());
        // Faulted-but-well-formed knobs validate too.
        let f = Scenario::paper_default()
            .with_hetero(HeteroSpec::Mix {
                slow_rate: 0.05,
                slow_factor: 1.5,
                link_rate: 0.1,
                link_factor: 4.0,
            })
            .with_fault_seed(7)
            .with_fail_rank(Some(FailSpec { rank: 255, at: 0.5 }))
            .with_mttf(Some(1800.0))
            .with_ckpt_interval(16);
        assert!(f.validate().is_ok());
    }

    #[test]
    fn validate_rejects_poisoned_knobs() {
        // Each case would otherwise produce inf/NaN rows that the
        // total_cmp-hardened sorts rank instead of crashing on.
        let base = Scenario::paper_default;
        let cases: Vec<(&str, Scenario)> = vec![
            ("straggler", {
                let mut s = base();
                s.straggler = 0.0; // bypasses with_straggler's clamp
                s
            }),
            ("straggler", {
                let mut s = base();
                s.straggler = -2.0;
                s
            }),
            ("straggler", {
                let mut s = base();
                s.straggler = f64::NAN;
                s
            }),
            ("gpu_flops", {
                let mut s = base();
                s.hw.gpu_flops = 0.0;
                s
            }),
            ("nvlink_bw", {
                let mut s = base();
                s.hw.nvlink_bw = 0.0;
                s
            }),
            ("ib_bw", {
                let mut s = base();
                s.hw.ib_bw = -1.0;
                s
            }),
            ("hbm_bw", {
                let mut s = base();
                s.hw.hbm_bw = f64::INFINITY;
                s
            }),
            ("c_max_bytes", base().with_c_max(Some(0.0))),
            ("c_max_bytes", base().with_c_max(Some(f64::NAN))),
            ("alpha", base().with_alpha(2.0)),
            ("ib_lat", {
                let mut s = base();
                s.hw.ib_lat = f64::NAN;
                s
            }),
            // --- fault/heterogeneity knobs (named like the rest) -----
            ("hetero", {
                let mut s = base();
                s.hetero = HeteroSpec::LastStage { factor: 0.5 }; // < 1.0
                s
            }),
            ("hetero", {
                let mut s = base();
                s.hetero = HeteroSpec::Mix {
                    slow_rate: 2.0, // rate > 1
                    slow_factor: 1.5,
                    link_rate: 0.0,
                    link_factor: 1.0,
                };
                s
            }),
            ("hetero", {
                let mut s = base();
                s.hetero = HeteroSpec::Mix {
                    slow_rate: 0.5,
                    slow_factor: f64::NAN,
                    link_rate: 0.0,
                    link_factor: 1.0,
                };
                s
            }),
            ("mttf", base().with_mttf(Some(0.0))),
            ("mttf", base().with_mttf(Some(f64::NAN))),
            ("mttf", base().with_mttf(Some(-60.0))),
            ("fail_rank", {
                let mut s = base();
                s.fail_rank = Some(FailSpec { rank: 256, at: 0.5 }); // == gpus
                s
            }),
            ("fail_rank", {
                let mut s = base();
                s.fail_rank = Some(FailSpec { rank: 0, at: 1.5 }); // at >= 1
                s
            }),
            ("fail_rank", {
                let mut s = base();
                s.fail_rank = Some(FailSpec { rank: 0, at: f64::NAN });
                s
            }),
            ("ckpt_interval", {
                let mut s = base();
                s.ckpt_interval = 0; // bypasses with_ckpt_interval's clamp
                s
            }),
        ];
        for (what, s) in cases {
            let e = s.validate().expect_err(what).to_string();
            assert!(e.contains("invalid scenario"), "{what}: {e}");
            assert!(e.contains(what), "{what} not named in: {e}");
        }
    }
}
