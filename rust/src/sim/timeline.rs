//! Discrete-event timeline engine: streams, dependent tasks, and the
//! pipeline-parallel (1F1B / GPipe) schedule builder.
//!
//! The closed-form playback in [`crate::sim::iteration`] times each PP
//! stage independently — correct only at `pp = 1`. Real 3D-parallel
//! iterations are *schedules*: forward/backward micro-batches flow
//! across stages, gradient collectives overlap the tail of backward,
//! and the asynchronous optimizer pipeline consumes whatever stream
//! slack the fill/drain bubbles leave. This module provides the event
//! engine those schedules are expressed in:
//!
//! * [`Timeline`] — a set of serially-executing streams (CUDA stream /
//!   NIC queue analogues). A task occupies one stream for its duration
//!   and starts no earlier than (a) the stream's previous task and (b)
//!   every declared dependency's completion. Tasks must be submitted in
//!   dependency order (ids are handed out at submission), which makes
//!   scheduling a single deterministic forward pass — no event queue,
//!   no tie-breaking.
//! * [`schedule_order`] / [`schedule_order_iter`] — the per-stage slot
//!   order of a pipeline schedule ([`PipelineSchedule::OneFOneB`]
//!   warmup/steady/cooldown or [`PipelineSchedule::GPipe`]
//!   all-forward-then-all-backward), as a `Vec` or as an
//!   allocation-free iterator.
//! * [`drive_pipeline`] / [`drive_pipeline_flat`] — turn those
//!   per-stage orders into tasks via a caller-supplied emitter,
//!   resolving cross-stage dependencies (`F(i,j)` after `F(i-1,j)`;
//!   `B(i,j)` after `F(i,j)` and `B(i+1,j)`) with a deadlock-checked
//!   work-list sweep. The nested-table form is the readable reference;
//!   the flat form drives the same sweep over a reusable
//!   [`PipeScratch`] (plus a pre-expanded [`OrderCache`] table) and
//!   performs zero heap allocations once the scratch has capacity —
//!   `tests/timeline_props.rs` pins the two shadow-equivalent.
//! * [`build_pipeline`] — the minimal emitter (one compute task per
//!   slot), used by the schedule-invariant property tests and as the
//!   reference for the analytic 1F1B bubble fraction
//!   `(pp-1)/(m+pp-1)`.
//!
//! # Lean vs. recording mode
//!
//! Scheduling needs only per-stream `free_at` running sums and each
//! task's end time; the full `TaskRec` + dependency trace exists so the
//! property/differential tests can *verify* a schedule. The two
//! concerns are split: [`Timeline::new`] builds a **lean** timeline
//! (per-stream `free_at`/`busy`, a flat `ends` vector, the makespan as
//! a running max, the serial sum as a running total — everything
//! dependency resolution and `Breakdown` extraction read), while
//! [`Timeline::recording`] additionally keeps the `TaskRec` + deps
//! trace behind [`Timeline::tasks`] / [`Timeline::deps_of`] /
//! [`Timeline::critical_path`]. Both modes run the identical
//! scheduling arithmetic in the identical order, so every timing they
//! produce is bit-identical (property-tested over randomized DAGs).
//! Sweeps run lean; [`Timeline::reset`] clears a timeline for reuse
//! while retaining capacity, which is what makes the warm
//! `simulate_iteration_timeline` path allocation-free.
//!
//! The full-iteration emitter (bucket-split first-forward/last-backward
//! micro-batches, reduce-scatter overlap, the optimizer as a trailing
//! stream consumer) lives in `sim::iteration::simulate_iteration_timeline`.
//!
//! Invariants the trace exposes for verification (see
//! `tests/timeline_props.rs`): no stream runs two tasks concurrently;
//! every task starts at or after all of its dependencies' ends; the
//! makespan is bounded below by the dependency-graph critical path and
//! above by the serial sum of all durations.

#![warn(missing_docs)]

/// Handle of one serially-executing resource in a [`Timeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamId(pub u32);

/// Handle of one scheduled task in a [`Timeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskId(pub u32);

/// What a task models — for trace analysis and bubble accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Forward compute of (part of) a micro-batch.
    Forward,
    /// Backward compute of (part of) a micro-batch.
    Backward,
    /// Gradient-path collective (Reduce-Scatter / All-Reduce).
    GradComm,
    /// Parameter All-Gather (ZeRO-1 prefetch).
    ParamComm,
    /// Inter-stage activation (or activation-gradient) transfer.
    ActComm,
    /// TP activation All-Reduce block.
    TpComm,
    /// Optimizer step (the micro-group pipeline as one consumer).
    Optimizer,
    /// Anything else (synthetic tests).
    Other,
}

/// One scheduled task: placement, timing, and its dependency slice.
/// Only kept in recording mode (see the module docs).
#[derive(Clone, Copy, Debug)]
pub struct TaskRec {
    /// The stream the task occupied.
    pub stream: StreamId,
    /// What the task models.
    pub kind: TaskKind,
    /// Start time (s).
    pub start: f64,
    /// Duration (s).
    pub dur: f64,
    /// Completion time (s) — `start + dur`.
    pub end: f64,
    dep_off: u32,
    dep_len: u32,
}

/// The opt-in verification trace: full task records plus a flattened
/// dependency arena.
#[derive(Clone, Debug, Default)]
struct Trace {
    tasks: Vec<TaskRec>,
    deps: Vec<TaskId>,
}

/// A deterministic discrete-event schedule under construction (see the
/// module docs). Lean by default; [`Timeline::recording`] keeps the
/// verification trace.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    free_at: Vec<f64>,
    busy: Vec<f64>,
    /// Per-task completion times — the lean core's whole task state.
    ends: Vec<f64>,
    /// Running `max` of `ends` in submission order (bit-identical to a
    /// fold over the trace).
    span: f64,
    /// Running sum of durations in submission order.
    dur_sum: f64,
    trace: Option<Trace>,
}

impl Timeline {
    /// An empty **lean** timeline with no streams: schedules and times
    /// tasks without recording a trace (the sweep hot path).
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// An empty **recording** timeline: additionally keeps the
    /// [`TaskRec`] + dependency trace behind [`Timeline::tasks`],
    /// [`Timeline::deps_of`] and [`Timeline::critical_path`] — the mode
    /// the property/differential tests verify schedules in.
    pub fn recording() -> Timeline {
        Timeline { trace: Some(Trace::default()), ..Timeline::default() }
    }

    /// Does this timeline keep the verification trace?
    pub fn is_recording(&self) -> bool {
        self.trace.is_some()
    }

    /// Clear all streams and tasks for reuse, retaining every buffer's
    /// capacity (and the lean/recording mode). A reset-then-rebuild of
    /// a same-shaped schedule performs zero heap allocations.
    pub fn reset(&mut self) {
        self.free_at.clear();
        self.busy.clear();
        self.ends.clear();
        self.span = 0.0;
        self.dur_sum = 0.0;
        if let Some(tr) = &mut self.trace {
            tr.tasks.clear();
            tr.deps.clear();
        }
    }

    /// Create a new stream (free from t = 0).
    pub fn stream(&mut self) -> StreamId {
        self.free_at.push(0.0);
        self.busy.push(0.0);
        StreamId((self.free_at.len() - 1) as u32)
    }

    /// Schedule a task of `dur` seconds on `stream`, starting no earlier
    /// than the stream's previous task and every task in `deps`.
    /// Dependencies must already be scheduled (ids are submission-time).
    pub fn task(&mut self, stream: StreamId, kind: TaskKind, dur: f64, deps: &[TaskId]) -> TaskId {
        debug_assert!(dur.is_finite() && dur >= 0.0, "bad duration {dur}");
        let mut ready = self.free_at[stream.0 as usize];
        for &d in deps {
            ready = ready.max(self.ends[d.0 as usize]);
        }
        let start = ready;
        let end = start + dur;
        self.free_at[stream.0 as usize] = end;
        self.busy[stream.0 as usize] += dur;
        self.span = self.span.max(end);
        self.dur_sum += dur;
        let id = TaskId(self.ends.len() as u32);
        self.ends.push(end);
        if let Some(tr) = &mut self.trace {
            let dep_off = tr.deps.len() as u32;
            tr.deps.extend_from_slice(deps);
            tr.tasks.push(TaskRec {
                stream,
                kind,
                start,
                dur,
                end,
                dep_off,
                dep_len: deps.len() as u32,
            });
        }
        id
    }

    /// Completion time of `t`.
    pub fn end(&self, t: TaskId) -> f64 {
        self.ends[t.0 as usize]
    }

    /// Latest completion time over all tasks (0 when empty).
    pub fn makespan(&self) -> f64 {
        self.span
    }

    /// Number of tasks scheduled so far (both modes).
    pub fn n_tasks(&self) -> usize {
        self.ends.len()
    }

    /// The trace, or a clear panic in lean mode — trace readers are
    /// verification paths that must opt in via [`Timeline::recording`].
    fn require_trace(&self) -> &Trace {
        self.trace
            .as_ref()
            .expect("task trace requires a recording timeline (Timeline::recording)")
    }

    /// The full task trace, in submission order (recording mode only).
    pub fn tasks(&self) -> &[TaskRec] {
        &self.require_trace().tasks
    }

    /// The declared dependencies of `t` (recording mode only).
    pub fn deps_of(&self, t: TaskId) -> &[TaskId] {
        let tr = self.require_trace();
        let r = &tr.tasks[t.0 as usize];
        &tr.deps[r.dep_off as usize..(r.dep_off + r.dep_len) as usize]
    }

    /// Total busy time (sum of task durations) on `s`.
    pub fn stream_busy(&self, s: StreamId) -> f64 {
        self.busy[s.0 as usize]
    }

    /// When `s` drains (end of its last task; 0 if idle).
    pub fn stream_free(&self, s: StreamId) -> f64 {
        self.free_at[s.0 as usize]
    }

    /// Number of streams created.
    pub fn n_streams(&self) -> usize {
        self.free_at.len()
    }

    /// Dependency-graph critical path: the resource-oblivious lower
    /// bound on the makespan (longest chain of `dur` through `deps`).
    /// Recording mode only (the lean core does not keep dependencies).
    pub fn critical_path(&self) -> f64 {
        let tr = self.require_trace();
        // Tasks are submitted in dependency order, so one forward pass.
        let mut lp = vec![0.0f64; tr.tasks.len()];
        let mut best = 0.0f64;
        for (i, t) in tr.tasks.iter().enumerate() {
            let mut start = 0.0f64;
            for &d in &tr.deps[t.dep_off as usize..(t.dep_off + t.dep_len) as usize] {
                start = start.max(lp[d.0 as usize]);
            }
            lp[i] = start + t.dur;
            best = best.max(lp[i]);
        }
        best
    }

    /// Sum of all task durations: the fully-serialized upper bound
    /// (maintained as a running total — available in both modes).
    pub fn serial_sum(&self) -> f64 {
        self.dur_sum
    }
}

/// Which pipeline-parallel schedule orders each stage's micro-batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineSchedule {
    /// One-forward-one-backward (Megatron / PipeDream-Flush): stage `i`
    /// runs `min(m, pp-1-i)` warmup forwards, then alternates
    /// forward/backward, then drains. Default.
    OneFOneB,
    /// GPipe: all `m` forwards, then all `m` backwards.
    GPipe,
}

impl PipelineSchedule {
    /// CLI / artifact label.
    pub fn label(&self) -> &'static str {
        match self {
            PipelineSchedule::OneFOneB => "1f1b",
            PipelineSchedule::GPipe => "gpipe",
        }
    }

    /// Parse a CLI spelling (`1f1b` / `gpipe`, case-insensitive) —
    /// per-spelling `eq_ignore_ascii_case`, no lowercase buffer.
    pub fn parse(s: &str) -> Option<PipelineSchedule> {
        if s.eq_ignore_ascii_case("1f1b") || s.eq_ignore_ascii_case("one-f-one-b") {
            Some(PipelineSchedule::OneFOneB)
        } else if s.eq_ignore_ascii_case("gpipe") {
            Some(PipelineSchedule::GPipe)
        } else {
            None
        }
    }
}

/// One slot in a stage's pipeline order: forward or backward of a
/// micro-batch index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeSlot {
    /// Forward of micro-batch `j`.
    Fwd(usize),
    /// Backward of micro-batch `j`.
    Bwd(usize),
}

/// Allocation-free iterator over one stage's slot order (see
/// [`schedule_order`]). Both schedules reduce to a single closed form
/// parameterized by the warmup length `w`: `w = m` for GPipe (all
/// forwards first), `w = min(pp-1-stage, m)` for 1F1B — slot `k` is
/// then warmup `Fwd(k)` for `k < w`, the alternating steady phase for
/// `k < 2m - w`, and cooldown `Bwd(k - m)` after.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleOrderIter {
    w: usize,
    m: usize,
    k: usize,
}

impl Iterator for ScheduleOrderIter {
    type Item = PipeSlot;

    fn next(&mut self) -> Option<PipeSlot> {
        if self.k >= 2 * self.m {
            return None;
        }
        let k = self.k;
        self.k += 1;
        Some(if k < self.w {
            PipeSlot::Fwd(k)
        } else if k < 2 * self.m - self.w {
            let t = k - self.w;
            if t % 2 == 0 {
                PipeSlot::Fwd(self.w + t / 2)
            } else {
                PipeSlot::Bwd(t / 2)
            }
        } else {
            PipeSlot::Bwd(k - self.m)
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = 2 * self.m - self.k;
        (left, Some(left))
    }
}

impl ExactSizeIterator for ScheduleOrderIter {}

/// The slot order stage `stage` (0-based, of `pp`) executes under
/// `sched` with `m` micro-batches, as an allocation-free iterator.
/// Every micro-batch appears exactly once as `Fwd` and once as `Bwd`,
/// with `Bwd(j)` after `Fwd(j)`.
pub fn schedule_order_iter(
    sched: PipelineSchedule,
    pp: usize,
    stage: usize,
    m: usize,
) -> ScheduleOrderIter {
    assert!(pp >= 1 && stage < pp && m >= 1);
    let w = match sched {
        PipelineSchedule::GPipe => m,
        PipelineSchedule::OneFOneB => (pp - 1 - stage).min(m),
    };
    ScheduleOrderIter { w, m, k: 0 }
}

/// [`schedule_order_iter`] collected into a `Vec` (the convenient form
/// for tests and one-off analysis).
pub fn schedule_order(
    sched: PipelineSchedule,
    pp: usize,
    stage: usize,
    m: usize,
) -> Vec<PipeSlot> {
    schedule_order_iter(sched, pp, stage, m).collect()
}

/// Interned, fully-expanded slot tables keyed by `(sched, pp, m)` —
/// the stage dimension is flattened in (stage-major, `2m` slots per
/// stage), so one entry serves a whole [`drive_pipeline_flat`] call.
/// Lookups are a linear scan over the handful of distinct grid shapes a
/// sweep visits and never allocate; only the first sighting of a shape
/// expands (and allocates) its table. Typically held in a per-worker
/// scratch so repeated grid points re-derive nothing.
#[derive(Debug, Default)]
pub struct OrderCache {
    entries: Vec<OrderEntry>,
}

#[derive(Debug)]
struct OrderEntry {
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    slots: Vec<PipeSlot>,
}

impl OrderCache {
    /// An empty cache.
    pub fn new() -> OrderCache {
        OrderCache::default()
    }

    /// The stage-major slot table for `(sched, pp, m)` (stage `i`'s
    /// order at `[i*2m .. (i+1)*2m]`), plus whether it was already
    /// interned (`true` = hit, no derivation).
    pub fn get(&mut self, sched: PipelineSchedule, pp: usize, m: usize) -> (&[PipeSlot], bool) {
        if let Some(i) = self
            .entries
            .iter()
            .position(|e| e.sched == sched && e.pp == pp && e.m == m)
        {
            return (&self.entries[i].slots, true);
        }
        let mut slots = Vec::with_capacity(pp * 2 * m);
        for stage in 0..pp {
            slots.extend(schedule_order_iter(sched, pp, stage, m));
        }
        self.entries.push(OrderEntry { sched, pp, m, slots });
        (&self.entries.last().expect("just pushed").slots, false)
    }

    /// Number of distinct `(sched, pp, m)` shapes interned.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no shapes have been interned yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The sentinel [`drive_pipeline_flat`] marks unscheduled slots with.
const NONE_TASK: TaskId = TaskId(u32::MAX);

/// Reusable flat state for [`drive_pipeline_flat`]: the `pp × m`
/// forward/backward completion-id tables (replacing the nested
/// `Vec<Vec<Option<TaskId>>>` of the reference driver), the per-stage
/// cursors, and the cross-stage dependency buffer. All buffers are
/// cleared and refilled in place, so reuse across calls is
/// allocation-free once capacity covers the largest `(pp, m)` seen.
#[derive(Debug, Default)]
pub struct PipeScratch {
    fwd: Vec<TaskId>,
    bwd: Vec<TaskId>,
    cursor: Vec<usize>,
    deps: Vec<TaskId>,
    /// Micro-batch count of the last drive (the flat tables' row
    /// stride; their length over `m` gives the stage count).
    m: usize,
}

impl PipeScratch {
    /// An empty scratch (buffers grow on first drive).
    pub fn new() -> PipeScratch {
        PipeScratch::default()
    }

    /// Completion id of `F(stage, j)` from the last completed drive.
    pub fn fwd_id(&self, stage: usize, j: usize) -> TaskId {
        let id = self.fwd[stage * self.m + j];
        debug_assert!(id != NONE_TASK, "slot F({stage},{j}) never scheduled");
        id
    }

    /// Completion id of `B(stage, j)` from the last completed drive.
    pub fn bwd_id(&self, stage: usize, j: usize) -> TaskId {
        let id = self.bwd[stage * self.m + j];
        debug_assert!(id != NONE_TASK, "slot B({stage},{j}) never scheduled");
        id
    }
}

/// Allocation-free twin of [`drive_pipeline`]: expand the pre-derived
/// stage-major `slots` table (from [`OrderCache::get`], `pp * 2m`
/// entries) into tasks via `emit`, tracking completion ids in the flat
/// tables of `sc`. Identical traversal, eligibility rule and emission
/// order to the nested reference — the shadow-equivalence property test
/// in `tests/timeline_props.rs` pins the two producing bit-identical
/// schedules. Completion ids stay readable through
/// [`PipeScratch::fwd_id`] / [`PipeScratch::bwd_id`] after the call.
pub fn drive_pipeline_flat<F>(
    tl: &mut Timeline,
    slots: &[PipeSlot],
    pp: usize,
    m: usize,
    sc: &mut PipeScratch,
    mut emit: F,
) where
    F: FnMut(&mut Timeline, usize, PipeSlot, &[TaskId]) -> TaskId,
{
    assert!(pp >= 1 && m >= 1);
    assert_eq!(slots.len(), pp * 2 * m, "slots must be the full stage-major table");
    sc.m = m;
    sc.fwd.clear();
    sc.fwd.resize(pp * m, NONE_TASK);
    sc.bwd.clear();
    sc.bwd.resize(pp * m, NONE_TASK);
    sc.cursor.clear();
    sc.cursor.resize(pp, 0);
    let mut remaining = 2 * m * pp;
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..pp {
            while sc.cursor[i] < 2 * m {
                let slot = slots[i * 2 * m + sc.cursor[i]];
                sc.deps.clear();
                let eligible = match slot {
                    PipeSlot::Fwd(j) => {
                        if i == 0 {
                            true
                        } else {
                            let d = sc.fwd[(i - 1) * m + j];
                            if d != NONE_TASK {
                                sc.deps.push(d);
                                true
                            } else {
                                false
                            }
                        }
                    }
                    PipeSlot::Bwd(j) => {
                        let own = sc.fwd[i * m + j];
                        if own == NONE_TASK {
                            false
                        } else {
                            sc.deps.push(own);
                            if i + 1 == pp {
                                true
                            } else {
                                let d = sc.bwd[(i + 1) * m + j];
                                if d != NONE_TASK {
                                    sc.deps.push(d);
                                    true
                                } else {
                                    false
                                }
                            }
                        }
                    }
                };
                if !eligible {
                    break;
                }
                let id = emit(tl, i, slot, &sc.deps);
                match slot {
                    PipeSlot::Fwd(j) => sc.fwd[i * m + j] = id,
                    PipeSlot::Bwd(j) => sc.bwd[i * m + j] = id,
                }
                sc.cursor[i] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline schedule deadlocked (invalid slot order)");
    }
}

/// Expand a pipeline schedule into tasks via `emit`, resolving
/// cross-stage dependencies with a deadlock-checked work-list sweep —
/// the readable nested-table reference implementation (the hot path
/// uses [`drive_pipeline_flat`]; a property test pins the two
/// equivalent).
///
/// `emit(timeline, stage, slot, deps)` schedules whatever tasks one
/// slot needs and returns the id representing that slot's *completion*
/// (later slots depend on it). The `deps` slice holds the cross-stage
/// gates: for `Fwd(j)` it is `[F(stage-1, j)]` (empty on stage 0); for
/// `Bwd(j)` it is `[F(stage, j)]` on the last stage and
/// `[F(stage, j), B(stage+1, j)]` elsewhere.
///
/// Returns the per-stage `(forward, backward)` completion-id tables.
pub fn drive_pipeline<F>(
    tl: &mut Timeline,
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    mut emit: F,
) -> (Vec<Vec<TaskId>>, Vec<Vec<TaskId>>)
where
    F: FnMut(&mut Timeline, usize, PipeSlot, &[TaskId]) -> TaskId,
{
    assert!(pp >= 1 && m >= 1);
    let orders: Vec<Vec<PipeSlot>> =
        (0..pp).map(|i| schedule_order(sched, pp, i, m)).collect();
    let mut fwd: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; pp];
    let mut bwd: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; pp];
    let mut cursor = vec![0usize; pp];
    let mut remaining = 2 * m * pp;
    let mut deps_buf: Vec<TaskId> = Vec::with_capacity(2);
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..pp {
            while cursor[i] < orders[i].len() {
                let slot = orders[i][cursor[i]];
                deps_buf.clear();
                let eligible = match slot {
                    PipeSlot::Fwd(j) => {
                        if i == 0 {
                            true
                        } else if let Some(d) = fwd[i - 1][j] {
                            deps_buf.push(d);
                            true
                        } else {
                            false
                        }
                    }
                    PipeSlot::Bwd(j) => match fwd[i][j] {
                        None => false,
                        Some(own) => {
                            deps_buf.push(own);
                            if i + 1 == pp {
                                true
                            } else if let Some(d) = bwd[i + 1][j] {
                                deps_buf.push(d);
                                true
                            } else {
                                false
                            }
                        }
                    },
                };
                if !eligible {
                    break;
                }
                let id = emit(tl, i, slot, &deps_buf);
                match slot {
                    PipeSlot::Fwd(j) => fwd[i][j] = Some(id),
                    PipeSlot::Bwd(j) => bwd[i][j] = Some(id),
                }
                cursor[i] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline schedule deadlocked (invalid slot order)");
    }
    let unwrap = |v: Vec<Vec<Option<TaskId>>>| -> Vec<Vec<TaskId>> {
        v.into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|t| t.expect("slot scheduled"))
                    .collect::<Vec<TaskId>>()
            })
            .collect()
    };
    (unwrap(fwd), unwrap(bwd))
}

/// A minimal scheduled pipeline: one compute stream per stage, one task
/// per slot (the reference shape the schedule-invariant property tests
/// analyze).
#[derive(Clone, Debug)]
pub struct Pipeline {
    /// Per-stage compute stream.
    pub compute: Vec<StreamId>,
    /// `fwd[stage][micro_batch]` completion ids.
    pub fwd: Vec<Vec<TaskId>>,
    /// `bwd[stage][micro_batch]` completion ids.
    pub bwd: Vec<Vec<TaskId>>,
}

/// Build a bare compute-only pipeline: stage `i` runs forwards of
/// `fwd_dur[i]` and backwards of `bwd_dur[i]` seconds under `sched`.
/// With uniform durations and `OneFOneB` (or `GPipe`) this reproduces
/// the analytic makespan `(m + pp - 1) * (f + b)` and hence the bubble
/// fraction `(pp - 1) / (m + pp - 1)` exactly.
pub fn build_pipeline(
    tl: &mut Timeline,
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    fwd_dur: &[f64],
    bwd_dur: &[f64],
) -> Pipeline {
    assert_eq!(fwd_dur.len(), pp);
    assert_eq!(bwd_dur.len(), pp);
    let compute: Vec<StreamId> = (0..pp).map(|_| tl.stream()).collect();
    let (fwd, bwd) = drive_pipeline(tl, sched, pp, m, |tl, i, slot, deps| match slot {
        PipeSlot::Fwd(_) => tl.task(compute[i], TaskKind::Forward, fwd_dur[i], deps),
        PipeSlot::Bwd(_) => tl.task(compute[i], TaskKind::Backward, bwd_dur[i], deps),
    });
    Pipeline { compute, fwd, bwd }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_serializes_and_deps_gate() {
        let mut tl = Timeline::recording();
        let a = tl.stream();
        let b = tl.stream();
        let t1 = tl.task(a, TaskKind::Other, 2.0, &[]);
        let t2 = tl.task(a, TaskKind::Other, 1.0, &[]); // queued behind t1
        assert_eq!(tl.end(t1), 2.0);
        assert_eq!(tl.end(t2), 3.0);
        let t3 = tl.task(b, TaskKind::Other, 0.5, &[t2]); // dep across streams
        assert_eq!(tl.end(t3), 3.5);
        assert_eq!(tl.stream_busy(a), 3.0);
        assert_eq!(tl.stream_busy(b), 0.5);
        assert_eq!(tl.makespan(), 3.5);
        assert_eq!(tl.deps_of(t3), &[t2]);
        assert_eq!(tl.n_tasks(), 3);
        assert!(tl.critical_path() <= tl.makespan() + 1e-12);
        assert!(tl.makespan() <= tl.serial_sum() + 1e-12);
    }

    #[test]
    fn lean_timeline_times_identically_and_resets_in_place() {
        let build = |tl: &mut Timeline| {
            let a = tl.stream();
            let b = tl.stream();
            let t1 = tl.task(a, TaskKind::Other, 2.0, &[]);
            let _ = tl.task(a, TaskKind::Other, 1.0, &[t1]);
            let t3 = tl.task(b, TaskKind::Other, 0.5, &[t1]);
            tl.end(t3)
        };
        let mut lean = Timeline::new();
        assert!(!lean.is_recording());
        let mut rec = Timeline::recording();
        assert_eq!(build(&mut lean).to_bits(), build(&mut rec).to_bits());
        assert_eq!(lean.makespan().to_bits(), rec.makespan().to_bits());
        assert_eq!(lean.serial_sum().to_bits(), rec.serial_sum().to_bits());
        assert_eq!(lean.n_tasks(), rec.n_tasks());
        // Reset retains the mode and produces identical timings again.
        let before = lean.makespan();
        lean.reset();
        assert_eq!(lean.n_tasks(), 0);
        assert_eq!(lean.n_streams(), 0);
        assert_eq!(lean.makespan(), 0.0);
        assert_eq!(build(&mut lean).to_bits(), 2.5f64.to_bits());
        assert_eq!(lean.makespan().to_bits(), before.to_bits());
        let mut rec2 = Timeline::recording();
        rec2.reset();
        assert!(rec2.is_recording());
    }

    #[test]
    #[should_panic(expected = "recording timeline")]
    fn lean_timeline_has_no_trace() {
        let mut tl = Timeline::new();
        let s = tl.stream();
        tl.task(s, TaskKind::Other, 1.0, &[]);
        let _ = tl.tasks();
    }

    #[test]
    fn schedule_order_covers_every_slot_once() {
        for sched in [PipelineSchedule::OneFOneB, PipelineSchedule::GPipe] {
            for pp in 1..=5 {
                for m in 1..=6 {
                    for stage in 0..pp {
                        let order = schedule_order(sched, pp, stage, m);
                        assert_eq!(order.len(), 2 * m);
                        for j in 0..m {
                            let f = order.iter().position(|&s| s == PipeSlot::Fwd(j));
                            let b = order.iter().position(|&s| s == PipeSlot::Bwd(j));
                            assert!(f.unwrap() < b.unwrap(), "{sched:?} pp{pp} s{stage} m{m}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn order_iter_is_exact_size() {
        let mut it = schedule_order_iter(PipelineSchedule::OneFOneB, 4, 1, 6);
        assert_eq!(it.len(), 12);
        it.next();
        assert_eq!(it.len(), 11);
        assert_eq!(it.count(), 11);
    }

    #[test]
    fn order_cache_interns_and_hits() {
        let mut cache = OrderCache::new();
        assert!(cache.is_empty());
        let (slots, hit) = cache.get(PipelineSchedule::OneFOneB, 3, 4);
        assert!(!hit);
        assert_eq!(slots.len(), 3 * 2 * 4);
        // Stage-major layout matches per-stage derivation.
        for stage in 0..3 {
            let expect = schedule_order(PipelineSchedule::OneFOneB, 3, stage, 4);
            let (slots, _) = cache.get(PipelineSchedule::OneFOneB, 3, 4);
            assert_eq!(&slots[stage * 8..(stage + 1) * 8], &expect[..], "stage {stage}");
        }
        let (_, hit) = cache.get(PipelineSchedule::OneFOneB, 3, 4);
        assert!(hit, "second lookup must hit");
        let (_, hit) = cache.get(PipelineSchedule::GPipe, 3, 4);
        assert!(!hit, "different schedule is a distinct shape");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn flat_drive_matches_nested_reference() {
        for (sched, pp, m) in [
            (PipelineSchedule::OneFOneB, 1, 1),
            (PipelineSchedule::OneFOneB, 3, 5),
            (PipelineSchedule::GPipe, 4, 2),
        ] {
            let fwd_dur: Vec<f64> = (0..pp).map(|i| 0.5 + i as f64 * 0.25).collect();
            let bwd_dur: Vec<f64> = (0..pp).map(|i| 1.0 + i as f64 * 0.125).collect();
            let mut ref_tl = Timeline::new();
            let p = build_pipeline(&mut ref_tl, sched, pp, m, &fwd_dur, &bwd_dur);

            let mut tl = Timeline::new();
            let compute: Vec<StreamId> = (0..pp).map(|_| tl.stream()).collect();
            let mut orders = OrderCache::new();
            let (slots, _) = orders.get(sched, pp, m);
            let mut sc = PipeScratch::new();
            drive_pipeline_flat(&mut tl, slots, pp, m, &mut sc, |tl, i, slot, deps| {
                match slot {
                    PipeSlot::Fwd(_) => tl.task(compute[i], TaskKind::Forward, fwd_dur[i], deps),
                    PipeSlot::Bwd(_) => tl.task(compute[i], TaskKind::Backward, bwd_dur[i], deps),
                }
            });
            assert_eq!(tl.makespan().to_bits(), ref_tl.makespan().to_bits());
            for i in 0..pp {
                for j in 0..m {
                    assert_eq!(sc.fwd_id(i, j), p.fwd[i][j], "F({i},{j})");
                    assert_eq!(sc.bwd_id(i, j), p.bwd[i][j], "B({i},{j})");
                }
            }
        }
    }

    #[test]
    fn uniform_1f1b_matches_analytic_makespan() {
        // Classic result: makespan = (m + pp - 1)(f + b), bubble
        // fraction (pp - 1)/(m + pp - 1).
        for (pp, m, f, b) in [(2, 2, 1.0, 1.0), (3, 3, 1.0, 2.0), (4, 8, 0.5, 1.0)] {
            let mut tl = Timeline::new();
            build_pipeline(
                &mut tl,
                PipelineSchedule::OneFOneB,
                pp,
                m,
                &vec![f; pp],
                &vec![b; pp],
            );
            let expect = (m + pp - 1) as f64 * (f + b);
            assert!(
                (tl.makespan() - expect).abs() < 1e-9,
                "pp{pp} m{m}: {} vs {expect}",
                tl.makespan()
            );
        }
    }

    #[test]
    fn gpipe_matches_analytic_makespan_uniform() {
        let (pp, m, f, b) = (3, 4, 1.0, 2.0);
        let mut tl = Timeline::new();
        build_pipeline(&mut tl, PipelineSchedule::GPipe, pp, m, &vec![f; pp], &vec![b; pp]);
        let expect = (m + pp - 1) as f64 * (f + b);
        assert!((tl.makespan() - expect).abs() < 1e-9, "{}", tl.makespan());
    }

    #[test]
    fn single_stage_pipeline_is_serial() {
        let mut tl = Timeline::new();
        let p = build_pipeline(&mut tl, PipelineSchedule::OneFOneB, 1, 3, &[1.0], &[2.0]);
        assert_eq!(tl.makespan(), 9.0);
        assert_eq!(tl.stream_busy(p.compute[0]), 9.0);
    }

    #[test]
    fn schedule_parse_round_trip() {
        for s in [PipelineSchedule::OneFOneB, PipelineSchedule::GPipe] {
            assert_eq!(PipelineSchedule::parse(s.label()), Some(s));
        }
        assert_eq!(PipelineSchedule::parse("GPipe"), Some(PipelineSchedule::GPipe));
        assert_eq!(PipelineSchedule::parse("1F1B"), Some(PipelineSchedule::OneFOneB));
        assert_eq!(
            PipelineSchedule::parse("One-F-One-B"),
            Some(PipelineSchedule::OneFOneB),
        );
        assert_eq!(PipelineSchedule::parse("zigzag"), None);
    }
}
