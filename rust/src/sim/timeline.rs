//! Discrete-event timeline engine: streams, dependent tasks, and the
//! pipeline-parallel (1F1B / GPipe) schedule builder.
//!
//! The closed-form playback in [`crate::sim::iteration`] times each PP
//! stage independently — correct only at `pp = 1`. Real 3D-parallel
//! iterations are *schedules*: forward/backward micro-batches flow
//! across stages, gradient collectives overlap the tail of backward,
//! and the asynchronous optimizer pipeline consumes whatever stream
//! slack the fill/drain bubbles leave. This module provides the event
//! engine those schedules are expressed in:
//!
//! * [`Timeline`] — a set of serially-executing streams (CUDA stream /
//!   NIC queue analogues) plus a task trace. A task occupies one stream
//!   for its duration and starts no earlier than (a) the stream's
//!   previous task and (b) every declared dependency's completion.
//!   Tasks must be submitted in dependency order (ids are handed out at
//!   submission), which makes scheduling a single deterministic forward
//!   pass — no event queue, no tie-breaking.
//! * [`schedule_order`] — the per-stage slot order of a pipeline
//!   schedule ([`PipelineSchedule::OneFOneB`] warmup/steady/cooldown or
//!   [`PipelineSchedule::GPipe`] all-forward-then-all-backward).
//! * [`drive_pipeline`] — turns those per-stage orders into tasks via a
//!   caller-supplied emitter, resolving cross-stage dependencies
//!   (`F(i,j)` after `F(i-1,j)`; `B(i,j)` after `F(i,j)` and
//!   `B(i+1,j)`) with a deadlock-checked work-list sweep.
//! * [`build_pipeline`] — the minimal emitter (one compute task per
//!   slot), used by the schedule-invariant property tests and as the
//!   reference for the analytic 1F1B bubble fraction
//!   `(pp-1)/(m+pp-1)`.
//!
//! The full-iteration emitter (bucket-split first-forward/last-backward
//! micro-batches, reduce-scatter overlap, the optimizer as a trailing
//! stream consumer) lives in `sim::iteration::simulate_iteration_timeline`.
//!
//! Invariants the trace exposes for verification (see
//! `tests/timeline_props.rs`): no stream runs two tasks concurrently;
//! every task starts at or after all of its dependencies' ends; the
//! makespan is bounded below by the dependency-graph critical path and
//! above by the serial sum of all durations.

#![warn(missing_docs)]

/// Handle of one serially-executing resource in a [`Timeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamId(pub u32);

/// Handle of one scheduled task in a [`Timeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskId(pub u32);

/// What a task models — for trace analysis and bubble accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Forward compute of (part of) a micro-batch.
    Forward,
    /// Backward compute of (part of) a micro-batch.
    Backward,
    /// Gradient-path collective (Reduce-Scatter / All-Reduce).
    GradComm,
    /// Parameter All-Gather (ZeRO-1 prefetch).
    ParamComm,
    /// Inter-stage activation (or activation-gradient) transfer.
    ActComm,
    /// TP activation All-Reduce block.
    TpComm,
    /// Optimizer step (the micro-group pipeline as one consumer).
    Optimizer,
    /// Anything else (synthetic tests).
    Other,
}

/// One scheduled task: placement, timing, and its dependency slice.
#[derive(Clone, Copy, Debug)]
pub struct TaskRec {
    /// The stream the task occupied.
    pub stream: StreamId,
    /// What the task models.
    pub kind: TaskKind,
    /// Start time (s).
    pub start: f64,
    /// Duration (s).
    pub dur: f64,
    /// Completion time (s) — `start + dur`.
    pub end: f64,
    dep_off: u32,
    dep_len: u32,
}

/// A deterministic discrete-event schedule under construction (see the
/// module docs).
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    free_at: Vec<f64>,
    busy: Vec<f64>,
    tasks: Vec<TaskRec>,
    deps: Vec<TaskId>,
}

impl Timeline {
    /// An empty timeline with no streams.
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Create a new stream (free from t = 0).
    pub fn stream(&mut self) -> StreamId {
        self.free_at.push(0.0);
        self.busy.push(0.0);
        StreamId((self.free_at.len() - 1) as u32)
    }

    /// Schedule a task of `dur` seconds on `stream`, starting no earlier
    /// than the stream's previous task and every task in `deps`.
    /// Dependencies must already be scheduled (ids are submission-time).
    pub fn task(&mut self, stream: StreamId, kind: TaskKind, dur: f64, deps: &[TaskId]) -> TaskId {
        debug_assert!(dur.is_finite() && dur >= 0.0, "bad duration {dur}");
        let mut ready = self.free_at[stream.0 as usize];
        for &d in deps {
            ready = ready.max(self.tasks[d.0 as usize].end);
        }
        let start = ready;
        let end = start + dur;
        self.free_at[stream.0 as usize] = end;
        self.busy[stream.0 as usize] += dur;
        let dep_off = self.deps.len() as u32;
        self.deps.extend_from_slice(deps);
        self.tasks.push(TaskRec {
            stream,
            kind,
            start,
            dur,
            end,
            dep_off,
            dep_len: deps.len() as u32,
        });
        TaskId((self.tasks.len() - 1) as u32)
    }

    /// Completion time of `t`.
    pub fn end(&self, t: TaskId) -> f64 {
        self.tasks[t.0 as usize].end
    }

    /// Latest completion time over all tasks (0 when empty).
    pub fn makespan(&self) -> f64 {
        self.tasks.iter().map(|t| t.end).fold(0.0, f64::max)
    }

    /// The full task trace, in submission order.
    pub fn tasks(&self) -> &[TaskRec] {
        &self.tasks
    }

    /// The declared dependencies of `t`.
    pub fn deps_of(&self, t: TaskId) -> &[TaskId] {
        let r = &self.tasks[t.0 as usize];
        &self.deps[r.dep_off as usize..(r.dep_off + r.dep_len) as usize]
    }

    /// Total busy time (sum of task durations) on `s`.
    pub fn stream_busy(&self, s: StreamId) -> f64 {
        self.busy[s.0 as usize]
    }

    /// When `s` drains (end of its last task; 0 if idle).
    pub fn stream_free(&self, s: StreamId) -> f64 {
        self.free_at[s.0 as usize]
    }

    /// Number of streams created.
    pub fn n_streams(&self) -> usize {
        self.free_at.len()
    }

    /// Dependency-graph critical path: the resource-oblivious lower
    /// bound on the makespan (longest chain of `dur` through `deps`).
    pub fn critical_path(&self) -> f64 {
        // Tasks are submitted in dependency order, so one forward pass.
        let mut lp = vec![0.0f64; self.tasks.len()];
        let mut best = 0.0f64;
        for (i, t) in self.tasks.iter().enumerate() {
            let mut start = 0.0f64;
            for &d in &self.deps[t.dep_off as usize..(t.dep_off + t.dep_len) as usize] {
                start = start.max(lp[d.0 as usize]);
            }
            lp[i] = start + t.dur;
            best = best.max(lp[i]);
        }
        best
    }

    /// Sum of all task durations: the fully-serialized upper bound.
    pub fn serial_sum(&self) -> f64 {
        self.tasks.iter().map(|t| t.dur).sum()
    }
}

/// Which pipeline-parallel schedule orders each stage's micro-batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineSchedule {
    /// One-forward-one-backward (Megatron / PipeDream-Flush): stage `i`
    /// runs `min(m, pp-1-i)` warmup forwards, then alternates
    /// forward/backward, then drains. Default.
    OneFOneB,
    /// GPipe: all `m` forwards, then all `m` backwards.
    GPipe,
}

impl PipelineSchedule {
    /// CLI / artifact label.
    pub fn label(&self) -> &'static str {
        match self {
            PipelineSchedule::OneFOneB => "1f1b",
            PipelineSchedule::GPipe => "gpipe",
        }
    }

    /// Parse a CLI spelling (`1f1b` / `gpipe`, case-insensitive).
    pub fn parse(s: &str) -> Option<PipelineSchedule> {
        match s.to_ascii_lowercase().as_str() {
            "1f1b" | "one-f-one-b" => Some(PipelineSchedule::OneFOneB),
            "gpipe" => Some(PipelineSchedule::GPipe),
            _ => None,
        }
    }
}

/// One slot in a stage's pipeline order: forward or backward of a
/// micro-batch index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeSlot {
    /// Forward of micro-batch `j`.
    Fwd(usize),
    /// Backward of micro-batch `j`.
    Bwd(usize),
}

/// The slot order stage `stage` (0-based, of `pp`) executes under
/// `sched` with `m` micro-batches. Every micro-batch appears exactly
/// once as `Fwd` and once as `Bwd`, with `Bwd(j)` after `Fwd(j)`.
pub fn schedule_order(
    sched: PipelineSchedule,
    pp: usize,
    stage: usize,
    m: usize,
) -> Vec<PipeSlot> {
    assert!(pp >= 1 && stage < pp && m >= 1);
    let mut out = Vec::with_capacity(2 * m);
    match sched {
        PipelineSchedule::GPipe => {
            out.extend((0..m).map(PipeSlot::Fwd));
            out.extend((0..m).map(PipeSlot::Bwd));
        }
        PipelineSchedule::OneFOneB => {
            let w = (pp - 1 - stage).min(m);
            for j in 0..w {
                out.push(PipeSlot::Fwd(j));
            }
            for k in 0..(m - w) {
                out.push(PipeSlot::Fwd(w + k));
                out.push(PipeSlot::Bwd(k));
            }
            for k in (m - w)..m {
                out.push(PipeSlot::Bwd(k));
            }
        }
    }
    out
}

/// Expand a pipeline schedule into tasks via `emit`, resolving
/// cross-stage dependencies with a deadlock-checked work-list sweep.
///
/// `emit(timeline, stage, slot, deps)` schedules whatever tasks one
/// slot needs and returns the id representing that slot's *completion*
/// (later slots depend on it). The `deps` slice holds the cross-stage
/// gates: for `Fwd(j)` it is `[F(stage-1, j)]` (empty on stage 0); for
/// `Bwd(j)` it is `[F(stage, j)]` on the last stage and
/// `[F(stage, j), B(stage+1, j)]` elsewhere.
///
/// Returns the per-stage `(forward, backward)` completion-id tables.
pub fn drive_pipeline<F>(
    tl: &mut Timeline,
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    mut emit: F,
) -> (Vec<Vec<TaskId>>, Vec<Vec<TaskId>>)
where
    F: FnMut(&mut Timeline, usize, PipeSlot, &[TaskId]) -> TaskId,
{
    assert!(pp >= 1 && m >= 1);
    let orders: Vec<Vec<PipeSlot>> =
        (0..pp).map(|i| schedule_order(sched, pp, i, m)).collect();
    let mut fwd: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; pp];
    let mut bwd: Vec<Vec<Option<TaskId>>> = vec![vec![None; m]; pp];
    let mut cursor = vec![0usize; pp];
    let mut remaining = 2 * m * pp;
    let mut deps_buf: Vec<TaskId> = Vec::with_capacity(2);
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..pp {
            while cursor[i] < orders[i].len() {
                let slot = orders[i][cursor[i]];
                deps_buf.clear();
                let eligible = match slot {
                    PipeSlot::Fwd(j) => {
                        if i == 0 {
                            true
                        } else if let Some(d) = fwd[i - 1][j] {
                            deps_buf.push(d);
                            true
                        } else {
                            false
                        }
                    }
                    PipeSlot::Bwd(j) => match fwd[i][j] {
                        None => false,
                        Some(own) => {
                            deps_buf.push(own);
                            if i + 1 == pp {
                                true
                            } else if let Some(d) = bwd[i + 1][j] {
                                deps_buf.push(d);
                                true
                            } else {
                                false
                            }
                        }
                    },
                };
                if !eligible {
                    break;
                }
                let id = emit(tl, i, slot, &deps_buf);
                match slot {
                    PipeSlot::Fwd(j) => fwd[i][j] = Some(id),
                    PipeSlot::Bwd(j) => bwd[i][j] = Some(id),
                }
                cursor[i] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline schedule deadlocked (invalid slot order)");
    }
    let unwrap = |v: Vec<Vec<Option<TaskId>>>| -> Vec<Vec<TaskId>> {
        v.into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|t| t.expect("slot scheduled"))
                    .collect::<Vec<TaskId>>()
            })
            .collect()
    };
    (unwrap(fwd), unwrap(bwd))
}

/// A minimal scheduled pipeline: one compute stream per stage, one task
/// per slot (the reference shape the schedule-invariant property tests
/// analyze).
#[derive(Clone, Debug)]
pub struct Pipeline {
    /// Per-stage compute stream.
    pub compute: Vec<StreamId>,
    /// `fwd[stage][micro_batch]` completion ids.
    pub fwd: Vec<Vec<TaskId>>,
    /// `bwd[stage][micro_batch]` completion ids.
    pub bwd: Vec<Vec<TaskId>>,
}

/// Build a bare compute-only pipeline: stage `i` runs forwards of
/// `fwd_dur[i]` and backwards of `bwd_dur[i]` seconds under `sched`.
/// With uniform durations and `OneFOneB` (or `GPipe`) this reproduces
/// the analytic makespan `(m + pp - 1) * (f + b)` and hence the bubble
/// fraction `(pp - 1) / (m + pp - 1)` exactly.
pub fn build_pipeline(
    tl: &mut Timeline,
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    fwd_dur: &[f64],
    bwd_dur: &[f64],
) -> Pipeline {
    assert_eq!(fwd_dur.len(), pp);
    assert_eq!(bwd_dur.len(), pp);
    let compute: Vec<StreamId> = (0..pp).map(|_| tl.stream()).collect();
    let (fwd, bwd) = drive_pipeline(tl, sched, pp, m, |tl, i, slot, deps| match slot {
        PipeSlot::Fwd(_) => tl.task(compute[i], TaskKind::Forward, fwd_dur[i], deps),
        PipeSlot::Bwd(_) => tl.task(compute[i], TaskKind::Backward, bwd_dur[i], deps),
    });
    Pipeline { compute, fwd, bwd }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_serializes_and_deps_gate() {
        let mut tl = Timeline::new();
        let a = tl.stream();
        let b = tl.stream();
        let t1 = tl.task(a, TaskKind::Other, 2.0, &[]);
        let t2 = tl.task(a, TaskKind::Other, 1.0, &[]); // queued behind t1
        assert_eq!(tl.end(t1), 2.0);
        assert_eq!(tl.end(t2), 3.0);
        let t3 = tl.task(b, TaskKind::Other, 0.5, &[t2]); // dep across streams
        assert_eq!(tl.end(t3), 3.5);
        assert_eq!(tl.stream_busy(a), 3.0);
        assert_eq!(tl.stream_busy(b), 0.5);
        assert_eq!(tl.makespan(), 3.5);
        assert_eq!(tl.deps_of(t3), &[t2]);
        assert!(tl.critical_path() <= tl.makespan() + 1e-12);
        assert!(tl.makespan() <= tl.serial_sum() + 1e-12);
    }

    #[test]
    fn schedule_order_covers_every_slot_once() {
        for sched in [PipelineSchedule::OneFOneB, PipelineSchedule::GPipe] {
            for pp in 1..=5 {
                for m in 1..=6 {
                    for stage in 0..pp {
                        let order = schedule_order(sched, pp, stage, m);
                        assert_eq!(order.len(), 2 * m);
                        for j in 0..m {
                            let f = order.iter().position(|&s| s == PipeSlot::Fwd(j));
                            let b = order.iter().position(|&s| s == PipeSlot::Bwd(j));
                            assert!(f.unwrap() < b.unwrap(), "{sched:?} pp{pp} s{stage} m{m}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn uniform_1f1b_matches_analytic_makespan() {
        // Classic result: makespan = (m + pp - 1)(f + b), bubble
        // fraction (pp - 1)/(m + pp - 1).
        for (pp, m, f, b) in [(2, 2, 1.0, 1.0), (3, 3, 1.0, 2.0), (4, 8, 0.5, 1.0)] {
            let mut tl = Timeline::new();
            build_pipeline(
                &mut tl,
                PipelineSchedule::OneFOneB,
                pp,
                m,
                &vec![f; pp],
                &vec![b; pp],
            );
            let expect = (m + pp - 1) as f64 * (f + b);
            assert!(
                (tl.makespan() - expect).abs() < 1e-9,
                "pp{pp} m{m}: {} vs {expect}",
                tl.makespan()
            );
        }
    }

    #[test]
    fn gpipe_matches_analytic_makespan_uniform() {
        let (pp, m, f, b) = (3, 4, 1.0, 2.0);
        let mut tl = Timeline::new();
        build_pipeline(&mut tl, PipelineSchedule::GPipe, pp, m, &vec![f; pp], &vec![b; pp]);
        let expect = (m + pp - 1) as f64 * (f + b);
        assert!((tl.makespan() - expect).abs() < 1e-9, "{}", tl.makespan());
    }

    #[test]
    fn single_stage_pipeline_is_serial() {
        let mut tl = Timeline::new();
        let p = build_pipeline(&mut tl, PipelineSchedule::OneFOneB, 1, 3, &[1.0], &[2.0]);
        assert_eq!(tl.makespan(), 9.0);
        assert_eq!(tl.stream_busy(p.compute[0]), 9.0);
    }

    #[test]
    fn schedule_parse_round_trip() {
        for s in [PipelineSchedule::OneFOneB, PipelineSchedule::GPipe] {
            assert_eq!(PipelineSchedule::parse(s.label()), Some(s));
        }
        assert_eq!(PipelineSchedule::parse("GPipe"), Some(PipelineSchedule::GPipe));
        assert_eq!(PipelineSchedule::parse("zigzag"), None);
    }
}
