//! Batched structure-of-arrays scenario evaluation (the closed-form arm).
//!
//! A sweep or `canzona optimize` search evaluates thousands of leaves
//! that share one plan fingerprint — same model/DP/TP/strategy/metric,
//! hence the same cached [`StageTable`] — and differ only in continuous
//! knobs: the fusion capacity `C_max`, link bandwidths, network
//! latencies, and a straggler derate. The scalar path re-derives the
//! whole closed form per leaf; this module evaluates N such *lanes* in
//! one call over structure-of-arrays buffers:
//!
//! * [`ScenarioBatch`] — one base [`Scenario`] (must satisfy the
//!   closed-form dispatch rule: `pp == 1`, `micro_batches == 1`,
//!   `straggler == 1.0`) plus per-lane [`LaneKnobs`] columns.
//! * [`BreakdownBatch`] — a caller-owned SoA output block: one column
//!   per [`Breakdown`] scalar, reused across calls with capacity
//!   retained (the warm batch path is zero-allocation, enforced by
//!   `tests/warm_alloc.rs`).
//! * [`simulate_batch_into`] — the evaluator: fixed-width chunks
//!   ([`BATCH_CHUNK`] lanes) of plain `f64` recurrences, std-only, no
//!   `unsafe`, shaped so the auto-vectorizer can keep the stream
//!   recurrences in registers.
//!
//! # Bit-for-bit contract
//!
//! For every lane, the batch path must produce **exactly** the bits the
//! scalar closed form produces for a `Scenario` carrying that lane's
//! knobs (`hw` = the lane hardware, `c_max_bytes` = the lane capacity)
//! — every [`Breakdown`] field except `planning_s`, which is wall-clock
//! plumbing. `tests/batch_differential.rs` pins this across all
//! strategies × optimizers × sizes × fusion modes with randomized knob
//! vectors and ragged tails. The implementation strategy makes the
//! contract structural rather than numerical:
//!
//! * Work that is *lane-invariant* (the stage-table fetch, the bucket
//!   shard reductions via [`shard_parts`], gradient wire volume) is
//!   hoisted once per batch — computing it once yields the same bits as
//!   computing it per lane because the inputs are identical.
//! * Work that is *per-lane* runs the **same functions** the scalar
//!   path runs ([`stage_times`], [`CommModel::collective`] /
//!   [`CommModel::collective_parts`], [`optimizer_step_knobs`]), in the
//!   same per-lane operation order; the chunked loops replicate
//!   [`Stream`](super::stream::Stream)'s `schedule` algebra
//!   (`start = ready.max(free); free = start + dur`) verbatim.
//!
//! # Straggler semantics
//!
//! A lane's `straggler` derates its compute/HBM throughput
//! ([`Hardware::derate`]) and leaves the fabric untouched — at `pp = 1`
//! there is only one stage, so "the last stage is slower" and "the
//! whole lane is slower" coincide, which is what lets the batch tier
//! model straggler sweeps without the timeline engine. `derate(1.0)` is
//! bit-exact (pinned in `cost::hardware`), so lanes built from plain
//! closed-form scenarios reproduce the scalar path's bits.

#![warn(missing_docs)]

use std::sync::Arc;
use std::time::Instant;

use crate::bail;
use crate::cost::comm::{shard_parts, CollectiveKind, CommModel};
use crate::cost::hardware::{Hardware, LinkKind};
use crate::schedule::microgroup::TpPlan;
use crate::sweep::cache::{PlanCache, StageKey};
use crate::util::error::Result;

use super::iteration::{
    closed_form_path, fill_loads, optimizer_step_knobs, stage_grad_bytes, stage_times,
    uses_all_reduce, with_batch_scratch, Breakdown, StageTable, ADAMW_BYTES_PER_ELEM,
};
use super::scenario::Scenario;

/// Lanes per inner-loop chunk. Wide enough to fill a 512-bit vector
/// unit with `f64`s, small enough that the per-chunk stream state
/// (six `[f64; BATCH_CHUNK]` arrays) stays in registers.
pub const BATCH_CHUNK: usize = 8;

/// One lane's continuous knobs: everything a batch member may vary
/// against the shared plan fingerprint.
#[derive(Clone, Copy, Debug)]
pub struct LaneKnobs {
    /// Micro-group fusion capacity in bytes; `None` = No-Fuse.
    pub c_max_bytes: Option<f64>,
    /// Dense-matmul throughput (FLOP/s), pre-derate.
    pub gpu_flops: f64,
    /// HBM bandwidth (bytes/s), pre-derate.
    pub hbm_bw: f64,
    /// Intra-node (NVLink) algorithm bandwidth (bytes/s).
    pub nvlink_bw: f64,
    /// Inter-node (InfiniBand) algorithm bandwidth (bytes/s).
    pub ib_bw: f64,
    /// Intra-node per-collective base latency (s).
    pub nvlink_lat: f64,
    /// Inter-node per-collective base latency (s).
    pub ib_lat: f64,
    /// Kernel-launch / per-message fixed overhead (s).
    pub launch_overhead: f64,
    /// Compute/HBM derate factor (`1.0` = none; see the module docs).
    pub straggler: f64,
}

impl LaneKnobs {
    /// The lane that reproduces `s` exactly: its hardware profile,
    /// capacity, and straggler. Pushing this onto a batch whose base
    /// shares `s`'s fingerprint yields the scalar path's bits.
    pub fn from_scenario(s: &Scenario) -> LaneKnobs {
        LaneKnobs {
            c_max_bytes: s.c_max_bytes,
            gpu_flops: s.hw.gpu_flops,
            hbm_bw: s.hw.hbm_bw,
            nvlink_bw: s.hw.nvlink_bw,
            ib_bw: s.hw.ib_bw,
            nvlink_lat: s.hw.nvlink_lat,
            ib_lat: s.hw.ib_lat,
            launch_overhead: s.hw.launch_overhead,
            straggler: s.straggler,
        }
    }

    /// Same validation contract as [`Scenario::validate`] — reject
    /// knobs that would divide or multiply to `inf`/`NaN` downstream,
    /// with the same greppable `invalid scenario:` prefix.
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("gpu_flops", self.gpu_flops),
            ("hbm_bw", self.hbm_bw),
            ("nvlink_bw", self.nvlink_bw),
            ("ib_bw", self.ib_bw),
        ] {
            if !v.is_finite() || v <= 0.0 {
                bail!("invalid scenario: lane {name} must be finite and > 0, got {v}");
            }
        }
        for (name, v) in [
            ("nvlink_lat", self.nvlink_lat),
            ("ib_lat", self.ib_lat),
            ("launch_overhead", self.launch_overhead),
        ] {
            if !v.is_finite() || v < 0.0 {
                bail!("invalid scenario: lane {name} must be finite and >= 0, got {v}");
            }
        }
        if !self.straggler.is_finite() || self.straggler < 1.0 {
            bail!(
                "invalid scenario: lane straggler expects a finite factor >= 1.0, got {}",
                self.straggler
            );
        }
        if let Some(cb) = self.c_max_bytes {
            if !cb.is_finite() || cb <= 0.0 {
                bail!(
                    "invalid scenario: lane c_max_bytes must be finite and > 0 \
                     (use None for No-Fuse), got {cb}"
                );
            }
        }
        Ok(())
    }

    /// The lane's effective hardware profile: the knob fields over the
    /// base profile's identity (name, GPUs per node), derated by the
    /// lane straggler.
    fn hardware(&self, base: &Hardware) -> Hardware {
        Hardware {
            gpu_flops: self.gpu_flops,
            hbm_bw: self.hbm_bw,
            nvlink_bw: self.nvlink_bw,
            ib_bw: self.ib_bw,
            nvlink_lat: self.nvlink_lat,
            ib_lat: self.ib_lat,
            launch_overhead: self.launch_overhead,
            ..base.clone()
        }
        .derate(self.straggler)
    }
}

/// N scenarios sharing one plan fingerprint (the base [`Scenario`]) and
/// varying only [`LaneKnobs`]. Construction validates eligibility
/// (closed-form arm) and every lane's knobs, so the evaluator itself
/// never has to.
pub struct ScenarioBatch {
    base: Scenario,
    lanes: Vec<LaneKnobs>,
}

impl ScenarioBatch {
    /// Start a batch over `base`'s fingerprint. Errors if `base` fails
    /// [`Scenario::validate`] or is not closed-form eligible (the batch
    /// tier only replaces the closed-form arm; `pp > 1` /
    /// `micro_batches > 1` scenarios route through the timeline engine
    /// one at a time).
    pub fn new(base: Scenario) -> Result<ScenarioBatch> {
        base.validate()?;
        if !closed_form_path(&base) {
            bail!(
                "scenario batch requires the closed-form arm \
                 (pp == 1, micro_batches == 1, straggler == 1.0); \
                 got pp={} micro_batches={} straggler={}",
                base.pp, base.micro_batches, base.straggler
            );
        }
        Ok(ScenarioBatch { base, lanes: Vec::new() })
    }

    /// The shared-fingerprint scenario the lanes perturb.
    pub fn base(&self) -> &Scenario {
        &self.base
    }

    /// Append a lane (validated — see [`LaneKnobs::validate`]).
    pub fn push(&mut self, knobs: LaneKnobs) -> Result<()> {
        knobs.validate()?;
        self.lanes.push(knobs);
        Ok(())
    }

    /// Append the lane reproducing `s` ([`LaneKnobs::from_scenario`]).
    /// The caller is responsible for `s` sharing the base fingerprint
    /// (the sweep engine groups by it); only the knobs are captured.
    pub fn push_scenario(&mut self, s: &Scenario) -> Result<()> {
        self.push(LaneKnobs::from_scenario(s))
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// The lane knob columns.
    pub fn lanes(&self) -> &[LaneKnobs] {
        &self.lanes
    }
}

/// Caller-owned SoA output block: one column per [`Breakdown`] scalar,
/// indexed by lane. Reuse one across [`simulate_batch_into`] calls —
/// columns are cleared and refilled in place, so a batch no larger than
/// a previous one performs zero heap allocations.
#[derive(Default)]
pub struct BreakdownBatch {
    /// Forward+backward wall time (s) per lane.
    pub fwd_bwd_s: Vec<f64>,
    /// Optimizer step wall time (s) per lane.
    pub optimizer_s: Vec<f64>,
    /// End-to-end iteration (s) per lane.
    pub total_s: Vec<f64>,
    /// AdamW reference time (s) per lane.
    pub adamw_ref_s: Vec<f64>,
    /// Exposed gradient-path communication (s) per lane.
    pub exposed_comm_s: Vec<f64>,
    /// Schedule idle time (s) per lane (== exposed comm at `pp = 1`).
    pub bubble_s: Vec<f64>,
    /// Gradient-path wire bytes per GPU per lane.
    pub grad_comm_bytes: Vec<f64>,
    /// Planning latency (s) per lane (stage fetch + TP solves; excluded
    /// from the bit-for-bit contract — it is wall-clock measurement).
    pub planning_s: Vec<f64>,
    /// Micro groups built (worst DP rank) per lane.
    pub n_micro_groups: Vec<usize>,
    /// Per lane: the worst rank's TP plan (feeds the TP load vectors on
    /// [`BreakdownBatch::write_into`]); `None` off the Atomic arm.
    worst_tplans: Vec<Option<Arc<TpPlan>>>,
    /// The batch's shared stage table (for load scatter).
    table: Option<Arc<StageTable>>,
    len: usize,
}

impl BreakdownBatch {
    /// An empty block (columns grow on first use).
    pub fn new() -> BreakdownBatch {
        BreakdownBatch::default()
    }

    /// Lanes held by the last evaluation.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the block empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop the Arc'd plan/table references (releasing cache pins) while
    /// keeping column capacity for the next batch.
    pub fn clear(&mut self) {
        self.reset(0);
    }

    /// Size every column to `n` lanes in place.
    fn reset(&mut self, n: usize) {
        fn fill(v: &mut Vec<f64>, n: usize) {
            v.clear();
            v.resize(n, 0.0);
        }
        fill(&mut self.fwd_bwd_s, n);
        fill(&mut self.optimizer_s, n);
        fill(&mut self.total_s, n);
        fill(&mut self.adamw_ref_s, n);
        fill(&mut self.exposed_comm_s, n);
        fill(&mut self.bubble_s, n);
        fill(&mut self.grad_comm_bytes, n);
        fill(&mut self.planning_s, n);
        self.n_micro_groups.clear();
        self.n_micro_groups.resize(n, 0);
        self.worst_tplans.clear();
        self.worst_tplans.resize(n, None);
        self.table = None;
        self.len = n;
    }

    /// Scatter lane `lane` into a scalar [`Breakdown`] (vector capacity
    /// reused — allocation-free once `out` has been sized). The result
    /// is bit-identical to the scalar closed form evaluated with that
    /// lane's knobs, `planning_s` excepted.
    pub fn write_into(&self, batch: &ScenarioBatch, lane: usize, out: &mut Breakdown) {
        out.reset();
        let table = self
            .table
            .as_ref()
            .expect("BreakdownBatch::write_into before simulate_batch_into");
        out.fwd_bwd_s = self.fwd_bwd_s[lane];
        out.optimizer_s = self.optimizer_s[lane];
        out.exposed_comm_s = self.exposed_comm_s[lane];
        out.n_micro_groups = self.n_micro_groups[lane];
        out.grad_comm_bytes = self.grad_comm_bytes[lane];
        out.adamw_ref_s = self.adamw_ref_s[lane];
        fill_loads(out, batch.base(), table, self.worst_tplans[lane].as_deref());
        out.planning_s = self.planning_s[lane];
        out.total_s = self.total_s[lane];
        out.bubble_s = self.bubble_s[lane];
    }
}

/// The per-worker reusable workspace of the batch tier, living inside
/// the thread's `SimScratch` (see `iteration::with_batch_scratch`): the
/// engine tier's SoA output block plus the hoisted lane-invariant
/// columns of the chunked loops. Capacity is retained across batches,
/// bounded by the largest (lane count, bucket count) shape the thread
/// has seen.
pub(crate) struct BatchScratch {
    /// Engine-tier per-worker output block (`simulate_batch_scatter`).
    out: BreakdownBatch,
    /// Per-lane comm models (stack-only `Hardware` payloads).
    comms: Vec<CommModel>,
    /// Per-lane forward compute time (s).
    fwd_t: Vec<f64>,
    /// Per-lane backward compute time (s).
    bwd_t: Vec<f64>,
    /// Per-lane TP activation All-Reduce block (s).
    tp_ar: Vec<f64>,
    /// Per-bucket shard totals ([`shard_parts`], ASC/LB-ASC only).
    shard_total: Vec<f64>,
    /// Per-bucket minimum shards.
    shard_min: Vec<f64>,
    /// Per-bucket shard counts (ranks).
    shard_ranks: Vec<usize>,
}

impl BatchScratch {
    pub(crate) fn new() -> BatchScratch {
        BatchScratch {
            out: BreakdownBatch::new(),
            comms: Vec::new(),
            fwd_t: Vec::new(),
            bwd_t: Vec::new(),
            tp_ar: Vec::new(),
            shard_total: Vec::new(),
            shard_min: Vec::new(),
            shard_ranks: Vec::new(),
        }
    }
}

/// Evaluate every lane of `batch` into the caller-owned `out` block.
///
/// One stage-table fetch covers the whole batch; per-lane work is the
/// chunked closed form (see the module docs for the bit-for-bit
/// contract). Warm caches + previously-sized buffers ⇒ zero heap
/// allocations. Rides the `batched_evals` cache counter.
pub fn simulate_batch_into(batch: &ScenarioBatch, cache: &PlanCache, out: &mut BreakdownBatch) {
    with_batch_scratch(|scratch| {
        simulate_batch_core(batch, cache, scratch, out);
    });
}

/// The engine tier's entry: evaluate `batch` through this worker's
/// scratch-resident [`BreakdownBatch`] and scatter lane `i` into
/// `outs[i]`. `outs.len()` must equal `batch.len()`.
pub(crate) fn simulate_batch_scatter(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    outs: &mut [Breakdown],
) {
    assert_eq!(outs.len(), batch.len(), "one output Breakdown per lane");
    with_batch_scratch(|scratch| {
        // Split-borrow: the SoA block and the hoist columns are
        // disjoint scratch fields.
        let BatchScratch { out, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks } =
            scratch;
        batch_core_split(
            batch, cache, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks, out,
        );
        for (lane, b) in outs.iter_mut().enumerate() {
            out.write_into(batch, lane, b);
        }
        // Release the Arc'd cache pins; capacity stays for the next group.
        out.clear();
    });
}

/// [`simulate_batch_into`]'s body once the thread scratch is borrowed.
fn simulate_batch_core(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    scratch: &mut BatchScratch,
    out: &mut BreakdownBatch,
) {
    let BatchScratch { out: _, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks } =
        scratch;
    batch_core_split(
        batch, cache, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks, out,
    );
}

/// The evaluator proper, over explicitly split scratch columns.
#[allow(clippy::too_many_arguments)]
fn batch_core_split(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    comms: &mut Vec<CommModel>,
    fwd_t: &mut Vec<f64>,
    bwd_t: &mut Vec<f64>,
    tp_ar: &mut Vec<f64>,
    shard_total: &mut Vec<f64>,
    shard_min: &mut Vec<f64>,
    shard_ranks: &mut Vec<usize>,
    out: &mut BreakdownBatch,
) {
    let s = batch.base();
    let n = batch.len();
    out.reset(n);
    if n == 0 {
        return;
    }

    // One stage-table fetch for the whole batch (the fetch latency is
    // the warm proxy for offline planning time, as on the scalar path).
    let t_fetch = Instant::now();
    let key = StageKey::for_scenario(s, 0);
    let table = cache.stage_table(&key, || StageTable::build(s, 0, cache));
    let stage_planning_s = t_fetch.elapsed().as_secs_f64();

    // --- lane-invariant hoists --------------------------------------
    // Gradient wire volume is hardware-free, so one lane's answer is
    // every lane's answer (bit-identical: same function, same inputs).
    let base_comm = CommModel::new(s.hw.clone());
    let grad_bytes = stage_grad_bytes(s, &base_comm, &table);
    let adamw_elems = table.total_elems / s.dp as f64;
    let nb = table.bucket_bytes.len();
    let dp = s.dp;
    let ar = uses_all_reduce(s);

    // Bucket shard reductions: `collective_v` = `shard_parts` (lane-
    // invariant) + `collective_parts` (per-lane) — hoist the first half.
    shard_total.clear();
    shard_min.clear();
    shard_ranks.clear();
    if let Some(shards) = &table.shard_bytes {
        for sb in shards {
            let (total, min) = shard_parts(sb);
            shard_total.push(total);
            shard_min.push(min);
            shard_ranks.push(sb.len());
        }
    }
    let has_shards = table.shard_bytes.is_some();

    // --- per-lane derived scalars ------------------------------------
    comms.clear();
    fwd_t.clear();
    bwd_t.clear();
    tp_ar.clear();
    for knobs in batch.lanes() {
        let comm = CommModel::new(knobs.hardware(&s.hw));
        let (f, b, ar_t, _act) = stage_times(s, &comm.hw, &comm, &table);
        fwd_t.push(f);
        bwd_t.push(b);
        tp_ar.push(ar_t);
        comms.push(comm);
    }

    // --- chunked stream recurrences ----------------------------------
    // Replicates `fwd_bwd_time`'s schedule algebra per lane:
    //   Stream::schedule(ready, dur): start = ready.max(free);
    //                                 free = start + dur; -> free
    // with the per-chunk stream state held in fixed-width stack arrays.
    let mut c0 = 0usize; // chunk base lane
    while c0 < n {
        let m = (n - c0).min(BATCH_CHUNK);

        // Backward: bucket grad collectives overlap later buckets.
        let mut compute = [0.0f64; BATCH_CHUNK];
        let mut comm_free = [0.0f64; BATCH_CHUNK];
        let mut bwd_end = [0.0f64; BATCH_CHUNK];
        let mut t_comm = [0.0f64; BATCH_CHUNK];
        for b in 0..nb {
            let frac = table.bucket_frac[b];
            bucket_comm_lanes(
                &comms[c0..c0 + m],
                GradOrAg::Grad,
                dp,
                ar,
                has_shards,
                table.bucket_bytes[b],
                shard_total.get(b).copied().unwrap_or(0.0),
                shard_min.get(b).copied().unwrap_or(0.0),
                shard_ranks.get(b).copied().unwrap_or(0),
                &mut t_comm[..m],
            );
            for l in 0..m {
                // grads_ready = compute.schedule(0.0, bwd_t * frac)
                let start = 0.0f64.max(compute[l]);
                compute[l] = start + bwd_t[c0 + l] * frac;
                let grads_ready = compute[l];
                // bwd_end = comm.schedule(grads_ready, t_comm).max(grads_ready)
                let cstart = grads_ready.max(comm_free[l]);
                comm_free[l] = cstart + t_comm[l];
                bwd_end[l] = comm_free[l].max(grads_ready);
            }
        }
        for l in 0..m {
            // bwd_end = bwd_end.max(compute.free_at())
            bwd_end[l] = bwd_end[l].max(compute[l]);
        }

        // Forward: ZeRO-1 parameter All-Gathers gate bucket compute.
        let mut f_compute = [0.0f64; BATCH_CHUNK];
        let mut f_comm = [0.0f64; BATCH_CHUNK];
        for b in 0..nb {
            let frac = table.bucket_frac[b];
            bucket_comm_lanes(
                &comms[c0..c0 + m],
                GradOrAg::Ag,
                dp,
                ar,
                has_shards,
                table.bucket_bytes[b],
                shard_total.get(b).copied().unwrap_or(0.0),
                shard_min.get(b).copied().unwrap_or(0.0),
                shard_ranks.get(b).copied().unwrap_or(0),
                &mut t_comm[..m],
            );
            for l in 0..m {
                // params_ready = fwd_comm.schedule(0.0, t_ag)
                let cstart = 0.0f64.max(f_comm[l]);
                f_comm[l] = cstart + t_comm[l];
                let params_ready = f_comm[l];
                // fwd_end = fwd_compute.schedule(params_ready, fwd_t * frac)
                let start = params_ready.max(f_compute[l]);
                f_compute[l] = start + fwd_t[c0 + l] * frac;
            }
        }

        for l in 0..m {
            let i = c0 + l;
            let fwd_end = f_compute[l];
            // total = bwd_end + fwd_end + tp_ar;
            // exposed = (bwd_end - bwd_t) + (fwd_end - fwd_t)
            out.fwd_bwd_s[i] = bwd_end[l] + fwd_end + tp_ar[i];
            out.exposed_comm_s[i] = (bwd_end[l] - bwd_t[i]) + (fwd_end - fwd_t[i]);
            out.bubble_s[i] = out.exposed_comm_s[i];
            out.grad_comm_bytes[i] = grad_bytes;
        }
        c0 += m;
    }

    // --- optimizer step + reference, per lane ------------------------
    // The step is dominated by cached per-rank plan lookups over the
    // shared table; each lane calls the scalar path's own function with
    // its knobs, which makes bit-equality structural.
    for (i, comm) in comms.iter().enumerate() {
        let opt = optimizer_step_knobs(
            s,
            &comm.hw,
            comm,
            &table,
            0,
            cache,
            batch.lanes()[i].c_max_bytes,
        );
        out.optimizer_s[i] = opt.time_s;
        out.n_micro_groups[i] = opt.n_micro_groups;
        out.adamw_ref_s[i] = comm.hw.memory_time(adamw_elems * ADAMW_BYTES_PER_ELEM);
        out.planning_s[i] = stage_planning_s + opt.planning_s;
        out.total_s[i] = out.fwd_bwd_s[i] + out.optimizer_s[i];
        out.worst_tplans[i] = opt.worst_tplan;
    }

    out.table = Some(table);
    cache.note_batched_evals(n as u64);
}

/// Which bucket collective a lane column prices.
#[derive(Clone, Copy)]
enum GradOrAg {
    /// The backward gradient path (`bucket_grad_time`).
    Grad,
    /// The forward ZeRO-1 parameter All-Gather (`bucket_ag_time`).
    Ag,
}

/// Fill `t_out[l]` with the bucket collective time for each lane in
/// `comms` — the per-lane half of `bucket_grad_time` / `bucket_ag_time`
/// with the shard reduction pre-hoisted. Matches those functions
/// branch-for-branch so the results are bit-identical.
#[allow(clippy::too_many_arguments)]
fn bucket_comm_lanes(
    comms: &[CommModel],
    which: GradOrAg,
    dp: usize,
    ar: bool,
    has_shards: bool,
    bucket_bytes: f64,
    total: f64,
    min: f64,
    ranks: usize,
    t_out: &mut [f64],
) {
    match which {
        GradOrAg::Grad => {
            if dp <= 1 {
                t_out.fill(0.0);
            } else if ar {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::AllReduce,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            } else if has_shards {
                if ranks <= 1 {
                    // collective_v's r <= 1 early return.
                    t_out.fill(0.0);
                } else {
                    for (t, c) in t_out.iter_mut().zip(comms) {
                        *t = c.collective_parts(
                            CollectiveKind::ReduceScatter,
                            total,
                            min,
                            ranks,
                            LinkKind::InterNode,
                        );
                    }
                }
            } else {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::ReduceScatter,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            }
        }
        GradOrAg::Ag => {
            if dp <= 1 || ar {
                t_out.fill(0.0);
            } else if has_shards {
                if ranks <= 1 {
                    t_out.fill(0.0);
                } else {
                    for (t, c) in t_out.iter_mut().zip(comms) {
                        *t = c.collective_parts(
                            CollectiveKind::AllGather,
                            total,
                            min,
                            ranks,
                            LinkKind::InterNode,
                        );
                    }
                }
            } else {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::AllGather,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;
    use crate::sim::simulate_iteration_cached;

    fn base() -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    #[test]
    fn rejects_non_closed_form_base() {
        let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 2, OptimKind::Muon, DpStrategy::LbAsc);
        let e = ScenarioBatch::new(s).expect_err("pp=2 must be rejected").to_string();
        assert!(e.contains("closed-form"), "{e}");
        let s = base().with_micro_batches(4);
        assert!(ScenarioBatch::new(s).is_err());
        let s = base().with_straggler(1.5);
        assert!(ScenarioBatch::new(s).is_err());
    }

    #[test]
    fn rejects_poisoned_lanes() {
        let mut b = ScenarioBatch::new(base()).unwrap();
        let mut k = LaneKnobs::from_scenario(&base());
        k.ib_bw = 0.0;
        let e = b.push(k).expect_err("zero bandwidth").to_string();
        assert!(e.contains("invalid scenario"), "{e}");
        let mut k = LaneKnobs::from_scenario(&base());
        k.straggler = 0.5;
        assert!(b.push(k).is_err());
        let mut k = LaneKnobs::from_scenario(&base());
        k.c_max_bytes = Some(-1.0);
        assert!(b.push(k).is_err());
        assert!(b.is_empty());
        assert!(b.push(LaneKnobs::from_scenario(&base())).is_ok());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn single_lane_matches_scalar_bits() {
        // The module-level smoke version of tests/batch_differential.rs:
        // one default lane == the scalar closed form, every field.
        let cache = PlanCache::new();
        let s = base();
        let scalar = simulate_iteration_cached(&s, &cache);
        let mut batch = ScenarioBatch::new(s.clone()).unwrap();
        batch.push_scenario(&s).unwrap();
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(out.len(), 1);
        let mut got = Breakdown::default();
        out.write_into(&batch, 0, &mut got);
        assert_eq!(got.fwd_bwd_s.to_bits(), scalar.fwd_bwd_s.to_bits());
        assert_eq!(got.optimizer_s.to_bits(), scalar.optimizer_s.to_bits());
        assert_eq!(got.total_s.to_bits(), scalar.total_s.to_bits());
        assert_eq!(got.adamw_ref_s.to_bits(), scalar.adamw_ref_s.to_bits());
        assert_eq!(got.exposed_comm_s.to_bits(), scalar.exposed_comm_s.to_bits());
        assert_eq!(got.bubble_s.to_bits(), scalar.bubble_s.to_bits());
        assert_eq!(got.grad_comm_bytes.to_bits(), scalar.grad_comm_bytes.to_bits());
        assert_eq!(got.n_micro_groups, scalar.n_micro_groups);
        assert_eq!(got.dp_loads_flops, scalar.dp_loads_flops);
        assert_eq!(got.dp_loads_state, scalar.dp_loads_state);
        assert_eq!(got.tp_loads_flops, scalar.tp_loads_flops);
        assert_eq!(got.tp_loads_state, scalar.tp_loads_state);
    }

    #[test]
    fn batched_evals_counter_rides_the_cache() {
        let cache = PlanCache::new();
        let s = base();
        let mut batch = ScenarioBatch::new(s.clone()).unwrap();
        for _ in 0..5 {
            batch.push_scenario(&s).unwrap();
        }
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_evals, 5);
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_evals, 10);
    }
}
