//! Batched structure-of-arrays scenario evaluation — both dispatch arms.
//!
//! A sweep or `canzona optimize` search evaluates thousands of leaves
//! that share one plan fingerprint — same model/DP/TP/strategy/metric,
//! hence the same cached [`StageTable`]s — and differ only in
//! continuous knobs: the fusion capacity `C_max`, link bandwidths,
//! network latencies, and a straggler derate. The scalar path
//! re-derives everything per leaf; this module evaluates N such *lanes*
//! in one call over structure-of-arrays buffers:
//!
//! * [`ScenarioBatch`] — one base [`Scenario`] plus per-lane
//!   [`LaneKnobs`] columns. The base's dispatch arm (the
//!   `closed_form_path` rule) picks the evaluator: the closed-form SoA
//!   recurrences for `pp == 1, micro_batches == 1, straggler == 1.0`
//!   bases, the schedule-tape timeline replay for everything else.
//! * [`BreakdownBatch`] — a caller-owned SoA output block: one column
//!   per [`Breakdown`] scalar, reused across calls with capacity
//!   retained (the warm batch path is zero-allocation on both arms,
//!   enforced by `tests/warm_alloc.rs`).
//! * [`simulate_batch_into`] — the evaluator: fixed-width chunks
//!   ([`BATCH_CHUNK`] lanes) of plain `f64` recurrences, std-only, no
//!   `unsafe`, shaped so the auto-vectorizer can keep the stream
//!   recurrences in registers.
//!
//! # Bit-for-bit contract
//!
//! For every lane, the batch path must produce **exactly** the bits the
//! scalar dispatcher produces for a `Scenario` carrying that lane's
//! knobs (`hw` = the lane hardware, `c_max_bytes` = the lane capacity,
//! `straggler` = the lane derate) — every [`Breakdown`] field except
//! `planning_s`, which is wall-clock plumbing.
//! `tests/batch_differential.rs` pins this across all strategies ×
//! optimizers × sizes × fusion modes (closed-form arm) and pp ×
//! schedule × micro-batches × straggler (timeline arm) with randomized
//! knob vectors and ragged tails. The implementation strategy makes the
//! contract structural rather than numerical:
//!
//! * Work that is *lane-invariant* (stage-table fetches, the bucket
//!   shard reductions via [`shard_parts`], gradient wire volume, and on
//!   the timeline arm the whole task DAG — see the schedule tape below)
//!   is hoisted once per batch — computing it once yields the same bits
//!   as computing it per lane because the inputs are identical.
//! * Work that is *per-lane* runs the **same functions** the scalar
//!   path runs ([`stage_times`], [`CommModel::collective`] /
//!   [`CommModel::collective_parts`], [`optimizer_step_knobs`],
//!   [`bucket_ag_time`] / [`bucket_grad_time`]), in the same per-lane
//!   operation order; the chunked loops replicate the scalar scheduling
//!   algebra (`Stream::schedule` on the closed-form arm,
//!   [`Timeline::task`] on the timeline arm:
//!   `ready = free.max(deps…); end = ready + dur`) verbatim.
//!
//! # The schedule tape (timeline arm)
//!
//! For a fixed plan fingerprint × `(schedule, pp, micro_batches)`
//! shape, the task DAG the timeline engine replays is **lane-invariant**:
//! the emission order, stream assignments, dependency edges, and the
//! formula each task's duration comes from are all decided by the
//! schedule shape and the cached stage census — never by the hardware
//! knobs. Only the duration *values* vary per lane. [`Tape::record`]
//! runs the scalar emitter's exact branch structure once (zero
//! durations) and stores, per task, the stream index, the resolved
//! dependency task indices (≤ 2 by construction), and a *duration slot*
//! — an index into a per-stage program of scalars (`fwd_t`, `bwd_t`,
//! per-bucket collective times, …). Replay then runs the identical
//! `free_at`/`ends` recurrence over SoA duration columns for
//! [`BATCH_CHUNK`] lanes at a time. Tapes are interned per worker in a
//! [`TapeCache`] keyed by `(schedule, pp, m, has_ag, per-stage bucket
//! counts)`; a tape is a pure function of its key, so there is no
//! invalidation — matching the schedule-order cache it subsumes.
//!
//! # Straggler semantics
//!
//! On the closed-form arm a lane's `straggler` derates the whole lane's
//! compute/HBM throughput ([`Hardware::derate`]) — at `pp = 1` there is
//! only one stage, so "the last stage is slower" and "the whole lane is
//! slower" coincide. On the timeline arm the lane straggler derates
//! only the **last pipeline stage**, exactly as the scalar timeline
//! dispatcher does, while collectives keep pricing against the lane's
//! un-derated fabric. `derate(1.0)` is bit-exact (pinned in
//! `cost::hardware`), so lanes built from plain scenarios reproduce the
//! scalar path's bits on either arm.

#![warn(missing_docs)]

use std::sync::Arc;
use std::time::Instant;

use crate::bail;
use crate::cost::comm::{shard_parts, CollectiveKind, CommModel};
use crate::cost::hardware::{Hardware, LinkKind};
use crate::schedule::microgroup::TpPlan;
use crate::sweep::cache::{canonical_stage, PlanCache, StageKey};
use crate::util::error::Result;

use super::iteration::{
    bucket_ag_time, bucket_grad_time, closed_form_path, fill_loads, optimizer_step_knobs,
    stage_grad_bytes, stage_times, uses_all_reduce, with_batch_scratch, Breakdown, StageTable,
    ADAMW_BYTES_PER_ELEM,
};
use super::scenario::Scenario;
use super::timeline::{
    drive_pipeline_flat, schedule_order_iter, PipeScratch, PipeSlot, PipelineSchedule, StreamId,
    TaskId, TaskKind, Timeline,
};

/// Lanes per inner-loop chunk. Wide enough to fill a 512-bit vector
/// unit with `f64`s, small enough that the per-chunk stream state
/// (six `[f64; BATCH_CHUNK]` arrays) stays in registers.
pub const BATCH_CHUNK: usize = 8;

/// One lane's continuous knobs: everything a batch member may vary
/// against the shared plan fingerprint.
#[derive(Clone, Copy, Debug)]
pub struct LaneKnobs {
    /// Micro-group fusion capacity in bytes; `None` = No-Fuse.
    pub c_max_bytes: Option<f64>,
    /// Dense-matmul throughput (FLOP/s), pre-derate.
    pub gpu_flops: f64,
    /// HBM bandwidth (bytes/s), pre-derate.
    pub hbm_bw: f64,
    /// Intra-node (NVLink) algorithm bandwidth (bytes/s).
    pub nvlink_bw: f64,
    /// Inter-node (InfiniBand) algorithm bandwidth (bytes/s).
    pub ib_bw: f64,
    /// Intra-node per-collective base latency (s).
    pub nvlink_lat: f64,
    /// Inter-node per-collective base latency (s).
    pub ib_lat: f64,
    /// Kernel-launch / per-message fixed overhead (s).
    pub launch_overhead: f64,
    /// Compute/HBM derate factor (`1.0` = none; see the module docs).
    pub straggler: f64,
}

impl LaneKnobs {
    /// The lane that reproduces `s` exactly: its hardware profile,
    /// capacity, and straggler. Pushing this onto a batch whose base
    /// shares `s`'s fingerprint yields the scalar path's bits.
    pub fn from_scenario(s: &Scenario) -> LaneKnobs {
        LaneKnobs {
            c_max_bytes: s.c_max_bytes,
            gpu_flops: s.hw.gpu_flops,
            hbm_bw: s.hw.hbm_bw,
            nvlink_bw: s.hw.nvlink_bw,
            ib_bw: s.hw.ib_bw,
            nvlink_lat: s.hw.nvlink_lat,
            ib_lat: s.hw.ib_lat,
            launch_overhead: s.hw.launch_overhead,
            straggler: s.straggler,
        }
    }

    /// Same validation contract as [`Scenario::validate`] — reject
    /// knobs that would divide or multiply to `inf`/`NaN` downstream,
    /// with the same greppable `invalid scenario:` prefix.
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("gpu_flops", self.gpu_flops),
            ("hbm_bw", self.hbm_bw),
            ("nvlink_bw", self.nvlink_bw),
            ("ib_bw", self.ib_bw),
        ] {
            if !v.is_finite() || v <= 0.0 {
                bail!("invalid scenario: lane {name} must be finite and > 0, got {v}");
            }
        }
        for (name, v) in [
            ("nvlink_lat", self.nvlink_lat),
            ("ib_lat", self.ib_lat),
            ("launch_overhead", self.launch_overhead),
        ] {
            if !v.is_finite() || v < 0.0 {
                bail!("invalid scenario: lane {name} must be finite and >= 0, got {v}");
            }
        }
        if !self.straggler.is_finite() || self.straggler < 1.0 {
            bail!(
                "invalid scenario: lane straggler expects a finite factor >= 1.0, got {}",
                self.straggler
            );
        }
        if let Some(cb) = self.c_max_bytes {
            if !cb.is_finite() || cb <= 0.0 {
                bail!(
                    "invalid scenario: lane c_max_bytes must be finite and > 0 \
                     (use None for No-Fuse), got {cb}"
                );
            }
        }
        Ok(())
    }

    /// The lane's raw hardware profile: the knob fields over the base
    /// profile's identity (name, GPUs per node), **not** derated — what
    /// a scalar `Scenario` carrying this lane's knobs would hold in
    /// `hw`. The timeline arm prices its fabric and non-last stages
    /// against this, deratings the last stage separately.
    fn base_hardware(&self, base: &Hardware) -> Hardware {
        Hardware {
            gpu_flops: self.gpu_flops,
            hbm_bw: self.hbm_bw,
            nvlink_bw: self.nvlink_bw,
            ib_bw: self.ib_bw,
            nvlink_lat: self.nvlink_lat,
            ib_lat: self.ib_lat,
            launch_overhead: self.launch_overhead,
            ..base.clone()
        }
    }

    /// The lane's effective single-stage profile (closed-form arm):
    /// [`LaneKnobs::base_hardware`] derated by the lane straggler.
    fn hardware(&self, base: &Hardware) -> Hardware {
        self.base_hardware(base).derate(self.straggler)
    }
}

/// N scenarios sharing one plan fingerprint (the base [`Scenario`]) and
/// varying only [`LaneKnobs`]. Construction validates the base and
/// every lane's knobs, so the evaluator itself never has to. The base's
/// dispatch arm selects the evaluator (see the module docs) — callers
/// batching lanes whose equivalent scalar scenarios take the *other*
/// arm than the base must not mix them (the sweep engine's group key
/// includes the arm bit exactly for this).
pub struct ScenarioBatch {
    base: Scenario,
    lanes: Vec<LaneKnobs>,
}

impl ScenarioBatch {
    /// Start a batch over `base`'s fingerprint. Errors if `base` fails
    /// [`Scenario::validate`]. Both dispatch arms are eligible: the
    /// closed-form SoA recurrences serve `pp == 1, micro_batches == 1,
    /// straggler == 1.0` bases, the schedule-tape timeline replay
    /// serves everything else. Faulted/heterogeneous bases
    /// ([`Scenario::faulted`]) are rejected outright: the lane columns
    /// carry no per-rank profile or recovery state, so such scenarios
    /// take the sweep engine's existing push-rejection fallback to the
    /// scalar timeline arm instead (graceful degradation — see
    /// `SweepEngine::eval_group`).
    pub fn new(base: Scenario) -> Result<ScenarioBatch> {
        base.validate()?;
        if base.faulted() {
            bail!(
                "invalid scenario: batch tier cannot evaluate faulted/heterogeneous \
                 scenarios (hetero={}, fail_rank={:?}, mttf={:?}); use the scalar \
                 timeline arm",
                base.hetero, base.fail_rank, base.mttf_s
            );
        }
        Ok(ScenarioBatch { base, lanes: Vec::new() })
    }

    /// The shared-fingerprint scenario the lanes perturb.
    pub fn base(&self) -> &Scenario {
        &self.base
    }

    /// Append a lane (validated — see [`LaneKnobs::validate`]).
    pub fn push(&mut self, knobs: LaneKnobs) -> Result<()> {
        knobs.validate()?;
        self.lanes.push(knobs);
        Ok(())
    }

    /// Append the lane reproducing `s` ([`LaneKnobs::from_scenario`]).
    /// The caller is responsible for `s` sharing the base fingerprint
    /// (the sweep engine groups by it); only the knobs are captured.
    pub fn push_scenario(&mut self, s: &Scenario) -> Result<()> {
        self.push(LaneKnobs::from_scenario(s))
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Is the batch empty?
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// The lane knob columns.
    pub fn lanes(&self) -> &[LaneKnobs] {
        &self.lanes
    }
}

/// Caller-owned SoA output block: one column per [`Breakdown`] scalar,
/// indexed by lane. Reuse one across [`simulate_batch_into`] calls —
/// columns are cleared and refilled in place, so a batch no larger than
/// a previous one performs zero heap allocations.
#[derive(Default)]
pub struct BreakdownBatch {
    /// Forward+backward wall time (s) per lane.
    pub fwd_bwd_s: Vec<f64>,
    /// Optimizer step wall time (s) per lane.
    pub optimizer_s: Vec<f64>,
    /// End-to-end iteration (s) per lane.
    pub total_s: Vec<f64>,
    /// AdamW reference time (s) per lane.
    pub adamw_ref_s: Vec<f64>,
    /// Exposed gradient-path communication (s) per lane.
    pub exposed_comm_s: Vec<f64>,
    /// Schedule idle time (s) per lane (== exposed comm at `pp = 1`).
    pub bubble_s: Vec<f64>,
    /// Gradient-path wire bytes per GPU per lane.
    pub grad_comm_bytes: Vec<f64>,
    /// Planning latency (s) per lane (stage fetch + TP solves; excluded
    /// from the bit-for-bit contract — it is wall-clock measurement).
    pub planning_s: Vec<f64>,
    /// Micro groups built (worst DP rank) per lane.
    pub n_micro_groups: Vec<usize>,
    /// Per lane: the worst rank's TP plan (feeds the TP load vectors on
    /// [`BreakdownBatch::write_into`]); `None` off the Atomic arm.
    worst_tplans: Vec<Option<Arc<TpPlan>>>,
    /// The batch's shared stage table (closed-form arm load scatter).
    table: Option<Arc<StageTable>>,
    /// Per lane: the pacing stage's table (timeline arm load scatter —
    /// each lane may pace on a different stage); `None` on the
    /// closed-form arm, where `table` covers every lane.
    lane_tables: Vec<Option<Arc<StageTable>>>,
    len: usize,
}

impl BreakdownBatch {
    /// An empty block (columns grow on first use).
    pub fn new() -> BreakdownBatch {
        BreakdownBatch::default()
    }

    /// Lanes held by the last evaluation.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the block empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop the Arc'd plan/table references (releasing cache pins) while
    /// keeping column capacity for the next batch.
    pub fn clear(&mut self) {
        self.reset(0);
    }

    /// Size every column to `n` lanes in place.
    fn reset(&mut self, n: usize) {
        fn fill(v: &mut Vec<f64>, n: usize) {
            v.clear();
            v.resize(n, 0.0);
        }
        fill(&mut self.fwd_bwd_s, n);
        fill(&mut self.optimizer_s, n);
        fill(&mut self.total_s, n);
        fill(&mut self.adamw_ref_s, n);
        fill(&mut self.exposed_comm_s, n);
        fill(&mut self.bubble_s, n);
        fill(&mut self.grad_comm_bytes, n);
        fill(&mut self.planning_s, n);
        self.n_micro_groups.clear();
        self.n_micro_groups.resize(n, 0);
        self.worst_tplans.clear();
        self.worst_tplans.resize(n, None);
        self.lane_tables.clear();
        self.lane_tables.resize(n, None);
        self.table = None;
        self.len = n;
    }

    /// Scatter lane `lane` into a scalar [`Breakdown`] (vector capacity
    /// reused — allocation-free once `out` has been sized). The result
    /// is bit-identical to the scalar closed form evaluated with that
    /// lane's knobs, `planning_s` excepted.
    pub fn write_into(&self, batch: &ScenarioBatch, lane: usize, out: &mut Breakdown) {
        out.reset();
        let table = self
            .lane_tables[lane]
            .as_ref()
            .or(self.table.as_ref())
            .expect("BreakdownBatch::write_into before simulate_batch_into");
        out.fwd_bwd_s = self.fwd_bwd_s[lane];
        out.optimizer_s = self.optimizer_s[lane];
        out.exposed_comm_s = self.exposed_comm_s[lane];
        out.n_micro_groups = self.n_micro_groups[lane];
        out.grad_comm_bytes = self.grad_comm_bytes[lane];
        out.adamw_ref_s = self.adamw_ref_s[lane];
        fill_loads(out, batch.base(), table, self.worst_tplans[lane].as_deref());
        out.planning_s = self.planning_s[lane];
        out.total_s = self.total_s[lane];
        out.bubble_s = self.bubble_s[lane];
    }
}

/// The per-worker reusable workspace of the batch tier, living inside
/// the thread's `SimScratch` (see `iteration::with_batch_scratch`): the
/// engine tier's SoA output block plus the hoisted lane-invariant
/// columns of the chunked loops. Capacity is retained across batches,
/// bounded by the largest (lane count, bucket count) shape the thread
/// has seen.
pub(crate) struct BatchScratch {
    /// Engine-tier per-worker output block (`simulate_batch_scatter`).
    out: BreakdownBatch,
    /// Per-lane comm models (stack-only `Hardware` payloads).
    comms: Vec<CommModel>,
    /// Per-lane forward compute time (s).
    fwd_t: Vec<f64>,
    /// Per-lane backward compute time (s).
    bwd_t: Vec<f64>,
    /// Per-lane TP activation All-Reduce block (s).
    tp_ar: Vec<f64>,
    /// Per-bucket shard totals ([`shard_parts`], ASC/LB-ASC only).
    shard_total: Vec<f64>,
    /// Per-bucket minimum shards.
    shard_min: Vec<f64>,
    /// Per-bucket shard counts (ranks).
    shard_ranks: Vec<usize>,
    /// The timeline arm's tape cache + SoA replay columns.
    tline: TimelineScratch,
}

impl BatchScratch {
    pub(crate) fn new() -> BatchScratch {
        BatchScratch {
            out: BreakdownBatch::new(),
            comms: Vec::new(),
            fwd_t: Vec::new(),
            bwd_t: Vec::new(),
            tp_ar: Vec::new(),
            shard_total: Vec::new(),
            shard_min: Vec::new(),
            shard_ranks: Vec::new(),
            tline: TimelineScratch::new(),
        }
    }
}

/// Evaluate every lane of `batch` into the caller-owned `out` block,
/// dispatching on the base scenario's arm (see the module docs).
///
/// Closed-form arm: one stage-table fetch covers the whole batch and
/// per-lane work is the chunked closed form. Timeline arm: one schedule
/// tape covers the whole batch and per-lane work is the chunked replay
/// ([`simulate_timeline_batch_into`] is the explicit-arm twin). Warm
/// caches + previously-sized buffers ⇒ zero heap allocations. Rides the
/// `batched_evals` / `batched_timeline_evals` cache counters.
pub fn simulate_batch_into(batch: &ScenarioBatch, cache: &PlanCache, out: &mut BreakdownBatch) {
    with_batch_scratch(|scratch| {
        simulate_batch_core(batch, cache, scratch, out);
    });
}

/// Evaluate every lane of `batch` through the schedule-tape timeline
/// replay regardless of the base's arm — the entry the timeline
/// differential tests exercise directly (the dispatching
/// [`simulate_batch_into`] routes non-closed-form bases here
/// automatically).
pub fn simulate_timeline_batch_into(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    out: &mut BreakdownBatch,
) {
    with_batch_scratch(|scratch| {
        timeline_core_split(batch, cache, &mut scratch.tline, out);
    });
}

/// The engine tier's entry: evaluate `batch` through this worker's
/// scratch-resident [`BreakdownBatch`] and scatter lane `i` into
/// `outs[i]`. `outs.len()` must equal `batch.len()`.
pub(crate) fn simulate_batch_scatter(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    outs: &mut [Breakdown],
) {
    assert_eq!(outs.len(), batch.len(), "one output Breakdown per lane");
    with_batch_scratch(|scratch| {
        // Split-borrow: the SoA block and the hoist columns are
        // disjoint scratch fields.
        let BatchScratch {
            out,
            comms,
            fwd_t,
            bwd_t,
            tp_ar,
            shard_total,
            shard_min,
            shard_ranks,
            tline,
        } = scratch;
        if closed_form_path(batch.base()) {
            batch_core_split(
                batch, cache, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks, out,
            );
        } else {
            timeline_core_split(batch, cache, tline, out);
        }
        for (lane, b) in outs.iter_mut().enumerate() {
            out.write_into(batch, lane, b);
        }
        // Release the Arc'd cache pins; capacity stays for the next group.
        out.clear();
    });
}

/// [`simulate_batch_into`]'s body once the thread scratch is borrowed.
fn simulate_batch_core(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    scratch: &mut BatchScratch,
    out: &mut BreakdownBatch,
) {
    let BatchScratch {
        out: _,
        comms,
        fwd_t,
        bwd_t,
        tp_ar,
        shard_total,
        shard_min,
        shard_ranks,
        tline,
    } = scratch;
    if closed_form_path(batch.base()) {
        batch_core_split(
            batch, cache, comms, fwd_t, bwd_t, tp_ar, shard_total, shard_min, shard_ranks, out,
        );
    } else {
        timeline_core_split(batch, cache, tline, out);
    }
}

/// The evaluator proper, over explicitly split scratch columns.
#[allow(clippy::too_many_arguments)]
fn batch_core_split(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    comms: &mut Vec<CommModel>,
    fwd_t: &mut Vec<f64>,
    bwd_t: &mut Vec<f64>,
    tp_ar: &mut Vec<f64>,
    shard_total: &mut Vec<f64>,
    shard_min: &mut Vec<f64>,
    shard_ranks: &mut Vec<usize>,
    out: &mut BreakdownBatch,
) {
    let s = batch.base();
    let n = batch.len();
    out.reset(n);
    if n == 0 {
        return;
    }

    // One stage-table fetch for the whole batch (the fetch latency is
    // the warm proxy for offline planning time, as on the scalar path).
    let t_fetch = Instant::now();
    let key = StageKey::for_scenario(s, 0);
    let table = cache.stage_table(&key, || StageTable::build(s, 0, cache));
    let stage_planning_s = t_fetch.elapsed().as_secs_f64();

    // --- lane-invariant hoists --------------------------------------
    // Gradient wire volume is hardware-free, so one lane's answer is
    // every lane's answer (bit-identical: same function, same inputs).
    let base_comm = CommModel::new(s.hw.clone());
    let grad_bytes = stage_grad_bytes(s, &base_comm, &table);
    let adamw_elems = table.total_elems / s.dp as f64;
    let nb = table.bucket_bytes.len();
    let dp = s.dp;
    let ar = uses_all_reduce(s);

    // Bucket shard reductions: `collective_v` = `shard_parts` (lane-
    // invariant) + `collective_parts` (per-lane) — hoist the first half.
    shard_total.clear();
    shard_min.clear();
    shard_ranks.clear();
    if let Some(shards) = &table.shard_bytes {
        for sb in shards {
            let (total, min) = shard_parts(sb);
            shard_total.push(total);
            shard_min.push(min);
            shard_ranks.push(sb.len());
        }
    }
    let has_shards = table.shard_bytes.is_some();

    // --- per-lane derived scalars ------------------------------------
    comms.clear();
    fwd_t.clear();
    bwd_t.clear();
    tp_ar.clear();
    for knobs in batch.lanes() {
        let comm = CommModel::new(knobs.hardware(&s.hw));
        let (f, b, ar_t, _act) = stage_times(s, &comm.hw, &comm, &table);
        fwd_t.push(f);
        bwd_t.push(b);
        tp_ar.push(ar_t);
        comms.push(comm);
    }

    // --- chunked stream recurrences ----------------------------------
    // Replicates `fwd_bwd_time`'s schedule algebra per lane:
    //   Stream::schedule(ready, dur): start = ready.max(free);
    //                                 free = start + dur; -> free
    // with the per-chunk stream state held in fixed-width stack arrays.
    let mut c0 = 0usize; // chunk base lane
    while c0 < n {
        let m = (n - c0).min(BATCH_CHUNK);

        // Backward: bucket grad collectives overlap later buckets.
        let mut compute = [0.0f64; BATCH_CHUNK];
        let mut comm_free = [0.0f64; BATCH_CHUNK];
        let mut bwd_end = [0.0f64; BATCH_CHUNK];
        let mut t_comm = [0.0f64; BATCH_CHUNK];
        for b in 0..nb {
            let frac = table.bucket_frac[b];
            bucket_comm_lanes(
                &comms[c0..c0 + m],
                GradOrAg::Grad,
                dp,
                ar,
                has_shards,
                table.bucket_bytes[b],
                shard_total.get(b).copied().unwrap_or(0.0),
                shard_min.get(b).copied().unwrap_or(0.0),
                shard_ranks.get(b).copied().unwrap_or(0),
                &mut t_comm[..m],
            );
            for l in 0..m {
                // grads_ready = compute.schedule(0.0, bwd_t * frac)
                let start = 0.0f64.max(compute[l]);
                compute[l] = start + bwd_t[c0 + l] * frac;
                let grads_ready = compute[l];
                // bwd_end = comm.schedule(grads_ready, t_comm).max(grads_ready)
                let cstart = grads_ready.max(comm_free[l]);
                comm_free[l] = cstart + t_comm[l];
                bwd_end[l] = comm_free[l].max(grads_ready);
            }
        }
        for l in 0..m {
            // bwd_end = bwd_end.max(compute.free_at())
            bwd_end[l] = bwd_end[l].max(compute[l]);
        }

        // Forward: ZeRO-1 parameter All-Gathers gate bucket compute.
        let mut f_compute = [0.0f64; BATCH_CHUNK];
        let mut f_comm = [0.0f64; BATCH_CHUNK];
        for b in 0..nb {
            let frac = table.bucket_frac[b];
            bucket_comm_lanes(
                &comms[c0..c0 + m],
                GradOrAg::Ag,
                dp,
                ar,
                has_shards,
                table.bucket_bytes[b],
                shard_total.get(b).copied().unwrap_or(0.0),
                shard_min.get(b).copied().unwrap_or(0.0),
                shard_ranks.get(b).copied().unwrap_or(0),
                &mut t_comm[..m],
            );
            for l in 0..m {
                // params_ready = fwd_comm.schedule(0.0, t_ag)
                let cstart = 0.0f64.max(f_comm[l]);
                f_comm[l] = cstart + t_comm[l];
                let params_ready = f_comm[l];
                // fwd_end = fwd_compute.schedule(params_ready, fwd_t * frac)
                let start = params_ready.max(f_compute[l]);
                f_compute[l] = start + fwd_t[c0 + l] * frac;
            }
        }

        for l in 0..m {
            let i = c0 + l;
            let fwd_end = f_compute[l];
            // total = bwd_end + fwd_end + tp_ar;
            // exposed = (bwd_end - bwd_t) + (fwd_end - fwd_t)
            out.fwd_bwd_s[i] = bwd_end[l] + fwd_end + tp_ar[i];
            out.exposed_comm_s[i] = (bwd_end[l] - bwd_t[i]) + (fwd_end - fwd_t[i]);
            out.bubble_s[i] = out.exposed_comm_s[i];
            out.grad_comm_bytes[i] = grad_bytes;
        }
        c0 += m;
    }

    // --- optimizer step + reference, per lane ------------------------
    // The step is dominated by cached per-rank plan lookups over the
    // shared table; each lane calls the scalar path's own function with
    // its knobs, which makes bit-equality structural.
    for (i, comm) in comms.iter().enumerate() {
        let opt = optimizer_step_knobs(
            s,
            &comm.hw,
            comm,
            &table,
            0,
            cache,
            batch.lanes()[i].c_max_bytes,
        );
        out.optimizer_s[i] = opt.time_s;
        out.n_micro_groups[i] = opt.n_micro_groups;
        out.adamw_ref_s[i] = comm.hw.memory_time(adamw_elems * ADAMW_BYTES_PER_ELEM);
        out.planning_s[i] = stage_planning_s + opt.planning_s;
        out.total_s[i] = out.fwd_bwd_s[i] + out.optimizer_s[i];
        out.worst_tplans[i] = opt.worst_tplan;
    }

    out.table = Some(table);
    cache.note_batched_evals(n as u64);
}

/// Which bucket collective a lane column prices.
#[derive(Clone, Copy)]
enum GradOrAg {
    /// The backward gradient path (`bucket_grad_time`).
    Grad,
    /// The forward ZeRO-1 parameter All-Gather (`bucket_ag_time`).
    Ag,
}

/// Fill `t_out[l]` with the bucket collective time for each lane in
/// `comms` — the per-lane half of `bucket_grad_time` / `bucket_ag_time`
/// with the shard reduction pre-hoisted. Matches those functions
/// branch-for-branch so the results are bit-identical.
#[allow(clippy::too_many_arguments)]
fn bucket_comm_lanes(
    comms: &[CommModel],
    which: GradOrAg,
    dp: usize,
    ar: bool,
    has_shards: bool,
    bucket_bytes: f64,
    total: f64,
    min: f64,
    ranks: usize,
    t_out: &mut [f64],
) {
    match which {
        GradOrAg::Grad => {
            if dp <= 1 {
                t_out.fill(0.0);
            } else if ar {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::AllReduce,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            } else if has_shards {
                if ranks <= 1 {
                    // collective_v's r <= 1 early return.
                    t_out.fill(0.0);
                } else {
                    for (t, c) in t_out.iter_mut().zip(comms) {
                        *t = c.collective_parts(
                            CollectiveKind::ReduceScatter,
                            total,
                            min,
                            ranks,
                            LinkKind::InterNode,
                        );
                    }
                }
            } else {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::ReduceScatter,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            }
        }
        GradOrAg::Ag => {
            if dp <= 1 || ar {
                t_out.fill(0.0);
            } else if has_shards {
                if ranks <= 1 {
                    t_out.fill(0.0);
                } else {
                    for (t, c) in t_out.iter_mut().zip(comms) {
                        *t = c.collective_parts(
                            CollectiveKind::AllGather,
                            total,
                            min,
                            ranks,
                            LinkKind::InterNode,
                        );
                    }
                }
            } else {
                for (t, c) in t_out.iter_mut().zip(comms) {
                    *t = c.collective(
                        CollectiveKind::AllGather,
                        bucket_bytes,
                        dp,
                        LinkKind::InterNode,
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Schedule tape: the timeline arm of the batch tier (module docs).
// ---------------------------------------------------------------------

/// Sentinel for "no task" in the tape's `u32` task-index fields.
const NONE: u32 = u32::MAX;

/// Fixed per-stage duration-slot offsets (relative to the stage's
/// `slot_base`; bucket-indexed slots follow from [`SLOT_BUCKETS`]).
const SLOT_FWD: usize = 0;
/// Full backward compute time.
const SLOT_BWD: usize = 1;
/// Boundary-activation p2p transfer time.
const SLOT_ACT: usize = 2;
/// The per-stage TP All-Reduce tail block (`m * tp_ar`).
const SLOT_TP: usize = 3;
/// The stage's optimizer step time.
const SLOT_OPT: usize = 4;
/// First bucket-indexed slot: `ag[b]`, then `grad[b]`, then
/// `fwd_t * frac[b]`, then `bwd_t * frac[b]` (`nb` each).
const SLOT_BUCKETS: usize = 5;

/// One replayed task: its stream, its duration slot, and its (≤ 2,
/// already-resolved) dependency task indices. The emitter never passes
/// more than two dependencies to [`Timeline::task`], which is what lets
/// the tape store them inline.
#[derive(Clone, Copy, Debug)]
struct TapeTask {
    stream: u32,
    slot: u32,
    deps: [u32; 2],
    n_deps: u8,
}

/// The `ready0` sample point of one stage's first-micro-batch
/// All-Gather block: just before task `at_task` runs, sample
/// `free[compute(stage)].max(end[gate])` — the scalar emitter's
/// pre-block snapshot that anchors the `ag_stretch` readout.
#[derive(Clone, Copy, Debug)]
struct AgMarker {
    at_task: u32,
    stage: u32,
    gate: u32,
}

/// The lane-invariant structure of one timeline playback for a fixed
/// `(schedule, pp, micro_batches, has_ag, per-stage bucket counts)`
/// shape: every task in emission order plus the readout anchors. See
/// the module docs for why this is lane-invariant.
struct Tape {
    n_streams: usize,
    /// Total duration slots (`Σ_i 5 + 4·nb[i]`).
    n_slots: usize,
    /// Per-stage first slot index.
    slot_base: Vec<u32>,
    /// Per-stage bucket count.
    nb: Vec<u32>,
    /// Every task, in the exact scalar emission order (task index ==
    /// scalar [`TaskId`]).
    tasks: Vec<TapeTask>,
    /// `ready0` sample points, ascending by `at_task`.
    markers: Vec<AgMarker>,
    /// Per-stage last forward of the AG block ([`NONE`] if no block).
    ag_last: Vec<u32>,
    /// Per-stage last backward compute task ([`NONE`] if `nb == 0` and
    /// the stage somehow never ran a backward — never in practice).
    last_bwd: Vec<u32>,
    /// Per-stage last gradient-collective task ([`NONE`] off ZeRO).
    last_rs: Vec<u32>,
    /// Per-stage TP tail task.
    tp_task: Vec<u32>,
    /// Per-stage optimizer task.
    opt_task: Vec<u32>,
}

/// Push one task onto the tape *and* mirror it into the recording
/// timeline (zero duration — only the ids and the dependency resolution
/// matter), so [`drive_pipeline_flat`]'s completion-id tables stay
/// consistent with tape indices.
fn rec(tape: &mut Tape, tl: &mut Timeline, stream: StreamId, slot: usize, deps: &[TaskId]) -> TaskId {
    debug_assert!(deps.len() <= 2, "tape tasks carry at most two deps");
    let mut d = [NONE; 2];
    for (k, dep) in deps.iter().enumerate() {
        d[k] = dep.0;
    }
    tape.tasks.push(TapeTask {
        stream: stream.0,
        slot: slot as u32,
        deps: d,
        n_deps: deps.len() as u8,
    });
    let id = tl.task(stream, TaskKind::Forward, 0.0, deps);
    debug_assert_eq!(id.0 as usize + 1, tape.tasks.len(), "tape index == TaskId");
    id
}

impl Tape {
    /// Record one playback's structure by running the scalar emitter's
    /// exact branch structure (`simulate_timeline_scratch`'s closure —
    /// kept in lockstep by the batch differential oracle) over a
    /// throwaway zero-duration timeline. Pure function of the
    /// arguments; cold-path allocations only.
    fn record(sched: PipelineSchedule, pp: usize, m: usize, has_ag: bool, nbs: &[u32]) -> Tape {
        let mut slot_base = Vec::with_capacity(pp);
        let mut n_slots = 0u32;
        for &nb in nbs {
            slot_base.push(n_slots);
            n_slots += (SLOT_BUCKETS as u32) + 4 * nb;
        }
        let mut tape = Tape {
            n_streams: 5 * pp,
            n_slots: n_slots as usize,
            slot_base,
            nb: nbs.to_vec(),
            tasks: Vec::new(),
            markers: Vec::new(),
            ag_last: vec![NONE; pp],
            last_bwd: vec![NONE; pp],
            last_rs: vec![NONE; pp],
            tp_task: vec![NONE; pp],
            opt_task: vec![NONE; pp],
        };

        // Streams in the scalar creation order: compute / optimizer /
        // DP-collective / forward p2p / backward p2p, pp of each.
        let mut tl = Timeline::new();
        for _ in 0..5 * pp {
            tl.stream();
        }
        let compute = |i: usize| StreamId(i as u32);
        let opt_stream = |i: usize| StreamId((pp + i) as u32);
        let dpc = |i: usize| StreamId((2 * pp + i) as u32);
        let p2p_f = |i: usize| StreamId((3 * pp + i) as u32);
        let p2p_b = |i: usize| StreamId((4 * pp + i) as u32);

        // Stage-major slot table — the same construction OrderCache
        // interns for the scalar path.
        let mut slots = Vec::with_capacity(pp * 2 * m);
        for stage in 0..pp {
            slots.extend(schedule_order_iter(sched, pp, stage, m));
        }
        let mut pipe = PipeScratch::new();
        let mut dbuf: Vec<TaskId> = Vec::new();
        drive_pipeline_flat(&mut tl, &slots, pp, m, &mut pipe, |tl, i, slot, deps| {
            let nb = nbs[i] as usize;
            let sb = tape.slot_base[i] as usize;
            match slot {
                PipeSlot::Fwd(j) => {
                    let gate = (i > 0).then(|| {
                        let up = tape.slot_base[i - 1] as usize;
                        rec(&mut tape, tl, p2p_f(i - 1), up + SLOT_ACT, deps)
                    });
                    if j == 0 && has_ag && nb > 0 {
                        tape.markers.push(AgMarker {
                            at_task: tape.tasks.len() as u32,
                            stage: i as u32,
                            gate: gate.map(|g| g.0).unwrap_or(NONE),
                        });
                        let mut last = None;
                        for b in 0..nb {
                            let ag = rec(&mut tape, tl, dpc(i), sb + SLOT_BUCKETS + b, &[]);
                            dbuf.clear();
                            dbuf.push(ag);
                            if b == 0 {
                                if let Some(g) = gate {
                                    dbuf.push(g);
                                }
                            }
                            last = Some(rec(
                                &mut tape,
                                tl,
                                compute(i),
                                sb + SLOT_BUCKETS + 2 * nb + b,
                                dbuf.as_slice(),
                            ));
                        }
                        let last = last.expect("nb > 0");
                        tape.ag_last[i] = last.0;
                        last
                    } else {
                        dbuf.clear();
                        if let Some(g) = gate {
                            dbuf.push(g);
                        }
                        rec(&mut tape, tl, compute(i), sb + SLOT_FWD, dbuf.as_slice())
                    }
                }
                PipeSlot::Bwd(j) => {
                    let gate = (i + 1 < pp)
                        .then(|| rec(&mut tape, tl, p2p_b(i + 1), sb + SLOT_ACT, &[deps[1]]));
                    if j == m - 1 && nb > 0 {
                        let mut last_c = None;
                        for b in 0..nb {
                            dbuf.clear();
                            if b == 0 {
                                dbuf.push(deps[0]);
                                if let Some(g) = gate {
                                    dbuf.push(g);
                                }
                            }
                            let c = rec(
                                &mut tape,
                                tl,
                                compute(i),
                                sb + SLOT_BUCKETS + 3 * nb + b,
                                dbuf.as_slice(),
                            );
                            let r = rec(&mut tape, tl, dpc(i), sb + SLOT_BUCKETS + nb + b, &[c]);
                            last_c = Some(c);
                            tape.last_rs[i] = r.0;
                        }
                        let last_c = last_c.expect("nb > 0");
                        tape.last_bwd[i] = last_c.0;
                        last_c
                    } else {
                        dbuf.clear();
                        dbuf.push(deps[0]);
                        if let Some(g) = gate {
                            dbuf.push(g);
                        }
                        let c = rec(&mut tape, tl, compute(i), sb + SLOT_BWD, dbuf.as_slice());
                        if j == m - 1 {
                            tape.last_bwd[i] = c.0;
                        }
                        c
                    }
                }
            }
        });

        // Per-stage tail: the TP All-Reduce block, then the optimizer.
        for i in 0..pp {
            let sb = tape.slot_base[i] as usize;
            dbuf.clear();
            if tape.last_bwd[i] != NONE {
                dbuf.push(TaskId(tape.last_bwd[i]));
            }
            if tape.last_rs[i] != NONE {
                dbuf.push(TaskId(tape.last_rs[i]));
            }
            let tp = rec(&mut tape, &mut tl, compute(i), sb + SLOT_TP, dbuf.as_slice());
            tape.tp_task[i] = tp.0;
            let opt = rec(&mut tape, &mut tl, opt_stream(i), sb + SLOT_OPT, &[tp]);
            tape.opt_task[i] = opt.0;
        }
        tape
    }
}

/// Interned tapes, keyed by `(schedule, pp, m, has_ag, per-stage bucket
/// counts)`. Like [`super::timeline::OrderCache`] this is a linear scan
/// over the handful of shapes a sweep visits, never allocates on a hit,
/// and needs no invalidation: a tape is a pure function of its key (the
/// bucket counts stand in for the census shape, and everything else the
/// durations depend on is per-lane by construction).
#[derive(Default)]
pub(crate) struct TapeCache {
    entries: Vec<TapeEntry>,
}

struct TapeEntry {
    sched: PipelineSchedule,
    pp: usize,
    m: usize,
    has_ag: bool,
    nbs: Vec<u32>,
    tape: Tape,
}

impl TapeCache {
    /// The tape for the shape, recording it on first sighting.
    fn get(
        &mut self,
        sched: PipelineSchedule,
        pp: usize,
        m: usize,
        has_ag: bool,
        nbs: &[u32],
    ) -> &Tape {
        if let Some(i) = self.entries.iter().position(|e| {
            e.sched == sched && e.pp == pp && e.m == m && e.has_ag == has_ag && e.nbs == nbs
        }) {
            return &self.entries[i].tape;
        }
        let tape = Tape::record(sched, pp, m, has_ag, nbs);
        self.entries.push(TapeEntry { sched, pp, m, has_ag, nbs: nbs.to_vec(), tape });
        &self.entries.last().expect("just pushed").tape
    }
}

/// The timeline arm's per-worker workspace: the interned tapes plus
/// every SoA column of the chunked replay. Capacity is retained across
/// batches, bounded by the largest `(pp, tasks, slots)` shape the
/// thread has seen; Arc'd refs are dropped at the end of every batch so
/// the scratch never pins evicted cache entries.
pub(crate) struct TimelineScratch {
    tapes: TapeCache,
    /// Per-stage cached tables (cleared after each batch).
    tables: Vec<Arc<StageTable>>,
    /// Per-stage bucket counts (the tape-key suffix).
    nbs: Vec<u32>,
    /// Per-stage gradient wire bytes (hardware-free ⇒ lane-invariant).
    grad_bytes: Vec<f64>,
    /// Per-stage AdamW-reference element counts (lane-invariant).
    adamw_elems: Vec<f64>,
    /// Duration columns: `slot * BATCH_CHUNK + lane`.
    durs: Vec<f64>,
    /// Completion columns: `task * BATCH_CHUNK + lane`.
    ends: Vec<f64>,
    /// Stream-free columns: `stream * BATCH_CHUNK + lane`.
    free: Vec<f64>,
    /// Compute-stream busy columns: `stage * BATCH_CHUNK + lane`.
    busy: Vec<f64>,
    /// AG-block `ready0` samples: `stage * BATCH_CHUNK + lane`.
    ready0: Vec<f64>,
    /// Per-(stage, lane) micro-group counts (pacing-stage readout).
    groups: Vec<usize>,
    /// Per-(stage, lane) worst-rank TP plans (cleared after each batch).
    tplans: Vec<Option<Arc<TpPlan>>>,
}

impl TimelineScratch {
    fn new() -> TimelineScratch {
        TimelineScratch {
            tapes: TapeCache::default(),
            tables: Vec::new(),
            nbs: Vec::new(),
            grad_bytes: Vec::new(),
            adamw_elems: Vec::new(),
            durs: Vec::new(),
            ends: Vec::new(),
            free: Vec::new(),
            busy: Vec::new(),
            ready0: Vec::new(),
            groups: Vec::new(),
            tplans: Vec::new(),
        }
    }
}

/// The timeline-arm evaluator: fill per-lane duration columns with the
/// scalar path's own formulas, replay the tape's `free`/`ends` algebra
/// over [`BATCH_CHUNK`]-lane chunks, then read each lane's
/// [`Breakdown`] off the columns exactly as the scalar readout does.
fn timeline_core_split(
    batch: &ScenarioBatch,
    cache: &PlanCache,
    tls: &mut TimelineScratch,
    out: &mut BreakdownBatch,
) {
    let TimelineScratch {
        tapes,
        tables,
        nbs,
        grad_bytes,
        adamw_elems,
        durs,
        ends,
        free,
        busy,
        ready0,
        groups,
        tplans,
    } = tls;
    let s = batch.base();
    let n = batch.len();
    out.reset(n);
    if n == 0 {
        return;
    }
    let pp = s.pp.max(1);
    let m = s.micro_batches.max(1);
    const C: usize = BATCH_CHUNK;

    // --- lane-invariant hoists: per-stage tables + census scalars ----
    // Canonical-equal stages share one fetch, as on the scalar path;
    // gradient wire volume and the AdamW element count are
    // hardware-free, so one lane's answer is every lane's answer.
    let t_fetch = Instant::now();
    let base_comm = CommModel::new(s.hw.clone());
    tables.clear();
    nbs.clear();
    grad_bytes.clear();
    adamw_elems.clear();
    for si in 0..pp {
        let canon = canonical_stage(s, si);
        if canon < si {
            let shared = tables[canon].clone();
            nbs.push(nbs[canon]);
            grad_bytes.push(grad_bytes[canon]);
            adamw_elems.push(adamw_elems[canon]);
            tables.push(shared);
            continue;
        }
        let key = StageKey::for_scenario(s, si);
        let table = cache.stage_table(&key, || StageTable::build(s, si, cache));
        nbs.push(table.bucket_bytes.len() as u32);
        grad_bytes.push(stage_grad_bytes(s, &base_comm, &table));
        adamw_elems.push(table.total_elems / s.dp as f64);
        tables.push(table);
    }
    let stage_planning_s = t_fetch.elapsed().as_secs_f64();
    let has_ag = s.dp > 1 && !uses_all_reduce(s);

    let tape = tapes.get(s.schedule, pp, m, has_ag, nbs);
    let n_tasks = tape.tasks.len();

    groups.clear();
    groups.resize(pp * C, 0);
    tplans.clear();
    tplans.resize(pp * C, None);

    let mut c0 = 0usize;
    while c0 < n {
        let mch = (n - c0).min(C);

        // --- per-lane duration fill ----------------------------------
        // Each lane runs the scalar emitter's own duration formulas —
        // same functions, same arguments, same order — over its knob
        // hardware; canonical-equal stages copy the canonical block,
        // mirroring the scalar StagePlayback clone.
        durs.clear();
        durs.resize(tape.n_slots * C, 0.0);
        for l in 0..mch {
            let knobs = &batch.lanes()[c0 + l];
            let lane_hw = knobs.base_hardware(&s.hw);
            let comm = CommModel::new(lane_hw.clone());
            let mut planning = stage_planning_s;
            for si in 0..pp {
                let sb = tape.slot_base[si] as usize;
                let nb = tape.nb[si] as usize;
                let canon = canonical_stage(s, si);
                if canon < si {
                    let cb = tape.slot_base[canon] as usize;
                    for k in 0..SLOT_BUCKETS + 4 * nb {
                        durs[(sb + k) * C + l] = durs[(cb + k) * C + l];
                    }
                    groups[si * C + l] = groups[canon * C + l];
                    tplans[si * C + l] = tplans[canon * C + l].clone();
                    continue;
                }
                let table = &tables[si];
                // The lane straggler derates the *last* stage's
                // compute/HBM; the fabric stays un-derated.
                let stage_hw =
                    if si == pp - 1 { lane_hw.derate(knobs.straggler) } else { lane_hw.clone() };
                let (fwd_t, bwd_t, tp_ar, act_bytes) = stage_times(s, &stage_hw, &comm, table);
                let act_p2p =
                    if pp > 1 { comm.p2p(act_bytes, LinkKind::InterNode) } else { 0.0 };
                let opt =
                    optimizer_step_knobs(s, &stage_hw, &comm, table, si, cache, knobs.c_max_bytes);
                planning += opt.planning_s;
                durs[(sb + SLOT_FWD) * C + l] = fwd_t;
                durs[(sb + SLOT_BWD) * C + l] = bwd_t;
                durs[(sb + SLOT_ACT) * C + l] = act_p2p;
                durs[(sb + SLOT_TP) * C + l] = m as f64 * tp_ar;
                durs[(sb + SLOT_OPT) * C + l] = opt.time_s;
                for b in 0..nb {
                    durs[(sb + SLOT_BUCKETS + b) * C + l] = bucket_ag_time(s, &comm, table, b);
                    durs[(sb + SLOT_BUCKETS + nb + b) * C + l] =
                        bucket_grad_time(s, &comm, table, b);
                    durs[(sb + SLOT_BUCKETS + 2 * nb + b) * C + l] =
                        fwd_t * table.bucket_frac[b];
                    durs[(sb + SLOT_BUCKETS + 3 * nb + b) * C + l] =
                        bwd_t * table.bucket_frac[b];
                }
                groups[si * C + l] = opt.n_micro_groups;
                tplans[si * C + l] = opt.worst_tplan;
            }
            out.planning_s[c0 + l] = planning;
        }

        // --- chunked tape replay -------------------------------------
        // Per task, per lane: the exact Timeline::task algebra —
        // `ready = free[stream].max(ends[dep]…); end = ready + dur` —
        // with busy tracked for the compute streams the readout uses.
        ends.clear();
        ends.resize(n_tasks * C, 0.0);
        free.clear();
        free.resize(tape.n_streams * C, 0.0);
        busy.clear();
        busy.resize(pp * C, 0.0);
        ready0.clear();
        ready0.resize(pp * C, 0.0);
        let mut mk = 0usize;
        for (ti, t) in tape.tasks.iter().enumerate() {
            while mk < tape.markers.len() && tape.markers[mk].at_task == ti as u32 {
                // Sample ready0 before the AG block's first task, as
                // the scalar emitter does (compute stream == stage id).
                let mark = &tape.markers[mk];
                let st = mark.stage as usize;
                for l in 0..mch {
                    let gate_end =
                        if mark.gate != NONE { ends[mark.gate as usize * C + l] } else { 0.0 };
                    ready0[st * C + l] = free[st * C + l].max(gate_end);
                }
                mk += 1;
            }
            let fs = t.stream as usize * C;
            let ds = t.slot as usize * C;
            let es = ti * C;
            match t.n_deps {
                0 => {
                    for l in 0..mch {
                        let end = free[fs + l] + durs[ds + l];
                        free[fs + l] = end;
                        ends[es + l] = end;
                    }
                }
                1 => {
                    let d0 = t.deps[0] as usize * C;
                    for l in 0..mch {
                        let ready = free[fs + l].max(ends[d0 + l]);
                        let end = ready + durs[ds + l];
                        free[fs + l] = end;
                        ends[es + l] = end;
                    }
                }
                _ => {
                    let d0 = t.deps[0] as usize * C;
                    let d1 = t.deps[1] as usize * C;
                    for l in 0..mch {
                        let ready = free[fs + l].max(ends[d0 + l]).max(ends[d1 + l]);
                        let end = ready + durs[ds + l];
                        free[fs + l] = end;
                        ends[es + l] = end;
                    }
                }
            }
            if (t.stream as usize) < pp {
                for l in 0..mch {
                    busy[fs + l] += durs[ds + l];
                }
            }
        }

        // --- per-lane readout (the scalar readout, columnized) -------
        for l in 0..mch {
            let i = c0 + l;
            let knobs = &batch.lanes()[i];
            let mut pacing = 0usize;
            for st in 1..pp {
                if ends[tape.opt_task[st] as usize * C + l]
                    > ends[tape.opt_task[pacing] as usize * C + l]
                {
                    pacing = st;
                }
            }
            let mut fwd_bwd_end = 0.0f64;
            for st in 0..pp {
                fwd_bwd_end = fwd_bwd_end.max(ends[tape.tp_task[st] as usize * C + l]);
            }
            out.fwd_bwd_s[i] = fwd_bwd_end;
            out.total_s[i] = ends[tape.opt_task[pacing] as usize * C + l].max(fwd_bwd_end);
            out.optimizer_s[i] = out.total_s[i] - out.fwd_bwd_s[i];
            let rs_tail = if tape.last_rs[pacing] != NONE && tape.last_bwd[pacing] != NONE {
                (ends[tape.last_rs[pacing] as usize * C + l]
                    - ends[tape.last_bwd[pacing] as usize * C + l])
                    .max(0.0)
            } else {
                0.0
            };
            let ag_stretch = if tape.ag_last[pacing] != NONE {
                let full_fwd = durs[(tape.slot_base[pacing] as usize + SLOT_FWD) * C + l];
                (ends[tape.ag_last[pacing] as usize * C + l] - ready0[pacing * C + l] - full_fwd)
                    .max(0.0)
            } else {
                0.0
            };
            out.exposed_comm_s[i] = ag_stretch + rs_tail;
            let mut max_busy = 0.0f64;
            for st in 0..pp {
                max_busy = max_busy.max(busy[st * C + l]);
            }
            out.bubble_s[i] = (out.fwd_bwd_s[i] - max_busy).max(0.0);
            out.n_micro_groups[i] = groups[pacing * C + l];
            out.grad_comm_bytes[i] = grad_bytes[pacing];
            // The pacing stage's hardware, rebuilt as the scalar path
            // built it (pure function ⇒ bit-identical).
            let pacing_hw = if pacing == pp - 1 {
                knobs.base_hardware(&s.hw).derate(knobs.straggler)
            } else {
                knobs.base_hardware(&s.hw)
            };
            out.adamw_ref_s[i] = pacing_hw.memory_time(adamw_elems[pacing] * ADAMW_BYTES_PER_ELEM);
            out.worst_tplans[i] = tplans[pacing * C + l].clone();
            out.lane_tables[i] = Some(tables[pacing].clone());
        }
        c0 += mch;
    }

    // Release the scratch's Arc pins now (out keeps its own refs until
    // the caller clears it), so the scratch never outlives evictions.
    tables.clear();
    for t in tplans.iter_mut() {
        *t = None;
    }
    cache.note_timeline_tasks((n_tasks * n) as u64);
    cache.note_batched_timeline_evals(n as u64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;
    use crate::sim::simulate_iteration_cached;

    fn base() -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    #[test]
    fn accepts_both_arms_and_dispatches_timeline_lanes_bit_exact() {
        // Non-closed-form bases are first-class since the schedule tape
        // landed: pp > 1, micro-batched, and straggler bases all build,
        // and the dispatching entry routes them through the timeline
        // replay with scalar-identical bits (the module-level smoke of
        // tests/batch_differential.rs's timeline oracle).
        for s in [
            Scenario::new(Qwen3Size::S1_7B, 8, 4, 2, OptimKind::Muon, DpStrategy::LbAsc)
                .with_micro_batches(4),
            base().with_micro_batches(4),
            base().with_straggler(1.5),
        ] {
            let cache = PlanCache::new();
            let scalar = simulate_iteration_cached(&s, &cache);
            let mut batch = ScenarioBatch::new(s.clone()).unwrap();
            batch.push_scenario(&s).unwrap();
            let mut out = BreakdownBatch::new();
            simulate_batch_into(&batch, &cache, &mut out);
            let mut got = Breakdown::default();
            out.write_into(&batch, 0, &mut got);
            assert_eq!(got.total_s.to_bits(), scalar.total_s.to_bits(), "{s:?}");
            assert_eq!(got.fwd_bwd_s.to_bits(), scalar.fwd_bwd_s.to_bits(), "{s:?}");
            assert_eq!(got.bubble_s.to_bits(), scalar.bubble_s.to_bits(), "{s:?}");
            assert_eq!(
                got.exposed_comm_s.to_bits(),
                scalar.exposed_comm_s.to_bits(),
                "{s:?}"
            );
            assert_eq!(cache.stats().batched_timeline_evals, 1, "{s:?}");
            assert_eq!(cache.stats().batched_evals, 0, "{s:?}");
        }
    }

    #[test]
    fn rejects_poisoned_lanes() {
        let mut b = ScenarioBatch::new(base()).unwrap();
        let mut k = LaneKnobs::from_scenario(&base());
        k.ib_bw = 0.0;
        let e = b.push(k).expect_err("zero bandwidth").to_string();
        assert!(e.contains("invalid scenario"), "{e}");
        let mut k = LaneKnobs::from_scenario(&base());
        k.straggler = 0.5;
        assert!(b.push(k).is_err());
        let mut k = LaneKnobs::from_scenario(&base());
        k.c_max_bytes = Some(-1.0);
        assert!(b.push(k).is_err());
        assert!(b.is_empty());
        assert!(b.push(LaneKnobs::from_scenario(&base())).is_ok());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn single_lane_matches_scalar_bits() {
        // The module-level smoke version of tests/batch_differential.rs:
        // one default lane == the scalar closed form, every field.
        let cache = PlanCache::new();
        let s = base();
        let scalar = simulate_iteration_cached(&s, &cache);
        let mut batch = ScenarioBatch::new(s.clone()).unwrap();
        batch.push_scenario(&s).unwrap();
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(out.len(), 1);
        let mut got = Breakdown::default();
        out.write_into(&batch, 0, &mut got);
        assert_eq!(got.fwd_bwd_s.to_bits(), scalar.fwd_bwd_s.to_bits());
        assert_eq!(got.optimizer_s.to_bits(), scalar.optimizer_s.to_bits());
        assert_eq!(got.total_s.to_bits(), scalar.total_s.to_bits());
        assert_eq!(got.adamw_ref_s.to_bits(), scalar.adamw_ref_s.to_bits());
        assert_eq!(got.exposed_comm_s.to_bits(), scalar.exposed_comm_s.to_bits());
        assert_eq!(got.bubble_s.to_bits(), scalar.bubble_s.to_bits());
        assert_eq!(got.grad_comm_bytes.to_bits(), scalar.grad_comm_bytes.to_bits());
        assert_eq!(got.n_micro_groups, scalar.n_micro_groups);
        assert_eq!(got.dp_loads_flops, scalar.dp_loads_flops);
        assert_eq!(got.dp_loads_state, scalar.dp_loads_state);
        assert_eq!(got.tp_loads_flops, scalar.tp_loads_flops);
        assert_eq!(got.tp_loads_state, scalar.tp_loads_state);
    }

    #[test]
    fn batched_evals_counter_rides_the_cache() {
        let cache = PlanCache::new();
        let s = base();
        let mut batch = ScenarioBatch::new(s.clone()).unwrap();
        for _ in 0..5 {
            batch.push_scenario(&s).unwrap();
        }
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_evals, 5);
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_evals, 10);
        // The closed-form arm never rides the timeline counter.
        assert_eq!(cache.stats().batched_timeline_evals, 0);
    }

    #[test]
    fn batched_timeline_evals_counter_rides_the_cache() {
        let cache = PlanCache::new();
        let s = base().with_micro_batches(4).with_straggler(1.3);
        let mut batch = ScenarioBatch::new(s.clone()).unwrap();
        for _ in 0..3 {
            batch.push_scenario(&s).unwrap();
        }
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_timeline_evals, 3);
        assert_eq!(cache.stats().batched_evals, 0);
        // The explicit-arm entry reports through the same counter.
        simulate_timeline_batch_into(&batch, &cache, &mut out);
        assert_eq!(cache.stats().batched_timeline_evals, 6);
        // And the replay contributes to the timeline task census.
        assert!(cache.stats().timeline_tasks > 0);
    }
}
