//! Admissible lower bounds on [`Breakdown`] objectives, for the
//! branch-and-bound optimizer search (`canzona optimize`).
//!
//! Each bound is a cheap closed-form expression over per-stage census
//! aggregates that provably never exceeds the value the full simulator
//! ([`simulate_iteration_into`]) produces for the same scenario — so a
//! best-first search that prunes on them returns the exact grid argmin.
//! Derivations (all against `iteration.rs`'s arithmetic, both arms):
//!
//! * **Iteration time.** Every stage's compute stream serially executes
//!   `micro_batches` forward (`fwd_t`) + backward (`bwd_t = 2 fwd_t`)
//!   blocks, with per-micro-batch work priced from the *full*
//!   [`Scenario::tokens`] (micro-batches multiply total work in this
//!   model). The makespan is at least any one stage's busy time, hence
//!   at least the stage *average*: `mb * 3 * Σ_stages fwd / pp /
//!   gpu_flops`, where `Σ_stages fwd = 2*T*Σ matrix_numel +
//!   2*T*S*Σ(n_layers_st * hidden_st) / tp` — exactly `stage_times`'s
//!   terms summed over the stage split (which partitions the census).
//!   The straggler factor only *derates* a stage's throughput, so
//!   pricing at the undegraded `gpu_flops` stays below. On the
//!   closed-form arm (`fwd_bwd = bwd_end + fwd_end + tp_ar ≥ 3 fwd_t`)
//!   the same expression applies with `mb = pp = 1`. The optimizer
//!   bound below adds on for both arms: closed form by `total =
//!   fwd_bwd + optimizer`, the timeline by `total ≥ fwd_bwd + min_i
//!   opt_i` (derived under **Optimizer latency**) with `fwd_bwd ≥`
//!   every stage's compute-busy sum `≥` the stage average.
//! * **Optimizer latency.** Claimed on *both* arms since PR 9. With
//!   `F =` matrix-update FLOPs: SC updates everything redundantly
//!   (`≥ F/gpu`); NV-layerwise partitions `F` over DP ranks and takes
//!   the max (`≥ F/(dp*gpu)`); ASC/LB-ASC additionally spread each DP
//!   rank's tasks over TP hosts, and the TP pipeline's compute stream
//!   serially runs every group's `max_rank_flops ≥ group_flops/tp`
//!   (`≥ F/(dp*tp*gpu)`). Fragmented tensors only ever *repeat* on
//!   ranks, so per-rank sums are ≥ an exact partition's.
//!   The rivals: MatrixFSDP's per-rank work is the full redundant
//!   preconditioner sum plus its row shard's linear pass, and rank 0
//!   always owns the (joint-)largest shard, so with `F_loc` the
//!   TP-local-shape matrix FLOPs, `M_loc` the TP-local matrix numel and
//!   `c` the optimizer's linear FLOPs coefficient,
//!   `max_rank ≥ (F_loc - c·M_loc)/gpu + c·M_loc/(dp·gpu)`. DMuon's LPT
//!   partitions the full-shape FLOPs exactly and its pipeline's compute
//!   stream runs the owned items serially (`≥ F/(dp·gpu)`). Dion's
//!   sketch pass streams `6·m·n·r/dp` FLOPs with `r ≥ 1`
//!   (`≥ 6·M_loc/(dp·gpu)`).
//!   On the closed-form arm `F` is the full census and `total = fwd_bwd
//!   + optimizer` pays the whole step. On the timeline arm each stage
//!   `i` runs one `Optimizer` task on its otherwise-empty opt stream,
//!   starting at its `TpComm` end: `opt_end_i = tp_end_i + opt_i`. The
//!   readout takes `fwd_bwd = max_i tp_end_i` and `total =
//!   max(max_i opt_end_i, fwd_bwd)`, so with `i* = argmax tp_end`,
//!   `optimizer = total - fwd_bwd ≥ opt_{i*} ≥ min_i opt_i` — the
//!   schedule can hide every stage's step *except the last to finish*,
//!   never all of them. Hence the bound: the **min over stages** of the
//!   per-stage strategy floor above (the stage census partitions the
//!   full census; pricing at the undegraded `gpu_flops` under-counts
//!   the straggler stage, which only loosens downward). At `pp = 1` the
//!   single stage *is* the census, so both arms evaluate the identical
//!   expression — bit-for-bit the pre-PR-9 closed-form bound.
//! * **Optimizer-state memory** (`max` of `dp_loads_state`). The loads
//!   come from the pacing stage, unknown before simulating, so the
//!   bound takes the *min over stages*. Per stage, every matrix
//!   parameter's `state_bytes(full_shape)` and `8` bytes per
//!   element-wise element land on some DP rank (SC replicates the full
//!   amount on every rank; `rank_state`/`dp_state` partition it), so
//!   the per-stage max is at least `state/1` (SC) or `(state + 8*ew)/dp`
//!   (all others). MatrixFSDP shards *TP-local* state row-wise (per-rank
//!   bytes sum exactly to the local census), so its floor is
//!   `(state_local + 8*ew)/dp`; Dion holds at least the DP-sharded bf16
//!   error-feedback buffer, `(2*matrix_numel_local + 8*ew)/dp`.
//!
//! * **Faults and heterogeneity** (PR 10) need no new terms. Every
//!   bound prices the *undegraded* `gpu_flops` — per-rank hetero
//!   derates ([`HeteroSpec`]) only slow stages down, exactly like the
//!   straggler factor the derivations already cover. Elastic events
//!   charge `Breakdown::recovery_s ≥ 0` *into* `total_s` and touch
//!   nothing else, so every fault-free bound stays admissible on
//!   faulted scenarios unchanged; the bound/value gap just widens by
//!   the recovery cost. The dispatch rule stays shared with the
//!   simulator via `closed_form_path`, so the arm agreement argument
//!   is untouched.
//!
//! Tightness is *not* required — only admissibility. The differential
//! suite (`tests/optimize_differential.rs`) checks both: winners are
//! bit-identical to the exhaustive argmin, and the bounds prune.
//!
//! [`HeteroSpec`]: crate::sim::HeteroSpec
//!
//! [`Breakdown`]: crate::sim::Breakdown
//! [`simulate_iteration_into`]: crate::sim::simulate_iteration_into

use std::collections::HashMap;

use crate::cost::optim::{linear_flops_coeff, OptimCost, OptimKind};
use crate::model::qwen3::Qwen3Size;
use crate::partition::DpStrategy;
use crate::sim::iteration::{local_view, stage_census, stage_layer_count};
use crate::sim::scenario::Scenario;

/// Census aggregates shared by every scenario with the same
/// `(model, tp, pp, optimizer)` — the axes the bounds actually read.
/// One build covers the whole `dp × strategy × α × C_max × schedule ×
/// straggler × micro-batch` sub-grid.
struct BoundAgg {
    /// `Σ_stages n_layers_stage * hidden_stage` (attention-FLOPs term).
    nl_hidden: f64,
    /// `Σ_stages` TP-local matrix numels (dense-FLOPs term).
    matrix_numel: f64,
    /// Per stage: matrix-optimizer FLOPs at full shapes. Stage sums
    /// partition the census — at `pp = 1`, entry 0 *is* the full-census
    /// total (identical accumulation order).
    stage_flops: Vec<f64>,
    /// Per stage: matrix-optimizer FLOPs at TP-*local* shapes
    /// (MatrixFSDP works on the local shards directly; no TP
    /// reconstruction).
    stage_flops_local: Vec<f64>,
    /// Per stage: matrix optimizer state bytes at full shapes.
    stage_state: Vec<f64>,
    /// Per stage: matrix optimizer state bytes at TP-local shapes.
    stage_state_local: Vec<f64>,
    /// Per stage: matrix-optimizer elements at TP-local shapes.
    stage_matrix_opt_local: Vec<f64>,
    /// Per stage: element-wise (AdamW-routed) elements.
    stage_ew: Vec<f64>,
}

impl BoundAgg {
    /// Aggregate the scenario's stage split with the same helpers the
    /// simulator's `StageTable::build` uses, so the terms can't drift.
    fn build(s: &Scenario) -> BoundAgg {
        let optim = OptimCost::new(s.optim);
        let stages = stage_census(&s.census, s.pp);
        let mut agg = BoundAgg {
            nl_hidden: 0.0,
            matrix_numel: 0.0,
            stage_flops: Vec::with_capacity(stages.len()),
            stage_flops_local: Vec::with_capacity(stages.len()),
            stage_state: Vec::with_capacity(stages.len()),
            stage_state_local: Vec::with_capacity(stages.len()),
            stage_matrix_opt_local: Vec::with_capacity(stages.len()),
            stage_ew: Vec::with_capacity(stages.len()),
        };
        for (si, stage) in stages.iter().enumerate() {
            let locals = local_view(stage, s.tp);
            let n_layers = stage_layer_count(s.n_layers, s.pp, si) as f64;
            let hidden = locals
                .iter()
                .find(|p| p.local.name.ends_with("attn_norm.weight"))
                .map(|p| p.local.numel() as f64)
                .unwrap_or(0.0);
            agg.nl_hidden += n_layers * hidden;
            let mut flops = 0.0;
            let mut flops_local = 0.0;
            let mut state = 0.0;
            let mut state_local = 0.0;
            let mut matrix_opt_local = 0.0;
            let mut ew = 0.0;
            for lp in &locals {
                if lp.local.shape.is_matrix() {
                    agg.matrix_numel += lp.local.numel() as f64;
                }
                if lp.local.is_matrix_opt() {
                    flops += optim.flops(&lp.full_shape);
                    flops_local += optim.flops(&lp.local.shape);
                    matrix_opt_local += lp.local.numel() as f64;
                    state += optim.state_bytes(&lp.full_shape);
                    state_local += optim.state_bytes(&lp.local.shape);
                } else {
                    ew += lp.local.numel() as f64;
                }
            }
            agg.stage_flops.push(flops);
            agg.stage_flops_local.push(flops_local);
            agg.stage_state.push(state);
            agg.stage_state_local.push(state_local);
            agg.stage_matrix_opt_local.push(matrix_opt_local);
            agg.stage_ew.push(ew);
        }
        agg
    }
}

/// Memoized lower-bound evaluator. One instance serves a whole search;
/// aggregates are built once per `(model, tp, pp, optimizer)` key and
/// each bound query is then a handful of float ops.
pub struct ScenarioBounds {
    memo: HashMap<(Qwen3Size, usize, usize, OptimKind), BoundAgg>,
}

impl Default for ScenarioBounds {
    fn default() -> ScenarioBounds {
        ScenarioBounds::new()
    }
}

impl ScenarioBounds {
    /// Empty memo; aggregates build lazily on first query.
    pub fn new() -> ScenarioBounds {
        ScenarioBounds { memo: HashMap::new() }
    }

    fn agg(&mut self, s: &Scenario) -> &BoundAgg {
        self.memo
            .entry((s.size, s.tp, s.pp, s.optim))
            .or_insert_with(|| BoundAgg::build(s))
    }

    /// Lower bound on `Breakdown::total_s`.
    pub fn iter_time(&mut self, s: &Scenario) -> f64 {
        let opt_lb = self.optimizer_latency(s);
        let tokens = s.tokens() as f64;
        let seq = s.seq_len as f64;
        let a = self.agg(s);
        let fwd_total =
            2.0 * tokens * a.matrix_numel + 2.0 * tokens * seq * a.nl_hidden / s.tp as f64;
        let mb = s.micro_batches.max(1) as f64;
        mb * 3.0 * fwd_total / (s.pp.max(1) as f64 * s.hw.gpu_flops) + opt_lb
    }

    /// Lower bound on `Breakdown::optimizer_s`, both arms: the min over
    /// stages of the per-stage strategy floor (see the module docs —
    /// the schedule can hide every stage's step except the last to
    /// finish). At `pp = 1` the min is over the single full-census
    /// stage, reproducing the closed-form bound bit-for-bit.
    pub fn optimizer_latency(&mut self, s: &Scenario) -> f64 {
        let gpu = s.hw.gpu_flops;
        let (dp, tp) = (s.dp as f64, s.tp as f64);
        let strategy = s.strategy;
        let optim = s.optim;
        let a = self.agg(s);
        (0..a.stage_flops.len())
            .map(|i| {
                let f = a.stage_flops[i];
                match strategy {
                    DpStrategy::Sc => f / gpu,
                    DpStrategy::NvLayerwise => f / (dp * gpu),
                    DpStrategy::Asc | DpStrategy::LbAsc => f / (dp * tp * gpu),
                    // Redundant preconditioners (paid in full by rank 0,
                    // which always owns the largest row shard) + its ≥
                    // average linear pass. `flops_local - c·M_loc ≥ 0`
                    // for every model: each FLOPs expression contains
                    // exactly the `c·m·n` linear term.
                    DpStrategy::MatrixFsdp => {
                        let c = linear_flops_coeff(optim);
                        let m_loc = a.stage_matrix_opt_local[i];
                        (a.stage_flops_local[i] - c * m_loc) / gpu
                            + c * m_loc / (dp * gpu)
                    }
                    // LPT partitions the full-shape FLOPs exactly across
                    // DP, and the owner's compute stream runs its items
                    // serially.
                    DpStrategy::DMuon => f / (dp * gpu),
                    // The sketch pass streams ≥ 6·m·n·1/dp FLOPs per
                    // matrix (r ≥ 1); factor-side work and the
                    // All-Reduce only add.
                    DpStrategy::Dion => {
                        6.0 * a.stage_matrix_opt_local[i] / (dp * gpu)
                    }
                }
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Lower bound on `max(Breakdown::dp_loads_state)` (the pacing
    /// stage's per-DP-rank optimizer state).
    pub fn memory(&mut self, s: &Scenario) -> f64 {
        let dp = s.dp as f64;
        let strategy = s.strategy;
        let a = self.agg(s);
        (0..a.stage_state.len())
            .map(|i| {
                let ew = a.stage_ew[i];
                match strategy {
                    DpStrategy::Sc => a.stage_state[i],
                    // Row-prorated TP-local state sums exactly to the
                    // local census, so the max is ≥ the average.
                    DpStrategy::MatrixFsdp => {
                        (a.stage_state_local[i] + 8.0 * ew) / dp
                    }
                    // At least the DP-sharded bf16 error-feedback
                    // buffer; the replicated factors only add.
                    DpStrategy::Dion => {
                        (2.0 * a.stage_matrix_opt_local[i] + 8.0 * ew) / dp
                    }
                    _ => (a.stage_state[i] + 8.0 * ew) / dp,
                }
            })
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_iteration_cached, Scenario};
    use crate::sweep::PlanCache;

    fn scenarios() -> Vec<Scenario> {
        use crate::model::qwen3::Qwen3Size::S1_7B;
        let mut out = Vec::new();
        for strategy in DpStrategy::ALL {
            for optim in [OptimKind::Muon, OptimKind::Shampoo, OptimKind::AdamW] {
                out.push(Scenario::new(S1_7B, 4, 2, 1, optim, strategy));
                out.push(
                    Scenario::new(S1_7B, 2, 2, 2, optim, strategy).with_micro_batches(4),
                );
                // Faulted/heterogeneous scenarios: derates and recovery
                // charges only ever add time, so the fault-free bounds
                // must stay below.
                out.push(
                    Scenario::new(S1_7B, 4, 2, 1, optim, strategy)
                        .with_hetero(
                            crate::sim::HeteroSpec::parse("slow:0.5:2+link:0.5:8").unwrap(),
                        )
                        .with_fault_seed(7)
                        .with_mttf(Some(600.0))
                        .with_ckpt_interval(8),
                );
                out.push(
                    Scenario::new(S1_7B, 2, 2, 2, optim, strategy)
                        .with_micro_batches(4)
                        .with_hetero(crate::sim::HeteroSpec::parse("last:1.5").unwrap())
                        .with_fail_rank(Some(crate::sim::FailSpec { rank: 0, at: 0.5 })),
                );
            }
        }
        out
    }

    #[test]
    fn bounds_are_admissible() {
        // The contract everything else rests on: bound <= simulated
        // value, for every objective, on both dispatch arms.
        let cache = PlanCache::new();
        let mut bounds = ScenarioBounds::new();
        for s in scenarios() {
            let b = simulate_iteration_cached(&s, &cache);
            let t_lb = bounds.iter_time(&s);
            assert!(
                t_lb <= b.total_s + 1e-12,
                "{}: time bound {t_lb} > total {}",
                s.label,
                b.total_s
            );
            let o_lb = bounds.optimizer_latency(&s);
            assert!(
                o_lb <= b.optimizer_s + 1e-12,
                "{}: optimizer bound {o_lb} > {}",
                s.label,
                b.optimizer_s
            );
            let m_lb = bounds.memory(&s);
            let m = b.dp_loads_state.iter().cloned().fold(0.0, f64::max);
            assert!(m_lb <= m + 1e-6, "{}: memory bound {m_lb} > max state {m}", s.label);
        }
    }

    #[test]
    fn bounds_are_positive_and_memoized() {
        let mut bounds = ScenarioBounds::new();
        let s = Scenario::paper_default();
        let t1 = bounds.iter_time(&s);
        assert!(t1 > 0.0);
        assert!(bounds.optimizer_latency(&s) > 0.0);
        assert!(bounds.memory(&s) > 0.0);
        // Same key, second query: identical value off the memo.
        assert_eq!(t1.to_bits(), bounds.iter_time(&s).to_bits());
        assert_eq!(bounds.memo.len(), 1);
    }

    #[test]
    fn timeline_arm_claims_positive_optimizer_bound() {
        // Pre-PR-9 the timeline arm claimed 0 here (documented caveat);
        // the min-over-stages floor is now positive on every arm, and
        // at pp = 1 it is bit-identical to the closed-form expression.
        let mut bounds = ScenarioBounds::new();
        let mb = Scenario::paper_default().with_micro_batches(2);
        assert!(bounds.optimizer_latency(&mb) > 0.0);
        assert!(bounds.iter_time(&mb) > 0.0);
        let pp = Scenario::new(
            crate::model::qwen3::Qwen3Size::S1_7B,
            2,
            2,
            4,
            OptimKind::Muon,
            DpStrategy::LbAsc,
        )
        .with_micro_batches(8);
        let o_lb = bounds.optimizer_latency(&pp);
        assert!(o_lb > 0.0, "deep-pipeline optimizer bound must not be vacuous");
        // The mb > 1 / straggler variants share the (size, tp, pp,
        // optim) aggregate with the straggler-free leaf — same bound.
        let strag = pp.clone().with_straggler(1.5);
        assert_eq!(o_lb.to_bits(), bounds.optimizer_latency(&strag).to_bits());
    }
}
