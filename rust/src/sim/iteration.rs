//! Iteration playback: reproduce one training step's timing per strategy.
//!
//! Decomposition (mirrors the paper's measurement methodology, §5.1):
//!
//! * **fwd-bwd** — dense compute per GPU + TP activation All-Reduces +
//!   the DP-plane gradient path, bucket-overlapped with backward compute
//!   (Reduce-Scatter for geometry-respecting strategies, All-Reduce for
//!   SC/NV-layerwise), and the parameter All-Gather overlapped with
//!   forward compute (ZeRO-1 strategies).
//! * **optimizer** — the per-strategy step:
//!   SC: per-tensor TP All-Gather + fully redundant compute;
//!   NV-layerwise: layer-granular DP ownership (redundant TP compute) +
//!   an exposed DP Broadcast of updated parameters;
//!   ASC: atomic static DP partition + unfused, round-robin TP pipeline;
//!   LB-ASC: α-balanced DP partition + micro-group TP pipeline.
//!
//! Pipeline parallelism is modelled at steady state: each PP stage is
//! simulated independently and the slowest stage paces the iteration.

use std::sync::Arc;
use std::time::Instant;

use crate::buffer::FlatBuffer;
use crate::cost::comm::{CollectiveKind, CommModel};
use crate::cost::hardware::LinkKind;
use crate::cost::optim::{CostMetric, OptimCost};
use crate::model::shapes::{Param, TensorShape};
use crate::model::tp::tp_split;
use crate::partition::{alpha_balanced, layerwise, naive_atomic_per_bucket, DpPlan, DpStrategy};
use crate::schedule::microgroup::{build_micro_groups, TpPlan, TpTask};
use crate::sweep::cache::{DpKey, PlanCache, TpKey};

use super::scenario::Scenario;
use super::stream::Stream;

/// Bytes per gradient / parameter element on the wire (bf16).
const WIRE_BYTES: f64 = 2.0;
/// Bytes of HBM traffic per element for an element-wise optimizer pass
/// (read w/g/m/v + write w/m/v, fp32 states, bf16 param+grad).
const ADAMW_BYTES_PER_ELEM: f64 = 26.0;

/// Simulation output for one scenario.
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    /// Forward+backward wall time (s), gradient/param comm overlapped.
    pub fwd_bwd_s: f64,
    /// Target-optimizer step wall time (s).
    pub optimizer_s: f64,
    /// End-to-end iteration (s).
    pub total_s: f64,
    /// AdamW reference optimizer time (s) — the paper's context metric.
    pub adamw_ref_s: f64,
    /// Exposed (non-overlapped) gradient-path communication (s).
    pub exposed_comm_s: f64,
    /// Per-DP-rank optimizer FLOPs (worst PP stage).
    pub dp_loads_flops: Vec<f64>,
    /// Per-DP-rank optimizer state bytes.
    pub dp_loads_state: Vec<f64>,
    /// Per-TP-rank hosted FLOPs (worst DP rank of worst stage).
    pub tp_loads_flops: Vec<f64>,
    /// Per-TP-rank hosted optimizer state bytes.
    pub tp_loads_state: Vec<f64>,
    /// Micro groups built (worst DP rank).
    pub n_micro_groups: usize,
    /// Offline planning latency (s) — Appendix D.1.
    pub planning_s: f64,
    /// Gradient-path bytes per GPU (diagnostic; AR = 2x RS).
    pub grad_comm_bytes: f64,
}

/// A stage-local parameter: buffer geometry uses the TP-shard shape,
/// optimizer-task cost uses the full shape.
#[derive(Clone, Debug)]
struct LocalParam {
    local: Param,
    full_shape: TensorShape,
}

/// Split the census into PP stages: layers round-robin by contiguous
/// block, embedding on the first stage, head + final norm on the last.
fn stage_census(census: &[Param], pp: usize) -> Vec<Vec<Param>> {
    let n_layers = census
        .iter()
        .filter_map(|p| p.param_layer())
        .max()
        .map(|l| l + 1)
        .unwrap_or(0);
    let per_stage = n_layers.div_ceil(pp.max(1));
    let mut stages: Vec<Vec<Param>> = vec![Vec::new(); pp];
    for p in census {
        match p.layer {
            Some(l) => stages[(l / per_stage).min(pp - 1)].push(p.clone()),
            None => {
                if p.name.starts_with("embed") {
                    stages[0].push(p.clone());
                } else {
                    stages[pp - 1].push(p.clone());
                }
            }
        }
    }
    stages
}

impl Param {
    fn param_layer(&self) -> Option<usize> {
        self.layer
    }
}

/// Build the TP-local view of a stage: shard shapes for geometry, full
/// shapes for task costing.
fn local_view(stage: &[Param], tp: usize) -> Vec<LocalParam> {
    tp_split(stage, tp)
        .into_iter()
        .map(|s| {
            let mut local = s.param.clone();
            let full_shape = local.shape.clone();
            local.shape = s.shard_shape;
            LocalParam { local, full_shape }
        })
        .collect()
}

/// fwd+bwd dense FLOPs per GPU for a stage (TP-local weights, one
/// microbatch of `tokens`): 2*T*numel forward, 2x that backward, plus the
/// attention score/value terms.
fn fwd_flops(locals: &[LocalParam], tokens: f64, seq: f64, tp: f64) -> f64 {
    let numel: f64 = locals
        .iter()
        .filter(|p| p.local.shape.is_matrix())
        .map(|p| p.local.numel() as f64)
        .sum();
    let n_layers = locals
        .iter()
        .filter_map(|p| p.local.layer)
        .max()
        .map(|l| l + 1)
        .unwrap_or(0) as f64;
    // Attention: QK^T and AV, causal (x1/2), fwd only here.
    let hidden = locals
        .iter()
        .find(|p| p.local.name.ends_with("attn_norm.weight"))
        .map(|p| p.local.numel() as f64)
        .unwrap_or(0.0);
    let attn = n_layers * 2.0 * tokens * seq * hidden / tp;
    2.0 * tokens * numel + attn
}

struct OptStepResult {
    time_s: f64,
    dp_loads_flops: Vec<f64>,
    dp_loads_state: Vec<f64>,
    tp_loads_flops: Vec<f64>,
    tp_loads_state: Vec<f64>,
    n_micro_groups: usize,
    planning_s: f64,
}

/// Convert a byte capacity to the balancing-cost units of `metric`.
fn c_max_units(c_bytes: f64, metric: CostMetric, tasks: &[TpTask]) -> f64 {
    match metric {
        CostMetric::Numel | CostMetric::StateBytes => c_bytes / WIRE_BYTES,
        CostMetric::Flops => {
            let total_cost: f64 = tasks.iter().map(|t| t.cost).sum();
            let total_bytes: f64 = tasks.iter().map(|t| t.comm_bytes).sum();
            if total_bytes == 0.0 {
                c_bytes
            } else {
                c_bytes * total_cost / total_bytes
            }
        }
    }
}

/// Micro-group pipeline timing (Fig. 2 right): gather All-to-All,
/// balanced compute, scatter All-to-All, with the communication stream
/// running ahead of compute (compute-comm overlap across groups).
fn tp_pipeline(plan: &TpPlan, comm: &CommModel, gpu_flops: f64) -> f64 {
    let tp = plan.ranks;
    let mut comm_stream = Stream::new();
    let mut compute_stream = Stream::new();
    let mut end = 0.0f64;
    for g in &plan.groups {
        // Per-rank hosted bytes in this group.
        let mut hosted_bytes = vec![0.0; tp];
        let mut hosted_flops = vec![0.0; tp];
        for &(t, r) in &g.assignments {
            hosted_bytes[r] += plan.tasks[t].comm_bytes;
            hosted_flops[r] += plan.tasks[t].flops;
        }
        // Each fused collective pays one kernel launch; unfused plans pay
        // it per tensor (the paper's "many small kernels" penalty).
        let t_gather = comm.hw.launch_overhead
            + comm.collective_v(CollectiveKind::AllToAll, &hosted_bytes, LinkKind::IntraNode);
        let t_compute = hosted_flops.iter().cloned().fold(0.0, f64::max) / gpu_flops;
        let t_scatter = t_gather; // updates are the same volume back
        let gather_done = comm_stream.schedule(0.0, t_gather);
        let compute_done = compute_stream.schedule(gather_done, t_compute);
        end = comm_stream.schedule(compute_done, t_scatter);
    }
    end
}

/// The optimizer step of one PP stage under the scenario's strategy.
///
/// `dp_plan` is the stage's shared DP partition (required for ASC /
/// LB-ASC — the same plan also drives the gradient-path shard sizes);
/// `cache` memoizes the layerwise and TP micro-group solves.
fn optimizer_step(
    s: &Scenario,
    locals: &[LocalParam],
    fb: &FlatBuffer,
    stage: usize,
    dp_plan: Option<&Arc<DpPlan>>,
    cache: &PlanCache,
) -> OptStepResult {
    let comm = CommModel::new(s.hw.clone());
    let optim = OptimCost::new(s.optim);
    let gpu = s.hw.gpu_flops;
    let tp = s.tp;

    // Helper: full-shape task for a local param index.
    let make_task = |id: usize, i: usize| -> TpTask {
        let lp = &locals[i];
        TpTask {
            id,
            name: lp.local.name.clone(),
            cost: optim.cost(&lp.full_shape, s.metric),
            comm_bytes: WIRE_BYTES * lp.full_shape.numel() as f64,
            flops: optim.flops(&lp.full_shape),
            state_bytes: optim.state_bytes(&lp.full_shape),
        }
    };

    // Element-wise (AdamW-routed) helpers over local shard elements.
    let ew_elems = |indices: &[usize]| -> f64 {
        indices
            .iter()
            .filter(|&&i| !locals[i].local.is_matrix_opt())
            .map(|&i| locals[i].local.numel() as f64)
            .sum()
    };
    let ew_time = |elems: f64| s.hw.memory_time(elems * ADAMW_BYTES_PER_ELEM);

    let all_indices: Vec<usize> = (0..locals.len()).collect();
    let matrix_indices: Vec<usize> = all_indices
        .iter()
        .cloned()
        .filter(|&i| locals[i].local.is_matrix_opt())
        .collect();

    match s.strategy {
        DpStrategy::Sc => {
            // Every GPU all-gathers every fragmented tensor (unfused) and
            // performs the identical full-tensor update.
            let t0 = Instant::now();
            let sizes: Vec<f64> = matrix_indices
                .iter()
                .map(|&i| WIRE_BYTES * locals[i].full_shape.numel() as f64)
                .collect();
            let comm_t = if tp > 1 {
                comm.per_message(&sizes, tp, LinkKind::IntraNode, CollectiveKind::AllGather)
            } else {
                0.0
            };
            let flops_total: f64 = matrix_indices
                .iter()
                .map(|&i| optim.flops(&locals[i].full_shape))
                .sum();
            let state_total: f64 = matrix_indices
                .iter()
                .map(|&i| optim.state_bytes(&locals[i].full_shape))
                .sum();
            let ew = ew_elems(&all_indices) * tp as f64; // replicated full tensors
            let time = comm_t + flops_total / gpu + ew_time(ew);
            OptStepResult {
                time_s: time,
                dp_loads_flops: vec![flops_total; s.dp],
                dp_loads_state: vec![state_total; s.dp],
                tp_loads_flops: vec![flops_total; tp],
                tp_loads_state: vec![state_total; tp],
                n_micro_groups: 0,
                planning_s: t0.elapsed().as_secs_f64(),
            }
        }
        DpStrategy::NvLayerwise => {
            // Layer-granular global LPT across DP; TP-redundant compute;
            // exposed DP Broadcast of updated parameters.
            let t0 = Instant::now();
            let w = |p: &crate::buffer::PlacedParam| p.numel() as f64;
            let plan = cache.layerwise_plan(&DpKey::for_scenario(s, stage), || {
                layerwise(fb, s.dp, w)
            });
            let planning_s = t0.elapsed().as_secs_f64();
            let rank_params = plan.rank_params(fb);
            let mut dp_flops = vec![0.0; s.dp];
            let mut dp_state = vec![0.0; s.dp];
            let mut dp_time = vec![0.0; s.dp];
            for d in 0..s.dp {
                let owned_matrix: Vec<usize> = rank_params[d]
                    .iter()
                    .cloned()
                    .filter(|&i| locals[i].local.is_matrix_opt())
                    .collect();
                let sizes: Vec<f64> = owned_matrix
                    .iter()
                    .map(|&i| WIRE_BYTES * locals[i].full_shape.numel() as f64)
                    .collect();
                let comm_t = if tp > 1 {
                    comm.per_message(&sizes, tp, LinkKind::IntraNode, CollectiveKind::AllGather)
                } else {
                    0.0
                };
                let flops: f64 = owned_matrix
                    .iter()
                    .map(|&i| optim.flops(&locals[i].full_shape))
                    .sum();
                dp_flops[d] = flops;
                dp_state[d] = owned_matrix
                    .iter()
                    .map(|&i| optim.state_bytes(&locals[i].full_shape))
                    .sum::<f64>()
                    + ew_elems(&rank_params[d]) * 8.0;
                dp_time[d] = comm_t + flops / gpu + ew_time(ew_elems(&rank_params[d]));
            }
            // Exposed redistribution of updated parameters over the DP
            // (inter-node) fabric.
            let param_bytes: f64 =
                locals.iter().map(|p| WIRE_BYTES * p.local.numel() as f64).sum();
            let bcast = comm.collective(CollectiveKind::Broadcast, param_bytes, s.dp,
                                        LinkKind::InterNode);
            let time = dp_time.iter().cloned().fold(0.0, f64::max) + bcast;
            OptStepResult {
                time_s: time,
                dp_loads_flops: dp_flops.clone(),
                dp_loads_state: dp_state,
                tp_loads_flops: vec![dp_flops.iter().cloned().fold(0.0, f64::max); tp],
                tp_loads_state: vec![0.0; tp],
                n_micro_groups: 0,
                planning_s,
            }
        }
        DpStrategy::Asc | DpStrategy::LbAsc => {
            let lb = s.strategy == DpStrategy::LbAsc;
            let plan = dp_plan.expect("ASC/LB-ASC optimizer step requires a DP plan");
            let rank_params = plan.rank_params(fb);
            // TP-plane planning latency (DP solves are timed by the caller).
            let mut tp_planning_s = 0.0f64;
            // Element-wise loads prorated by actual cut overlap.
            let ew_loads = plan.rank_loads(fb, |p| {
                if p.param.is_matrix_opt() { 0.0 } else { p.numel() as f64 }
            });

            let mut dp_flops = vec![0.0; s.dp];
            let mut dp_state = vec![0.0; s.dp];
            let mut dp_time = vec![0.0; s.dp];
            let mut worst: (f64, Option<Arc<TpPlan>>) = (0.0, None);
            for d in 0..s.dp {
                let owned_matrix: Vec<usize> = rank_params[d]
                    .iter()
                    .cloned()
                    .filter(|&i| locals[i].local.is_matrix_opt())
                    .collect();
                let tasks: Vec<TpTask> = owned_matrix
                    .iter()
                    .enumerate()
                    .map(|(id, &i)| make_task(id, i))
                    .collect();
                let flops: f64 = tasks.iter().map(|t| t.flops).sum();
                dp_flops[d] = flops + 12.0 * ew_loads[d];
                dp_state[d] = tasks.iter().map(|t| t.state_bytes).sum::<f64>()
                    + ew_loads[d] * 8.0;

                let tp_time = if tp > 1 && !tasks.is_empty() {
                    let t_tp = Instant::now();
                    let key = TpKey::for_scenario(s, stage, d);
                    let tplan = cache.tp_plan(&key, || {
                        if lb {
                            match s.c_max_bytes {
                                // No-Fuse (Fig. 14 baseline): one collective
                                // per tensor, hosts still load-balanced.
                                None => unfused_plan(tasks.clone(), tp),
                                Some(cb) => {
                                    let cap = c_max_units(cb, s.metric, &tasks)
                                        .max(tasks.iter().map(|t| t.cost).fold(0.0, f64::max));
                                    build_micro_groups(tasks.clone(), tp, cap)
                                }
                            }
                        } else {
                            naive_tp_plan(tasks.clone(), tp, s.c_max_bytes)
                        }
                    });
                    tp_planning_s += t_tp.elapsed().as_secs_f64();
                    let t = tp_pipeline(&tplan, &comm, gpu);
                    if dp_flops[d] >= worst.0 {
                        worst = (dp_flops[d], Some(tplan));
                    }
                    t
                } else {
                    // tp == 1: all hosted locally, pure compute.
                    flops / gpu
                };
                dp_time[d] = tp_time + ew_time(ew_loads[d]);
            }
            let (tp_loads_flops, tp_loads_state, n_groups) = match &worst.1 {
                Some(tplan) => (
                    tplan.rank_totals(|t| t.flops),
                    tplan.rank_totals(|t| t.state_bytes),
                    tplan.groups.len(),
                ),
                None => (vec![0.0; tp], vec![0.0; tp], 0),
            };
            OptStepResult {
                time_s: dp_time.iter().cloned().fold(0.0, f64::max),
                dp_loads_flops: dp_flops,
                dp_loads_state: dp_state,
                tp_loads_flops,
                tp_loads_state,
                n_micro_groups: n_groups,
                planning_s: tp_planning_s,
            }
        }
    }
}

/// The Fig. 14 "No-Fuse" baseline: one micro-group (i.e. one pair of
/// collectives) per tensor; host ranks still balanced greedily so the
/// comparison isolates the *fusion* benefit.
fn unfused_plan(tasks: Vec<TpTask>, tp: usize) -> TpPlan {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| tasks[b].cost.partial_cmp(&tasks[a].cost).unwrap());
    let mut loads = vec![0.0; tp];
    let mut groups = Vec::with_capacity(tasks.len());
    for i in order {
        let host = (0..tp)
            .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
            .unwrap();
        loads[host] += tasks[i].cost;
        let mut rank_loads = vec![0.0; tp];
        rank_loads[host] = tasks[i].cost;
        groups.push(crate::schedule::microgroup::MicroGroup {
            assignments: vec![(i, host)],
            rank_loads,
            max_load: tasks[i].cost,
            comm_bytes: tasks[i].comm_bytes,
        });
    }
    TpPlan { ranks: tp, c_max: 0.0, tasks, groups }
}

/// The ASC TP path: fixed census-order chunking (no LPT), round-robin
/// host assignment (no min-heap), optional fusion cap by bytes.
fn naive_tp_plan(tasks: Vec<TpTask>, tp: usize, c_max_bytes: Option<f64>) -> TpPlan {
    let cap_bytes = c_max_bytes.unwrap_or(0.0);
    let mut groups = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut current_bytes = 0.0;
    let mut rr = 0usize;
    let mut assignments_acc: Vec<Vec<(usize, usize)>> = Vec::new();
    let mut current_assign: Vec<(usize, usize)> = Vec::new();
    for (i, t) in tasks.iter().enumerate() {
        if !current.is_empty() && current_bytes + t.comm_bytes > cap_bytes {
            assignments_acc.push(std::mem::take(&mut current_assign));
            groups.push(std::mem::take(&mut current));
            current_bytes = 0.0;
        }
        current.push(i);
        current_assign.push((i, rr % tp));
        rr += 1;
        current_bytes += t.comm_bytes;
    }
    if !current.is_empty() {
        assignments_acc.push(current_assign);
        groups.push(current);
    }
    let mg = assignments_acc
        .into_iter()
        .map(|assignments| {
            let mut rank_loads = vec![0.0; tp];
            let mut comm_bytes = 0.0;
            for &(t, r) in &assignments {
                rank_loads[r] += tasks[t].cost;
                comm_bytes += tasks[t].comm_bytes;
            }
            let max_load = rank_loads.iter().cloned().fold(0.0, f64::max);
            crate::schedule::microgroup::MicroGroup { assignments, rank_loads, max_load, comm_bytes }
        })
        .collect();
    TpPlan { ranks: tp, c_max: cap_bytes, tasks, groups: mg }
}

/// Gradient-path + parameter-path communication schedule per bucket.
fn fwd_bwd_time(
    s: &Scenario,
    locals: &[LocalParam],
    fb: &FlatBuffer,
    dp_plan_shards: Option<Vec<Vec<f64>>>,
) -> (f64, f64, f64) {
    let comm = CommModel::new(s.hw.clone());
    let tokens = s.tokens() as f64;
    let fwd = fwd_flops(locals, tokens, s.seq_len as f64, s.tp as f64);
    let bwd = 2.0 * fwd;
    let fwd_t = fwd / s.hw.gpu_flops;
    let bwd_t = bwd / s.hw.gpu_flops;

    // TP activation All-Reduces: 2 per layer fwd + 2 bwd.
    let n_layers = locals
        .iter()
        .filter_map(|p| p.local.layer)
        .max()
        .map(|l| l + 1)
        .unwrap_or(0) as f64;
    let hidden = locals
        .iter()
        .find(|p| p.local.name.ends_with("attn_norm.weight"))
        .map(|p| p.local.numel() as f64)
        .unwrap_or(0.0);
    let act_bytes = WIRE_BYTES * tokens * hidden;
    let tp_ar = if s.tp > 1 {
        4.0 * n_layers
            * comm.collective(CollectiveKind::AllReduce, act_bytes, s.tp, LinkKind::IntraNode)
    } else {
        0.0
    };

    // Backward: buckets complete sequentially; grad collective per bucket
    // overlaps subsequent buckets' compute.
    let total_elems = fb.total as f64;
    let mut compute = Stream::new();
    let mut comm_stream = Stream::new();
    let mut grad_bytes_per_gpu = 0.0;
    let mut bwd_end = 0.0f64;
    let uses_ar = matches!(s.strategy, DpStrategy::Sc | DpStrategy::NvLayerwise);
    for (i, b) in fb.buckets.iter().enumerate() {
        let frac = b.size() as f64 / total_elems;
        let grads_ready = compute.schedule(0.0, bwd_t * frac);
        let bucket_bytes = WIRE_BYTES * b.size() as f64;
        let t_comm = if s.dp > 1 {
            if uses_ar {
                comm.collective(CollectiveKind::AllReduce, bucket_bytes, s.dp, LinkKind::InterNode)
            } else if let Some(shards) = &dp_plan_shards {
                let sizes: Vec<f64> = shards[i].iter().map(|e| e * WIRE_BYTES).collect();
                comm.collective_v(CollectiveKind::ReduceScatter, &sizes, LinkKind::InterNode)
            } else {
                comm.collective(CollectiveKind::ReduceScatter, bucket_bytes, s.dp,
                                LinkKind::InterNode)
            }
        } else {
            0.0
        };
        grad_bytes_per_gpu += comm.volume(
            if uses_ar { CollectiveKind::AllReduce } else { CollectiveKind::ReduceScatter },
            bucket_bytes,
            s.dp,
        );
        bwd_end = comm_stream.schedule(grads_ready, t_comm).max(grads_ready);
    }
    bwd_end = bwd_end.max(compute.free_at());
    let exposed_bwd = bwd_end - bwd_t;

    // Forward: ZeRO-1 strategies all-gather each bucket's parameters,
    // overlapped with the previous bucket's forward compute. SC and
    // NV-layerwise hold full parameter copies (no gather here; layerwise
    // pays its Broadcast inside the optimizer step instead).
    let mut fwd_compute = Stream::new();
    let mut fwd_comm = Stream::new();
    let mut fwd_end = 0.0f64;
    for (i, b) in fb.buckets.iter().enumerate() {
        let frac = b.size() as f64 / total_elems;
        let t_ag = if s.dp > 1 && !uses_ar {
            let bucket_bytes = WIRE_BYTES * b.size() as f64;
            if let Some(shards) = &dp_plan_shards {
                let sizes: Vec<f64> = shards[i].iter().map(|e| e * WIRE_BYTES).collect();
                comm.collective_v(CollectiveKind::AllGather, &sizes, LinkKind::InterNode)
            } else {
                comm.collective(CollectiveKind::AllGather, bucket_bytes, s.dp, LinkKind::InterNode)
            }
        } else {
            0.0
        };
        let params_ready = fwd_comm.schedule(0.0, t_ag);
        fwd_end = fwd_compute.schedule(params_ready, fwd_t * frac);
    }
    let exposed_fwd = fwd_end - fwd_t;

    let total = bwd_end + fwd_end + tp_ar;
    (total, exposed_bwd + exposed_fwd, grad_bytes_per_gpu)
}

/// Simulate one full iteration with a throwaway plan cache (cold path).
pub fn simulate_iteration(s: &Scenario) -> Breakdown {
    simulate_iteration_cached(s, &PlanCache::new())
}

/// Simulate one full iteration; the slowest PP stage paces both phases.
///
/// The DP partition of each stage is solved **once** (shared between the
/// gradient-path shard geometry and the optimizer step) and memoized in
/// `cache`, as are the per-rank TP micro-group plans — a warm cache skips
/// every LPT solve, which is what makes repeated scenario sweeps fast.
pub fn simulate_iteration_cached(s: &Scenario, cache: &PlanCache) -> Breakdown {
    let stages = stage_census(&s.census, s.pp);
    let mut out = Breakdown::default();
    for (si, stage) in stages.iter().enumerate() {
        let locals = local_view(stage, s.tp);
        let local_census: Vec<Param> = locals.iter().map(|lp| lp.local.clone()).collect();
        let fb = FlatBuffer::build(&local_census, s.bucket_elems);

        // One DP plan per stage: it defines both the gradient-path shard
        // sizes (variable-size RS for ASC/LB-ASC) and optimizer ownership.
        let t_plan = Instant::now();
        let dp_plan: Option<Arc<DpPlan>> = match s.strategy {
            DpStrategy::Asc => Some(cache.dp_plan(&DpKey::for_scenario(s, si), || {
                naive_atomic_per_bucket(&fb, s.dp)
            })),
            DpStrategy::LbAsc => {
                let optim = OptimCost::new(s.optim);
                let metric = s.metric;
                let locals_ref: &[LocalParam] = &locals;
                Some(cache.dp_plan(&DpKey::for_scenario(s, si), || {
                    alpha_balanced(&fb, s.dp, s.alpha, true, move |p| {
                        if p.param.is_matrix_opt() {
                            optim.cost(&locals_ref[p.index].full_shape, metric)
                        } else {
                            optim.cost(&p.param.shape, metric)
                        }
                    })
                }))
            }
            _ => None,
        };
        let dp_planning_s = t_plan.elapsed().as_secs_f64();
        let shards: Option<Vec<Vec<f64>>> = dp_plan.as_ref().map(|plan| {
            (0..fb.buckets.len())
                .map(|i| plan.shard_sizes(i).iter().map(|&x| x as f64).collect())
                .collect()
        });

        let (fb_time, exposed, grad_bytes) = fwd_bwd_time(s, &locals, &fb, shards);
        let opt = optimizer_step(s, &locals, &fb, si, dp_plan.as_ref(), cache);

        // AdamW reference: equal-chunk ZeRO-1, memory-bound, per DP rank.
        let adamw_elems = fb.total as f64 / s.dp as f64;
        let adamw_t = s.hw.memory_time(adamw_elems * ADAMW_BYTES_PER_ELEM);

        if fb_time + opt.time_s > out.fwd_bwd_s + out.optimizer_s {
            out.fwd_bwd_s = fb_time;
            out.optimizer_s = opt.time_s;
            out.exposed_comm_s = exposed;
            out.dp_loads_flops = opt.dp_loads_flops;
            out.dp_loads_state = opt.dp_loads_state;
            out.tp_loads_flops = opt.tp_loads_flops;
            out.tp_loads_state = opt.tp_loads_state;
            out.n_micro_groups = opt.n_micro_groups;
            out.grad_comm_bytes = grad_bytes;
            out.adamw_ref_s = adamw_t;
        }
        out.planning_s += dp_planning_s + opt.planning_s;
    }
    out.total_s = out.fwd_bwd_s + out.optimizer_s;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::Qwen3Size;
    use crate::util::stats::load_balance_ratio;

    fn scen(strategy: DpStrategy) -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, crate::cost::optim::OptimKind::Muon, strategy)
    }

    #[test]
    fn strategy_ordering_matches_paper() {
        // LB-ASC < ASC < NV-layerwise < SC on optimizer time (Fig. 3a/4).
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let asc = simulate_iteration(&scen(DpStrategy::Asc));
        let nv = simulate_iteration(&scen(DpStrategy::NvLayerwise));
        let sc = simulate_iteration(&scen(DpStrategy::Sc));
        assert!(lb.optimizer_s < asc.optimizer_s, "{} vs {}", lb.optimizer_s, asc.optimizer_s);
        assert!(asc.optimizer_s < sc.optimizer_s);
        assert!(lb.optimizer_s < nv.optimizer_s);
        assert!(nv.optimizer_s < sc.optimizer_s);
    }

    #[test]
    fn fwd_bwd_rs_beats_ar() {
        // Ours (RS path) must beat NV-layerwise (AR path) on fwd-bwd.
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let nv = simulate_iteration(&scen(DpStrategy::NvLayerwise));
        assert!(lb.fwd_bwd_s < nv.fwd_bwd_s, "{} vs {}", lb.fwd_bwd_s, nv.fwd_bwd_s);
        assert!(nv.grad_comm_bytes > 1.9 * lb.grad_comm_bytes);
    }

    #[test]
    fn lb_flattens_dp_loads() {
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let asc = simulate_iteration(&scen(DpStrategy::Asc));
        let r_lb = load_balance_ratio(&lb.dp_loads_flops);
        let r_asc = load_balance_ratio(&asc.dp_loads_flops);
        assert!(r_lb < r_asc, "{r_lb} vs {r_asc}");
        assert!(r_lb < 1.5, "{r_lb}");
    }

    #[test]
    fn planning_is_fast() {
        // Appendix D.1: offline planning is ms-scale.
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        assert!(lb.planning_s < 0.5, "{}", lb.planning_s);
    }

    #[test]
    fn pp_stages_dont_crash() {
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 4;
        let b = simulate_iteration(&s);
        assert!(b.total_s > 0.0);
    }

    #[test]
    fn tp1_works() {
        let mut s = scen(DpStrategy::LbAsc);
        s.tp = 1;
        let b = simulate_iteration(&s);
        assert!(b.optimizer_s > 0.0);
    }

    #[test]
    fn warm_cache_skips_solves_and_preserves_results() {
        fn timing_free(b: &Breakdown) -> (u64, u64, u64, Vec<u64>, Vec<u64>, usize) {
            (
                b.fwd_bwd_s.to_bits(),
                b.optimizer_s.to_bits(),
                b.exposed_comm_s.to_bits(),
                b.dp_loads_flops.iter().map(|x| x.to_bits()).collect(),
                b.tp_loads_flops.iter().map(|x| x.to_bits()).collect(),
                b.n_micro_groups,
            )
        }
        for strategy in [DpStrategy::Sc, DpStrategy::NvLayerwise,
                         DpStrategy::Asc, DpStrategy::LbAsc] {
            let s = scen(strategy);
            let cache = PlanCache::new();
            let first = simulate_iteration_cached(&s, &cache);
            let solves = cache.stats().solves;
            let second = simulate_iteration_cached(&s, &cache);
            assert_eq!(cache.stats().solves, solves,
                       "{strategy:?}: warm run re-solved a plan");
            if strategy != DpStrategy::Sc {
                assert!(solves > 0, "{strategy:?}: no solve recorded");
                assert!(cache.stats().hits > 0, "{strategy:?}: no cache hit");
            }
            let cold = simulate_iteration(&s);
            assert_eq!(timing_free(&first), timing_free(&second), "{strategy:?}");
            assert_eq!(timing_free(&first), timing_free(&cold), "{strategy:?}");
        }
    }
}
