//! Iteration playback: reproduce one training step's timing per strategy.
//!
//! Decomposition (mirrors the paper's measurement methodology, §5.1):
//!
//! * **fwd-bwd** — dense compute per GPU + TP activation All-Reduces +
//!   the DP-plane gradient path, bucket-overlapped with backward compute
//!   (Reduce-Scatter for geometry-respecting strategies, All-Reduce for
//!   SC/NV-layerwise), and the parameter All-Gather overlapped with
//!   forward compute (ZeRO-1 strategies).
//! * **optimizer** — the per-strategy step:
//!   SC: per-tensor TP All-Gather + fully redundant compute;
//!   NV-layerwise: layer-granular DP ownership (redundant TP compute) +
//!   an exposed DP Broadcast of updated parameters;
//!   ASC: atomic static DP partition + unfused, round-robin TP pipeline;
//!   LB-ASC: α-balanced DP partition + micro-group TP pipeline;
//!   MatrixFSDP: ZeRO-3 row sharding, communication-free update
//!   (redundant per-matrix preconditioners, sharded linear pass);
//!   DMuon: whole-tensor DP ownership with overlapped Gather /
//!   orthogonalize / Scatter of momentum shards;
//!   Dion: low-rank factor updates + one fused low-rank All-Reduce.
//!
//! # Closed form vs. timeline engine
//!
//! At `pp == 1`, `micro_batches == 1`, `straggler == 1.0` the iteration
//! is a single-stage schedule with a closed form (the bucket-overlap
//! loops below) — that stays the warm, zero-allocation fast path. Every
//! other scenario routes through [`simulate_iteration_timeline`]: an
//! event-driven schedule (built on [`crate::sim::timeline`]) that runs
//! forward/backward micro-batches under a 1F1B or GPipe pipeline across
//! the `pp` stages, overlaps each stage's gradient bucket
//! Reduce-Scatter with the tail of its last backward micro-batch
//! (Megatron semantics), gates the first forward micro-batch's buckets
//! on the ZeRO-1 parameter All-Gather, models inter-stage activation
//! transfers point-to-point, and schedules the per-stage optimizer step
//! (the micro-group pipeline) as just another stream consumer after
//! that stage's gradients are synchronized — so an early-draining stage
//! starts optimizing while later stages are still in their backward
//! cooldown. At `pp = 1, m = 1` the two paths agree to 1e-9 relative
//! tolerance (enforced by `tests/timeline_differential.rs`).
//!
//! # Cold vs. warm path
//!
//! Everything a stage's playback derives from the census — TP-local
//! shapes, flat-buffer geometry, the per-stage optimizer task table that
//! `make_task` used to rebuild per DP rank, per-rank load aggregates —
//! is hoisted into one cached [`StageTable`] (keyed by
//! [`StageKey`]). The first (cold) evaluation of a scenario builds its
//! tables and plans; every later (warm) evaluation is pure f64
//! arithmetic over the cached tables and performs **zero heap
//! allocations** — enforced by the counting allocator in
//! [`crate::util::alloc`] and `tests/warm_alloc.rs`. Use
//! [`simulate_iteration_into`] with a reused [`Breakdown`] to stay on
//! that path; [`simulate_iteration_cached`] allocates only the output
//! struct's vectors.
//!
//! The zero-allocation contract covers **both** dispatch arms. The
//! timeline arm runs a *lean* [`Timeline`] (no trace — see
//! `sim::timeline`'s module docs) over a per-thread `SimScratch`
//! workspace: the timeline itself (reset in place, capacity retained),
//! the flat `pp × m` pipeline-drive tables, the interned schedule-order
//! tables, and the per-stage `StagePlayback`/`ag_stretch`/`last_*`/
//! `opt_ends` vectors all live in the scratch and are refilled per
//! call. Each `util::pool` worker (and the caller's thread) owns one
//! scratch — and because the pool's workers are *persistent*
//! (long-lived threads serving every batch), a scratch warmed by one
//! `SweepEngine::eval` batch is still warm for the next: scratch
//! warm-up is paid once per process, not once per batch, so a warm
//! family sweep's steady state never touches the heap even across
//! batch boundaries and whole `run("all")` sessions. The counters the
//! scratch feeds (`timeline_tasks`, `scratch_reuses`, `order_hits`)
//! surface in the sweep summary via
//! [`crate::sweep::cache::CacheStats`] — `scratch_reuses` now shows
//! cross-batch reuse, which `tests/pool_lifecycle.rs` pins.

#![warn(missing_docs)]

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use crate::buffer::FlatBuffer;
use crate::cost::comm::{CollectiveKind, CommModel};
use crate::cost::hardware::{Hardware, LinkKind};
use crate::cost::optim::{
    dion_factor_elems, dion_flops, dion_state_bytes, linear_flops_coeff, CostMetric, OptimCost,
    DION_RANK_FRACTION,
};
use crate::model::shapes::{Param, TensorShape};
use crate::model::tp::tp_split;
use crate::partition::rivals::{lpt_owners, zero3_rows};
use crate::partition::{alpha_balanced, layerwise, naive_atomic_per_bucket, DpPlan, DpStrategy};
use crate::schedule::microgroup::{build_micro_groups, MicroGroup, Symbols, TaskMeta, TpPlan, TpTask};
use crate::sweep::cache::{DpKey, PlanCache, StageKey, TpKey};

use super::faults::{self, ClusterProfile};
use super::scenario::Scenario;
use super::stream::Stream;
use super::timeline::{
    drive_pipeline_flat, OrderCache, PipeScratch, PipeSlot, StreamId, TaskId, TaskKind, Timeline,
};

/// Bytes per gradient / parameter element on the wire (bf16).
pub(crate) const WIRE_BYTES: f64 = 2.0;
/// Bytes of HBM traffic per element for an element-wise optimizer pass
/// (read w/g/m/v + write w/m/v, fp32 states, bf16 param+grad).
pub(crate) const ADAMW_BYTES_PER_ELEM: f64 = 26.0;

/// Simulation output for one scenario.
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    /// Forward+backward wall time (s), gradient/param comm overlapped.
    pub fwd_bwd_s: f64,
    /// Target-optimizer step wall time (s).
    pub optimizer_s: f64,
    /// End-to-end iteration (s).
    pub total_s: f64,
    /// AdamW reference optimizer time (s) — the paper's context metric.
    pub adamw_ref_s: f64,
    /// Exposed (non-overlapped) gradient-path communication (s).
    pub exposed_comm_s: f64,
    /// Per-DP-rank optimizer FLOPs (worst PP stage).
    pub dp_loads_flops: Vec<f64>,
    /// Per-DP-rank optimizer state bytes.
    pub dp_loads_state: Vec<f64>,
    /// Per-TP-rank hosted FLOPs (worst DP rank of worst stage).
    pub tp_loads_flops: Vec<f64>,
    /// Per-TP-rank hosted optimizer state bytes.
    pub tp_loads_state: Vec<f64>,
    /// Micro groups built (worst DP rank).
    pub n_micro_groups: usize,
    /// Offline planning latency (s) — Appendix D.1.
    pub planning_s: f64,
    /// Gradient-path bytes per GPU (diagnostic; AR = 2x RS).
    pub grad_comm_bytes: f64,
    /// Schedule idle time (s): `fwd_bwd_s` minus the busiest stage's
    /// compute occupancy. For `pp > 1` this is dominated by the
    /// pipeline fill/drain bubble (`(pp-1)/(m+pp-1)` of the span for
    /// uniform stages); at `pp = 1` it reduces to the exposed
    /// communication time.
    pub bubble_s: f64,
    /// Elastic-event recovery cost (s): detection timeout + checkpoint
    /// reload + re-partition + redone work, charged by the timeline arm
    /// when `--fail-rank` / `--mttf` are configured (see
    /// [`crate::sim::faults::recovery_seconds`]). Included in
    /// `total_s`; exactly `0.0` on fault-free scenarios.
    pub recovery_s: f64,
}

impl Breakdown {
    /// Clear for reuse, keeping vector capacity — the warm path's
    /// zero-allocation guarantee depends on refilling in place.
    pub(crate) fn reset(&mut self) {
        self.fwd_bwd_s = 0.0;
        self.optimizer_s = 0.0;
        self.total_s = 0.0;
        self.adamw_ref_s = 0.0;
        self.exposed_comm_s = 0.0;
        self.dp_loads_flops.clear();
        self.dp_loads_state.clear();
        self.tp_loads_flops.clear();
        self.tp_loads_state.clear();
        self.n_micro_groups = 0;
        self.planning_s = 0.0;
        self.grad_comm_bytes = 0.0;
        self.bubble_s = 0.0;
        self.recovery_s = 0.0;
    }
}

/// A stage-local parameter: buffer geometry uses the TP-shard shape,
/// optimizer-task cost uses the full shape.
#[derive(Clone, Debug)]
pub(crate) struct LocalParam {
    pub(crate) local: Param,
    pub(crate) full_shape: TensorShape,
}

/// The stage hosting transformer layer `l` under the PP split rule:
/// contiguous blocks of `ceil(n_layers / pp)` layers, overflow clamped
/// to the last stage. The single source of truth shared by
/// [`stage_census`] and the plan cache's stage canonicalization
/// ([`crate::sweep::cache::canonical_stage`]).
pub(crate) fn stage_of_layer(n_layers: usize, pp: usize, l: usize) -> usize {
    let per_stage = n_layers.div_ceil(pp.max(1));
    if per_stage == 0 {
        return 0;
    }
    (l / per_stage).min(pp.max(1) - 1)
}

/// Number of transformer layers stage `stage` hosts under
/// [`stage_of_layer`]'s rule.
pub(crate) fn stage_layer_count(n_layers: usize, pp: usize, stage: usize) -> usize {
    let pp = pp.max(1);
    let per_stage = n_layers.div_ceil(pp);
    if per_stage == 0 {
        return 0;
    }
    let lo = stage * per_stage;
    if stage + 1 == pp {
        n_layers.saturating_sub(lo)
    } else {
        ((stage + 1) * per_stage).min(n_layers).saturating_sub(lo)
    }
}

/// Split the census into PP stages: layers round-robin by contiguous
/// block ([`stage_of_layer`]), embedding on the first stage, head +
/// final norm on the last.
pub(crate) fn stage_census(census: &[Param], pp: usize) -> Vec<Vec<Param>> {
    // Clamp like `Scenario::new` does: `pp = 0` through the pub field
    // would otherwise index an empty stage list.
    let pp = pp.max(1);
    let n_layers = census
        .iter()
        .filter_map(|p| p.param_layer())
        .max()
        .map(|l| l + 1)
        .unwrap_or(0);
    let mut stages: Vec<Vec<Param>> = vec![Vec::new(); pp];
    for p in census {
        match p.layer {
            Some(l) => stages[stage_of_layer(n_layers, pp, l)].push(p.clone()),
            None => {
                if p.name.starts_with("embed") {
                    stages[0].push(p.clone());
                } else {
                    stages[pp - 1].push(p.clone());
                }
            }
        }
    }
    stages
}

impl Param {
    fn param_layer(&self) -> Option<usize> {
        self.layer
    }
}

/// Build the TP-local view of a stage: shard shapes for geometry, full
/// shapes for task costing.
pub(crate) fn local_view(stage: &[Param], tp: usize) -> Vec<LocalParam> {
    tp_split(stage, tp)
        .into_iter()
        .map(|s| {
            let mut local = s.param.clone();
            let full_shape = local.shape.clone();
            local.shape = s.shard_shape;
            LocalParam { local, full_shape }
        })
        .collect()
}

/// Per-strategy optimizer-step tables of one stage (see [`StageTable`]).
pub(crate) enum StrategyTable {
    /// SC: every GPU all-gathers and redundantly updates everything.
    Sc {
        /// Per fragmented matrix tensor: full-shape wire bytes.
        sizes: Vec<f64>,
        /// Full-census matrix update FLOPs (identical on every rank).
        flops_total: f64,
        /// Full-census matrix optimizer state bytes.
        state_total: f64,
        /// Element-wise (AdamW-routed) elements of the whole stage.
        ew_all: f64,
    },
    /// NV-layerwise: layer-granular DP ownership.
    Nv {
        /// Per DP rank: owned matrix tensors' full-shape wire bytes.
        rank_sizes: Vec<Vec<f64>>,
        /// Per DP rank: owned matrix update FLOPs.
        rank_flops: Vec<f64>,
        /// Per DP rank: optimizer state bytes (matrix + element-wise).
        rank_state: Vec<f64>,
        /// Per DP rank: element-wise elements owned.
        rank_ew: Vec<f64>,
    },
    /// ASC / LB-ASC: atomic static DP partition + TP pipeline.
    Atomic {
        /// The hoisted per-stage task table (`make_task` outputs for
        /// every fragmented matrix parameter, in census order).
        tasks: Vec<TaskMeta>,
        /// Interned task names (cold TP solves resolve through this).
        symbols: Symbols,
        /// Per DP rank: indices into `tasks` for the owned census.
        rank_tasks: Vec<Vec<u32>>,
        /// Per DP rank: owned task FLOPs (the tp==1 compute path).
        rank_task_flops: Vec<f64>,
        /// Per DP rank: matrix FLOPs + 12·element-wise (Breakdown load).
        dp_flops: Vec<f64>,
        /// Per DP rank: optimizer state bytes.
        dp_state: Vec<f64>,
        /// Per DP rank: element-wise elements (cut-overlap prorated).
        ew_loads: Vec<f64>,
        /// The TP-active rank with the highest `dp_flops` (its TP plan
        /// reports the Breakdown's TP loads), if any.
        worst_rank: Option<usize>,
    },
    /// MatrixFSDP: ZeRO-3 contiguous row sharding of every TP-local
    /// matrix across DP. The update is communication-free — each rank
    /// recomputes the matrix-level preconditioner from the parameter
    /// All-Gather already in flight for FSDP compute (redundant work),
    /// and only the element-linear update pass is sharded.
    Fsdp {
        /// Per DP rank: redundant preconditioner + owned-row FLOPs.
        rank_flops: Vec<f64>,
        /// Per DP rank: row-prorated optimizer state bytes (matrix +
        /// element-wise); sums exactly to the unsharded census.
        rank_state: Vec<f64>,
        /// Element-wise (AdamW-routed) elements of the whole stage.
        ew_all: f64,
    },
    /// DMuon: whole-tensor DP ownership by greedy LPT over update
    /// FLOPs; each owner gathers the momentum shards over the DP
    /// fabric, orthogonalizes, and scatters the update back, with the
    /// comm stream running ahead of compute.
    DMuon {
        /// Per DP rank, per owned matrix tensor: full-shape wire bytes.
        rank_sizes: Vec<Vec<f64>>,
        /// Per DP rank, per owned matrix tensor: update FLOPs
        /// (parallel to `rank_sizes`).
        rank_item_flops: Vec<Vec<f64>>,
        /// Per DP rank: owned matrix update FLOPs (row sums of
        /// `rank_item_flops`).
        rank_flops: Vec<f64>,
        /// Per DP rank: ZeRO-1-sharded optimizer state bytes.
        rank_state: Vec<f64>,
        /// Element-wise elements of the whole stage.
        ew_all: f64,
    },
    /// Dion: rank-fraction low-rank factor updates with DP-sharded
    /// error feedback and one fused low-rank All-Reduce per step.
    /// Uniform across ranks by construction, so scalars suffice.
    Dion {
        /// Per-GPU low-rank update FLOPs (the m·n-sized passes are
        /// DP-sharded; the factor-side work is replicated).
        flops_per_gpu: f64,
        /// Fused All-Reduce payload: Σ wire · r·(m+n) over matrices.
        factor_bytes: f64,
        /// Per DP rank: state bytes (sharded error feedback +
        /// replicated factors + sharded element-wise).
        state_per_rank: f64,
        /// Element-wise elements of the whole stage.
        ew_all: f64,
    },
}

/// Everything `simulate_iteration` derives from a scenario's census for
/// one PP stage, hoisted out of the hot path and memoized in the
/// [`PlanCache`] under a [`StageKey`].
///
/// The table is hardware-independent (timing applies the hardware model
/// to these numbers at playback) and `C_max`-independent (fusion only
/// shapes the separately-cached TP plans), so it is shared across
/// hardware profiles and the whole Fig. 14 ablation. All fields are
/// plain `f64` aggregates — a warm `simulate_iteration` reads them
/// without allocating.
pub struct StageTable {
    /// Transformer layers hosted by the stage.
    pub(crate) n_layers: f64,
    /// Hidden size proxy (attn-norm numel) for attention FLOPs.
    pub(crate) hidden: f64,
    /// Sum of TP-local matrix numels (dense fwd FLOPs term).
    pub(crate) matrix_numel: f64,
    /// Flat-buffer total elements.
    pub(crate) total_elems: f64,
    /// Stage parameter bytes on the wire (NV-layerwise Broadcast).
    pub(crate) param_bytes: f64,
    /// Per bucket: gradient bytes.
    pub(crate) bucket_bytes: Vec<f64>,
    /// Per bucket: fraction of the stage's elements.
    pub(crate) bucket_frac: Vec<f64>,
    /// Per bucket, per DP rank: shard wire bytes (ASC/LB-ASC only).
    pub(crate) shard_bytes: Option<Vec<Vec<f64>>>,
    /// Per-strategy optimizer-step tables.
    pub(crate) strat: StrategyTable,
}

impl StageTable {
    /// Approximate heap bytes held by the table (the plan cache's
    /// byte-budget accounting unit).
    pub fn heap_bytes(&self) -> usize {
        use std::mem::size_of;
        let f64s = |v: &Vec<f64>| v.len() * size_of::<f64>();
        let nested = |v: &Vec<Vec<f64>>| {
            v.len() * size_of::<Vec<f64>>() + v.iter().map(f64s).sum::<usize>()
        };
        let mut bytes = f64s(&self.bucket_bytes) + f64s(&self.bucket_frac);
        if let Some(sb) = &self.shard_bytes {
            bytes += nested(sb);
        }
        bytes += match &self.strat {
            StrategyTable::Sc { sizes, .. } => f64s(sizes),
            StrategyTable::Nv { rank_sizes, rank_flops, rank_state, rank_ew } => {
                nested(rank_sizes) + f64s(rank_flops) + f64s(rank_state) + f64s(rank_ew)
            }
            StrategyTable::Atomic {
                tasks,
                symbols,
                rank_tasks,
                rank_task_flops,
                dp_flops,
                dp_state,
                ew_loads,
                ..
            } => {
                tasks.len() * size_of::<TaskMeta>()
                    + symbols.heap_bytes()
                    + rank_tasks.len() * size_of::<Vec<u32>>()
                    + rank_tasks.iter().map(|v| v.len() * size_of::<u32>()).sum::<usize>()
                    + f64s(rank_task_flops)
                    + f64s(dp_flops)
                    + f64s(dp_state)
                    + f64s(ew_loads)
            }
            StrategyTable::Fsdp { rank_flops, rank_state, .. } => {
                f64s(rank_flops) + f64s(rank_state)
            }
            StrategyTable::DMuon {
                rank_sizes,
                rank_item_flops,
                rank_flops,
                rank_state,
                ..
            } => {
                nested(rank_sizes)
                    + nested(rank_item_flops)
                    + f64s(rank_flops)
                    + f64s(rank_state)
            }
            StrategyTable::Dion { .. } => 0,
        };
        bytes
    }

    /// Build the stage table (cold path): stage census, TP-local view,
    /// flat buffer, DP plan (memoized in `cache`), and the per-strategy
    /// aggregates the warm path reads.
    pub(crate) fn build(s: &Scenario, si: usize, cache: &PlanCache) -> StageTable {
        let stages = stage_census(&s.census, s.pp);
        let locals = local_view(&stages[si], s.tp);
        let local_census: Vec<Param> = locals.iter().map(|lp| lp.local.clone()).collect();
        let fb = FlatBuffer::build(&local_census, s.bucket_elems);

        // --- fwd/bwd geometry -------------------------------------------
        // Layers *hosted by this stage*, from the split rule shared with
        // `stage_census` — not `max global layer index + 1`, which for
        // stages > 0 would count every upstream layer too (inflating the
        // attention-FLOPs and TP-AR terms) and, worse, differ between
        // shape-identical interior stages, breaking the canonical-stage
        // sharing contract (`canonical_stage` assumes equal-layer-count
        // interior stages build identical tables).
        let n_layers = stage_layer_count(s.n_layers, s.pp, si) as f64;
        let hidden = locals
            .iter()
            .find(|p| p.local.name.ends_with("attn_norm.weight"))
            .map(|p| p.local.numel() as f64)
            .unwrap_or(0.0);
        let matrix_numel: f64 = locals
            .iter()
            .filter(|p| p.local.shape.is_matrix())
            .map(|p| p.local.numel() as f64)
            .sum();
        let total_elems = fb.total as f64;
        let param_bytes: f64 =
            locals.iter().map(|p| WIRE_BYTES * p.local.numel() as f64).sum();
        let bucket_bytes: Vec<f64> =
            fb.buckets.iter().map(|b| WIRE_BYTES * b.size() as f64).collect();
        let bucket_frac: Vec<f64> =
            fb.buckets.iter().map(|b| b.size() as f64 / total_elems).collect();

        // One DP plan per stage: it defines both the gradient-path shard
        // sizes (variable-size RS for ASC/LB-ASC) and optimizer ownership.
        let dp_plan: Option<Arc<DpPlan>> = match s.strategy {
            DpStrategy::Asc => Some(cache.dp_plan(&DpKey::for_scenario(s, si), || {
                naive_atomic_per_bucket(&fb, s.dp)
            })),
            DpStrategy::LbAsc => {
                let optim = OptimCost::new(s.optim);
                let metric = s.metric;
                let locals_ref: &[LocalParam] = &locals;
                Some(cache.dp_plan(&DpKey::for_scenario(s, si), || {
                    alpha_balanced(&fb, s.dp, s.alpha, true, move |p| {
                        if p.param.is_matrix_opt() {
                            optim.cost(&locals_ref[p.index].full_shape, metric)
                        } else {
                            optim.cost(&p.param.shape, metric)
                        }
                    })
                }))
            }
            _ => None,
        };
        let shard_bytes: Option<Vec<Vec<f64>>> = dp_plan.as_ref().map(|plan| {
            (0..fb.buckets.len())
                .map(|i| {
                    plan.shard_sizes(i).iter().map(|&x| x as f64 * WIRE_BYTES).collect()
                })
                .collect()
        });

        // --- optimizer-step tables --------------------------------------
        let ew_elems = |indices: &[usize]| -> f64 {
            indices
                .iter()
                .filter(|&&i| !locals[i].local.is_matrix_opt())
                .map(|&i| locals[i].local.numel() as f64)
                .sum()
        };
        let optim = OptimCost::new(s.optim);

        let strat = match s.strategy {
            DpStrategy::Sc => {
                let all_indices: Vec<usize> = (0..locals.len()).collect();
                let matrix_indices: Vec<usize> = all_indices
                    .iter()
                    .cloned()
                    .filter(|&i| locals[i].local.is_matrix_opt())
                    .collect();
                StrategyTable::Sc {
                    sizes: matrix_indices
                        .iter()
                        .map(|&i| WIRE_BYTES * locals[i].full_shape.numel() as f64)
                        .collect(),
                    flops_total: matrix_indices
                        .iter()
                        .map(|&i| optim.flops(&locals[i].full_shape))
                        .sum(),
                    state_total: matrix_indices
                        .iter()
                        .map(|&i| optim.state_bytes(&locals[i].full_shape))
                        .sum(),
                    ew_all: ew_elems(&all_indices),
                }
            }
            DpStrategy::NvLayerwise => {
                let w = |p: &crate::buffer::PlacedParam| p.numel() as f64;
                let plan = cache.layerwise_plan(&DpKey::for_scenario(s, si), || {
                    layerwise(&fb, s.dp, w)
                });
                let rank_params = plan.rank_params(&fb);
                let mut rank_sizes: Vec<Vec<f64>> = Vec::with_capacity(s.dp);
                let mut rank_flops = vec![0.0; s.dp];
                let mut rank_state = vec![0.0; s.dp];
                let mut rank_ew = vec![0.0; s.dp];
                for d in 0..s.dp {
                    let owned_matrix: Vec<usize> = rank_params[d]
                        .iter()
                        .cloned()
                        .filter(|&i| locals[i].local.is_matrix_opt())
                        .collect();
                    rank_sizes.push(
                        owned_matrix
                            .iter()
                            .map(|&i| WIRE_BYTES * locals[i].full_shape.numel() as f64)
                            .collect(),
                    );
                    rank_flops[d] = owned_matrix
                        .iter()
                        .map(|&i| optim.flops(&locals[i].full_shape))
                        .sum();
                    rank_state[d] = owned_matrix
                        .iter()
                        .map(|&i| optim.state_bytes(&locals[i].full_shape))
                        .sum::<f64>()
                        + ew_elems(&rank_params[d]) * 8.0;
                    rank_ew[d] = ew_elems(&rank_params[d]);
                }
                StrategyTable::Nv { rank_sizes, rank_flops, rank_state, rank_ew }
            }
            DpStrategy::Asc | DpStrategy::LbAsc => {
                let plan = dp_plan.as_ref().expect("ASC/LB-ASC requires a DP plan");
                let rank_params = plan.rank_params(&fb);
                // Element-wise loads prorated by actual cut overlap.
                let ew_loads = plan.rank_loads(&fb, |p| {
                    if p.param.is_matrix_opt() { 0.0 } else { p.numel() as f64 }
                });
                // The hoisted task table: one `make_task` record per
                // fragmented matrix parameter, computed once per stage
                // instead of per DP rank per iteration.
                let mut symbols = Symbols::new();
                let mut tasks: Vec<TaskMeta> = Vec::new();
                let mut meta_of_local: Vec<Option<u32>> = vec![None; locals.len()];
                for (i, lp) in locals.iter().enumerate() {
                    if lp.local.is_matrix_opt() {
                        meta_of_local[i] = Some(tasks.len() as u32);
                        tasks.push(TaskMeta {
                            id: i,
                            name: symbols.intern(&lp.local.name),
                            cost: optim.cost(&lp.full_shape, s.metric),
                            comm_bytes: WIRE_BYTES * lp.full_shape.numel() as f64,
                            flops: optim.flops(&lp.full_shape),
                            state_bytes: optim.state_bytes(&lp.full_shape),
                        });
                    }
                }
                let rank_tasks: Vec<Vec<u32>> = rank_params
                    .iter()
                    .map(|ps| ps.iter().filter_map(|&i| meta_of_local[i]).collect())
                    .collect();
                let mut rank_task_flops = vec![0.0; s.dp];
                let mut dp_flops = vec![0.0; s.dp];
                let mut dp_state = vec![0.0; s.dp];
                for d in 0..s.dp {
                    let flops: f64 =
                        rank_tasks[d].iter().map(|&t| tasks[t as usize].flops).sum();
                    rank_task_flops[d] = flops;
                    dp_flops[d] = flops + 12.0 * ew_loads[d];
                    dp_state[d] = rank_tasks[d]
                        .iter()
                        .map(|&t| tasks[t as usize].state_bytes)
                        .sum::<f64>()
                        + ew_loads[d] * 8.0;
                }
                let mut worst: (f64, Option<usize>) = (0.0, None);
                for d in 0..s.dp {
                    if s.tp > 1 && !rank_tasks[d].is_empty() && dp_flops[d] >= worst.0 {
                        worst = (dp_flops[d], Some(d));
                    }
                }
                StrategyTable::Atomic {
                    tasks,
                    symbols,
                    rank_tasks,
                    rank_task_flops,
                    dp_flops,
                    dp_state,
                    ew_loads,
                    worst_rank: worst.1,
                }
            }
            DpStrategy::MatrixFsdp => {
                // ZeRO-3 contiguous row sharding of every TP-local matrix.
                // The preconditioner (Newton-Schulz / Gram / eigen work) is
                // recomputed redundantly by every rank holding a shard —
                // that is what makes the update communication-free — and
                // only the element-linear pass (the `coeff·numel` term of
                // each FLOPs model) shards with the rows. State is
                // row-prorated, so per-rank bytes sum exactly to the
                // unsharded census (pinned by `tests/rivals_props.rs`).
                let coeff = linear_flops_coeff(s.optim);
                let mut rank_flops = vec![0.0; s.dp];
                let mut rank_state = vec![0.0; s.dp];
                let mut ew_all = 0.0;
                for lp in &locals {
                    if !lp.local.is_matrix_opt() {
                        ew_all += lp.local.numel() as f64;
                        continue;
                    }
                    let rows = lp.local.shape.rows();
                    let cols = lp.local.shape.cols() as f64;
                    let numel = lp.local.numel() as f64;
                    let precond = optim.flops(&lp.local.shape) - coeff * numel;
                    let state = optim.state_bytes(&lp.local.shape);
                    for (d, rf) in rank_flops.iter_mut().enumerate() {
                        let owned = zero3_rows(rows, s.dp, d) as f64;
                        if owned == 0.0 {
                            continue; // no shard -> no redundant precond
                        }
                        *rf += precond + coeff * owned * cols;
                        rank_state[d] += state * (owned * cols / numel);
                    }
                }
                for st in rank_state.iter_mut() {
                    *st += 8.0 * ew_all / s.dp as f64;
                }
                StrategyTable::Fsdp { rank_flops, rank_state, ew_all }
            }
            DpStrategy::DMuon => {
                // Whole-tensor DP ownership: greedy LPT over full-shape
                // update FLOPs (deterministic, see `partition::rivals`).
                // Momentum lives ZeRO-1-sharded across DP; owners gather
                // shards, orthogonalize, scatter updates back.
                let all_indices: Vec<usize> = (0..locals.len()).collect();
                let matrix_indices: Vec<usize> = all_indices
                    .iter()
                    .cloned()
                    .filter(|&i| locals[i].local.is_matrix_opt())
                    .collect();
                let costs: Vec<f64> = matrix_indices
                    .iter()
                    .map(|&i| optim.flops(&locals[i].full_shape))
                    .collect();
                let owners = lpt_owners(&costs, s.dp);
                let mut rank_sizes: Vec<Vec<f64>> = vec![Vec::new(); s.dp];
                let mut rank_item_flops: Vec<Vec<f64>> = vec![Vec::new(); s.dp];
                let mut rank_flops = vec![0.0; s.dp];
                for (k, &i) in matrix_indices.iter().enumerate() {
                    let d = owners[k];
                    rank_sizes[d].push(WIRE_BYTES * locals[i].full_shape.numel() as f64);
                    rank_item_flops[d].push(costs[k]);
                    rank_flops[d] += costs[k];
                }
                let state_total: f64 = matrix_indices
                    .iter()
                    .map(|&i| optim.state_bytes(&locals[i].full_shape))
                    .sum();
                let ew_all = ew_elems(&all_indices);
                let rank_state =
                    vec![(state_total + 8.0 * ew_all) / s.dp as f64; s.dp];
                StrategyTable::DMuon {
                    rank_sizes,
                    rank_item_flops,
                    rank_flops,
                    rank_state,
                    ew_all,
                }
            }
            DpStrategy::Dion => {
                // Low-rank factor updates at rank fraction
                // `DION_RANK_FRACTION`: the m·n-sized sketch/error-feedback
                // passes stream over the DP-sharded buffer, the factor-side
                // work and factors themselves are replicated, and one fused
                // All-Reduce of the concatenated factors synchronizes ranks.
                let all_indices: Vec<usize> = (0..locals.len()).collect();
                let mut flops_per_gpu = 0.0;
                let mut factor_elems = 0.0;
                let mut state_per_rank = 0.0;
                for lp in &locals {
                    if !lp.local.is_matrix_opt() {
                        continue;
                    }
                    let m = lp.local.shape.rows() as f64;
                    let n = lp.local.shape.cols() as f64;
                    flops_per_gpu += dion_flops(m, n, DION_RANK_FRACTION, s.dp);
                    factor_elems += dion_factor_elems(m, n, DION_RANK_FRACTION);
                    state_per_rank += dion_state_bytes(m, n, DION_RANK_FRACTION, s.dp);
                }
                let ew_all = ew_elems(&all_indices);
                state_per_rank += 8.0 * ew_all / s.dp as f64;
                StrategyTable::Dion {
                    flops_per_gpu,
                    factor_bytes: WIRE_BYTES * factor_elems,
                    state_per_rank,
                    ew_all,
                }
            }
        };

        StageTable {
            n_layers,
            hidden,
            matrix_numel,
            total_elems,
            param_bytes,
            bucket_bytes,
            bucket_frac,
            shard_bytes,
            strat,
        }
    }
}

/// Materialize one DP rank's build-time task census from the hoisted
/// table (cold TP solves only — the warm path never calls this).
fn rank_census(tasks: &[TaskMeta], symbols: &Symbols, rank_tasks: &[u32]) -> Vec<TpTask> {
    rank_tasks
        .iter()
        .enumerate()
        .map(|(id, &t)| {
            let m = &tasks[t as usize];
            TpTask {
                id,
                name: symbols.name(m.name).to_string(),
                cost: m.cost,
                comm_bytes: m.comm_bytes,
                flops: m.flops,
                state_bytes: m.state_bytes,
            }
        })
        .collect()
}

/// Convert a byte capacity to the balancing-cost units of `metric`.
fn c_max_units(c_bytes: f64, metric: CostMetric, tasks: &[TpTask]) -> f64 {
    match metric {
        CostMetric::Numel | CostMetric::StateBytes => c_bytes / WIRE_BYTES,
        CostMetric::Flops => {
            let total_cost: f64 = tasks.iter().map(|t| t.cost).sum();
            let total_bytes: f64 = tasks.iter().map(|t| t.comm_bytes).sum();
            if total_bytes == 0.0 {
                c_bytes
            } else {
                c_bytes * total_cost / total_bytes
            }
        }
    }
}

/// Micro-group pipeline timing (Fig. 2 right): gather All-to-All,
/// balanced compute, scatter All-to-All, with the communication stream
/// running ahead of compute (compute-comm overlap across groups).
/// Reads the plan's precomputed [`GroupCost`] scalars — no allocation.
///
/// [`GroupCost`]: crate::schedule::microgroup::GroupCost
fn tp_pipeline(plan: &TpPlan, comm: &CommModel, gpu_flops: f64) -> f64 {
    let mut comm_stream = Stream::new();
    let mut compute_stream = Stream::new();
    let mut end = 0.0f64;
    for gc in &plan.group_cost {
        // Each fused collective pays one kernel launch; unfused plans pay
        // it per tensor (the paper's "many small kernels" penalty).
        let t_gather = comm.hw.launch_overhead
            + comm.collective_parts(
                CollectiveKind::AllToAll,
                gc.total_bytes,
                gc.min_rank_bytes,
                plan.ranks,
                LinkKind::IntraNode,
            );
        let t_compute = gc.max_rank_flops / gpu_flops;
        let t_scatter = t_gather; // updates are the same volume back
        let gather_done = comm_stream.schedule(0.0, t_gather);
        let compute_done = compute_stream.schedule(gather_done, t_compute);
        end = comm_stream.schedule(compute_done, t_scatter);
    }
    end
}

/// Scalar results of one stage's optimizer step; the per-rank load
/// vectors live in the [`StageTable`] / worst [`TpPlan`] and are copied
/// into the output only for the pacing stage (see [`fill_loads`]).
#[derive(Clone)]
pub(crate) struct OptScalars {
    pub(crate) time_s: f64,
    pub(crate) planning_s: f64,
    pub(crate) n_micro_groups: usize,
    pub(crate) worst_tplan: Option<Arc<TpPlan>>,
}

/// The optimizer step of one PP stage under the scenario's strategy —
/// warm-path arithmetic over the stage table; only cold TP-plan solves
/// (cache misses) allocate. `hw` is the stage's (possibly
/// straggler-derated) compute profile; collectives always price against
/// the shared fabric in `comm`.
pub(crate) fn optimizer_step(
    s: &Scenario,
    hw: &Hardware,
    comm: &CommModel,
    table: &StageTable,
    stage: usize,
    cache: &PlanCache,
) -> OptScalars {
    optimizer_step_knobs(s, hw, comm, table, stage, cache, s.c_max_bytes)
}

/// [`optimizer_step`] with the fusion capacity supplied by the caller
/// instead of read off the scenario — the batch tier's per-lane entry
/// ([`crate::sim::batch`]), where N lanes share one `StageTable` but
/// carry their own `C_max`. Passing `s.c_max_bytes` is bit-identical to
/// [`optimizer_step`]: the TP-plan key below is constructed exactly as
/// [`TpKey::for_scenario`] does.
pub(crate) fn optimizer_step_knobs(
    s: &Scenario,
    hw: &Hardware,
    comm: &CommModel,
    table: &StageTable,
    stage: usize,
    cache: &PlanCache,
    c_max_bytes: Option<f64>,
) -> OptScalars {
    let gpu = hw.gpu_flops;
    let tp = s.tp;
    let ew_time = |elems: f64| hw.memory_time(elems * ADAMW_BYTES_PER_ELEM);

    match &table.strat {
        StrategyTable::Sc { sizes, flops_total, state_total: _, ew_all } => {
            // Every GPU all-gathers every fragmented tensor (unfused) and
            // performs the identical full-tensor update.
            let comm_t = if tp > 1 {
                comm.per_message(sizes, tp, LinkKind::IntraNode, CollectiveKind::AllGather)
            } else {
                0.0
            };
            let ew = ew_all * tp as f64; // replicated full tensors
            OptScalars {
                time_s: comm_t + flops_total / gpu + ew_time(ew),
                planning_s: 0.0,
                n_micro_groups: 0,
                worst_tplan: None,
            }
        }
        StrategyTable::Nv { rank_sizes, rank_flops, rank_state: _, rank_ew } => {
            // Layer-granular global LPT across DP; TP-redundant compute;
            // exposed DP Broadcast of updated parameters.
            let mut max_time = 0.0f64;
            for d in 0..s.dp {
                let comm_t = if tp > 1 {
                    comm.per_message(
                        &rank_sizes[d],
                        tp,
                        LinkKind::IntraNode,
                        CollectiveKind::AllGather,
                    )
                } else {
                    0.0
                };
                let t = comm_t + rank_flops[d] / gpu + ew_time(rank_ew[d]);
                max_time = max_time.max(t);
            }
            // Exposed redistribution of updated parameters over the DP
            // (inter-node) fabric.
            let bcast = comm.collective(
                CollectiveKind::Broadcast,
                table.param_bytes,
                s.dp,
                LinkKind::InterNode,
            );
            OptScalars {
                time_s: max_time + bcast,
                planning_s: 0.0,
                n_micro_groups: 0,
                worst_tplan: None,
            }
        }
        StrategyTable::Atomic {
            tasks,
            symbols,
            rank_tasks,
            rank_task_flops,
            dp_flops: _,
            dp_state: _,
            ew_loads,
            worst_rank,
        } => {
            let lb = s.strategy == DpStrategy::LbAsc;
            let mut tp_planning_s = 0.0f64;
            let mut max_time = 0.0f64;
            let mut worst_tplan: Option<Arc<TpPlan>> = None;
            for d in 0..s.dp {
                let tp_time = if tp > 1 && !rank_tasks[d].is_empty() {
                    let t_tp = Instant::now();
                    let key = TpKey {
                        dp_key: DpKey::for_scenario(s, stage),
                        rank: d,
                        c_max_bits: c_max_bytes.map(f64::to_bits),
                        optim: s.optim,
                    };
                    let tplan = cache.tp_plan(&key, || {
                        let census = rank_census(tasks, symbols, &rank_tasks[d]);
                        if lb {
                            match c_max_bytes {
                                // No-Fuse (Fig. 14 baseline): one collective
                                // per tensor, hosts still load-balanced.
                                None => unfused_plan(census, tp),
                                Some(cb) => {
                                    let cap = c_max_units(cb, s.metric, &census).max(
                                        census.iter().map(|t| t.cost).fold(0.0, f64::max),
                                    );
                                    build_micro_groups(census, tp, cap)
                                }
                            }
                        } else {
                            naive_tp_plan(census, tp, c_max_bytes)
                        }
                    });
                    tp_planning_s += t_tp.elapsed().as_secs_f64();
                    let t = tp_pipeline(&tplan, comm, gpu);
                    if Some(d) == *worst_rank {
                        worst_tplan = Some(tplan);
                    }
                    t
                } else {
                    // tp == 1: all hosted locally, pure compute.
                    rank_task_flops[d] / gpu
                };
                max_time = max_time.max(tp_time + ew_time(ew_loads[d]));
            }
            let n_micro_groups = worst_tplan.as_ref().map(|p| p.groups.len()).unwrap_or(0);
            OptScalars {
                time_s: max_time,
                planning_s: tp_planning_s,
                n_micro_groups,
                worst_tplan,
            }
        }
        StrategyTable::Fsdp { rank_flops, rank_state: _, ew_all } => {
            // Communication-free: every rank recomputes the matrix-level
            // preconditioners for the matrices it holds rows of (the
            // parameters are already materialized by FSDP's compute-path
            // All-Gather) and applies the update to its own rows; the
            // element-wise tail is ZeRO-3-sharded too.
            let max_flops = rank_flops.iter().cloned().fold(0.0, f64::max);
            OptScalars {
                time_s: max_flops / gpu + ew_time(*ew_all / s.dp as f64),
                planning_s: 0.0,
                n_micro_groups: 0,
                worst_tplan: None,
            }
        }
        StrategyTable::DMuon { rank_sizes, rank_item_flops, rank_flops: _, rank_state: _, ew_all } => {
            // Per owner rank: gather each owned tensor's momentum shards
            // over the DP fabric, orthogonalize, scatter the update
            // shards back — the comm stream runs ahead of compute
            // (gather i+1 overlaps orthogonalization i), mirroring
            // `tp_pipeline` at whole-tensor granularity on the
            // inter-node link.
            let ew = ew_time(*ew_all / s.dp as f64);
            let mut max_time = 0.0f64;
            for d in 0..s.dp {
                let mut comm_stream = Stream::new();
                let mut compute_stream = Stream::new();
                let mut end = 0.0f64;
                for (k, &bytes) in rank_sizes[d].iter().enumerate() {
                    let t_move = comm.hw.launch_overhead
                        + comm.collective(
                            CollectiveKind::Gather,
                            bytes,
                            s.dp,
                            LinkKind::InterNode,
                        );
                    let t_compute = rank_item_flops[d][k] / gpu;
                    let gather_done = comm_stream.schedule(0.0, t_move);
                    let compute_done = compute_stream.schedule(gather_done, t_compute);
                    // Scatter returns the same volume (CollectiveKind::
                    // Scatter prices identically to Gather).
                    end = comm_stream.schedule(compute_done, t_move);
                }
                max_time = max_time.max(end + ew);
            }
            OptScalars {
                time_s: max_time,
                planning_s: 0.0,
                n_micro_groups: 0,
                worst_tplan: None,
            }
        }
        StrategyTable::Dion { flops_per_gpu, factor_bytes, state_per_rank: _, ew_all } => {
            // One fused All-Reduce of the concatenated low-rank factors,
            // then the (replicated) factor update and the DP-sharded
            // error-feedback / element-wise pass.
            let comm_t = if s.dp > 1 {
                comm.hw.launch_overhead
                    + comm.collective(
                        CollectiveKind::AllReduce,
                        *factor_bytes,
                        s.dp,
                        LinkKind::InterNode,
                    )
            } else {
                0.0
            };
            OptScalars {
                time_s: comm_t + flops_per_gpu / gpu + ew_time(*ew_all / s.dp as f64),
                planning_s: 0.0,
                n_micro_groups: 0,
                worst_tplan: None,
            }
        }
    }
}

/// Copy the pacing stage's per-rank load vectors into `out`, reusing its
/// capacity (no allocation once the vectors have been sized).
pub(crate) fn fill_loads(out: &mut Breakdown, s: &Scenario, table: &StageTable, worst: Option<&TpPlan>) {
    fn set(dst: &mut Vec<f64>, src: &[f64]) {
        dst.clear();
        dst.extend_from_slice(src);
    }
    fn fill(dst: &mut Vec<f64>, n: usize, v: f64) {
        dst.clear();
        dst.resize(n, v);
    }
    match &table.strat {
        StrategyTable::Sc { flops_total, state_total, .. } => {
            fill(&mut out.dp_loads_flops, s.dp, *flops_total);
            fill(&mut out.dp_loads_state, s.dp, *state_total);
            fill(&mut out.tp_loads_flops, s.tp, *flops_total);
            fill(&mut out.tp_loads_state, s.tp, *state_total);
        }
        StrategyTable::Nv { rank_flops, rank_state, .. } => {
            set(&mut out.dp_loads_flops, rank_flops);
            set(&mut out.dp_loads_state, rank_state);
            let max_flops = rank_flops.iter().cloned().fold(0.0, f64::max);
            fill(&mut out.tp_loads_flops, s.tp, max_flops);
            fill(&mut out.tp_loads_state, s.tp, 0.0);
        }
        StrategyTable::Atomic { dp_flops, dp_state, .. } => {
            set(&mut out.dp_loads_flops, dp_flops);
            set(&mut out.dp_loads_state, dp_state);
            match worst {
                Some(plan) => {
                    set(&mut out.tp_loads_flops, &plan.rank_flops);
                    set(&mut out.tp_loads_state, &plan.rank_state);
                }
                None => {
                    fill(&mut out.tp_loads_flops, s.tp, 0.0);
                    fill(&mut out.tp_loads_state, s.tp, 0.0);
                }
            }
        }
        StrategyTable::Fsdp { rank_flops, rank_state, .. }
        | StrategyTable::DMuon { rank_flops, rank_state, .. } => {
            // Like NV-layerwise: DP is the load-bearing plane; TP ranks
            // replicate the pacing rank's compute and hold no extra state.
            set(&mut out.dp_loads_flops, rank_flops);
            set(&mut out.dp_loads_state, rank_state);
            let max_flops = rank_flops.iter().cloned().fold(0.0, f64::max);
            fill(&mut out.tp_loads_flops, s.tp, max_flops);
            fill(&mut out.tp_loads_state, s.tp, 0.0);
        }
        StrategyTable::Dion { flops_per_gpu, state_per_rank, .. } => {
            // Uniform by construction: every rank runs the same low-rank
            // update over its shard.
            fill(&mut out.dp_loads_flops, s.dp, *flops_per_gpu);
            fill(&mut out.dp_loads_state, s.dp, *state_per_rank);
            fill(&mut out.tp_loads_flops, s.tp, *flops_per_gpu);
            fill(&mut out.tp_loads_state, s.tp, 0.0);
        }
    }
}

/// The Fig. 14 "No-Fuse" baseline: one micro-group (i.e. one pair of
/// collectives) per tensor; host ranks still balanced greedily so the
/// comparison isolates the *fusion* benefit.
fn unfused_plan(tasks: Vec<TpTask>, tp: usize) -> TpPlan {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| tasks[b].cost.total_cmp(&tasks[a].cost));
    let mut loads = vec![0.0; tp];
    let mut groups = Vec::with_capacity(tasks.len());
    for i in order {
        let host = (0..tp)
            .min_by(|&a, &b| loads[a].total_cmp(&loads[b]))
            .unwrap();
        loads[host] += tasks[i].cost;
        let mut rank_loads = vec![0.0; tp];
        rank_loads[host] = tasks[i].cost;
        groups.push(MicroGroup {
            assignments: vec![(i, host)],
            rank_loads,
            max_load: tasks[i].cost,
            comm_bytes: tasks[i].comm_bytes,
        });
    }
    TpPlan::assemble(tp, 0.0, tasks, groups)
}

/// The ASC TP path: fixed census-order chunking (no LPT), round-robin
/// host assignment (no min-heap), optional fusion cap by bytes.
fn naive_tp_plan(tasks: Vec<TpTask>, tp: usize, c_max_bytes: Option<f64>) -> TpPlan {
    let cap_bytes = c_max_bytes.unwrap_or(0.0);
    let mut groups = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut current_bytes = 0.0;
    let mut rr = 0usize;
    let mut assignments_acc: Vec<Vec<(usize, usize)>> = Vec::new();
    let mut current_assign: Vec<(usize, usize)> = Vec::new();
    for (i, t) in tasks.iter().enumerate() {
        if !current.is_empty() && current_bytes + t.comm_bytes > cap_bytes {
            assignments_acc.push(std::mem::take(&mut current_assign));
            groups.push(std::mem::take(&mut current));
            current_bytes = 0.0;
        }
        current.push(i);
        current_assign.push((i, rr % tp));
        rr += 1;
        current_bytes += t.comm_bytes;
    }
    if !current.is_empty() {
        assignments_acc.push(current_assign);
        groups.push(current);
    }
    let mg = assignments_acc
        .into_iter()
        .map(|assignments| {
            let mut rank_loads = vec![0.0; tp];
            let mut comm_bytes = 0.0;
            for &(t, r) in &assignments {
                rank_loads[r] += tasks[t].cost;
                comm_bytes += tasks[t].comm_bytes;
            }
            let max_load = rank_loads.iter().cloned().fold(0.0, f64::max);
            MicroGroup { assignments, rank_loads, max_load, comm_bytes }
        })
        .collect();
    TpPlan::assemble(tp, cap_bytes, tasks, mg)
}

/// Per-micro-batch compute/comm scalars of one stage: forward compute
/// time, backward compute time, the TP activation All-Reduce block, and
/// the boundary activation bytes (for PP point-to-point transfers).
/// `hw` is the stage's (possibly straggler-derated) compute profile.
pub(crate) fn stage_times(s: &Scenario, hw: &Hardware, comm: &CommModel, t: &StageTable) -> (f64, f64, f64, f64) {
    let tokens = s.tokens() as f64;
    let seq = s.seq_len as f64;
    let tp = s.tp as f64;
    // fwd+bwd dense FLOPs per GPU (TP-local weights, one microbatch):
    // 2*T*numel forward, 2x that backward, plus the attention
    // score/value terms (QK^T and AV, causal x1/2, fwd only here).
    let attn = t.n_layers * 2.0 * tokens * seq * t.hidden / tp;
    let fwd = 2.0 * tokens * t.matrix_numel + attn;
    let bwd = 2.0 * fwd;
    let fwd_t = fwd / hw.gpu_flops;
    let bwd_t = bwd / hw.gpu_flops;

    // TP activation All-Reduces: 2 per layer fwd + 2 bwd.
    let act_bytes = WIRE_BYTES * tokens * t.hidden;
    let tp_ar = if s.tp > 1 {
        4.0 * t.n_layers
            * comm.collective(CollectiveKind::AllReduce, act_bytes, s.tp, LinkKind::IntraNode)
    } else {
        0.0
    };
    (fwd_t, bwd_t, tp_ar, act_bytes)
}

/// The collective-timing model of a scenario's shared fabric — the one
/// construction both dispatch arms ([`simulate_closed_form_into`] and
/// [`simulate_timeline_into`]) price collectives against, hoisted here
/// so the two can't drift. `Hardware` owns no heap data (`&'static`
/// name + scalars), so this is a stack copy: warm-path safe.
fn comm_model(s: &Scenario) -> CommModel {
    CommModel::new(s.hw.clone())
}

/// Does the strategy's gradient path use All-Reduce (full parameter
/// copies) rather than the ZeRO-1 Reduce-Scatter / All-Gather pair?
pub(crate) fn uses_all_reduce(s: &Scenario) -> bool {
    matches!(s.strategy, DpStrategy::Sc | DpStrategy::NvLayerwise)
}

/// Gradient collective time for bucket `b` (Reduce-Scatter with the DP
/// plan's variable shard sizes, or All-Reduce for SC/NV-layerwise).
pub(crate) fn bucket_grad_time(s: &Scenario, comm: &CommModel, t: &StageTable, b: usize) -> f64 {
    if s.dp <= 1 {
        return 0.0;
    }
    if uses_all_reduce(s) {
        comm.collective(CollectiveKind::AllReduce, t.bucket_bytes[b], s.dp, LinkKind::InterNode)
    } else if let Some(shards) = &t.shard_bytes {
        comm.collective_v(CollectiveKind::ReduceScatter, &shards[b], LinkKind::InterNode)
    } else {
        comm.collective(CollectiveKind::ReduceScatter, t.bucket_bytes[b], s.dp,
                        LinkKind::InterNode)
    }
}

/// ZeRO-1 parameter All-Gather time for bucket `b` (0 for strategies
/// holding full parameter copies).
pub(crate) fn bucket_ag_time(s: &Scenario, comm: &CommModel, t: &StageTable, b: usize) -> f64 {
    if s.dp <= 1 || uses_all_reduce(s) {
        return 0.0;
    }
    if let Some(shards) = &t.shard_bytes {
        comm.collective_v(CollectiveKind::AllGather, &shards[b], LinkKind::InterNode)
    } else {
        comm.collective(CollectiveKind::AllGather, t.bucket_bytes[b], s.dp, LinkKind::InterNode)
    }
}

/// Gradient-path wire bytes per GPU across the stage's buckets.
pub(crate) fn stage_grad_bytes(s: &Scenario, comm: &CommModel, t: &StageTable) -> f64 {
    let kind = if uses_all_reduce(s) {
        CollectiveKind::AllReduce
    } else {
        CollectiveKind::ReduceScatter
    };
    t.bucket_bytes.iter().map(|&b| comm.volume(kind, b, s.dp)).sum()
}

/// Gradient-path + parameter-path communication schedule per bucket —
/// warm-path arithmetic over the stage table's bucket/shard vectors.
fn fwd_bwd_time(s: &Scenario, comm: &CommModel, t: &StageTable) -> (f64, f64, f64) {
    let (fwd_t, bwd_t, tp_ar, _act_bytes) = stage_times(s, &s.hw, comm, t);

    // Backward: buckets complete sequentially; grad collective per bucket
    // overlaps subsequent buckets' compute.
    let mut compute = Stream::new();
    let mut comm_stream = Stream::new();
    let mut bwd_end = 0.0f64;
    for i in 0..t.bucket_bytes.len() {
        let frac = t.bucket_frac[i];
        let grads_ready = compute.schedule(0.0, bwd_t * frac);
        let t_comm = bucket_grad_time(s, comm, t, i);
        bwd_end = comm_stream.schedule(grads_ready, t_comm).max(grads_ready);
    }
    bwd_end = bwd_end.max(compute.free_at());
    let exposed_bwd = bwd_end - bwd_t;
    let grad_bytes_per_gpu = stage_grad_bytes(s, comm, t);

    // Forward: ZeRO-1 strategies all-gather each bucket's parameters,
    // overlapped with the previous bucket's forward compute. SC and
    // NV-layerwise hold full parameter copies (no gather here; layerwise
    // pays its Broadcast inside the optimizer step instead).
    let mut fwd_compute = Stream::new();
    let mut fwd_comm = Stream::new();
    let mut fwd_end = 0.0f64;
    for i in 0..t.bucket_bytes.len() {
        let frac = t.bucket_frac[i];
        let t_ag = bucket_ag_time(s, comm, t, i);
        let params_ready = fwd_comm.schedule(0.0, t_ag);
        fwd_end = fwd_compute.schedule(params_ready, fwd_t * frac);
    }
    let exposed_fwd = fwd_end - fwd_t;

    let total = bwd_end + fwd_end + tp_ar;
    (total, exposed_bwd + exposed_fwd, grad_bytes_per_gpu)
}

/// Simulate one full iteration with a throwaway plan cache (cold path).
pub fn simulate_iteration(s: &Scenario) -> Breakdown {
    simulate_iteration_cached(s, &PlanCache::new())
}

/// Simulate one full iteration; the slowest PP stage paces both phases.
///
/// Per-stage census tables, the DP partition, and the per-rank TP
/// micro-group plans are solved **once** and memoized in `cache`; a warm
/// cache turns the whole call into table arithmetic (see the module
/// docs). Allocates only the returned [`Breakdown`]'s vectors — reuse
/// one via [`simulate_iteration_into`] to avoid even that.
pub fn simulate_iteration_cached(s: &Scenario, cache: &PlanCache) -> Breakdown {
    let mut out = Breakdown::default();
    simulate_iteration_into(s, cache, &mut out);
    out
}

/// [`simulate_iteration_cached`] writing into a caller-owned
/// [`Breakdown`]. With a warm `cache` and an `out` whose vectors have
/// been sized by a prior call (same DP/TP), this performs **zero heap
/// allocations** at steady state on *both* dispatch arms — the
/// closed-form fast path (`pp == 1`, `micro_batches == 1`,
/// `straggler == 1.0`) outright, and the event-driven timeline arm
/// once the calling thread's `SimScratch` (lean timeline, flat
/// pipeline tables, interned schedule orders) has grown to the
/// scenario's shape. Both contracts are enforced by the counting
/// allocator in `tests/warm_alloc.rs`.
pub fn simulate_iteration_into(s: &Scenario, cache: &PlanCache, out: &mut Breakdown) {
    if closed_form_path(s) {
        simulate_closed_form_into(s, cache, out);
    } else {
        simulate_timeline_into(s, cache, out);
    }
}

/// The dispatch rule: does `s` take the closed-form single-stage fast
/// path (vs the event-driven timeline engine)? The single source of
/// truth shared by [`simulate_iteration_into`] and the optimizer-search
/// lower bounds ([`crate::sim::bounds`]), which are tighter on the
/// closed-form arm and must agree exactly with the dispatcher.
/// Fault/heterogeneity knobs ([`Scenario::faulted`]) route to the
/// timeline arm, which owns per-stage derates, per-link pricing, and
/// recovery charging.
pub(crate) fn closed_form_path(s: &Scenario) -> bool {
    s.pp <= 1 && s.micro_batches <= 1 && s.straggler == 1.0 && !s.faulted()
}

/// The closed-form single-stage playback (see the module docs) — the
/// dispatcher only routes `pp == 1` here, so this is exactly one
/// stage's bucket-overlap arithmetic plus its optimizer step.
fn simulate_closed_form_into(s: &Scenario, cache: &PlanCache, out: &mut Breakdown) {
    debug_assert!(s.pp <= 1, "closed form is the pp <= 1 fast path");
    out.reset();
    let comm = comm_model(s);
    // Fetch (or cold-build) the stage's hoisted tables; the fetch
    // latency is the warm proxy for offline planning time.
    let t_fetch = Instant::now();
    let key = StageKey::for_scenario(s, 0);
    let table = cache.stage_table(&key, || StageTable::build(s, 0, cache));
    let stage_planning_s = t_fetch.elapsed().as_secs_f64();

    let (fb_time, exposed, grad_bytes) = fwd_bwd_time(s, &comm, &table);
    let opt = optimizer_step(s, &s.hw, &comm, &table, 0, cache);

    // AdamW reference: equal-chunk ZeRO-1, memory-bound, per DP rank.
    let adamw_elems = table.total_elems / s.dp as f64;
    out.fwd_bwd_s = fb_time;
    out.optimizer_s = opt.time_s;
    out.exposed_comm_s = exposed;
    out.n_micro_groups = opt.n_micro_groups;
    out.grad_comm_bytes = grad_bytes;
    out.adamw_ref_s = s.hw.memory_time(adamw_elems * ADAMW_BYTES_PER_ELEM);
    fill_loads(out, s, &table, opt.worst_tplan.as_deref());
    out.planning_s = stage_planning_s + opt.planning_s;
    out.total_s = out.fwd_bwd_s + out.optimizer_s;
    // With a single stage, schedule idle == exposed communication.
    out.bubble_s = out.exposed_comm_s;
}

/// Everything the timeline engine schedules one stage from: the cached
/// table, the stage's (possibly straggler-derated) hardware, and the
/// per-micro-batch / per-step scalars. Cheap to clone (Arcs + scalars):
/// canonical-equal interior stages share one build.
#[derive(Clone)]
struct StagePlayback {
    table: Arc<StageTable>,
    hw: Hardware,
    /// The stage's collective-pricing model: the shared fabric with the
    /// inter-node bandwidth divided by the stage's worst link factor
    /// ([`ClusterProfile::stage_link`]). On homogeneous profiles the
    /// divisor is exactly 1.0, so this is bit-identical to the old
    /// single shared `comm_model(s)` — `CommModel` owns no heap, so the
    /// per-stage copy keeps the warm path allocation-free.
    comm: CommModel,
    /// Forward compute per micro-batch (s).
    fwd_t: f64,
    /// Backward compute per micro-batch (s).
    bwd_t: f64,
    /// TP activation All-Reduce block per micro-batch (s).
    tp_ar: f64,
    /// Point-to-point transfer of this stage's boundary activations (s).
    act_p2p: f64,
    /// Gradient-path wire bytes per GPU.
    grad_bytes: f64,
    /// The stage's optimizer step (scheduled as one stream consumer).
    opt: OptScalars,
}

/// Simulate one iteration on the event-driven timeline engine,
/// regardless of the fast-path rule — the entry the differential tests
/// compare against the closed form at `pp = 1, micro_batches = 1`.
/// [`simulate_iteration_into`] dispatches here automatically for
/// `pp > 1`, `micro_batches > 1`, or `straggler != 1.0`.
pub fn simulate_iteration_timeline(s: &Scenario, cache: &PlanCache) -> Breakdown {
    let mut out = Breakdown::default();
    simulate_timeline_into(s, cache, &mut out);
    out
}

/// The per-thread reusable workspace of the timeline playback: the lean
/// [`Timeline`], the flat pipeline-drive tables, the interned
/// schedule-order tables, and every per-stage vector
/// [`simulate_timeline_into`] used to allocate per call. One lives on
/// each thread that evaluates timeline scenarios — the sweep's
/// (persistent) `util::pool` workers and the caller's own thread — so a
/// warm sweep's steady state refills buffers in place instead of
/// touching the heap, across `parallel_map` batches as well as within
/// one (workers outlive the batch; see `util::pool`'s module docs).
///
/// Ownership/reset rules: the scratch is reachable only through the
/// thread-local [`SIM_SCRATCH`] (one playback at a time per thread; the
/// playback never re-enters itself). Every buffer is cleared at the top
/// of a playback and refilled, so stale state can't leak between
/// scenarios; capacity is retained and only grows, bounded by the
/// largest `(pp, micro_batches, bucket-count)` shape the thread has
/// seen.
struct SimScratch {
    /// The event timeline, lean mode ([`Timeline::reset`] per call).
    tl: Timeline,
    /// Interned `(schedule, pp, m)` slot tables.
    orders: OrderCache,
    /// Flat `pp × m` forward/backward drive tables + cursors + deps.
    pipe: PipeScratch,
    /// Per-stage playback scalars (Arc'd tables — clone-cheap).
    stages: Vec<StagePlayback>,
    /// Per-stage exposed All-Gather stretch of the first micro-batch.
    ag_stretch: Vec<f64>,
    /// Per-stage last backward compute task.
    last_bwd: Vec<Option<TaskId>>,
    /// Per-stage last gradient-collective task.
    last_rs: Vec<Option<TaskId>>,
    /// Per-stage optimizer completion times.
    opt_ends: Vec<f64>,
    /// Small dependency assembly buffer for emitted tasks.
    dbuf: Vec<TaskId>,
    /// Has this scratch served a playback before? (feeds the
    /// `scratch_reuses` counter).
    used: bool,
    /// The batch tier's per-worker buffers ([`crate::sim::batch`]): the
    /// SoA output block engine workers reuse across shared-plan groups
    /// plus the hoisted per-bucket columns of the chunked loops. Lives
    /// here so it rides the same persistent-worker warm-up story as the
    /// timeline scratch.
    batch: crate::sim::batch::BatchScratch,
}

impl SimScratch {
    fn new() -> SimScratch {
        SimScratch {
            tl: Timeline::new(),
            orders: OrderCache::new(),
            pipe: PipeScratch::new(),
            stages: Vec::new(),
            ag_stretch: Vec::new(),
            last_bwd: Vec::new(),
            last_rs: Vec::new(),
            opt_ends: Vec::new(),
            dbuf: Vec::new(),
            used: false,
            batch: crate::sim::batch::BatchScratch::new(),
        }
    }
}

thread_local! {
    /// One [`SimScratch`] per thread — pool workers and direct callers
    /// alike (see the struct docs for the ownership rules).
    static SIM_SCRATCH: RefCell<SimScratch> = RefCell::new(SimScratch::new());
}

/// Borrow this thread's batch-tier scratch ([`crate::sim::batch`]'s
/// per-worker buffers, co-located with the timeline scratch so
/// persistent pool workers keep both warm). The batch evaluator never
/// re-enters the simulator, so the `RefCell` borrow cannot nest.
pub(crate) fn with_batch_scratch<R>(
    f: impl FnOnce(&mut crate::sim::batch::BatchScratch) -> R,
) -> R {
    SIM_SCRATCH.with(|sc| f(&mut sc.borrow_mut().batch))
}

/// The timeline playback entry: borrow this thread's scratch and run
/// the schedule. The playback never calls back into itself, so the
/// `RefCell` borrow cannot be re-entered.
fn simulate_timeline_into(s: &Scenario, cache: &PlanCache, out: &mut Breakdown) {
    SIM_SCRATCH.with(|sc| simulate_timeline_scratch(s, cache, &mut sc.borrow_mut(), out));
}

/// The timeline playback: build the pipeline schedule as a task graph
/// over the reusable `scratch` and read the [`Breakdown`] off the lean
/// timeline (see the module docs for the schedule shape). Allocation
/// profile: warm caches + a scratch that has seen this `(pp, m,
/// schedule)` shape ⇒ zero heap allocations (`tests/warm_alloc.rs`).
fn simulate_timeline_scratch(
    s: &Scenario,
    cache: &PlanCache,
    scratch: &mut SimScratch,
    out: &mut Breakdown,
) {
    out.reset();
    if scratch.used {
        cache.note_scratch_reuse();
    } else {
        scratch.used = true;
    }
    let pp = s.pp.max(1);
    let m = s.micro_batches.max(1);
    let profile = ClusterProfile::for_scenario(s);

    // --- per-stage cached tables + playback scalars ---------------------
    // Canonical-equal interior stages (see `canonical_stage`) resolve to
    // the same cached table, hardware and plans, so their playback
    // scalars are bit-identical — build once, clone for the rest (Arc
    // bumps + scalar copies, no heap). The straggler-derated last stage
    // canonicalizes to itself, and its hardware is derated exactly once
    // per playback. Heterogeneous profiles can break the interior-stage
    // symmetry (different ranks draw different derates), so sharing is
    // additionally gated on equal per-stage factors; the *table* itself
    // is hardware-independent and still shared through the cache.
    //
    // Per stage: the straggler factor derates the *last* stage and the
    // profile's max rank derate the stage's own compute/HBM; DP
    // collectives price against the stage's slowest inter-node link. On
    // the homogeneous default every factor is exactly 1.0, and
    // `derate(1.0)` / `/ 1.0` are bitwise no-ops — today's artifacts
    // are reproduced bit-for-bit.
    scratch.stages.clear();
    for si in 0..pp {
        let canon = crate::sweep::cache::canonical_stage(s, si);
        if canon < si
            && (profile.is_trivial()
                || (profile.stage_derate(si) == profile.stage_derate(canon)
                    && profile.stage_link(si) == profile.stage_link(canon)))
        {
            let shared = scratch.stages[canon].clone();
            scratch.stages.push(shared);
            continue;
        }
        let t_fetch = Instant::now();
        let key = StageKey::for_scenario(s, si);
        let table = cache.stage_table(&key, || StageTable::build(s, si, cache));
        out.planning_s += t_fetch.elapsed().as_secs_f64();
        let straggler = if si == pp - 1 { s.straggler } else { 1.0 };
        let hw = s.hw.derate(profile.stage_derate(si) * straggler);
        let mut fabric = s.hw.clone();
        fabric.ib_bw /= profile.stage_link(si);
        let comm = CommModel::new(fabric);
        let (fwd_t, bwd_t, tp_ar, act_bytes) = stage_times(s, &hw, &comm, &table);
        let act_p2p = if pp > 1 { comm.p2p(act_bytes, LinkKind::InterNode) } else { 0.0 };
        let grad_bytes = stage_grad_bytes(s, &comm, &table);
        let opt = optimizer_step(s, &hw, &comm, &table, si, cache);
        out.planning_s += opt.planning_s;
        scratch
            .stages
            .push(StagePlayback { table, hw, comm, fwd_t, bwd_t, tp_ar, act_p2p, grad_bytes, opt });
    }

    // Split-borrow the scratch: the emitter below mutates the per-stage
    // vectors and `dbuf` while `drive_pipeline_flat` drives `tl` +
    // `pipe` and the slot table borrows `orders` — all disjoint fields.
    let SimScratch {
        tl,
        orders,
        pipe,
        stages,
        ag_stretch,
        last_bwd,
        last_rs,
        opt_ends,
        dbuf,
        ..
    } = scratch;

    // --- streams: compute / optimizer / DP-collective / PP send ---------
    // Creation order (pp of each group, in this sequence) pins the same
    // ids the old per-group `Vec<StreamId>` tables held, so the id of
    // group g's stage i is plain index math.
    tl.reset();
    for _ in 0..5 * pp {
        tl.stream();
    }
    let compute = |i: usize| StreamId(i as u32);
    let opt_stream = |i: usize| StreamId((pp + i) as u32);
    let dpc = |i: usize| StreamId((2 * pp + i) as u32);
    let p2p_f = |i: usize| StreamId((3 * pp + i) as u32);
    let p2p_b = |i: usize| StreamId((4 * pp + i) as u32);

    let has_ag = s.dp > 1 && !uses_all_reduce(s);
    ag_stretch.clear();
    ag_stretch.resize(pp, 0.0);
    last_bwd.clear();
    last_bwd.resize(pp, None);
    last_rs.clear();
    last_rs.resize(pp, None);

    let (slots, order_hit) = orders.get(s.schedule, pp, m);
    if order_hit {
        cache.note_order_hit();
    }
    drive_pipeline_flat(tl, slots, pp, m, pipe, |tl, i, slot, deps| {
        let sp = &stages[i];
        let nb = sp.table.bucket_bytes.len();
        match slot {
            PipeSlot::Fwd(j) => {
                // Activation arrival rides the upstream stage's forward
                // p2p stream.
                let gate = (i > 0)
                    .then(|| tl.task(p2p_f(i - 1), TaskKind::ActComm, stages[i - 1].act_p2p, deps));
                if j == 0 && has_ag && nb > 0 {
                    // First micro-batch: each bucket's forward compute is
                    // gated on that bucket's parameter All-Gather
                    // (ZeRO-1 prefetch; the AGs start at t=0 and hide in
                    // the pipeline-fill bubble on later stages).
                    let ready0 = tl
                        .stream_free(compute(i))
                        .max(gate.map(|g| tl.end(g)).unwrap_or(0.0));
                    let mut last = None;
                    for b in 0..nb {
                        let ag = tl.task(
                            dpc(i),
                            TaskKind::ParamComm,
                            bucket_ag_time(s, &sp.comm, &sp.table, b),
                            &[],
                        );
                        dbuf.clear();
                        dbuf.push(ag);
                        if b == 0 {
                            if let Some(g) = gate {
                                dbuf.push(g);
                            }
                        }
                        let frac = sp.table.bucket_frac[b];
                        last = Some(tl.task(
                            compute(i),
                            TaskKind::Forward,
                            sp.fwd_t * frac,
                            dbuf.as_slice(),
                        ));
                    }
                    let last = last.expect("nb > 0");
                    ag_stretch[i] = (tl.end(last) - ready0 - sp.fwd_t).max(0.0);
                    last
                } else {
                    dbuf.clear();
                    if let Some(g) = gate {
                        dbuf.push(g);
                    }
                    tl.task(compute(i), TaskKind::Forward, sp.fwd_t, dbuf.as_slice())
                }
            }
            PipeSlot::Bwd(j) => {
                // deps[0] is this stage's own forward; deps[1] (when the
                // stage is not last) the downstream backward — its
                // activation gradients ride the downstream p2p stream.
                let gate = (i + 1 < pp)
                    .then(|| tl.task(p2p_b(i + 1), TaskKind::ActComm, sp.act_p2p, &[deps[1]]));
                if j == m - 1 && nb > 0 {
                    // Last micro-batch: buckets complete sequentially and
                    // each bucket's gradient collective overlaps the
                    // remaining backward compute (Megatron semantics —
                    // gradients accumulate locally until the final
                    // micro-batch).
                    let mut last_c = None;
                    for b in 0..nb {
                        dbuf.clear();
                        if b == 0 {
                            dbuf.push(deps[0]);
                            if let Some(g) = gate {
                                dbuf.push(g);
                            }
                        }
                        let frac = sp.table.bucket_frac[b];
                        let c = tl.task(
                            compute(i),
                            TaskKind::Backward,
                            sp.bwd_t * frac,
                            dbuf.as_slice(),
                        );
                        let r = tl.task(
                            dpc(i),
                            TaskKind::GradComm,
                            bucket_grad_time(s, &sp.comm, &sp.table, b),
                            &[c],
                        );
                        last_c = Some(c);
                        last_rs[i] = Some(r);
                    }
                    let last_c = last_c.expect("nb > 0");
                    last_bwd[i] = Some(last_c);
                    last_c
                } else {
                    dbuf.clear();
                    dbuf.push(deps[0]);
                    if let Some(g) = gate {
                        dbuf.push(g);
                    }
                    let c = tl.task(compute(i), TaskKind::Backward, sp.bwd_t, dbuf.as_slice());
                    if j == m - 1 {
                        last_bwd[i] = Some(c);
                    }
                    c
                }
            }
        }
    });

    // --- per-stage tail: TP All-Reduce block, then the optimizer --------
    // The optimizer is just another stream consumer: it starts as soon as
    // *its* stage's gradients are synchronized, overlapping later stages'
    // backward cooldown (the paper's asynchronous-optimizer claim).
    let mut fwd_bwd_end = 0.0f64;
    opt_ends.clear();
    opt_ends.resize(pp, 0.0);
    for i in 0..pp {
        dbuf.clear();
        if let Some(c) = last_bwd[i] {
            dbuf.push(c);
        }
        if let Some(r) = last_rs[i] {
            dbuf.push(r);
        }
        let tp_id =
            tl.task(compute(i), TaskKind::TpComm, m as f64 * stages[i].tp_ar, dbuf.as_slice());
        fwd_bwd_end = fwd_bwd_end.max(tl.end(tp_id));
        let opt_id = tl.task(opt_stream(i), TaskKind::Optimizer, stages[i].opt.time_s, &[tp_id]);
        opt_ends[i] = tl.end(opt_id);
    }
    cache.note_timeline_tasks(tl.n_tasks() as u64);

    // --- read the Breakdown off the lean timeline -----------------------
    // Pacing stage: the one whose optimizer drains last.
    let mut pacing = 0usize;
    for i in 1..pp {
        if opt_ends[i] > opt_ends[pacing] {
            pacing = i;
        }
    }
    let sp = &stages[pacing];
    out.fwd_bwd_s = fwd_bwd_end;
    out.total_s = opt_ends[pacing].max(fwd_bwd_end);
    out.optimizer_s = out.total_s - out.fwd_bwd_s;
    let rs_tail = match (last_rs[pacing], last_bwd[pacing]) {
        (Some(r), Some(c)) => (tl.end(r) - tl.end(c)).max(0.0),
        _ => 0.0,
    };
    out.exposed_comm_s = ag_stretch[pacing] + rs_tail;
    let max_busy = (0..pp).map(|i| tl.stream_busy(compute(i))).fold(0.0, f64::max);
    out.bubble_s = (out.fwd_bwd_s - max_busy).max(0.0);
    out.n_micro_groups = sp.opt.n_micro_groups;
    out.grad_comm_bytes = sp.grad_bytes;
    let adamw_elems = sp.table.total_elems / s.dp as f64;
    out.adamw_ref_s = sp.hw.memory_time(adamw_elems * ADAMW_BYTES_PER_ELEM);
    fill_loads(out, s, &sp.table, sp.opt.worst_tplan.as_deref());
    // --- elastic events: recovery charge + the N−1 re-solve -------------
    // A configured failure (deterministic `--fail-rank` or an expected
    // `--mttf` rate) pays detection, checkpoint reload (the pacing
    // stage's largest state shard over the inter-node fabric),
    // re-partition, and redone work — and the surviving N−1 population's
    // deployment is actually re-solved through the plan cache (which
    // memoizes both populations), its wall time charged to `planning_s`.
    // Every term is >= 0, so the fault-free bounds stay admissible, and
    // an injected failure strictly increases `recovery_s` and `total_s`.
    if s.fail_rank.is_some() || s.mttf_s.is_some() {
        out.planning_s += faults::replan_for_failure(s, cache);
        let state_bytes = out.dp_loads_state.iter().cloned().fold(0.0, f64::max);
        out.recovery_s = faults::recovery_seconds(s, out.total_s, state_bytes);
        out.total_s += out.recovery_s;
    }
    // Drop the stage Arcs now rather than at the thread's next playback:
    // holding them would pin evicted StageTables/TpPlans past the plan
    // cache's byte budget. The buffer keeps its capacity (it is refilled
    // from the cache every call), so the warm path stays allocation-free.
    stages.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::Qwen3Size;
    use crate::util::stats::load_balance_ratio;

    fn scen(strategy: DpStrategy) -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, crate::cost::optim::OptimKind::Muon, strategy)
    }

    #[test]
    fn strategy_ordering_matches_paper() {
        // LB-ASC < ASC < NV-layerwise < SC on optimizer time (Fig. 3a/4).
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let asc = simulate_iteration(&scen(DpStrategy::Asc));
        let nv = simulate_iteration(&scen(DpStrategy::NvLayerwise));
        let sc = simulate_iteration(&scen(DpStrategy::Sc));
        assert!(lb.optimizer_s < asc.optimizer_s, "{} vs {}", lb.optimizer_s, asc.optimizer_s);
        assert!(asc.optimizer_s < sc.optimizer_s);
        assert!(lb.optimizer_s < nv.optimizer_s);
        assert!(nv.optimizer_s < sc.optimizer_s);
    }

    #[test]
    fn fwd_bwd_rs_beats_ar() {
        // Ours (RS path) must beat NV-layerwise (AR path) on fwd-bwd.
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let nv = simulate_iteration(&scen(DpStrategy::NvLayerwise));
        assert!(lb.fwd_bwd_s < nv.fwd_bwd_s, "{} vs {}", lb.fwd_bwd_s, nv.fwd_bwd_s);
        assert!(nv.grad_comm_bytes > 1.9 * lb.grad_comm_bytes);
    }

    #[test]
    fn lb_flattens_dp_loads() {
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        let asc = simulate_iteration(&scen(DpStrategy::Asc));
        let r_lb = load_balance_ratio(&lb.dp_loads_flops);
        let r_asc = load_balance_ratio(&asc.dp_loads_flops);
        assert!(r_lb < r_asc, "{r_lb} vs {r_asc}");
        assert!(r_lb < 1.5, "{r_lb}");
    }

    #[test]
    fn planning_is_fast() {
        // Appendix D.1: offline planning is ms-scale.
        let lb = simulate_iteration(&scen(DpStrategy::LbAsc));
        assert!(lb.planning_s < 0.5, "{}", lb.planning_s);
    }

    #[test]
    fn pp_stages_dont_crash() {
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 4;
        let b = simulate_iteration(&s);
        assert!(b.total_s > 0.0);
    }

    #[test]
    fn stage_tables_count_hosted_layers_and_match_across_interior_stages() {
        // Qwen3-1.7B has 28 layers; pp = 4 -> every stage hosts exactly
        // 7. The table must count the layers the stage *hosts* (not
        // "max global layer index + 1", which for stage 2 would be 21
        // and would also differ between shape-identical interior stages
        // — breaking the canonical-stage sharing contract that lets a
        // racing build of stage 2 stand in for stage 1's cache entry).
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 4;
        let cache = PlanCache::unbounded();
        let t1 = StageTable::build(&s, 1, &cache);
        let t2 = StageTable::build(&s, 2, &cache);
        assert_eq!(t1.n_layers, 7.0);
        assert_eq!(t2.n_layers.to_bits(), t1.n_layers.to_bits());
        assert_eq!(t2.matrix_numel.to_bits(), t1.matrix_numel.to_bits());
        assert_eq!(t2.total_elems.to_bits(), t1.total_elems.to_bits());
        assert_eq!(t2.param_bytes.to_bits(), t1.param_bytes.to_bits());
    }

    #[test]
    fn pp_routes_through_timeline_and_has_bubble() {
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 2;
        s.micro_batches = 2;
        let cache = PlanCache::unbounded();
        let dispatched = simulate_iteration_cached(&s, &cache);
        let direct = simulate_iteration_timeline(&s, &cache);
        assert_eq!(dispatched.total_s.to_bits(), direct.total_s.to_bits());
        assert_eq!(dispatched.fwd_bwd_s.to_bits(), direct.fwd_bwd_s.to_bits());
        assert!(dispatched.bubble_s > 0.0, "pp=2 must expose a pipeline bubble");
        assert!(dispatched.total_s > 0.0);
    }

    #[test]
    fn more_micro_batches_shrink_bubble_fraction() {
        let frac = |m: usize| {
            let mut s = scen(DpStrategy::LbAsc);
            s.pp = 4;
            s.micro_batches = m;
            let b = simulate_iteration(&s);
            b.bubble_s / b.fwd_bwd_s
        };
        let f1 = frac(1);
        let f8 = frac(8);
        assert!(f8 < f1, "bubble fraction must shrink with micro-batches: {f8} vs {f1}");
    }

    #[test]
    fn straggler_slows_the_iteration() {
        let base = simulate_iteration(&scen(DpStrategy::LbAsc));
        let slow = simulate_iteration(&scen(DpStrategy::LbAsc).with_straggler(2.0));
        assert!(slow.total_s > base.total_s, "{} vs {}", slow.total_s, base.total_s);
        // Straggler routes through the timeline even at pp = 1.
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 2;
        s.micro_batches = 4;
        let pipe = simulate_iteration(&s);
        let pipe_slow = simulate_iteration(&s.clone().with_straggler(1.5));
        assert!(pipe_slow.total_s > pipe.total_s);
    }

    #[test]
    fn gpipe_and_1f1b_agree_on_makespan_shape() {
        // For uniform stages the two schedules have identical makespans
        // (they differ in memory, which the simulator does not charge);
        // our stages are only embed/head-skewed, so the spans must stay
        // close — and both positive and deterministic.
        let mut s = scen(DpStrategy::LbAsc);
        s.pp = 4;
        s.micro_batches = 8;
        let f1b1 = simulate_iteration(&s);
        let gp = simulate_iteration(&s.clone().with_schedule(
            crate::sim::timeline::PipelineSchedule::GPipe));
        assert!(f1b1.total_s > 0.0 && gp.total_s > 0.0);
        let rel = (f1b1.fwd_bwd_s - gp.fwd_bwd_s).abs() / gp.fwd_bwd_s;
        assert!(rel < 0.25, "1F1B {} vs GPipe {}", f1b1.fwd_bwd_s, gp.fwd_bwd_s);
    }

    #[test]
    fn tp1_works() {
        let mut s = scen(DpStrategy::LbAsc);
        s.tp = 1;
        let b = simulate_iteration(&s);
        assert!(b.optimizer_s > 0.0);
    }

    #[test]
    fn warm_cache_skips_solves_and_preserves_results() {
        fn timing_free(b: &Breakdown) -> (u64, u64, u64, Vec<u64>, Vec<u64>, usize) {
            (
                b.fwd_bwd_s.to_bits(),
                b.optimizer_s.to_bits(),
                b.exposed_comm_s.to_bits(),
                b.dp_loads_flops.iter().map(|x| x.to_bits()).collect(),
                b.tp_loads_flops.iter().map(|x| x.to_bits()).collect(),
                b.n_micro_groups,
            )
        }
        for strategy in DpStrategy::ALL {
            let s = scen(strategy);
            // Unbounded: an env budget override must not evict mid-test.
            let cache = PlanCache::unbounded();
            let first = simulate_iteration_cached(&s, &cache);
            let solves = cache.stats().solves;
            let second = simulate_iteration_cached(&s, &cache);
            assert_eq!(cache.stats().solves, solves,
                       "{strategy:?}: warm run re-solved a plan");
            assert!(solves > 0, "{strategy:?}: no solve recorded");
            assert!(cache.stats().hits > 0, "{strategy:?}: no cache hit");
            let cold = simulate_iteration(&s);
            assert_eq!(timing_free(&first), timing_free(&second), "{strategy:?}");
            assert_eq!(timing_free(&first), timing_free(&cold), "{strategy:?}");
        }
    }

    #[test]
    fn into_reuses_output_and_matches_fresh() {
        let s = scen(DpStrategy::LbAsc);
        let cache = PlanCache::unbounded();
        let fresh = simulate_iteration_cached(&s, &cache);
        let mut reused = Breakdown::default();
        simulate_iteration_into(&s, &cache, &mut reused);
        // And again, exercising the in-place reset/refill path.
        simulate_iteration_into(&s, &cache, &mut reused);
        assert_eq!(fresh.total_s.to_bits(), reused.total_s.to_bits());
        assert_eq!(fresh.dp_loads_flops, reused.dp_loads_flops);
        assert_eq!(fresh.tp_loads_state, reused.tp_loads_state);
        assert_eq!(fresh.n_micro_groups, reused.n_micro_groups);
    }
}
