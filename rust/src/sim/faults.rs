//! Elastic-cluster fault & heterogeneity model.
//!
//! The paper evaluates on a homogeneous, never-failing 256-GPU cluster;
//! the only degradation knob the simulator carried until now was the
//! single last-stage `--straggler` scalar. At that scale, real fleets
//! mix GPU generations, carry flaky links, and lose ranks mid-run — and
//! the strategy zoo reacts *differently* to each (MatrixFSDP's update
//! is communication-free, DMuon's gather/scatter rides the inter-node
//! fabric, the alpha-balanced partition re-solves cheaply for N−1
//! ranks). This module is the general case the straggler scalar is a
//! special case of:
//!
//! * [`HeteroSpec`] — a deterministic per-rank hardware profile spec
//!   (seed-derived slow-node and degraded-link Bernoulli mixes, plus
//!   the `last:<f>` deterministic form that reproduces `--straggler f`
//!   bit-for-bit).
//! * [`ClusterProfile`] — the allocation-free per-rank view the
//!   timeline arm reads: each stage's compute is derated by the *max*
//!   derate among its ranks, each stage's DP collectives price against
//!   the slowest participating inter-node link.
//! * [`FailSpec`] / `mttf` — elastic events. The timeline arm charges
//!   detection timeout, checkpoint reload, the re-partition of the
//!   surviving N−1 population (actually re-solved through the
//!   [`PlanCache`], which memoizes both populations), and the lost
//!   work since the last checkpoint, into [`Breakdown::recovery_s`].
//!
//! Determinism is load-bearing: every per-rank draw is a pure function
//! of `(fault_seed, rank)` via the same SplitMix64/xoshiro256** stream
//! the numeric trainer uses, so the same `--fault-seed` yields
//! byte-identical artifacts on any thread count (pinned by
//! `tests/elastic_differential.rs`).
//!
//! [`Breakdown::recovery_s`]: super::iteration::Breakdown::recovery_s
//! [`PlanCache`]: crate::sweep::cache::PlanCache

use std::fmt;
use std::time::Instant;

use crate::bail;
use crate::sweep::cache::{PlanCache, StageKey};
use crate::util::error::Result;
use crate::util::rng::Rng;

use super::iteration::StageTable;
use super::scenario::Scenario;

/// Modeled failure-detection timeout (collective-watchdog scale, s).
/// Every injected failure pays this before recovery can begin.
pub const DETECT_TIMEOUT_S: f64 = 5.0;
/// Coordinator-round base cost of re-solving the deployment for the
/// surviving population (s) — the modeled (deterministic) counterpart
/// of the measured re-solve charged to `planning_s`.
pub const REPLAN_BASE_S: f64 = 0.25;
/// Per-census-tensor term of the modeled re-partition charge (s).
pub const REPLAN_PER_TENSOR_S: f64 = 1e-5;

/// A per-rank hardware heterogeneity spec. Parsed from `--hetero`:
///
/// * `none` — homogeneous (the default; bit-identical to pre-fault
///   artifacts).
/// * `slow:<rate>:<factor>` — each rank is independently a slow node
///   with probability `rate` (seed-derived), derating its compute/HBM
///   throughput by `factor` (`1.5` = 50% slower).
/// * `link:<rate>:<factor>` — each rank's inter-node link bandwidth is
///   divided by `factor` with probability `rate`.
/// * `slow:R:F+link:R:F` — both mixes at once.
/// * `last:<factor>` — deterministically derate exactly the last PP
///   stage's ranks by `factor`: the spec that reproduces
///   `--straggler <factor>` bit-for-bit (the differential oracle).
///
/// Parsing canonicalizes inert terms (`rate == 0` or `factor == 1`)
/// away, so `parse(x.to_string()) == x` holds for every parse product.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HeteroSpec {
    /// Homogeneous cluster (the default).
    None,
    /// Deterministic last-stage derate — the straggler equivalence spec.
    LastStage {
        /// Compute/HBM derate factor for the last stage's ranks.
        factor: f64,
    },
    /// Seed-derived Bernoulli mixes: slow nodes and degraded links.
    Mix {
        /// Probability a rank is a slow node (compute/HBM derated).
        slow_rate: f64,
        /// Compute/HBM derate factor of a slow node.
        slow_factor: f64,
        /// Probability a rank's inter-node link is degraded.
        link_rate: f64,
        /// Inter-node bandwidth divisor of a degraded link.
        link_factor: f64,
    },
}

impl HeteroSpec {
    /// Parse a `--hetero` spec token (see the type docs for the forms).
    pub fn parse(tok: &str) -> Result<HeteroSpec> {
        if tok == "none" {
            return Ok(HeteroSpec::None);
        }
        let mut slow: Option<(f64, f64)> = None;
        let mut link: Option<(f64, f64)> = None;
        let mut last: Option<f64> = None;
        for term in tok.split('+') {
            let parts: Vec<&str> = term.split(':').collect();
            let num = |x: &str| -> Result<f64> {
                x.parse::<f64>().map_err(|_| {
                    crate::util::error::Error::msg(format!(
                        "invalid hetero spec '{tok}': '{x}' is not a number"
                    ))
                })
            };
            match parts.as_slice() {
                ["slow", r, f] if slow.is_none() => slow = Some((num(r)?, num(f)?)),
                ["link", r, f] if link.is_none() => link = Some((num(r)?, num(f)?)),
                ["last", f] if last.is_none() => last = Some(num(f)?),
                ["slow", ..] | ["link", ..] | ["last", ..] => {
                    bail!("invalid hetero spec '{tok}': duplicate or malformed term '{term}'")
                }
                _ => bail!(
                    "invalid hetero spec '{tok}': expected none, last:<f>, slow:<r>:<f>, \
                     link:<r>:<f>, or slow:..+link:.., got term '{term}'"
                ),
            }
        }
        if last.is_some() && (slow.is_some() || link.is_some()) {
            bail!("invalid hetero spec '{tok}': last:<f> cannot be combined");
        }
        let spec = if let Some(f) = last {
            if f == 1.0 { HeteroSpec::None } else { HeteroSpec::LastStage { factor: f } }
        } else {
            // Canonicalize inert terms so label() round-trips by value.
            let norm = |t: Option<(f64, f64)>| match t {
                Some((r, f)) if r != 0.0 && f != 1.0 => (r, f),
                _ => (0.0, 1.0),
            };
            let (slow_rate, slow_factor) = norm(slow);
            let (link_rate, link_factor) = norm(link);
            if slow_rate == 0.0 && link_rate == 0.0 {
                HeteroSpec::None
            } else {
                HeteroSpec::Mix { slow_rate, slow_factor, link_rate, link_factor }
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Named-field validation (`invalid scenario:`-prefixed like
    /// [`Scenario::validate`]): rates in `[0, 1]`, factors finite and
    /// `>= 1` — a derate below 1 would manufacture infinite throughput.
    pub fn validate(&self) -> Result<()> {
        let rate_ok = |v: f64| v.is_finite() && (0.0..=1.0).contains(&v);
        let factor_ok = |v: f64| v.is_finite() && v >= 1.0;
        match *self {
            HeteroSpec::None => Ok(()),
            HeteroSpec::LastStage { factor } => {
                if !factor_ok(factor) {
                    bail!(
                        "invalid scenario: hetero last factor expects a finite \
                         factor >= 1.0, got {factor}"
                    );
                }
                Ok(())
            }
            HeteroSpec::Mix { slow_rate, slow_factor, link_rate, link_factor } => {
                if !rate_ok(slow_rate) || !rate_ok(link_rate) {
                    bail!(
                        "invalid scenario: hetero rates must be finite and in [0, 1], \
                         got slow={slow_rate} link={link_rate}"
                    );
                }
                if !factor_ok(slow_factor) || !factor_ok(link_factor) {
                    bail!(
                        "invalid scenario: hetero factors must be finite and >= 1.0, \
                         got slow={slow_factor} link={link_factor}"
                    );
                }
                Ok(())
            }
        }
    }

    /// Hash/eq bits for sweep-engine group keys ([`f64::to_bits`] on
    /// every term plus a variant tag): scenarios with different specs
    /// must never share a batched group.
    pub fn key_bits(&self) -> [u64; 5] {
        match *self {
            HeteroSpec::None => [0, 0, 0, 0, 0],
            HeteroSpec::LastStage { factor } => [1, factor.to_bits(), 0, 0, 0],
            HeteroSpec::Mix { slow_rate, slow_factor, link_rate, link_factor } => [
                2,
                slow_rate.to_bits(),
                slow_factor.to_bits(),
                link_rate.to_bits(),
                link_factor.to_bits(),
            ],
        }
    }
}

impl fmt::Display for HeteroSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            HeteroSpec::None => write!(f, "none"),
            HeteroSpec::LastStage { factor } => write!(f, "last:{factor}"),
            HeteroSpec::Mix { slow_rate, slow_factor, link_rate, link_factor } => {
                let mut first = true;
                if slow_rate != 0.0 {
                    write!(f, "slow:{slow_rate}:{slow_factor}")?;
                    first = false;
                }
                if link_rate != 0.0 {
                    if !first {
                        write!(f, "+")?;
                    }
                    write!(f, "link:{link_rate}:{link_factor}")?;
                }
                Ok(())
            }
        }
    }
}

/// A deterministic rank-failure injection: rank `rank` dies at fraction
/// `at` of the iteration (`0.5` = mid-iteration). Parsed from
/// `--fail-rank r@frac` (bare `r` defaults to `@0.5`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailSpec {
    /// The failing global rank (stage-major layout; must be < gpus).
    pub rank: usize,
    /// Fractional position of the failure within the iteration, [0, 1).
    pub at: f64,
}

impl FailSpec {
    /// Parse `r@frac` or bare `r` (mid-iteration default).
    pub fn parse(tok: &str) -> Result<FailSpec> {
        let (r, at) = match tok.split_once('@') {
            Some((r, a)) => {
                let at = a.parse::<f64>().map_err(|_| {
                    crate::util::error::Error::msg(format!(
                        "invalid fail_rank '{tok}': '{a}' is not a number"
                    ))
                })?;
                (r, at)
            }
            None => (tok, 0.5),
        };
        let rank = r.parse::<usize>().map_err(|_| {
            crate::util::error::Error::msg(format!(
                "invalid fail_rank '{tok}': '{r}' is not a rank index"
            ))
        })?;
        let spec = FailSpec { rank, at };
        spec.validate(usize::MAX)?;
        Ok(spec)
    }

    /// Named-field validation; `gpus` bounds the rank index (callers
    /// that don't know the deployment yet pass `usize::MAX`).
    pub fn validate(&self, gpus: usize) -> Result<()> {
        if !self.at.is_finite() || !(0.0..1.0).contains(&self.at) {
            bail!(
                "invalid scenario: fail_rank position expects a finite fraction \
                 in [0, 1), got {}",
                self.at
            );
        }
        if self.rank >= gpus {
            bail!(
                "invalid scenario: fail_rank {} out of range for a {}-GPU deployment",
                self.rank, gpus
            );
        }
        Ok(())
    }
}

impl fmt::Display for FailSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.rank, self.at)
    }
}

/// One rank's uniform draw in `[0, 1)`: a pure function of
/// `(seed, salt, rank)`, independent of evaluation order or thread
/// count. `salt` separates the compute-derate stream from the
/// link-degradation stream.
fn rank_u01(seed: u64, salt: u64, rank: usize) -> f64 {
    Rng::new(seed.wrapping_add(salt.wrapping_mul(0xA076_1D64_78BD_642F)))
        .fork(rank as u64)
        .next_f64()
}

/// The allocation-free per-rank hardware view of a scenario: which
/// ranks are slow, which links are degraded, and the per-stage
/// aggregates the timeline arm prices against. Ranks are laid out
/// stage-major: stage `s` owns ranks `[s·dp·tp, (s+1)·dp·tp)`.
///
/// Everything is computed on demand from `(spec, seed, rank)` — no
/// heap, so the timeline playback's zero-allocation warm contract is
/// untouched even on fault paths.
#[derive(Clone, Copy, Debug)]
pub struct ClusterProfile {
    spec: HeteroSpec,
    seed: u64,
    dp: usize,
    tp: usize,
    pp: usize,
}

impl ClusterProfile {
    /// The profile of a scenario's deployment.
    pub fn for_scenario(s: &Scenario) -> ClusterProfile {
        ClusterProfile {
            spec: s.hetero,
            seed: s.fault_seed,
            dp: s.dp,
            tp: s.tp,
            pp: s.pp.max(1),
        }
    }

    /// Homogeneous profile? (Every factor is exactly 1.0, so callers
    /// may skip the per-rank scan entirely.)
    pub fn is_trivial(&self) -> bool {
        self.spec == HeteroSpec::None
    }

    /// The PP stage hosting global rank `r` (stage-major layout).
    pub fn stage_of_rank(&self, r: usize) -> usize {
        (r / (self.dp * self.tp)).min(self.pp - 1)
    }

    /// Compute/HBM derate factor of rank `r` (1.0 = healthy).
    pub fn rank_derate(&self, r: usize) -> f64 {
        match self.spec {
            HeteroSpec::None => 1.0,
            HeteroSpec::LastStage { factor } => {
                if self.stage_of_rank(r) == self.pp - 1 { factor } else { 1.0 }
            }
            HeteroSpec::Mix { slow_rate, slow_factor, .. } => {
                if slow_rate > 0.0 && rank_u01(self.seed, 0, r) < slow_rate {
                    slow_factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Inter-node bandwidth divisor of rank `r`'s link (1.0 = healthy).
    pub fn rank_link(&self, r: usize) -> f64 {
        match self.spec {
            // `last:` models slow *GPUs* (the straggler semantics) —
            // the fabric stays healthy.
            HeteroSpec::None | HeteroSpec::LastStage { .. } => 1.0,
            HeteroSpec::Mix { link_rate, link_factor, .. } => {
                if link_rate > 0.0 && rank_u01(self.seed, 1, r) < link_rate {
                    link_factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Max compute derate among stage `si`'s ranks — bulk-synchronous
    /// compute inside a stage paces on its slowest rank.
    pub fn stage_derate(&self, si: usize) -> f64 {
        self.stage_max(si, |p, r| p.rank_derate(r))
    }

    /// Max link divisor among stage `si`'s ranks — a collective is as
    /// slow as its slowest participating link.
    pub fn stage_link(&self, si: usize) -> f64 {
        self.stage_max(si, |p, r| p.rank_link(r))
    }

    fn stage_max(&self, si: usize, f: impl Fn(&ClusterProfile, usize) -> f64) -> f64 {
        if self.is_trivial() {
            return 1.0;
        }
        let per = self.dp * self.tp;
        let mut worst = 1.0f64;
        for r in si * per..(si + 1) * per {
            let v = f(self, r);
            if v > worst {
                worst = v;
            }
        }
        worst
    }
}

/// The deterministic recovery-cost model, charged into
/// `Breakdown::recovery_s` by the timeline arm when an elastic event is
/// configured. `span_s` is the fault-free iteration time,
/// `state_bytes` the pacing stage's largest per-rank optimizer-state
/// shard (the checkpoint reload volume).
///
/// Per event: detection timeout + checkpoint reload over the inter-node
/// fabric + the modeled re-partition round + the work lost since the
/// last checkpoint (`(k−1)/2` iterations in expectation at checkpoint
/// interval `k`, plus the failed iteration's own progress). A
/// `--fail-rank` charges one full event; `--mttf` charges the expected
/// cost: `min(1, span/mttf)` events per iteration losing half an
/// iteration each in expectation. Every term is `>= 0`, so the
/// fault-free lower bounds in [`super::bounds`] stay admissible
/// unchanged — and an injected failure *strictly* increases both
/// `recovery_s` (by at least [`DETECT_TIMEOUT_S`]) and `total_s`.
pub fn recovery_seconds(s: &Scenario, span_s: f64, state_bytes: f64) -> f64 {
    let reload_s = state_bytes / s.hw.ib_bw + s.hw.ib_lat;
    let replan_s = REPLAN_BASE_S + REPLAN_PER_TENSOR_S * s.census.len() as f64;
    let redo_s = 0.5 * s.ckpt_interval.saturating_sub(1) as f64 * span_s;
    let per_event = DETECT_TIMEOUT_S + reload_s + replan_s + redo_s;
    let mut rec = 0.0;
    if let Some(f) = s.fail_rank {
        // The failed iteration's own progress up to the fault is redone.
        rec += per_event + f.at * span_s;
    }
    if let Some(mttf) = s.mttf_s {
        let p = (span_s / mttf).min(1.0);
        rec += p * (per_event + 0.5 * span_s);
    }
    rec
}

/// Actually re-solve the deployment for the surviving N−1 population
/// (`dp − 1`, the failed rank's DP group shrinks) through the plan
/// cache — [`PlanCache`] memoizes both populations, so repeated
/// evaluations of the same faulted scenario re-solve nothing. Returns
/// the measured wall time, charged to `planning_s` (a wall-clock
/// diagnostic that never enters artifacts, so byte-determinism holds).
pub(crate) fn replan_for_failure(s: &Scenario, cache: &PlanCache) -> f64 {
    if s.dp <= 1 {
        return 0.0; // no surviving DP peers to re-balance across
    }
    let t0 = Instant::now();
    let mut red = s.clone();
    red.dp -= 1;
    for si in 0..red.pp.max(1) {
        let key = StageKey::for_scenario(&red, si);
        let _ = cache.stage_table(&key, || StageTable::build(&red, si, cache));
    }
    t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;

    #[test]
    fn spec_parse_round_trips_by_value() {
        for tok in [
            "none",
            "last:1.5",
            "slow:0.05:1.5",
            "link:1:16",
            "slow:0.1:2+link:0.25:4",
        ] {
            let spec = HeteroSpec::parse(tok).unwrap();
            assert_eq!(HeteroSpec::parse(&spec.to_string()).unwrap(), spec, "{tok}");
        }
        // Inert terms canonicalize to None (so value round-trip holds).
        assert_eq!(HeteroSpec::parse("slow:0:1.5").unwrap(), HeteroSpec::None);
        assert_eq!(HeteroSpec::parse("link:0.5:1").unwrap(), HeteroSpec::None);
        assert_eq!(HeteroSpec::parse("last:1").unwrap(), HeteroSpec::None);
    }

    #[test]
    fn spec_rejects_malformed_and_out_of_range() {
        for bad in [
            "bogus",
            "slow:0.5",
            "slow:x:2",
            "last:0.5",          // factor < 1: infinite throughput
            "slow:2:1.5",        // rate > 1
            "slow:-0.1:1.5",     // rate < 0
            "link:0.5:nan",      // non-finite factor
            "slow:0.5:2+slow:0.5:2", // duplicate term
            "last:2+slow:0.5:2", // last is exclusive
        ] {
            assert!(HeteroSpec::parse(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn fail_spec_parse_and_bounds() {
        let f = FailSpec::parse("3@0.25").unwrap();
        assert_eq!((f.rank, f.at), (3, 0.25));
        assert_eq!(FailSpec::parse("7").unwrap().at, 0.5);
        assert_eq!(FailSpec::parse(&f.to_string()).unwrap(), f);
        assert!(FailSpec::parse("x@0.5").is_err());
        assert!(FailSpec::parse("3@1.5").is_err()); // at >= 1
        assert!(FailSpec::parse("3@-0.1").is_err());
        assert!(FailSpec { rank: 8, at: 0.0 }.validate(8).is_err()); // out of range
        assert!(FailSpec { rank: 7, at: 0.0 }.validate(8).is_ok());
    }

    fn scen(spec: &str, seed: u64) -> Scenario {
        let mut s =
            Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc);
        s.hetero = HeteroSpec::parse(spec).unwrap();
        s.fault_seed = seed;
        s
    }

    #[test]
    fn profile_is_deterministic_in_the_seed() {
        let p1 = ClusterProfile::for_scenario(&scen("slow:0.3:2", 42));
        let p2 = ClusterProfile::for_scenario(&scen("slow:0.3:2", 42));
        let p3 = ClusterProfile::for_scenario(&scen("slow:0.3:2", 43));
        let mut differs = false;
        for r in 0..8 {
            assert_eq!(p1.rank_derate(r).to_bits(), p2.rank_derate(r).to_bits());
            differs |= p1.rank_derate(r) != p3.rank_derate(r);
        }
        assert!(differs, "different seeds should draw different slow sets");
    }

    #[test]
    fn stage_aggregates_take_the_max() {
        // Deterministic rate-1 mix: every rank slow, every link degraded.
        let p = ClusterProfile::for_scenario(&scen("slow:1:1.5+link:1:8", 0));
        for si in 0..2 {
            assert_eq!(p.stage_derate(si), 1.5);
            assert_eq!(p.stage_link(si), 8.0);
        }
        // last:f derates only the final stage, with healthy links —
        // the straggler-equivalence spec.
        let p = ClusterProfile::for_scenario(&scen("last:1.7", 0));
        assert_eq!(p.stage_derate(0), 1.0);
        assert_eq!(p.stage_derate(1), 1.7);
        assert_eq!(p.stage_link(1), 1.0);
        // Trivial profile: exactly 1.0 everywhere (bit-identity anchor).
        let p = ClusterProfile::for_scenario(&scen("none", 9));
        assert!(p.is_trivial());
        assert_eq!(p.stage_derate(1).to_bits(), 1.0f64.to_bits());
        assert_eq!(p.stage_link(0).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn rank_layout_is_stage_major() {
        let p = ClusterProfile::for_scenario(&scen("none", 0));
        assert_eq!(p.stage_of_rank(0), 0);
        assert_eq!(p.stage_of_rank(7), 0);
        assert_eq!(p.stage_of_rank(8), 1);
        assert_eq!(p.stage_of_rank(15), 1);
    }

    #[test]
    fn recovery_is_positive_and_monotone() {
        let mut s = scen("none", 0);
        s.fail_rank = Some(FailSpec { rank: 0, at: 0.5 });
        let base = recovery_seconds(&s, 10.0, 1e9);
        assert!(base >= DETECT_TIMEOUT_S);
        // Sparser checkpoints lose more work.
        s.ckpt_interval = 8;
        assert!(recovery_seconds(&s, 10.0, 1e9) > base);
        // A failure rate adds expected cost on top.
        s.mttf_s = Some(3600.0);
        let with_rate = recovery_seconds(&s, 10.0, 1e9);
        assert!(with_rate > recovery_seconds(&scen_fail(8, None), 10.0, 1e9));
        // Shorter MTTF costs more.
        s.mttf_s = Some(600.0);
        assert!(recovery_seconds(&s, 10.0, 1e9) > with_rate);
        // No events -> exactly zero.
        assert_eq!(recovery_seconds(&scen("slow:0.3:2", 1), 10.0, 1e9), 0.0);
    }

    fn scen_fail(ckpt: usize, mttf: Option<f64>) -> Scenario {
        let mut s = scen("none", 0);
        s.fail_rank = Some(FailSpec { rank: 0, at: 0.5 });
        s.ckpt_interval = ckpt;
        s.mttf_s = mttf;
        s
    }
}
