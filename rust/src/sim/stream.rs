//! Single-resource event scheduling: the closed-form playback's
//! primitive.
//!
//! GPUs expose independent compute and communication streams; overlap is
//! expressed by scheduling work on different streams with data-dependency
//! ready-times. A [`Stream`] is one such serially-executing resource.
//! The closed-form `pp = 1` iteration playback composes a handful of
//! them by hand (bucket-overlap, the micro-group pipeline of Fig. 2);
//! multi-stage schedules with cross-stage dependencies use the full
//! discrete-event engine in [`crate::sim::timeline`] instead, which can
//! additionally record a verifiable task trace (opt-in recording mode —
//! the sweep hot path runs the lean, trace-free core).

#![warn(missing_docs)]

/// One serially-executing resource (a CUDA stream / NIC queue).
#[derive(Clone, Debug, Default)]
pub struct Stream {
    free_at: f64,
}

impl Stream {
    /// A stream that is free from t = 0.
    pub fn new() -> Stream {
        Stream::default()
    }

    /// Schedule a task that becomes ready at `ready` and takes `dur`.
    /// Returns its completion time.
    pub fn schedule(&mut self, ready: f64, dur: f64) -> f64 {
        let start = ready.max(self.free_at);
        self.free_at = start + dur;
        self.free_at
    }

    /// Time at which the stream drains.
    pub fn free_at(&self) -> f64 {
        self.free_at
    }

    /// Advance the stream's availability to at least `t` (a barrier).
    pub fn barrier(&mut self, t: f64) {
        self.free_at = self.free_at.max(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_execution() {
        let mut s = Stream::new();
        assert_eq!(s.schedule(0.0, 2.0), 2.0);
        assert_eq!(s.schedule(0.0, 3.0), 5.0); // queued behind first
        assert_eq!(s.schedule(10.0, 1.0), 11.0); // idle gap respected
    }

    #[test]
    fn overlap_across_streams() {
        // Classic bucket overlap: comm of bucket i runs while compute of
        // bucket i+1 proceeds.
        let mut compute = Stream::new();
        let mut comm = Stream::new();
        let mut comm_done = 0.0;
        for _ in 0..4 {
            let grads_ready = compute.schedule(0.0, 1.0);
            comm_done = comm.schedule(grads_ready, 0.5);
        }
        // compute: 4.0; comm: starts at 1.0, each 0.5 but gated by
        // grads_ready -> last grads at 4.0, comm ends 4.5.
        assert_eq!(compute.free_at(), 4.0);
        assert_eq!(comm_done, 4.5);
    }

    #[test]
    fn exposed_comm_when_slow() {
        // Comm slower than compute => serialization behind the ring.
        let mut compute = Stream::new();
        let mut comm = Stream::new();
        let mut done = 0.0;
        for _ in 0..4 {
            let g = compute.schedule(0.0, 1.0);
            done = comm.schedule(g, 2.0);
        }
        assert_eq!(done, 9.0); // 1 + 4*2
    }

    #[test]
    fn barrier_advances() {
        let mut s = Stream::new();
        s.schedule(0.0, 1.0);
        s.barrier(5.0);
        assert_eq!(s.schedule(0.0, 1.0), 6.0);
    }
}
