//! Discrete-event cluster simulator.
//!
//! Plays out one training iteration on a modelled GPU cluster and
//! produces the paper's metrics (makespan, per-rank load distributions,
//! step-time breakdowns). Timing-only: the *numeric* path lives in
//! [`crate::train`] on real thread ranks.
//!
//! * [`stream`] — per-resource (compute / communication stream) event
//!   scheduling primitives.
//! * [`scenario`] — the experiment configuration (model, DP/TP/PP grid,
//!   optimizer, strategy, hardware).
//! * [`iteration`] — the iteration playback: bucket-overlapped fwd/bwd
//!   gradient communication + the per-strategy optimizer step.

pub mod iteration;
pub mod scenario;
pub mod stream;

pub use iteration::{
    simulate_iteration, simulate_iteration_cached, simulate_iteration_into, Breakdown, StageTable,
};
pub use scenario::Scenario;
