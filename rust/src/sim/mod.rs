//! Discrete-event cluster simulator.
//!
//! Plays out one training iteration on a modelled GPU cluster and
//! produces the paper's metrics (makespan, per-rank load distributions,
//! step-time breakdowns). Timing-only: the *numeric* path lives in
//! [`crate::train`] on real thread ranks.
//!
//! * [`stream`] — the single-resource scheduling primitive the
//!   closed-form playback composes by hand.
//! * [`timeline`] — the discrete-event engine (streams + dependent
//!   tasks; lean scheduling core with an opt-in verification trace) and
//!   the 1F1B / GPipe pipeline schedule builder that times `pp > 1` /
//!   multi-micro-batch / straggler scenarios over a reusable per-worker
//!   scratch.
//! * [`scenario`] — the experiment configuration (model, DP/TP/PP grid,
//!   micro-batches, schedule, optimizer, strategy, hardware).
//! * [`iteration`] — the iteration playback: bucket-overlapped fwd/bwd
//!   gradient communication + the per-strategy optimizer step, with a
//!   closed-form `pp = 1` fast path and the timeline engine for
//!   everything else.
//! * [`batch`] — structure-of-arrays evaluation of N knob-varying lanes
//!   sharing one plan fingerprint, on both dispatch arms (chunked
//!   closed-form recurrences, and schedule-tape timeline replay for
//!   `pp > 1` / micro-batched / straggler shapes).
//! * [`bounds`] — admissible closed-form lower bounds on the playback's
//!   objectives, for the `canzona optimize` branch-and-bound search.
//! * [`faults`] — the elastic-cluster fault & heterogeneity model:
//!   deterministic seed-derived per-rank hardware profiles, rank-failure
//!   injection, and the recovery-cost charging rules the timeline arm
//!   applies (the single straggler scalar is the `last:<f>` special
//!   case).

pub mod batch;
pub mod bounds;
pub mod faults;
pub mod iteration;
pub mod scenario;
pub mod stream;
pub mod timeline;

pub use batch::{
    simulate_batch_into, simulate_timeline_batch_into, BreakdownBatch, LaneKnobs, ScenarioBatch,
    BATCH_CHUNK,
};
pub use bounds::ScenarioBounds;
pub use faults::{ClusterProfile, FailSpec, HeteroSpec};
pub use iteration::{
    simulate_iteration, simulate_iteration_cached, simulate_iteration_into,
    simulate_iteration_timeline, Breakdown, StageTable,
};
pub use scenario::Scenario;
pub use timeline::{PipelineSchedule, Timeline};
