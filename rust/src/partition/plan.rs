//! The DP partition plan (the Global Partition Map Π of Section 3.3).

#![warn(missing_docs)]

use crate::bail;
use crate::buffer::{FlatBuffer, PlacedParam};
use crate::util::error::Result;

/// Per-bucket slicing vectors: `cuts[i]` holds R+1 monotone absolute
/// offsets, `[s_{i,0} .. s_{i,R}]`, with `s_{i,0} = bucket.start` and
/// `s_{i,R} = bucket.end`. Rank r owns `[s_{i,r}, s_{i,r+1})` of bucket i.
///
/// Atomicity applies to *matrix-based* parameters only: element-wise
/// (AdamW-routed) tensors such as embeddings are mathematically splittable
/// at any offset, and exploiting that is what keeps the balanced plans
/// near ratio 1.0 despite a 300M-element embedding in the census.
#[derive(Clone, Debug)]
pub struct DpPlan {
    /// DP group size (R).
    pub ranks: usize,
    /// Per-bucket cut vectors (see the struct docs).
    pub cuts: Vec<Vec<usize>>,
    /// Atomicity discipline of interior cuts:
    /// `Strict` — every interior cut on a parameter boundary;
    /// `MatrixOnly` — cuts may fall inside element-wise parameters;
    /// `None` — cuts anywhere (ZeRO-1 equal chunk).
    pub atomicity: Atomicity,
}

/// See [`DpPlan::atomicity`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Atomicity {
    /// Every interior cut on a parameter boundary.
    Strict,
    /// Cuts may fall inside element-wise (AdamW-routed) parameters.
    MatrixOnly,
    /// Cuts anywhere (ZeRO-1 equal chunk).
    None,
}

impl DpPlan {
    /// The shard sizes `S_{i,r}` of bucket `i` (elements).
    pub fn shard_sizes(&self, bucket: usize) -> Vec<usize> {
        let c = &self.cuts[bucket];
        (0..self.ranks).map(|r| c[r + 1] - c[r]).collect()
    }

    /// Owner rank of a placed parameter (by its start offset — paper
    /// Eq. (1) anchoring). Only meaningful for atomic plans.
    pub fn owner_of(&self, p: &PlacedParam) -> usize {
        let c = &self.cuts[p.bucket];
        // The unique r with c[r] <= start < c[r+1]. Plans with empty
        // shards hold duplicate cut values, and `binary_search` returns
        // an arbitrary duplicate — which attributed parameters to ranks
        // whose interval is empty and disagreed with `rank_loads`. The
        // last cut <= start is the only rank that can own a non-empty
        // span beginning there.
        let ins = c.partition_point(|&x| x <= p.start);
        (ins - 1).min(self.ranks - 1)
    }

    /// Parameter indices owned by each rank (atomic ownership by start
    /// index — exact for `Strict` plans; for `MatrixOnly` plans a split
    /// element-wise param is attributed to the rank holding its start).
    pub fn rank_params(&self, fb: &FlatBuffer) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.ranks];
        for p in &fb.params {
            out[self.owner_of(p)].push(p.index);
        }
        out
    }

    /// Aggregate per-rank load under a weight function, prorating
    /// parameters that straddle a cut by element overlap (exact for
    /// element-wise costs, which are linear in elements; matrix params
    /// never straddle cuts in valid plans).
    pub fn rank_loads<F: Fn(&PlacedParam) -> f64>(&self, fb: &FlatBuffer, w: F) -> Vec<f64> {
        let mut loads = vec![0.0; self.ranks];
        for p in &fb.params {
            let c = &self.cuts[p.bucket];
            let wp = w(p);
            let numel = p.numel().max(1) as f64;
            // Ranks whose interval intersects [p.start, p.end).
            let first = match c.binary_search(&p.start) {
                Ok(r) => r.min(self.ranks - 1),
                Err(ins) => ins - 1,
            };
            for r in first..self.ranks {
                let lo = c[r].max(p.start);
                let hi = c[r + 1].min(p.end);
                if hi <= lo {
                    if c[r] >= p.end {
                        break;
                    }
                    continue;
                }
                loads[r] += wp * (hi - lo) as f64 / numel;
            }
        }
        loads
    }

    /// Validate the plan's structural invariants against the buffer:
    /// monotone cuts covering each bucket exactly, plus the atomicity
    /// discipline (`Strict`: all interior cuts on parameter boundaries;
    /// `MatrixOnly`: cuts inside matrix-based parameters are forbidden).
    pub fn validate(&self, fb: &FlatBuffer) -> Result<()> {
        if self.cuts.len() != fb.buckets.len() {
            bail!("plan has {} buckets, buffer has {}", self.cuts.len(), fb.buckets.len());
        }
        for (i, b) in fb.buckets.iter().enumerate() {
            let c = &self.cuts[i];
            if c.len() != self.ranks + 1 {
                bail!("bucket {i}: {} cuts for {} ranks", c.len(), self.ranks);
            }
            if c[0] != b.start || c[self.ranks] != b.end {
                bail!("bucket {i}: cuts do not span [{}, {})", b.start, b.end);
            }
            for r in 0..self.ranks {
                if c[r + 1] < c[r] {
                    bail!("bucket {i}: cuts not monotone at rank {r}");
                }
            }
            if self.atomicity == Atomicity::None {
                continue;
            }
            let atomic_cuts = fb.atomic_cuts(i);
            for (r, cut) in c[1..self.ranks].iter().enumerate() {
                if atomic_cuts.contains(cut) {
                    continue;
                }
                if self.atomicity == Atomicity::Strict {
                    bail!("bucket {i}: cut {cut} (rank {}) inside a tensor", r + 1);
                }
                // MatrixOnly: the enclosing parameter must be splittable.
                let host = b
                    .members
                    .iter()
                    .map(|&pi| &fb.params[pi])
                    .find(|p| p.start < *cut && *cut < p.end);
                match host {
                    Some(p) if p.param.is_matrix_opt() => {
                        bail!("bucket {i}: cut {cut} inside matrix param {}", p.param.name)
                    }
                    Some(_) => {}
                    None => bail!("bucket {i}: cut {cut} outside bucket"),
                }
            }
        }
        Ok(())
    }

    /// Approximate heap bytes held by the plan (the plan cache's
    /// byte-budget accounting unit).
    pub fn heap_bytes(&self) -> usize {
        self.cuts.len() * std::mem::size_of::<Vec<usize>>()
            + self.cuts.iter().map(|c| c.len() * std::mem::size_of::<usize>()).sum::<usize>()
    }

    /// J_DP (paper Eq. 2): max deviation of per-rank load from the mean.
    pub fn j_dp<F: Fn(&PlacedParam) -> f64>(&self, fb: &FlatBuffer, w: F) -> f64 {
        let loads = self.rank_loads(fb, w);
        let mu = loads.iter().sum::<f64>() / self.ranks as f64;
        loads.iter().map(|l| (l - mu).abs()).fold(0.0, f64::max)
    }

    /// J_Comm (paper Eq. 3): total deviation of shard sizes from |B|/R.
    pub fn j_comm(&self, fb: &FlatBuffer) -> f64 {
        let mut total = 0.0;
        for (i, b) in fb.buckets.iter().enumerate() {
            let ideal = b.size() as f64 / self.ranks as f64;
            for s in self.shard_sizes(i) {
                total += (s as f64 - ideal).abs();
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shapes::{Param, ParamKind, TensorShape};

    fn fb(sizes: &[usize], bucket: usize) -> FlatBuffer {
        let params: Vec<Param> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                Param::new(&format!("p{i}"), TensorShape::vector(n), ParamKind::Vector, None)
            })
            .collect();
        FlatBuffer::build(&params, bucket)
    }

    #[test]
    fn owner_by_start_index() {
        let fb = fb(&[10, 10, 10, 10], 1000);
        let plan = DpPlan { ranks: 2, cuts: vec![vec![0, 20, 40]], atomicity: Atomicity::Strict };
        assert_eq!(plan.owner_of(&fb.params[0]), 0);
        assert_eq!(plan.owner_of(&fb.params[1]), 0);
        assert_eq!(plan.owner_of(&fb.params[2]), 1);
        assert_eq!(plan.owner_of(&fb.params[3]), 1);
    }

    #[test]
    fn owner_skips_empty_shards() {
        // Duplicate cuts (ranks 0..2 hold empty intervals): the owner of
        // a parameter starting at the duplicated offset is the rank with
        // the non-empty span, matching where rank_loads attributes it.
        let fb = fb(&[10, 10], 1000);
        let plan = DpPlan {
            ranks: 4,
            cuts: vec![vec![0, 0, 0, 10, 20]],
            atomicity: Atomicity::Strict,
        };
        assert_eq!(plan.owner_of(&fb.params[0]), 2);
        assert_eq!(plan.owner_of(&fb.params[1]), 3);
        let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
        assert_eq!(loads, vec![0.0, 0.0, 10.0, 10.0]);
        let rp = plan.rank_params(&fb);
        assert_eq!(rp[2], vec![0]);
        assert_eq!(rp[3], vec![1]);
    }

    #[test]
    fn validate_catches_bad_span() {
        let fb = fb(&[10, 10], 1000);
        let plan = DpPlan { ranks: 2, cuts: vec![vec![0, 10, 19]], atomicity: Atomicity::Strict };
        assert!(plan.validate(&fb).is_err());
    }

    #[test]
    fn validate_catches_non_atomic() {
        let fb = fb(&[10, 10], 1000);
        let plan = DpPlan { ranks: 2, cuts: vec![vec![0, 5, 20]], atomicity: Atomicity::Strict };
        assert!(plan.validate(&fb).is_err());
        let plan2 = DpPlan { ranks: 2, cuts: vec![vec![0, 5, 20]], atomicity: Atomicity::None };
        assert!(plan2.validate(&fb).is_ok());
    }

    #[test]
    fn objectives() {
        let fb = fb(&[30, 10], 1000);
        let plan = DpPlan { ranks: 2, cuts: vec![vec![0, 30, 40]], atomicity: Atomicity::Strict };
        let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
        assert_eq!(loads, vec![30.0, 10.0]);
        assert_eq!(plan.j_dp(&fb, |p| p.numel() as f64), 10.0);
        assert_eq!(plan.j_comm(&fb), 20.0); // |30-20| + |10-20|
    }
}
