//! Standard ZeRO-1 "Equal Chunk" partitioning (paper Fig. 1, gray path).
//!
//! Uniform |B|/R slices per bucket, agnostic to parameter boundaries.
//! Perfect communication balance, zero atomicity: the baseline geometry
//! that element-wise optimizers use and matrix-based optimizers cannot.

use crate::buffer::FlatBuffer;

use super::plan::{Atomicity, DpPlan};

pub fn equal_chunk(fb: &FlatBuffer, ranks: usize) -> DpPlan {
    assert!(ranks >= 1);
    let cuts = (0..fb.buckets.len())
        .map(|i| fb.equal_chunk_cuts(i, ranks))
        .collect();
    DpPlan { ranks, cuts, atomicity: Atomicity::None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::shapes::{Param, ParamKind, TensorShape};

    #[test]
    fn uniform_shards() {
        let params: Vec<Param> = (0..4)
            .map(|i| Param::new(&format!("p{i}"), TensorShape::vector(25), ParamKind::Vector, None))
            .collect();
        let fb = FlatBuffer::build(&params, 1000);
        let plan = equal_chunk(&fb, 4);
        plan.validate(&fb).unwrap();
        assert_eq!(plan.shard_sizes(0), vec![25; 4]);
        assert_eq!(plan.j_comm(&fb), 0.0);
    }

    #[test]
    fn real_census_valid_but_not_atomic() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = equal_chunk(&fb, 16);
        plan.validate(&fb).unwrap();
        // Force-checking atomicity must fail on a real census.
        let strict = DpPlan { atomicity: super::super::plan::Atomicity::Strict, ..plan };
        assert!(strict.validate(&fb).is_err());
    }
}
