//! α-Balanced Greedy LPT Partitioning — paper Algorithm 1.
//!
//! Buckets are processed in LPT (descending total load) order. For each
//! bucket, a target allocation vector blends a uniform basis `v_even`
//! (α→0: ZeRO-1-like communication balance) with a deficit-filling basis
//! `v_fill` (α→1: global compute balance), then is discretized onto the
//! bucket's feasible cut points. Boundaries only *shift* within buckets —
//! the sequential rank ordering is preserved, so coalesced variable-size
//! Reduce-Scatter / All-Gather remain launchable (the paper's key
//! geometric-compatibility property).
//!
//! The feasible cut set `U_i` contains every parameter boundary, plus —
//! when `split_elementwise` is on — arbitrary offsets *inside*
//! element-wise (AdamW-routed) parameters: those updates are separable,
//! so only matrix-based tensors are truly atomic. This is what lets the
//! balanced plan stay near ratio 1.0 even though the embedding is a
//! single ~300M-element tensor.

use crate::buffer::{FlatBuffer, PlacedParam};

use super::plan::{Atomicity, DpPlan};

/// Compute the α-balanced partition plan.
///
/// * `w` — per-parameter load (paper default: `numel`; Fig. 16 shows exact
///   FLOPs changes results by ~1e-4 s).
/// * `alpha` — blend factor in `[0, 1]`.
/// * `split_elementwise` — allow cuts inside element-wise parameters
///   (production default). The numeric trainer passes `false` because its
///   per-shape update executables expect whole tensors.
pub fn alpha_balanced<F: Fn(&PlacedParam) -> f64>(
    fb: &FlatBuffer,
    ranks: usize,
    alpha: f64,
    split_elementwise: bool,
    w: F,
) -> DpPlan {
    assert!(ranks >= 1);
    assert!((0.0..=1.0).contains(&alpha), "alpha out of range: {alpha}");
    let n_buckets = fb.buckets.len();

    // Per-bucket: boundary offsets, prefix loads Φ, and per-segment
    // splittability. Segment j lies between boundary j and j+1.
    let mut bucket_load = vec![0.0f64; n_buckets];
    let mut cut_offsets: Vec<Vec<usize>> = Vec::with_capacity(n_buckets);
    let mut cut_prefix: Vec<Vec<f64>> = Vec::with_capacity(n_buckets);
    let mut seg_soft: Vec<Vec<bool>> = Vec::with_capacity(n_buckets);
    for (i, b) in fb.buckets.iter().enumerate() {
        let mut offsets = Vec::with_capacity(b.members.len() + 1);
        let mut prefix = Vec::with_capacity(b.members.len() + 1);
        let mut soft = Vec::with_capacity(b.members.len());
        offsets.push(b.start);
        prefix.push(0.0);
        let mut acc = 0.0;
        for &pi in &b.members {
            let p = &fb.params[pi];
            acc += w(p);
            offsets.push(p.end);
            prefix.push(acc);
            soft.push(split_elementwise && !p.param.is_matrix_opt());
        }
        bucket_load[i] = acc;
        cut_offsets.push(offsets);
        cut_prefix.push(prefix);
        seg_soft.push(soft);
    }

    // LPT virtual reorder (descending load; stable on index for determinism).
    let mut order: Vec<usize> = (0..n_buckets).collect();
    order.sort_by(|&a, &b| {
        bucket_load[b]
            .partial_cmp(&bucket_load[a])
            .unwrap()
            .then(a.cmp(&b))
    });

    let total: f64 = bucket_load.iter().sum();
    let mu = total / ranks as f64;
    let mut global_load = vec![0.0f64; ranks];
    let mut cuts: Vec<Vec<usize>> = vec![Vec::new(); n_buckets];

    for &k in &order {
        // Step (1): deficits in the load domain.
        let deficits: Vec<f64> = global_load.iter().map(|l| (mu - l).max(0.0)).collect();
        let d_total: f64 = deficits.iter().sum();

        // Steps (2)-(3): blended target allocation.
        let v_even = 1.0 / ranks as f64;
        let target_alloc: Vec<f64> = (0..ranks)
            .map(|r| {
                let v_fill = if d_total > 0.0 { deficits[r] / d_total } else { v_even };
                bucket_load[k] * ((1.0 - alpha) * v_even + alpha * v_fill)
            })
            .collect();

        // Step (4): discretize onto feasible cuts, monotone.
        let offsets = &cut_offsets[k];
        let prefix = &cut_prefix[k];
        let soft = &seg_soft[k];
        let n_bounds = offsets.len();
        let mut c = Vec::with_capacity(ranks + 1);
        c.push(fb.buckets[k].start);
        // Position of the previous cut in "load space" and element space.
        let mut prev_load = 0.0f64;
        let mut prev_off = fb.buckets[k].start;
        let mut prev_bound = 0usize; // boundary index <= prev cut
        let mut target_c = 0.0;
        for r in 0..ranks - 1 {
            target_c += target_alloc[r];
            let t = target_c.max(prev_load);
            // Binary search the first boundary with prefix >= t.
            let mut a = prev_bound;
            let mut b = n_bounds - 1;
            while a < b {
                let mid = (a + b) / 2;
                if prefix[mid] < t {
                    a = mid + 1;
                } else {
                    b = mid;
                }
            }
            // Candidates: boundary `a`, boundary `a-1` (if >= prev cut),
            // or an interior point of segment a-1 when it is splittable.
            let (cut_off, cut_load, cut_bound) = if a > prev_bound
                && a >= 1
                && soft[a - 1]
                && t < prefix[a]
                && t > prefix[a - 1].max(prev_load)
            {
                // Exact interior cut inside a splittable segment.
                let seg_lo_off = offsets[a - 1].max(prev_off);
                let seg_lo_load = prefix[a - 1].max(prev_load);
                let seg_hi_off = offsets[a];
                let seg_hi_load = prefix[a];
                let frac = (t - seg_lo_load) / (seg_hi_load - seg_lo_load).max(1e-30);
                let off = seg_lo_off + (frac * (seg_hi_off - seg_lo_off) as f64).round() as usize;
                let off = off.clamp(seg_lo_off, seg_hi_off);
                let load = seg_lo_load
                    + (off - seg_lo_off) as f64 / (seg_hi_off - seg_lo_off).max(1) as f64
                        * (seg_hi_load - seg_lo_load);
                (off, load, a - 1)
            } else {
                // Choose the nearer of the bracketing boundaries (>= prev).
                let lo_ok = a > 0 && offsets[a - 1] >= prev_off && a - 1 >= prev_bound;
                let pick_lo = lo_ok && (t - prefix[a - 1]).abs() < (prefix[a] - t).abs();
                let j = if pick_lo { a - 1 } else { a };
                (offsets[j].max(prev_off), prefix[j].max(prev_load), j)
            };
            global_load[r] += cut_load - prev_load;
            prev_load = cut_load;
            prev_off = cut_off;
            prev_bound = cut_bound;
            c.push(cut_off);
        }
        // Last rank takes the remainder.
        global_load[ranks - 1] += prefix[n_bounds - 1] - prev_load;
        c.push(fb.buckets[k].end);
        cuts[k] = c;
    }

    DpPlan {
        ranks,
        cuts,
        atomicity: if split_elementwise { Atomicity::MatrixOnly } else { Atomicity::Strict },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::shapes::{Param, ParamKind, TensorShape};
    use crate::partition::naive_atomic::naive_atomic;
    use crate::util::stats::load_balance_ratio;

    fn numel(p: &PlacedParam) -> f64 {
        p.numel() as f64
    }

    fn toy(sizes: &[usize], bucket: usize) -> FlatBuffer {
        let params: Vec<Param> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                Param::new(&format!("p{i}"), TensorShape::vector(n), ParamKind::Vector, None)
            })
            .collect();
        FlatBuffer::build(&params, bucket)
    }

    #[test]
    fn valid_plan_both_modes() {
        let fb = toy(&[50, 30, 20, 40, 10, 60, 25, 15], 120);
        for alpha in [0.0, 0.3, 0.7, 1.0] {
            for split in [false, true] {
                let plan = alpha_balanced(&fb, 3, alpha, split, numel);
                plan.validate(&fb).unwrap();
            }
        }
    }

    #[test]
    fn alpha_zero_tracks_equal_chunk_comm() {
        let fb = toy(&[64, 64, 64, 64, 64, 64, 64, 64], 1_000_000);
        let j0 = alpha_balanced(&fb, 4, 0.0, false, numel).j_comm(&fb);
        assert_eq!(j0, 0.0); // perfectly divisible case
    }

    #[test]
    fn alpha_one_beats_naive_on_makespan() {
        // The headline property (paper Fig. 3c / 13): α=1 flattens the
        // load where the stride rule straggles.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let naive = naive_atomic(&fb, 32);
        let balanced = alpha_balanced(&fb, 32, 1.0, true, numel);
        balanced.validate(&fb).unwrap();
        let r_naive = load_balance_ratio(&naive.rank_loads(&fb, numel));
        let r_bal = load_balance_ratio(&balanced.rank_loads(&fb, numel));
        assert!(r_bal < r_naive, "balanced {r_bal} vs naive {r_naive}");
        assert!(r_bal < 1.25, "balanced ratio too high: {r_bal}");
    }

    #[test]
    fn strict_mode_bounded_by_largest_atom() {
        // Without element-wise splitting the embedding bounds the ratio;
        // the plan must still achieve (close to) that lower bound.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = alpha_balanced(&fb, 32, 1.0, false, numel);
        plan.validate(&fb).unwrap();
        let loads = plan.rank_loads(&fb, numel);
        let avg = loads.iter().sum::<f64>() / 32.0;
        let biggest = fb.params.iter().map(|p| p.numel()).max().unwrap() as f64;
        let lower_bound = (biggest / avg).max(1.0);
        let r = load_balance_ratio(&loads);
        assert!(r <= lower_bound * 1.15, "{r} vs lb {lower_bound}");
    }

    #[test]
    fn monotone_in_alpha_jdp() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let j_dp_0 = alpha_balanced(&fb, 16, 0.0, true, numel).j_dp(&fb, numel);
        let j_dp_1 = alpha_balanced(&fb, 16, 1.0, true, numel).j_dp(&fb, numel);
        assert!(j_dp_1 <= j_dp_0, "{j_dp_1} vs {j_dp_0}");
    }

    #[test]
    fn single_rank_owns_all() {
        let fb = toy(&[10, 20, 30], 1000);
        let plan = alpha_balanced(&fb, 1, 1.0, false, numel);
        plan.validate(&fb).unwrap();
        assert_eq!(plan.rank_loads(&fb, numel), vec![60.0]);
    }

    #[test]
    fn conservation_of_load() {
        let params = qwen3(Qwen3Size::S4B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        for split in [false, true] {
            let plan = alpha_balanced(&fb, 8, 1.0, split, numel);
            let total: f64 = plan.rank_loads(&fb, numel).iter().sum();
            assert!((total - fb.total as f64).abs() < 1.0, "{total} vs {}", fb.total);
        }
    }

    #[test]
    fn deterministic() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let a = alpha_balanced(&fb, 16, 0.5, true, numel);
        let b = alpha_balanced(&fb, 16, 0.5, true, numel);
        assert_eq!(a.cuts, b.cuts);
    }

    #[test]
    fn split_mode_handles_one_giant_softtensor() {
        // A single element-wise tensor much larger than everything else:
        // split mode must distribute it almost perfectly.
        let mut params = vec![Param::new(
            "embed", TensorShape::matrix(1000, 100), ParamKind::Embed, None)];
        for i in 0..8 {
            params.push(Param::new(&format!("m{i}"), TensorShape::matrix(10, 10),
                                   ParamKind::Matrix, Some(i)));
        }
        let fb = FlatBuffer::build(&params, usize::MAX);
        let plan = alpha_balanced(&fb, 8, 1.0, true, numel);
        plan.validate(&fb).unwrap();
        let r = load_balance_ratio(&plan.rank_loads(&fb, numel));
        assert!(r < 1.1, "{r}");
    }
}
