//! NV-layerwise baseline (paper Paradigm 2 / Appendix D.2).
//!
//! Assigns optimizer ownership at *layer* granularity via global LPT,
//! ignoring the physical bucket geometry. Mathematically exact, but the
//! resulting Data-Task Mismatch breaks bucket coalescing: the simulator
//! must time its gradient path as All-Reduce (2x volume) and add an
//! explicit Broadcast/All-Gather of updated parameters during the
//! optimizer step (the paper's "lose-lose dilemma", Option A).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::buffer::{FlatBuffer, PlacedParam};

/// Layerwise ownership: one owner rank per layer group.
#[derive(Clone, Debug)]
pub struct LayerwisePlan {
    pub ranks: usize,
    /// Owner rank per parameter index.
    pub owner: Vec<usize>,
    /// Load per rank under the weight used for assignment.
    pub rank_loads: Vec<f64>,
}

/// Ordered float for the min-heap.
#[derive(PartialEq, PartialOrd)]
struct F(f64);
impl Eq for F {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

/// Global LPT over layer groups: sort groups by descending load, assign
/// each to the currently least-loaded rank.
pub fn layerwise<F2: Fn(&PlacedParam) -> f64>(
    fb: &FlatBuffer,
    ranks: usize,
    w: F2,
) -> LayerwisePlan {
    assert!(ranks >= 1);
    // Group parameters by layer id; non-layer params (embed/head/final
    // norm) each form their own group (NVIDIA's implementation treats
    // them as standalone "layers").
    let mut groups: Vec<(u64, Vec<usize>, f64)> = Vec::new();
    let mut layer_slot: std::collections::BTreeMap<usize, usize> = Default::default();
    for p in &fb.params {
        match p.param.layer {
            Some(l) => {
                let slot = *layer_slot.entry(l).or_insert_with(|| {
                    groups.push((l as u64, Vec::new(), 0.0));
                    groups.len() - 1
                });
                groups[slot].1.push(p.index);
                groups[slot].2 += w(p);
            }
            None => {
                groups.push((1_000_000 + p.index as u64, vec![p.index], w(p)));
            }
        }
    }
    // LPT: heaviest group first, deterministic tie-break on group id.
    groups.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0)));

    let mut heap: BinaryHeap<Reverse<(F, usize)>> =
        (0..ranks).map(|r| Reverse((F(0.0), r))).collect();
    let mut owner = vec![0usize; fb.params.len()];
    let mut rank_loads = vec![0.0; ranks];
    for (_, members, load) in &groups {
        let Reverse((F(l), r)) = heap.pop().unwrap();
        for &pi in members {
            owner[pi] = r;
        }
        rank_loads[r] = l + load;
        heap.push(Reverse((F(rank_loads[r]), r)));
    }
    LayerwisePlan { ranks, owner, rank_loads }
}

impl LayerwisePlan {
    /// Approximate heap bytes held by the plan (the plan cache's
    /// byte-budget accounting unit).
    pub fn heap_bytes(&self) -> usize {
        self.owner.len() * std::mem::size_of::<usize>()
            + self.rank_loads.len() * std::mem::size_of::<f64>()
    }

    /// Does the assignment violate the ZeRO-1 geometric constraint in any
    /// bucket? True iff some bucket's owner sequence (in physical order)
    /// is not monotonically non-decreasing — the condition under which
    /// bucket-coalesced Reduce-Scatter is impossible (paper Fig. 15).
    pub fn violates_geometry(&self, fb: &FlatBuffer) -> bool {
        for b in &fb.buckets {
            let mut prev = 0usize;
            for (i, &pi) in b.members.iter().enumerate() {
                let o = self.owner[pi];
                if i > 0 && o < prev {
                    return true;
                }
                prev = o;
            }
        }
        false
    }

    pub fn rank_params(&self, fb: &FlatBuffer) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.ranks];
        for p in &fb.params {
            out[self.owner[p.index]].push(p.index);
        }
        out
    }

    pub fn rank_loads_with<F2: Fn(&PlacedParam) -> f64>(
        &self,
        fb: &FlatBuffer,
        w: F2,
    ) -> Vec<f64> {
        let mut loads = vec![0.0; self.ranks];
        for p in &fb.params {
            loads[self.owner[p.index]] += w(p);
        }
        loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::util::stats::load_balance_ratio;

    fn numel(p: &PlacedParam) -> f64 {
        p.numel() as f64
    }

    #[test]
    fn balances_load_well() {
        // Layerwise LPT *is* a good load balancer — that's not its flaw.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = layerwise(&fb, 16, numel);
        let r = load_balance_ratio(&plan.rank_loads_with(&fb, numel));
        assert!(r < 6.0, "{r}");
    }

    #[test]
    fn breaks_zero1_geometry() {
        // ...its flaw is geometric: owners interleave inside buckets.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = layerwise(&fb, 16, numel);
        assert!(plan.violates_geometry(&fb),
                "expected interleaved owners inside buckets");
    }

    #[test]
    fn whole_layers_colocated() {
        let params = qwen3(Qwen3Size::S4B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = layerwise(&fb, 8, numel);
        for l in 0..4 {
            let owners: Vec<usize> = fb
                .params
                .iter()
                .filter(|p| p.param.layer == Some(l))
                .map(|p| plan.owner[p.index])
                .collect();
            assert!(owners.windows(2).all(|w| w[0] == w[1]), "layer {l} split");
        }
    }

    #[test]
    fn all_params_assigned() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = layerwise(&fb, 4, numel);
        let total: f64 = plan.rank_loads_with(&fb, numel).iter().sum();
        assert_eq!(total as usize, fb.total);
    }

    #[test]
    fn deterministic() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        assert_eq!(layerwise(&fb, 8, numel).owner, layerwise(&fb, 8, numel).owner);
    }
}
