//! Naive atomic static partitioning — the stride rule of paper Eq. (1).
//!
//! Rank r owns parameter p iff `(r-1)·S <= Start_Index(p) < r·S` with
//! `S = |B|/R`. Atomic and geometry-respecting (zero-communication
//! optimizer step) but load-*unaware*: this is the paper's ASC ablation,
//! whose 3.2x straggers motivate Algorithm 1.

use crate::buffer::FlatBuffer;

use super::plan::{Atomicity, DpPlan};

/// Eq. (1) with `S = |B_i|/R` applied **per bucket** — the literal
/// Megatron-shard-registration reading, and the variant whose measured
/// imbalance (FLOPs 3.24x / mem 2.46x on Qwen3-32B) the paper reports
/// for its ASC ablation. Each bucket's stride grid is snapped forward to
/// parameter boundaries.
pub fn naive_atomic_per_bucket(fb: &FlatBuffer, ranks: usize) -> DpPlan {
    assert!(ranks >= 1);
    let mut cuts = Vec::with_capacity(fb.buckets.len());
    for b in &fb.buckets {
        let stride = b.size() as f64 / ranks as f64;
        let mut c = Vec::with_capacity(ranks + 1);
        c.push(b.start);
        for r in 1..ranks {
            let threshold = b.start + (r as f64 * stride) as usize;
            let cut = b
                .members
                .iter()
                .map(|&i| fb.params[i].start)
                .find(|&s| s >= threshold)
                .unwrap_or(b.end);
            c.push(cut.max(*c.last().unwrap()));
        }
        c.push(b.end);
        cuts.push(c);
    }
    DpPlan { ranks, cuts, atomicity: Atomicity::Strict }
}

/// Eq. (1) with `S = |B|/R` taken over the **whole flat buffer**: rank r
/// owns parameter p iff `r·S <= Start_Index(p) < (r+1)·S`. Per-bucket cut
/// vectors are derived by intersecting the global stride grid with each
/// bucket (a parameter's ownership never changes, so the per-bucket view
/// is consistent and still launches coalesced variable-size collectives).
/// Less pathological than the per-bucket variant; the numeric trainer's
/// ASC strategy uses this one.
pub fn naive_atomic(fb: &FlatBuffer, ranks: usize) -> DpPlan {
    assert!(ranks >= 1);
    let stride = fb.total as f64 / ranks as f64;
    // Global owner of a start offset under the stride rule.
    let owner = |start: usize| -> usize {
        ((start as f64 / stride) as usize).min(ranks - 1)
    };
    let mut cuts = Vec::with_capacity(fb.buckets.len());
    for b in &fb.buckets {
        let first_owner = owner(b.start);
        // Ranks before the bucket's first owner hold empty intervals.
        let mut c = vec![b.start; first_owner + 1];
        let mut current = first_owner;
        for &pi in &b.members {
            let p = &fb.params[pi];
            let o = owner(p.start);
            while current < o {
                c.push(p.start);
                current += 1;
            }
        }
        // Trailing ranks (past the bucket's last owner) hold empty tails.
        while c.len() < ranks + 1 {
            c.push(b.end);
        }
        cuts.push(c);
    }
    DpPlan { ranks, cuts, atomicity: Atomicity::Strict }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::shapes::{Param, ParamKind, TensorShape};
    use crate::util::stats::load_balance_ratio;

    fn toy(sizes: &[usize]) -> FlatBuffer {
        let params: Vec<Param> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                Param::new(&format!("p{i}"), TensorShape::vector(n), ParamKind::Vector, None)
            })
            .collect();
        FlatBuffer::build(&params, usize::MAX)
    }

    #[test]
    fn respects_eq1_stride_rule() {
        // buffer [0,100), R=2, S=50. p0 [0,60) starts at 0 -> rank 0;
        // p1 [60,100) starts at 60 >= 50 -> rank 1.
        let fb = toy(&[60, 40]);
        let plan = naive_atomic(&fb, 2);
        plan.validate(&fb).unwrap();
        assert_eq!(plan.owner_of(&fb.params[0]), 0);
        assert_eq!(plan.owner_of(&fb.params[1]), 1);
    }

    #[test]
    fn heavy_head_creates_straggler() {
        // One giant tensor followed by many small => rank 0 is overloaded.
        let mut sizes = vec![1000usize];
        sizes.extend(std::iter::repeat(10).take(100));
        let fb = toy(&sizes);
        let plan = naive_atomic(&fb, 4);
        plan.validate(&fb).unwrap();
        let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
        assert!(load_balance_ratio(&loads) > 1.5, "{loads:?}");
    }

    #[test]
    fn valid_on_real_census() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        for ranks in [2, 8, 32] {
            let plan = naive_atomic(&fb, ranks);
            plan.validate(&fb).unwrap();
            // every param owned exactly once is implied by owner_of + cuts
            let total: f64 = plan.rank_loads(&fb, |p| p.numel() as f64).iter().sum();
            assert_eq!(total as usize, fb.total);
        }
    }

    #[test]
    fn imbalanced_on_real_census() {
        // The paper's motivating measurement (Fig. 3c "naive"): real
        // censuses produce significant stragglers under the stride rule.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let plan = naive_atomic(&fb, 32);
        let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
        assert!(load_balance_ratio(&loads) > 1.3, "{}", load_balance_ratio(&loads));
    }
}
