//! Partition rules for the rival sharding strategies (ROADMAP item 3).
//!
//! Canzona's own partitioners slice a `FlatBuffer`; the rivals shard at
//! tensor granularity, so their rules are plain functions over shapes:
//!
//! * [`zero3_rows`] — **MatrixFSDP**: each TP-local matrix is split
//!   into contiguous row blocks of `ceil(rows / dp)`, rank `d` owning
//!   block `d` (trailing ranks may own nothing). The optimizer update
//!   is communication-free: the preconditioner is recomputed per rank
//!   from the parameter All-Gather already in flight for FSDP compute,
//!   and only the element-linear update pass is sharded.
//! * [`lpt_owners`] — **DMuon**: whole tensors are assigned to DP
//!   owner ranks by greedy LPT over their update FLOPs; each owner
//!   gathers the momentum shards, orthogonalizes, and scatters the
//!   update back (overlapped, see `sim::iteration`).
//!
//! Dion has no buffer-geometry rule — its split is in factor space
//! (see `cost::optim::dion_rank`).

/// Number of rows of a `rows`-row matrix owned by `rank` under ZeRO-3
/// contiguous row sharding across `dp` ranks: blocks of
/// `ceil(rows / dp)`, overflow clamped, so trailing ranks may own zero
/// rows. The blocks tile the matrix exactly — `Σ_d zero3_rows(r, dp, d)
/// == r` — which is what the state-conservation property pins.
pub fn zero3_rows(rows: usize, dp: usize, rank: usize) -> usize {
    debug_assert!(dp > 0 && rank < dp);
    let per = rows.div_ceil(dp);
    let lo = (rank * per).min(rows);
    let hi = (lo + per).min(rows);
    hi - lo
}

/// Greedy LPT assignment of whole tensors to `dp` owner ranks:
/// heaviest cost first, each onto the currently least-loaded rank.
/// Deterministic — cost ties keep input order, load ties pick the
/// lowest rank — so repeated builds of the same stage table are
/// bit-identical. Returns one owner rank per input tensor.
pub fn lpt_owners(costs: &[f64], dp: usize) -> Vec<usize> {
    debug_assert!(dp > 0);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    // Stable sort: equal costs keep declaration order.
    order.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]));
    let mut loads = vec![0.0f64; dp];
    let mut owners = vec![0usize; costs.len()];
    for i in order {
        let mut best = 0usize;
        for d in 1..dp {
            if loads[d] < loads[best] {
                best = d;
            }
        }
        owners[i] = best;
        loads[best] += costs[i];
    }
    owners
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero3_rows_tile_exactly() {
        for rows in [1usize, 2, 7, 8, 64, 151, 4096] {
            for dp in [1usize, 2, 3, 8, 32, 200] {
                let total: usize = (0..dp).map(|d| zero3_rows(rows, dp, d)).sum();
                assert_eq!(total, rows, "rows={rows} dp={dp}");
                // Rank 0 always owns the (joint-)largest block.
                let r0 = zero3_rows(rows, dp, 0);
                for d in 1..dp {
                    assert!(zero3_rows(rows, dp, d) <= r0);
                }
            }
        }
    }

    #[test]
    fn zero3_rows_overflow_ranks_own_nothing() {
        // 5 rows over 4 ranks: blocks of 2 → [2, 2, 1, 0].
        assert_eq!(
            (0..4).map(|d| zero3_rows(5, 4, d)).collect::<Vec<_>>(),
            vec![2, 2, 1, 0]
        );
    }

    #[test]
    fn lpt_owners_balances_and_covers() {
        let costs = [8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let owners = lpt_owners(&costs, 2);
        assert_eq!(owners.len(), costs.len());
        let mut loads = [0.0f64; 2];
        for (i, &d) in owners.iter().enumerate() {
            assert!(d < 2);
            loads[d] += costs[i];
        }
        // Classic LPT on this instance is perfectly balanced.
        assert_eq!(loads[0], loads[1]);
    }

    #[test]
    fn lpt_owners_is_deterministic_under_ties() {
        let costs = [1.0, 1.0, 1.0, 1.0];
        assert_eq!(lpt_owners(&costs, 4), lpt_owners(&costs, 4));
        // Equal costs fall heaviest-first in declaration order onto
        // ranks 0, 1, 2, 3.
        assert_eq!(lpt_owners(&costs, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn lpt_owners_more_ranks_than_tensors() {
        let owners = lpt_owners(&[3.0, 1.0], 8);
        assert_eq!(owners, vec![0, 1]);
    }
}
