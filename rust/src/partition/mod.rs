//! DP-plane partitioners (paper Section 3).
//!
//! Four strategies over the same `FlatBuffer` geometry:
//!
//! * [`equal_chunk`] — standard ZeRO-1 uniform slicing (violates
//!   atomicity; only valid for element-wise optimizers).
//! * [`naive_atomic`] — the stride rule of paper Eq. (1): atomic, zero
//!   extra communication, but load-imbalanced (the ASC ablation).
//! * [`alpha_balanced`] — **α-Balanced Greedy LPT** (paper Alg. 1): atomic
//!   *and* load-balanced by shifting slice boundaries within buckets.
//! * [`layerwise`] — the NV-layerwise baseline: global LPT over layers,
//!   which breaks the ZeRO-1 geometric constraint and forces the
//!   All-Reduce + Broadcast communication path (paper Appendix D.2).

pub mod alpha_balanced;
pub mod equal_chunk;
pub mod layerwise;
pub mod naive_atomic;
pub mod plan;

pub use alpha_balanced::alpha_balanced;
pub use equal_chunk::equal_chunk;
pub use layerwise::{layerwise, LayerwisePlan};
pub use naive_atomic::{naive_atomic, naive_atomic_per_bucket};
pub use plan::{Atomicity, DpPlan};

/// The DP strategies the experiments compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DpStrategy {
    /// Synchronous/redundant compute (DDP — every rank updates everything).
    Sc,
    /// NVIDIA layerwise_optimizer baseline.
    NvLayerwise,
    /// Atomic static partition without load balancing.
    Asc,
    /// α-balanced atomic static partition (Canzona).
    LbAsc,
}

impl DpStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            DpStrategy::Sc => "SC",
            DpStrategy::NvLayerwise => "NV-layerwise",
            DpStrategy::Asc => "ASC",
            DpStrategy::LbAsc => "LB-ASC",
        }
    }

    pub fn parse(s: &str) -> Option<DpStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "sc" => Some(DpStrategy::Sc),
            "nv-layerwise" | "layerwise" | "nv" => Some(DpStrategy::NvLayerwise),
            "asc" => Some(DpStrategy::Asc),
            "lb-asc" | "lbasc" | "canzona" => Some(DpStrategy::LbAsc),
            _ => None,
        }
    }
}
