//! DP-plane partitioners (paper Section 3) and the strategy zoo.
//!
//! Canzona's own ladder over the same `FlatBuffer` geometry:
//!
//! * [`equal_chunk`] — standard ZeRO-1 uniform slicing (violates
//!   atomicity; only valid for element-wise optimizers).
//! * [`naive_atomic`] — the stride rule of paper Eq. (1): atomic, zero
//!   extra communication, but load-imbalanced (the ASC ablation).
//! * [`alpha_balanced`] — **α-Balanced Greedy LPT** (paper Alg. 1): atomic
//!   *and* load-balanced by shifting slice boundaries within buckets.
//! * [`layerwise`] — the NV-layerwise baseline: global LPT over layers,
//!   which breaks the ZeRO-1 geometric constraint and forces the
//!   All-Reduce + Broadcast communication path (paper Appendix D.2).
//!
//! Plus the rival sharding rules from the related work ([`rivals`]):
//!
//! * [`rivals::zero3_rows`] — MatrixFSDP's ZeRO-3 contiguous row
//!   sharding (communication-free update, redundant preconditioners).
//! * [`rivals::lpt_owners`] — DMuon's whole-tensor DP ownership
//!   (gather/orthogonalize/scatter of momentum shards).
//! * Dion's low-rank factor split lives in
//!   [`crate::cost::optim::dion_rank`] (cost-model-side: the factor
//!   shapes, not the buffer geometry, define its plan).

pub mod alpha_balanced;
pub mod equal_chunk;
pub mod layerwise;
pub mod naive_atomic;
pub mod plan;
pub mod rivals;

pub use alpha_balanced::alpha_balanced;
pub use equal_chunk::equal_chunk;
pub use layerwise::{layerwise, LayerwisePlan};
pub use naive_atomic::{naive_atomic, naive_atomic_per_bucket};
pub use plan::{Atomicity, DpPlan};

/// The DP strategies the experiments compare: Canzona's ladder
/// (SC → NV-layerwise → ASC → LB-ASC) plus the rival sharding
/// strategies from the related work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DpStrategy {
    /// Synchronous/redundant compute (DDP — every rank updates everything).
    Sc,
    /// NVIDIA layerwise_optimizer baseline.
    NvLayerwise,
    /// Atomic static partition without load balancing.
    Asc,
    /// α-balanced atomic static partition (Canzona).
    LbAsc,
    /// ZeRO-3 row-sharded matrix optimizer, communication-free update.
    MatrixFsdp,
    /// Distributed Muon: whole-tensor DP ownership with overlapped
    /// Gather/Scatter of momentum shards.
    DMuon,
    /// Low-rank factor updates with DP-sharded error feedback.
    Dion,
}

impl DpStrategy {
    /// Every variant, in declaration order — the sweep axes' and test
    /// grids' canonical enumeration. [`ordinal`] (an exhaustive match)
    /// forces a compile error when a variant lands without being added
    /// here, and `tests::parse_label_round_trip_is_exhaustive` pins the
    /// parse/label round-trip over exactly this list.
    ///
    /// [`ordinal`]: DpStrategy::ordinal
    pub const ALL: [DpStrategy; 7] = [
        DpStrategy::Sc,
        DpStrategy::NvLayerwise,
        DpStrategy::Asc,
        DpStrategy::LbAsc,
        DpStrategy::MatrixFsdp,
        DpStrategy::DMuon,
        DpStrategy::Dion,
    ];

    /// Declaration-order index of the variant. The match is exhaustive
    /// on purpose: adding a variant without extending [`ALL`] (and the
    /// parse/label arms, which the round-trip test then covers) fails
    /// to compile here instead of silently missing the sweep axes.
    ///
    /// [`ALL`]: DpStrategy::ALL
    pub fn ordinal(&self) -> usize {
        match self {
            DpStrategy::Sc => 0,
            DpStrategy::NvLayerwise => 1,
            DpStrategy::Asc => 2,
            DpStrategy::LbAsc => 3,
            DpStrategy::MatrixFsdp => 4,
            DpStrategy::DMuon => 5,
            DpStrategy::Dion => 6,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DpStrategy::Sc => "SC",
            DpStrategy::NvLayerwise => "NV-layerwise",
            DpStrategy::Asc => "ASC",
            DpStrategy::LbAsc => "LB-ASC",
            DpStrategy::MatrixFsdp => "MatrixFSDP",
            DpStrategy::DMuon => "DMuon",
            DpStrategy::Dion => "Dion",
        }
    }

    pub fn parse(s: &str) -> Option<DpStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "sc" => Some(DpStrategy::Sc),
            "nv-layerwise" | "layerwise" | "nv" => Some(DpStrategy::NvLayerwise),
            "asc" => Some(DpStrategy::Asc),
            "lb-asc" | "lbasc" | "canzona" => Some(DpStrategy::LbAsc),
            "matrix-fsdp" | "matrixfsdp" | "fsdp" => Some(DpStrategy::MatrixFsdp),
            "dmuon" | "d-muon" => Some(DpStrategy::DMuon),
            "dion" => Some(DpStrategy::Dion),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::DpStrategy;

    #[test]
    fn parse_label_round_trip_is_exhaustive() {
        // The PR 7 `CacheStats` pattern: `ordinal`'s exhaustive match
        // breaks the build when a variant is added; this test then
        // fails until ALL / label / parse cover it too.
        assert_eq!(DpStrategy::ALL.len(), 7);
        for (i, s) in DpStrategy::ALL.iter().enumerate() {
            assert_eq!(s.ordinal(), i, "ALL must list variants in declaration order");
            // label() must re-parse both verbatim and lowercased — the
            // latter is what `SweepGrid::to_cli_args` emits.
            assert_eq!(DpStrategy::parse(s.label()), Some(*s), "{s:?}");
            assert_eq!(
                DpStrategy::parse(&s.label().to_ascii_lowercase()),
                Some(*s),
                "{s:?}: lowercase label must round-trip (CLI emission)"
            );
        }
        // Labels (and therefore CLI tokens) must be pairwise distinct.
        for a in DpStrategy::ALL {
            for b in DpStrategy::ALL {
                if a != b {
                    assert_ne!(a.label(), b.label());
                }
            }
        }
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(DpStrategy::parse("canzona"), Some(DpStrategy::LbAsc));
        assert_eq!(DpStrategy::parse("fsdp"), Some(DpStrategy::MatrixFsdp));
        assert_eq!(DpStrategy::parse("d-muon"), Some(DpStrategy::DMuon));
        assert_eq!(DpStrategy::parse("warp"), None);
    }
}
