//! # Canzona
//!
//! A reproduction of *"Canzona: A Unified, Asynchronous, and Load-Balanced
//! Framework for Distributed Matrix-based Optimizers"* (CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — offline-environment substrates (JSON, PRNG, CLI, stats,
//!   a miniature property-testing harness, a bench timer).
//! * [`model`] — the Qwen3 parameter-shape census and tensor-parallel
//!   splitting rules that define the paper's workloads.
//! * [`buffer`] — Megatron-style `param_and_grad_buffer` (flattening,
//!   bucketing, start offsets — the ZeRO-1 geometry).
//! * [`cost`] — optimizer FLOPs/state models (Muon, Shampoo, SOAP, AdamW)
//!   and the α-β interconnect model (NVLink / InfiniBand collectives).
//! * [`partition`] — the DP plane: equal-chunk ZeRO-1, naive atomic (ASC),
//!   **α-balanced greedy LPT** (paper Alg. 1), and the NV-layerwise
//!   baseline.
//! * [`schedule`] — the TP plane: **micro-group construction with greedy
//!   rollback** (paper Algs. 2/3) over the min-heap LPT solver (Alg. 4),
//!   plus the TP-SC baseline.
//! * [`sim`] — a discrete-event cluster simulator that plays out full
//!   training iterations (bucket-overlapped fwd/bwd communication,
//!   per-rank optimizer timelines) and produces the paper's metrics.
//! * [`sweep`] — the batch-evaluation service: a plan cache keyed by
//!   scenario fingerprint plus a work-stealing parallel runner, which the
//!   figure harnesses and the `sweep` CLI subcommand run on.
//! * [`collectives`] — real in-memory collectives over thread "ranks"
//!   (variable-size reduce-scatter / all-gather, fused all-to-all) for the
//!   numeric training path.
//! * [`runtime`] — PJRT: load AOT-compiled HLO-text artifacts and execute
//!   them on the request path (python is build-time only).
//! * [`train`] — the distributed numeric trainer (paper Fig. 5 parity).
//! * [`experiments`] — one harness per paper figure/table.
//! * [`coordinator`] — configuration + CLI entry points.

pub mod buffer;
pub mod collectives;
pub mod coordinator;
pub mod cost;
pub mod experiments;
pub mod model;
pub mod partition;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod sweep;
pub mod train;
pub mod util;

pub use coordinator::config::Config;

/// Counting pass-through allocator (see [`util::alloc`]): lets the test
/// suite prove the warm simulation path is allocation-free. Overhead is
/// one thread-local increment per allocation.
#[global_allocator]
static GLOBAL_ALLOC: util::alloc::CountingAllocator = util::alloc::CountingAllocator;
