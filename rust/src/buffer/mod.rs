//! Megatron-style `param_and_grad_buffer` (Appendix B of the paper).
//!
//! All parameters are flattened, in registration order, into one
//! contiguous buffer that is logically divided into *buckets* to pipeline
//! communication with computation. ZeRO-1's "equal chunk" rule slices
//! each bucket into `R` uniform segments agnostic to parameter
//! boundaries — the geometry the paper's static partitioning must respect
//! while moving slice boundaries to parameter edges.

use crate::model::shapes::Param;

/// A parameter's placement in the flat buffer.
#[derive(Clone, Debug)]
pub struct PlacedParam {
    pub param: Param,
    /// Index of the parameter in the census (stable id).
    pub index: usize,
    /// Start offset in the flat buffer (elements).
    pub start: usize,
    /// End offset (exclusive).
    pub end: usize,
    /// Bucket this parameter belongs to.
    pub bucket: usize,
}

impl PlacedParam {
    pub fn numel(&self) -> usize {
        self.end - self.start
    }
}

/// One logical bucket: a contiguous range of the flat buffer holding a
/// whole number of parameters.
#[derive(Clone, Debug)]
pub struct Bucket {
    pub index: usize,
    pub start: usize,
    pub end: usize,
    /// Indices (into `FlatBuffer::params`) of the members, in order.
    pub members: Vec<usize>,
}

impl Bucket {
    pub fn size(&self) -> usize {
        self.end - self.start
    }
}

/// The flattened parameter/gradient buffer with bucket structure.
#[derive(Clone, Debug)]
pub struct FlatBuffer {
    pub params: Vec<PlacedParam>,
    pub buckets: Vec<Bucket>,
    pub total: usize,
}

impl FlatBuffer {
    /// Pack `params` in order; start a new bucket whenever the current one
    /// reaches `bucket_size` elements (Megatron's default is 40M elements;
    /// parameters are never split across buckets).
    pub fn build(params: &[Param], bucket_size: usize) -> FlatBuffer {
        assert!(bucket_size > 0);
        let mut placed = Vec::with_capacity(params.len());
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut offset = 0usize;
        for (index, p) in params.iter().enumerate() {
            let need_new = match buckets.last() {
                None => true,
                Some(b) => b.end - b.start >= bucket_size,
            };
            if need_new {
                buckets.push(Bucket {
                    index: buckets.len(),
                    start: offset,
                    end: offset,
                    members: Vec::new(),
                });
            }
            let b = buckets.last_mut().unwrap();
            let numel = p.numel();
            placed.push(PlacedParam {
                param: p.clone(),
                index,
                start: offset,
                end: offset + numel,
                bucket: b.index,
            });
            b.members.push(index);
            offset += numel;
            b.end = offset;
        }
        FlatBuffer { params: placed, buckets, total: offset }
    }

    /// ZeRO-1 "equal chunk" boundaries for a bucket: R+1 cut points that
    /// slice `[start, end)` into R uniform segments (the last absorbs the
    /// remainder). This is the geometric rule Reduce-Scatter assumes.
    pub fn equal_chunk_cuts(&self, bucket: usize, ranks: usize) -> Vec<usize> {
        let b = &self.buckets[bucket];
        let size = b.size();
        let stride = size / ranks;
        let mut cuts = Vec::with_capacity(ranks + 1);
        for r in 0..ranks {
            cuts.push(b.start + r * stride);
        }
        cuts.push(b.end);
        cuts
    }

    /// Feasible atomic cut points of a bucket: offsets at parameter
    /// boundaries (the set `U_i` in the paper), including both ends.
    pub fn atomic_cuts(&self, bucket: usize) -> Vec<usize> {
        let b = &self.buckets[bucket];
        let mut cuts: Vec<usize> = b.members.iter().map(|&i| self.params[i].start).collect();
        cuts.push(b.end);
        cuts
    }

    /// Cumulative load `Φ_i(u)` of a bucket up to cut point `u` under a
    /// per-parameter weight function.
    pub fn cumulative_load<F: Fn(&PlacedParam) -> f64>(
        &self,
        bucket: usize,
        upto: usize,
        w: &F,
    ) -> f64 {
        self.buckets[bucket]
            .members
            .iter()
            .map(|&i| &self.params[i])
            .filter(|p| p.end <= upto)
            .map(w)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::shapes::{Param, ParamKind, TensorShape};

    fn toy_params(sizes: &[usize]) -> Vec<Param> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                Param::new(&format!("p{i}"), TensorShape::vector(n), ParamKind::Vector, None)
            })
            .collect()
    }

    #[test]
    fn contiguous_and_complete() {
        let params = toy_params(&[10, 20, 30, 40]);
        let fb = FlatBuffer::build(&params, 35);
        assert_eq!(fb.total, 100);
        let mut prev_end = 0;
        for p in &fb.params {
            assert_eq!(p.start, prev_end);
            prev_end = p.end;
        }
        assert_eq!(prev_end, fb.total);
    }

    #[test]
    fn bucket_boundaries_respect_params() {
        let params = toy_params(&[10, 20, 30, 40]);
        let fb = FlatBuffer::build(&params, 35);
        // bucket 0: p0+p1+p2 would be 60 > 35 after p1 (10+20=30 < 35, add p2 -> 60)
        // rule: open new bucket when current >= bucket_size
        for b in &fb.buckets {
            assert!(!b.members.is_empty());
            assert_eq!(fb.params[b.members[0]].start, b.start);
            assert_eq!(fb.params[*b.members.last().unwrap()].end, b.end);
        }
        // buckets tile the buffer
        let mut prev = 0;
        for b in &fb.buckets {
            assert_eq!(b.start, prev);
            prev = b.end;
        }
        assert_eq!(prev, fb.total);
    }

    #[test]
    fn equal_chunk_cuts_uniform() {
        let params = toy_params(&[100]);
        let fb = FlatBuffer::build(&params, 1000);
        let cuts = fb.equal_chunk_cuts(0, 4);
        assert_eq!(cuts, vec![0, 25, 50, 75, 100]);
    }

    #[test]
    fn equal_chunk_violates_atomicity_on_real_census() {
        // The motivating observation: uniform cuts land inside tensors.
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        let cuts = fb.equal_chunk_cuts(0, 8);
        let atomic = fb.atomic_cuts(0);
        let violations = cuts[1..cuts.len() - 1]
            .iter()
            .filter(|c| !atomic.contains(c))
            .count();
        assert!(violations > 0, "expected equal-chunk cuts inside tensors");
    }

    #[test]
    fn atomic_cuts_are_param_starts() {
        let params = toy_params(&[5, 7, 9]);
        let fb = FlatBuffer::build(&params, 1000);
        assert_eq!(fb.atomic_cuts(0), vec![0, 5, 12, 21]);
    }

    #[test]
    fn cumulative_load_counts_whole_params() {
        let params = toy_params(&[5, 7, 9]);
        let fb = FlatBuffer::build(&params, 1000);
        let w = |p: &PlacedParam| p.numel() as f64;
        assert_eq!(fb.cumulative_load(0, 0, &w), 0.0);
        assert_eq!(fb.cumulative_load(0, 5, &w), 5.0);
        assert_eq!(fb.cumulative_load(0, 12, &w), 12.0);
        assert_eq!(fb.cumulative_load(0, 11, &w), 5.0); // p1 not fully included
        assert_eq!(fb.cumulative_load(0, 21, &w), 21.0);
    }

    #[test]
    fn qwen_buffer_buckets_nonempty() {
        let params = qwen3(Qwen3Size::S1_7B);
        let fb = FlatBuffer::build(&params, 40_000_000);
        assert!(fb.buckets.len() > 10);
        assert_eq!(fb.total, crate::model::qwen3::total_params(&params));
    }
}
