//! Artifact manifest (emitted by `python/compile/aot.py`).

use std::path::Path;

use crate::model::shapes::{Param, ParamKind, TensorShape};
use crate::util::error::{Context, Result};
use crate::util::json::Value;

/// One parameter entry of the manifest.
#[derive(Clone, Debug)]
pub struct ManifestParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
    pub numel: usize,
    /// "muon" or "adamw".
    pub optim: String,
    /// Artifact key of this parameter's update executable.
    pub artifact: String,
    pub init_std: f64,
}

impl ManifestParam {
    /// Convert to the census `Param` type (layer parsed from the name).
    pub fn to_param(&self) -> Param {
        let layer = self
            .name
            .strip_prefix("layers.")
            .and_then(|rest| rest.split('.').next())
            .and_then(|s| s.parse().ok());
        Param::new(&self.name, TensorShape(self.shape.clone()), self.kind, layer)
    }
}

/// Model dims recorded in the manifest.
#[derive(Clone, Debug)]
pub struct ManifestModel {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
}

/// The full manifest of one preset.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub model: ManifestModel,
    pub params: Vec<ManifestParam>,
    /// artifact key -> file name.
    pub artifacts: Vec<(String, String)>,
    pub muon_lr: f64,
    pub muon_beta: f64,
    pub adamw_lr: f64,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path, preset: &str) -> Result<Manifest> {
        let path = artifacts_dir.join(format!("manifest__{preset}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = Value::parse(&text)?;

        let m = v.get("model")?;
        let model = ManifestModel {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
            batch: m.get("batch")?.as_usize()?,
        };

        let mut params = Vec::new();
        for p in v.get("params")?.as_arr()? {
            let kind = match p.get("kind")?.as_str()? {
                "matrix" => ParamKind::Matrix,
                "embed" => ParamKind::Embed,
                _ => ParamKind::Vector,
            };
            params.push(ManifestParam {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p.get("shape")?.as_arr()?.iter()
                    .map(|d| d.as_usize()).collect::<Result<_>>()?,
                kind,
                numel: p.get("numel")?.as_usize()?,
                optim: p.get("optim")?.as_str()?.to_string(),
                artifact: p.get("artifact")?.as_str()?.to_string(),
                init_std: p.get("init_std")?.as_f64()?,
            });
        }

        let mut artifacts = Vec::new();
        if let Value::Obj(map) = v.get("artifacts")? {
            for (k, file) in map {
                artifacts.push((k.clone(), file.as_str()?.to_string()));
            }
        }

        let hy = v.get("hypers")?;
        Ok(Manifest {
            preset: v.get("preset")?.as_str()?.to_string(),
            model,
            params,
            artifacts,
            muon_lr: hy.get("muon")?.get("lr")?.as_f64()?,
            muon_beta: hy.get("muon")?.get("beta")?.as_f64()?,
            adamw_lr: hy.get("adamw")?.get("lr")?.as_f64()?,
        })
    }

    /// File name of an artifact key.
    pub fn artifact_file(&self, key: &str) -> Result<&str> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, f)| f.as_str())
            .ok_or_else(|| crate::err!("artifact {key:?} not in manifest"))
    }

    /// The census as `Param`s, in canonical flattening order.
    pub fn census(&self) -> Vec<Param> {
        self.params.iter().map(|p| p.to_param()).collect()
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel).sum()
    }
}
