//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path bridge: `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` → `execute`. HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax≥0.5 serialized protos).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so every rank thread owns its
//! own [`Runtime`]; compiled executables are cached per thread.
//!
//! The XLA bridge needs a vendored `xla` crate, which the offline build
//! environment does not ship — it is gated behind the `xla` cargo
//! feature. The default build substitutes a stub backend with the same
//! surface whose `Runtime::new` fails with a clear message, so the
//! planning/simulation/sweep stack (and the tests that skip without
//! artifacts) build and run everywhere.

pub mod manifest;

pub use manifest::{Manifest, ManifestParam};

#[cfg(feature = "xla")]
mod backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use crate::util::error::{Context, Error, Result};
    use crate::{ensure, err};

    pub type Literal = xla::Literal;

    impl From<xla::Error> for Error {
        fn from(e: xla::Error) -> Error {
            Error::msg(format!("xla: {e}"))
        }
    }

    /// Per-thread PJRT execution context.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Create a CPU-PJRT runtime rooted at the artifacts directory.
        pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu()?;
            Ok(Runtime { client, dir: artifacts_dir.to_path_buf(), cache: HashMap::new() })
        }

        /// Load + compile an artifact by file name (cached).
        pub fn load(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(file) {
                let path = self.dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
                )
                .with_context(|| format!("parsing HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {file}"))?;
                self.cache.insert(file.to_string(), exe);
            }
            Ok(&self.cache[file])
        }

        /// Execute an artifact on literal inputs; the jax lowering uses
        /// `return_tuple=True`, so the single tuple output is decomposed
        /// here.
        pub fn execute(&mut self, file: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
            let exe = self.load(file)?;
            let result = exe.execute::<Literal>(inputs)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple()?)
        }

        /// Number of artifacts compiled so far (diagnostics).
        pub fn compiled_count(&self) -> usize {
            self.cache.len()
        }
    }

    /// Build an f32 literal of the given logical dims.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        ensure!(numel as usize == data.len(), "shape {dims:?} != data len {}", data.len());
        if dims.len() == 1 {
            return Ok(Literal::vec1(data));
        }
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// Build an i32 literal of the given logical dims.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        ensure!(numel as usize == data.len(), "shape {dims:?} != data len {}", data.len());
        if dims.len() == 1 {
            return Ok(Literal::vec1(data));
        }
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// Scalar f32 literal.
    pub fn literal_scalar(x: f32) -> Literal {
        Literal::scalar(x)
    }

    /// Extract the f32 payload of a literal.
    pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }
}

#[cfg(not(feature = "xla"))]
mod backend {
    use std::path::{Path, PathBuf};

    use crate::util::error::Result;
    use crate::{bail, ensure, err};

    const UNAVAILABLE: &str =
        "canzona was built without the `xla` feature; the PJRT request path \
         is unavailable (vendor the `xla` crate and build with `--features xla`)";

    /// Stub literal: carries shape checks, no payload.
    #[derive(Clone, Debug, Default)]
    pub struct Literal;

    impl Literal {
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            Err(err!("{UNAVAILABLE}"))
        }
    }

    /// Stub runtime: construction fails, so every numeric-path caller
    /// (trainer, artifact tests) errors out early with a clear message.
    pub struct Runtime {
        _dir: PathBuf,
    }

    impl Runtime {
        pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
            Err(err!("{UNAVAILABLE}"))
        }

        pub fn load(&mut self, _file: &str) -> Result<()> {
            bail!("{UNAVAILABLE}")
        }

        pub fn execute(&mut self, _file: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }

    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        ensure!(numel as usize == data.len(), "shape {dims:?} != data len {}", data.len());
        Ok(Literal)
    }

    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        ensure!(numel as usize == data.len(), "shape {dims:?} != data len {}", data.len());
        Ok(Literal)
    }

    pub fn literal_scalar(_x: f32) -> Literal {
        Literal
    }

    pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
    }
}

pub use backend::{literal_f32, literal_i32, literal_scalar, to_f32_vec, Literal, Runtime};

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_with_clear_message() {
        let e = Runtime::new(std::path::Path::new("artifacts")).err().unwrap();
        assert!(e.to_string().contains("xla"), "{e}");
    }

    #[test]
    fn stub_literals_still_check_shapes() {
        assert!(literal_f32(&[0.0; 6], &[2, 3]).is_ok());
        assert!(literal_f32(&[0.0; 5], &[2, 3]).is_err());
        assert!(literal_i32(&[1, 2], &[2]).is_ok());
        assert!(to_f32_vec(&literal_scalar(1.0)).is_err());
    }
}
