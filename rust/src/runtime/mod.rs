//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path bridge: `HloModuleProto::from_text_file` →
//! `PjRtClient::compile` → `execute`. HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax≥0.5 serialized protos).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so every rank thread owns its
//! own [`Runtime`]; compiled executables are cached per thread.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub use manifest::{Manifest, ManifestParam};

/// Per-thread PJRT execution context.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Load + compile an artifact by file name (cached).
    pub fn load(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(file) {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?;
            self.cache.insert(file.to_string(), exe);
        }
        Ok(&self.cache[file])
    }

    /// Execute an artifact on literal inputs; the jax lowering uses
    /// `return_tuple=True`, so the single tuple output is decomposed here.
    pub fn execute(&mut self, file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(file)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Number of artifacts compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

/// Build an f32 literal of the given logical dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(),
                    "shape {dims:?} != data len {}", data.len());
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given logical dims.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(),
                    "shape {dims:?} != data len {}", data.len());
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract the f32 payload of a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
