//! Synthetic corpus generator.
//!
//! A deterministic, learnable token stream: mostly a fixed affine
//! successor rule (so a next-token LM can drive the loss well below the
//! uniform baseline within a few hundred steps), perturbed by Zipf noise
//! (so it does not collapse to a lookup table). Each (seed, step, rank)
//! triple yields a distinct batch — the DP axis sees different data, as
//! in real data parallelism.

use crate::util::rng::Rng;

/// One batch: `tokens` and next-token `targets`, both `[batch, seq]`
/// row-major i32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Probability of following the deterministic successor rule.
const STRUCTURE: f64 = 0.85;

/// Generate the batch for a given (seed, step, rank).
pub fn batch(vocab: usize, batch_size: usize, seq: usize, seed: u64,
             step: usize, rank: usize) -> Batch {
    assert!(vocab >= 4);
    let mut rng = Rng::new(seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15)
        ^ (rank as u64).wrapping_mul(0xD1B54A32D192ED03));
    let mut tokens = Vec::with_capacity(batch_size * seq);
    let mut targets = Vec::with_capacity(batch_size * seq);
    for _ in 0..batch_size {
        let mut t = rng.index(vocab);
        let mut row = Vec::with_capacity(seq + 1);
        row.push(t);
        for _ in 0..seq {
            t = if rng.next_f64() < STRUCTURE {
                (t * 31 + 7) % vocab
            } else {
                rng.zipf(vocab)
            };
            row.push(t);
        }
        tokens.extend(row[..seq].iter().map(|&x| x as i32));
        targets.extend(row[1..].iter().map(|&x| x as i32));
    }
    Batch { tokens, targets, batch: batch_size, seq }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_bounds() {
        let b = batch(256, 4, 32, 1, 0, 0);
        assert_eq!(b.tokens.len(), 4 * 32);
        assert_eq!(b.targets.len(), 4 * 32);
        assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
        assert!(b.targets.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let b = batch(256, 2, 16, 7, 3, 1);
        for row in 0..2 {
            for i in 0..15 {
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_per_key() {
        let a = batch(128, 2, 8, 42, 5, 2);
        let b = batch(128, 2, 8, 42, 5, 2);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn distinct_across_ranks_and_steps() {
        let a = batch(128, 2, 32, 42, 5, 0);
        let b = batch(128, 2, 32, 42, 5, 1);
        let c = batch(128, 2, 32, 42, 6, 0);
        assert_ne!(a.tokens, b.tokens);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn mostly_structured() {
        let b = batch(256, 1, 1000, 9, 0, 0);
        let follows = b.tokens[..]
            .windows(2)
            .filter(|w| w[1] == ((w[0] as usize * 31 + 7) % 256) as i32)
            .count();
        let frac = follows as f64 / 999.0;
        assert!(frac > 0.7 && frac < 0.95, "{frac}");
    }
}
