//! Distributed numeric trainer (the paper's Fig. 5 precision path).
//!
//! Real DP training over thread ranks: every rank executes the AOT
//! fwd/bwd artifact on its own batch shard, gradients flow through the
//! in-memory collectives according to the partition plan, and optimizer
//! updates run through the per-shape Muon/AdamW executables. The SC and
//! LB-ASC strategies must produce **bitwise identical** loss curves —
//! asserted by `rust/tests/parity_tests.rs`.

pub mod data;
pub mod trainer;

pub use trainer::{train, TrainConfig, TrainResult};
