//! The distributed training loop (thread ranks + PJRT artifacts).

use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::buffer::FlatBuffer;
use crate::util::error::{Context, Result};
use crate::{ensure, err};
use crate::collectives::{Communicator, Group};
use crate::partition::{alpha_balanced, naive_atomic, Atomicity, DpPlan, DpStrategy};
use crate::runtime::{literal_f32, literal_i32, literal_scalar, to_f32_vec, Manifest, Runtime};
use crate::train::data;
use crate::util::rng::Rng;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub artifacts_dir: PathBuf,
    pub preset: String,
    pub ranks: usize,
    pub steps: usize,
    pub strategy: DpStrategy,
    pub alpha: f64,
    pub seed: u64,
    /// Flat-buffer bucket size in elements.
    pub bucket_elems: usize,
    /// Print a loss line every N steps (0 = silent).
    pub log_every: usize,
}

impl TrainConfig {
    pub fn new(preset: &str) -> TrainConfig {
        TrainConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            preset: preset.to_string(),
            ranks: 4,
            steps: 50,
            strategy: DpStrategy::LbAsc,
            alpha: 1.0,
            seed: 42,
            bucket_elems: 4_000_000,
            log_every: 10,
        }
    }
}

/// Result of a training run (collected on rank 0).
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Mean cross-entropy per step (DP-averaged).
    pub losses: Vec<f32>,
    /// Wall time per step (s).
    pub step_times: Vec<f64>,
    /// Optimizer-phase time per step (s).
    pub opt_times: Vec<f64>,
    /// Total collective bytes (per-GPU wire estimate).
    pub comm_bytes: u64,
    /// FNV hash of the final flat parameter buffer (parity checks).
    pub params_hash: u64,
}

fn fnv1a(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in data {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Deterministic parameter init shared by all ranks: per-parameter
/// forked stream, `N(0, init_std)` (norm vectors start at exactly 1.0).
fn init_flat(manifest: &Manifest, fb: &FlatBuffer, seed: u64) -> Vec<f32> {
    let mut flat = vec![0.0f32; fb.total];
    let mut root = Rng::new(seed);
    for (i, mp) in manifest.params.iter().enumerate() {
        let placed = &fb.params[i];
        let dst = &mut flat[placed.start..placed.end];
        if mp.init_std == 0.0 {
            dst.fill(1.0);
        } else {
            let mut rng = root.fork(i as u64);
            rng.fill_normal_f32(dst, mp.init_std as f32);
        }
    }
    flat
}

/// Run distributed training; returns rank 0's log.
pub fn train(cfg: &TrainConfig) -> Result<TrainResult> {
    let manifest = Manifest::load(&cfg.artifacts_dir, &cfg.preset)?;
    let census = manifest.census();
    let fb = Arc::new(FlatBuffer::build(&census, cfg.bucket_elems));

    // Plan: strict atomicity — the per-shape update executables operate
    // on whole tensors (element-wise splitting is a timing-plane
    // optimization; see DESIGN.md).
    let plan: Option<Arc<DpPlan>> = match cfg.strategy {
        DpStrategy::Sc => None,
        DpStrategy::Asc => Some(Arc::new(naive_atomic(&fb, cfg.ranks))),
        DpStrategy::LbAsc => Some(Arc::new(alpha_balanced(
            &fb, cfg.ranks, cfg.alpha, false, |p| p.numel() as f64))),
        // NV-layerwise and the rival sharding strategies (MatrixFSDP,
        // DMuon, Dion) are cost-model citizens only — the numeric
        // trainer's update executables run Canzona's own ladder.
        _ => return Err(err!("numeric trainer supports sc/asc/lb-asc strategies")),
    };
    if let Some(p) = &plan {
        assert_eq!(p.atomicity, Atomicity::Strict);
        p.validate(&fb).expect("invalid plan");
    }

    let group = Group::new(cfg.ranks);
    let manifest = Arc::new(manifest);
    let cfg = Arc::new(cfg.clone());

    let mut handles = Vec::new();
    for rank in 0..cfg.ranks {
        let comm = Communicator::new(group.clone(), rank);
        let manifest = manifest.clone();
        let fb = fb.clone();
        let plan = plan.clone();
        let cfg = cfg.clone();
        handles.push(thread::spawn(move || -> Result<TrainResult> {
            rank_main(rank, comm, &cfg, &manifest, &fb, plan.as_deref())
        }));
    }
    let mut result = None;
    for (rank, h) in handles.into_iter().enumerate() {
        let r = h.join().map_err(|_| err!("rank {rank} panicked"))??;
        if rank == 0 {
            result = Some(r);
        }
    }
    let mut result = result.unwrap();
    result.comm_bytes = group.total_bytes();
    Ok(result)
}

/// Per-rank training loop.
fn rank_main(
    rank: usize,
    comm: Communicator,
    cfg: &TrainConfig,
    manifest: &Manifest,
    fb: &FlatBuffer,
    plan: Option<&DpPlan>,
) -> Result<TrainResult> {
    let mut rt = Runtime::new(&cfg.artifacts_dir)
        .with_context(|| format!("rank {rank}: PJRT init"))?;
    let fwd_bwd_file = manifest.artifact_file("fwd_bwd")?.to_string();

    let mut flat = init_flat(manifest, fb, cfg.seed);
    // Optimizer states, flat per parameter: muon momentum (numel) or
    // adamw m+v (2*numel).
    let mut states: Vec<Vec<f32>> = manifest
        .params
        .iter()
        .map(|p| if p.optim == "muon" { vec![0.0; p.numel] } else { vec![0.0; 2 * p.numel] })
        .collect();

    // Which parameter indices this rank updates.
    let owned: Vec<usize> = match plan {
        None => (0..manifest.params.len()).collect(),
        Some(p) => p.rank_params(fb).swap_remove(rank),
    };

    let mb = manifest.model.batch;
    let seq = manifest.model.seq_len;
    let vocab = manifest.model.vocab;
    let muon_lr = manifest.muon_lr as f32;
    let muon_beta = manifest.muon_beta as f32;
    let adamw_lr = manifest.adamw_lr as f32;
    let inv_ranks = 1.0f32 / cfg.ranks as f32;

    let mut losses = Vec::with_capacity(cfg.steps);
    let mut step_times = Vec::with_capacity(cfg.steps);
    let mut opt_times = Vec::with_capacity(cfg.steps);
    let mut grads = vec![0.0f32; fb.total];

    for step in 1..=cfg.steps {
        let t_step = Instant::now();
        let b = data::batch(vocab, mb, seq, cfg.seed, step, rank);

        // fwd + bwd through the AOT artifact.
        let mut inputs = Vec::with_capacity(manifest.params.len() + 2);
        for (i, mp) in manifest.params.iter().enumerate() {
            let placed = &fb.params[i];
            let dims: Vec<i64> = mp.shape.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(&flat[placed.start..placed.end], &dims)?);
        }
        let bs = [mb as i64, seq as i64];
        inputs.push(literal_i32(&b.tokens, &bs)?);
        inputs.push(literal_i32(&b.targets, &bs)?);
        let outputs = rt.execute(&fwd_bwd_file, &inputs)?;
        ensure!(outputs.len() == manifest.params.len() + 1,
                "unexpected fwd_bwd arity {}", outputs.len());
        let loss = outputs[0].to_vec::<f32>()?[0];
        for (i, out) in outputs[1..].iter().enumerate() {
            let placed = &fb.params[i];
            let g = to_f32_vec(out)?;
            grads[placed.start..placed.end].copy_from_slice(&g);
        }

        // DP gradient synchronisation (averaged in fixed rank order).
        let t_opt = Instant::now();
        if cfg.ranks > 1 {
            match plan {
                None => {
                    // SC/DDP: All-Reduce, every rank keeps full gradients.
                    let reduced = comm.all_reduce(&grads);
                    for (g, r) in grads.iter_mut().zip(&reduced) {
                        *g = r * inv_ranks;
                    }
                }
                Some(p) => {
                    // Variable-size Reduce-Scatter per bucket; only the
                    // owned segment is kept (zero-communication updates).
                    for (bi, bucket) in fb.buckets.iter().enumerate() {
                        let sizes = p.shard_sizes(bi);
                        let shard = comm
                            .reduce_scatter_v(&grads[bucket.start..bucket.end], &sizes);
                        let my_start = bucket.start
                            + sizes[..rank].iter().sum::<usize>();
                        for (dst, s) in grads[my_start..my_start + sizes[rank]]
                            .iter_mut()
                            .zip(&shard)
                        {
                            *dst = s * inv_ranks;
                        }
                    }
                }
            }
        }

        // Optimizer step on owned parameters (whole tensors, local states).
        for &i in &owned {
            let mp = &manifest.params[i];
            let placed = &fb.params[i];
            let file = manifest.artifact_file(&mp.artifact)?.to_string();
            let w = &flat[placed.start..placed.end];
            let g = &grads[placed.start..placed.end];
            if mp.optim == "muon" {
                let dims: Vec<i64> = mp.shape.iter().map(|&d| d as i64).collect();
                let outs = rt.execute(&file, &[
                    literal_f32(w, &dims)?,
                    literal_f32(g, &dims)?,
                    literal_f32(&states[i], &dims)?,
                    literal_scalar(muon_lr),
                    literal_scalar(muon_beta),
                ])?;
                ensure!(outs.len() == 2, "muon artifact arity");
                flat[placed.start..placed.end].copy_from_slice(&to_f32_vec(&outs[0])?);
                states[i].copy_from_slice(&to_f32_vec(&outs[1])?);
            } else {
                let n = mp.numel as i64;
                let (m, v) = states[i].split_at(mp.numel);
                let outs = rt.execute(&file, &[
                    literal_f32(w, &[n])?,
                    literal_f32(g, &[n])?,
                    literal_f32(m, &[n])?,
                    literal_f32(v, &[n])?,
                    literal_scalar(step as f32),
                    literal_scalar(adamw_lr),
                ])?;
                ensure!(outs.len() == 3, "adamw artifact arity");
                flat[placed.start..placed.end].copy_from_slice(&to_f32_vec(&outs[0])?);
                let new_m = to_f32_vec(&outs[1])?;
                let new_v = to_f32_vec(&outs[2])?;
                states[i][..mp.numel].copy_from_slice(&new_m);
                states[i][mp.numel..].copy_from_slice(&new_v);
            }
        }

        // Parameter redistribution: variable-size All-Gather per bucket.
        if cfg.ranks > 1 {
            if let Some(p) = plan {
                for (bi, bucket) in fb.buckets.iter().enumerate() {
                    let sizes = p.shard_sizes(bi);
                    let my_start = bucket.start + sizes[..rank].iter().sum::<usize>();
                    let shard = flat[my_start..my_start + sizes[rank]].to_vec();
                    let full = comm.all_gather_v(&shard, &sizes);
                    flat[bucket.start..bucket.end].copy_from_slice(&full);
                }
            }
        }
        let opt_elapsed = t_opt.elapsed().as_secs_f64();

        // DP-mean loss for logging.
        let mean_loss = if cfg.ranks > 1 {
            comm.all_reduce(&[loss])[0] * inv_ranks
        } else {
            loss
        };
        losses.push(mean_loss);
        step_times.push(t_step.elapsed().as_secs_f64());
        opt_times.push(opt_elapsed);
        if rank == 0 && cfg.log_every > 0 && step % cfg.log_every == 0 {
            println!(
                "step {step:>5}  loss {mean_loss:.4}  step {:.3}s  opt {:.3}s",
                step_times.last().unwrap(),
                opt_elapsed,
            );
        }
    }

    Ok(TrainResult {
        losses,
        step_times,
        opt_times,
        comm_bytes: 0, // filled by the caller from group counters
        params_hash: fnv1a(&flat),
    })
}
