//! α-β collective communication model.
//!
//! Ring-algorithm volume formulas (what NCCL uses at these sizes):
//!
//! | collective        | per-GPU traffic        | steps  |
//! |-------------------|------------------------|--------|
//! | All-Reduce        | 2·B·(R-1)/R            | 2(R-1) |
//! | Reduce-Scatter    | B·(R-1)/R              | R-1    |
//! | All-Gather        | B·(R-1)/R              | R-1    |
//! | All-to-All        | B·(R-1)/R              | R-1    |
//! | Gather (to root)  | B·(R-1)/R              | R-1    |
//! | Scatter (from root)| B·(R-1)/R             | R-1    |
//! | Broadcast (tree)  | B                      | log2 R |
//!
//! Gather/Scatter are the rooted halves of All-Gather: the root
//! receives (or sends) everyone else's shard, so the root link — the
//! busiest — moves `B (R-1)/R` bytes, identical to the ring formulas
//! above. They price DMuon's momentum-shard ownership pattern.
//!
//! The *variable-size* variants model the paper's non-uniform shards: a
//! ring step is paced by the largest shard it moves, so imbalanced cuts
//! cost `(R-1)·max_shard` instead of `(R-1)·B/R` — exactly the
//! J_Comm penalty the α-parameter trades off (paper Eq. 3, App. C.5).

use super::hardware::{Hardware, LinkKind};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    AllReduce,
    ReduceScatter,
    AllGather,
    AllToAll,
    /// Rooted gather: every rank sends its shard to one owner rank
    /// (DMuon's momentum collection). Root-link paced, so it prices
    /// like one All-Gather step pattern: `B·(R-1)/R` at the root.
    Gather,
    /// Rooted scatter: the owner rank sends each rank its update shard
    /// back (DMuon's return path). Mirror of [`CollectiveKind::Gather`].
    Scatter,
    Broadcast,
}

/// Collective timing under a hardware profile.
#[derive(Clone, Debug)]
pub struct CommModel {
    pub hw: Hardware,
}

impl CommModel {
    pub fn new(hw: Hardware) -> CommModel {
        CommModel { hw }
    }

    /// Time for a uniform collective over `bytes` total buffer across `r`
    /// ranks on `link`.
    pub fn collective(&self, kind: CollectiveKind, bytes: f64, r: usize, link: LinkKind) -> f64 {
        if r <= 1 {
            return 0.0;
        }
        let bw = self.hw.bandwidth(link);
        let lat = self.hw.latency(link);
        let rf = r as f64;
        match kind {
            CollectiveKind::AllReduce => {
                2.0 * bytes * (rf - 1.0) / rf / bw + 2.0 * (rf - 1.0) * lat
            }
            CollectiveKind::ReduceScatter
            | CollectiveKind::AllGather
            | CollectiveKind::AllToAll
            | CollectiveKind::Gather
            | CollectiveKind::Scatter => {
                bytes * (rf - 1.0) / rf / bw + (rf - 1.0) * lat
            }
            CollectiveKind::Broadcast => bytes / bw + (rf as f64).log2().ceil() * lat,
        }
    }

    /// Variable-size Reduce-Scatter / All-Gather / All-to-All.
    ///
    /// With chunk pipelining (NCCL-style), a ring collective over
    /// non-uniform shards is paced by the busiest link: every link
    /// carries every shard except the one terminating at it, i.e.
    /// `total - min_shard` bytes. For uniform shards this reduces to the
    /// classic `B (R-1)/R`. Skew therefore costs `(total - min) -
    /// (total (R-1)/R)` extra — small, which is exactly why the paper can
    /// hide α=1's communication imbalance under compute (App. C.5).
    pub fn collective_v(
        &self,
        kind: CollectiveKind,
        shard_bytes: &[f64],
        link: LinkKind,
    ) -> f64 {
        let r = shard_bytes.len();
        if r <= 1 {
            return 0.0;
        }
        let (total, min_shard) = shard_parts(shard_bytes);
        self.collective_parts(kind, total, min_shard, r, link)
    }

    /// Scalar form of [`CommModel::collective_v`] for callers that have
    /// precomputed the total and minimum shard of a variable-size
    /// collective (e.g. the cached micro-group cost scalars): identical
    /// formula, no per-rank slice required — the simulator's warm path
    /// uses this to stay allocation-free.
    pub fn collective_parts(
        &self,
        kind: CollectiveKind,
        total_bytes: f64,
        min_shard_bytes: f64,
        ranks: usize,
        link: LinkKind,
    ) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        match kind {
            CollectiveKind::ReduceScatter
            | CollectiveKind::AllGather
            | CollectiveKind::AllToAll => {
                let bw = self.hw.bandwidth(link);
                let lat = self.hw.latency(link);
                (total_bytes - min_shard_bytes) / bw + (ranks - 1) as f64 * lat
            }
            _ => self.collective(kind, total_bytes, ranks, link),
        }
    }

    /// Per-parameter (non-coalesced) communication: the paper's "Option B"
    /// latency penalty. `sizes` are per-message byte counts; every message
    /// pays the kernel-launch overhead.
    pub fn per_message(&self, sizes: &[f64], r: usize, link: LinkKind,
                       kind: CollectiveKind) -> f64 {
        sizes
            .iter()
            .map(|&b| self.hw.launch_overhead + self.collective(kind, b, r, link))
            .sum()
    }

    /// Point-to-point transfer (pipeline-parallel activation /
    /// activation-gradient send): pure α-β, no collective scaling.
    pub fn p2p(&self, bytes: f64, link: LinkKind) -> f64 {
        bytes / self.hw.bandwidth(link) + self.hw.latency(link)
    }

    /// Communication volume in bytes actually crossing the wire per GPU.
    pub fn volume(&self, kind: CollectiveKind, bytes: f64, r: usize) -> f64 {
        Self::volume_static(kind, bytes, r)
    }

    /// [`CommModel::volume`] without a model instance — the formula is
    /// hardware-free (pure bytes arithmetic), so batch evaluation hoists
    /// it out of per-lane loops.
    pub fn volume_static(kind: CollectiveKind, bytes: f64, r: usize) -> f64 {
        if r <= 1 {
            return 0.0;
        }
        let rf = r as f64;
        match kind {
            CollectiveKind::AllReduce => 2.0 * bytes * (rf - 1.0) / rf,
            CollectiveKind::ReduceScatter
            | CollectiveKind::AllGather
            | CollectiveKind::AllToAll
            | CollectiveKind::Gather
            | CollectiveKind::Scatter => bytes * (rf - 1.0) / rf,
            CollectiveKind::Broadcast => bytes,
        }
    }
}

/// The `(total, min_shard)` reduction of a variable-size collective's
/// shard vector — the lane-invariant half of [`CommModel::collective_v`],
/// exposed so batched evaluation ([`crate::sim::batch`]) can hoist it
/// once per bucket and price only [`CommModel::collective_parts`] per
/// lane. Kept here (and used by `collective_v` itself) so the two
/// computations cannot drift: bit-identical results are a test contract.
pub fn shard_parts(shard_bytes: &[f64]) -> (f64, f64) {
    let total: f64 = shard_bytes.iter().sum();
    let min_shard = shard_bytes.iter().cloned().fold(f64::INFINITY, f64::min);
    (total, min_shard)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CommModel {
        CommModel::new(Hardware::h800())
    }

    #[test]
    fn all_reduce_is_2x_reduce_scatter() {
        // The core claim behind the paper's fwd-bwd speedup (Fig. 7).
        let m = model();
        let b = 1e9;
        let ar = m.volume(CollectiveKind::AllReduce, b, 32);
        let rs = m.volume(CollectiveKind::ReduceScatter, b, 32);
        assert!((ar / rs - 2.0).abs() < 1e-9);
        let t_ar = m.collective(CollectiveKind::AllReduce, b, 32, LinkKind::InterNode);
        let t_rs = m.collective(CollectiveKind::ReduceScatter, b, 32, LinkKind::InterNode);
        assert!(t_ar > 1.9 * t_rs && t_ar < 2.1 * t_rs);
    }

    #[test]
    fn single_rank_is_free() {
        let m = model();
        assert_eq!(m.collective(CollectiveKind::AllReduce, 1e9, 1, LinkKind::InterNode), 0.0);
        assert_eq!(m.collective_v(CollectiveKind::AllGather, &[1e9], LinkKind::IntraNode), 0.0);
    }

    #[test]
    fn variable_size_skew_penalty_is_bounded() {
        let m = model();
        let uniform = m.collective_v(CollectiveKind::ReduceScatter,
                                     &[1e6; 4], LinkKind::InterNode);
        let skewed = m.collective_v(CollectiveKind::ReduceScatter,
                                    &[4e6, 0.0, 0.0, 0.0], LinkKind::InterNode);
        // Skew costs more, but bounded by total/bw (busiest link).
        assert!(skewed > uniform, "{skewed} vs {uniform}");
        assert!(skewed < uniform * 1.5, "{skewed} vs {uniform}");
        // Equal totals, equal shards => matches uniform formula exactly.
        let total_uniform = m.collective(CollectiveKind::ReduceScatter, 4e6, 4,
                                         LinkKind::InterNode);
        assert!((uniform - total_uniform).abs() / total_uniform < 0.05);
    }

    #[test]
    fn collective_parts_matches_slice_form() {
        let m = model();
        for shards in [vec![1e6, 2e6, 0.0, 4e6], vec![5e5; 8], vec![0.0; 4]] {
            let total: f64 = shards.iter().sum();
            let min = shards.iter().cloned().fold(f64::INFINITY, f64::min);
            let a = m.collective_v(CollectiveKind::AllToAll, &shards, LinkKind::IntraNode);
            let b = m.collective_parts(CollectiveKind::AllToAll, total, min,
                                       shards.len(), LinkKind::IntraNode);
            assert_eq!(a.to_bits(), b.to_bits(), "{shards:?}");
        }
        assert_eq!(m.collective_parts(CollectiveKind::AllGather, 1e9, 0.0, 1,
                                      LinkKind::InterNode), 0.0);
    }

    #[test]
    fn per_message_launch_overhead_dominates_small() {
        // 1000 tiny messages must cost >> one fused message of equal volume.
        let m = model();
        let sizes = vec![1e3; 1000];
        let fused = m.collective(CollectiveKind::AllToAll, 1e6, 8, LinkKind::IntraNode);
        let scattered = m.per_message(&sizes, 8, LinkKind::IntraNode,
                                      CollectiveKind::AllToAll);
        assert!(scattered > 10.0 * fused, "{scattered} vs {fused}");
    }

    #[test]
    fn gather_scatter_price_like_all_gather() {
        // The rooted halves share the root-link-paced formula with the
        // ring All-Gather — in both time and wire volume — and stay
        // free at a single rank.
        let m = model();
        for r in [2usize, 8, 32] {
            let ag = m.collective(CollectiveKind::AllGather, 3e8, r, LinkKind::InterNode);
            let g = m.collective(CollectiveKind::Gather, 3e8, r, LinkKind::InterNode);
            let s = m.collective(CollectiveKind::Scatter, 3e8, r, LinkKind::InterNode);
            assert_eq!(ag.to_bits(), g.to_bits());
            assert_eq!(g.to_bits(), s.to_bits());
            assert_eq!(
                CommModel::volume_static(CollectiveKind::Gather, 3e8, r),
                CommModel::volume_static(CollectiveKind::AllGather, 3e8, r)
            );
        }
        assert_eq!(m.collective(CollectiveKind::Gather, 3e8, 1, LinkKind::InterNode), 0.0);
        assert_eq!(CommModel::volume_static(CollectiveKind::Scatter, 3e8, 1), 0.0);
    }

    #[test]
    fn p2p_is_alpha_beta() {
        let m = model();
        let t = m.p2p(40e9, LinkKind::InterNode); // 40 GB over 40 GB/s
        assert!((t - (1.0 + m.hw.ib_lat)).abs() < 1e-9);
        assert!(m.p2p(1e6, LinkKind::IntraNode) < m.p2p(1e6, LinkKind::InterNode));
    }

    #[test]
    fn internode_slower_than_intranode() {
        let m = model();
        let t_ib = m.collective(CollectiveKind::AllGather, 1e8, 8, LinkKind::InterNode);
        let t_nv = m.collective(CollectiveKind::AllGather, 1e8, 8, LinkKind::IntraNode);
        assert!(t_ib > 3.0 * t_nv);
    }

    #[test]
    fn bandwidth_term_scales_linearly() {
        let m = model();
        let t1 = m.collective(CollectiveKind::ReduceScatter, 1e9, 16, LinkKind::InterNode);
        let t2 = m.collective(CollectiveKind::ReduceScatter, 2e9, 16, LinkKind::InterNode);
        assert!(t2 / t1 > 1.9 && t2 / t1 < 2.1);
    }
}
