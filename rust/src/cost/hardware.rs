//! Cluster hardware profiles (the α-β model's constants).
//!
//! The paper's testbed is 256-512 H800-class GPUs: NVLink inside a node
//! (TP domain), InfiniBand between nodes (DP domain). Absolute numbers do
//! not need to match the authors' cluster — only the *ratios* (NVLink >>
//! IB bandwidth, launch overhead >> per-byte cost for tiny messages)
//! matter for reproducing the result shapes, and those are physical.

/// Which fabric a collective crosses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Intra-node (NVLink/NVSwitch) — the TP domain.
    IntraNode,
    /// Inter-node (InfiniBand/RoCE) — the DP domain.
    InterNode,
}

/// One cluster profile.
#[derive(Clone, Debug)]
pub struct Hardware {
    pub name: &'static str,
    /// Effective dense-matmul throughput per GPU (FLOP/s).
    pub gpu_flops: f64,
    /// HBM bandwidth per GPU (bytes/s) — bounds element-wise ops.
    pub hbm_bw: f64,
    /// NVLink algorithm bandwidth per GPU (bytes/s).
    pub nvlink_bw: f64,
    /// InfiniBand algorithm bandwidth per GPU (bytes/s).
    pub ib_bw: f64,
    /// Per-collective base latency, intra-node (s).
    pub nvlink_lat: f64,
    /// Per-collective base latency, inter-node (s).
    pub ib_lat: f64,
    /// Kernel-launch / per-message fixed overhead (s) — dominates the
    /// per-parameter communication paths the paper's Option B suffers.
    pub launch_overhead: f64,
    /// GPUs per node (the TP domain size ceiling).
    pub gpus_per_node: usize,
}

impl Hardware {
    /// H800-class default (the paper's testbed flavour).
    pub fn h800() -> Hardware {
        Hardware {
            name: "h800",
            gpu_flops: 400e12, // achievable bf16 matmul throughput
            hbm_bw: 3.0e12,
            nvlink_bw: 200e9,
            ib_bw: 40e9,
            nvlink_lat: 6e-6,
            ib_lat: 18e-6,
            launch_overhead: 12e-6,
            gpus_per_node: 8,
        }
    }

    /// A100-class alternative profile.
    pub fn a100() -> Hardware {
        Hardware {
            name: "a100",
            gpu_flops: 250e12,
            hbm_bw: 1.9e12,
            nvlink_bw: 150e9,
            ib_bw: 25e9,
            nvlink_lat: 8e-6,
            ib_lat: 20e-6,
            launch_overhead: 12e-6,
            gpus_per_node: 8,
        }
    }

    pub fn by_name(name: &str) -> Option<Hardware> {
        match name {
            "h800" => Some(Hardware::h800()),
            "a100" => Some(Hardware::a100()),
            _ => None,
        }
    }

    pub fn bandwidth(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::IntraNode => self.nvlink_bw,
            LinkKind::InterNode => self.ib_bw,
        }
    }

    pub fn latency(&self, link: LinkKind) -> f64 {
        match link {
            LinkKind::IntraNode => self.nvlink_lat,
            LinkKind::InterNode => self.ib_lat,
        }
    }

    /// This profile with compute and HBM throughput derated by `factor`
    /// (`1.0` = unchanged; `1.2` = 20% slower GPU). The network terms
    /// stay unscaled — the fabric is shared, a slow *GPU* does not slow
    /// the wire. Used for per-stage straggler perturbation in the
    /// timeline engine.
    pub fn derate(&self, factor: f64) -> Hardware {
        Hardware {
            gpu_flops: self.gpu_flops / factor,
            hbm_bw: self.hbm_bw / factor,
            ..self.clone()
        }
    }

    /// Time to execute `flops` of dense matmul work on one GPU.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.gpu_flops
    }

    /// Time for a memory-bound elementwise pass over `bytes`.
    pub fn memory_time(&self, bytes: f64) -> f64 {
        bytes / self.hbm_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_sane() {
        for hw in [Hardware::h800(), Hardware::a100()] {
            assert!(hw.nvlink_bw > hw.ib_bw * 3.0, "{}", hw.name);
            assert!(hw.ib_lat >= hw.nvlink_lat);
            assert!(hw.gpus_per_node >= 2);
        }
    }

    #[test]
    fn lookup() {
        assert!(Hardware::by_name("h800").is_some());
        assert!(Hardware::by_name("tpu").is_none());
    }

    #[test]
    fn derate_scales_compute_not_network() {
        let hw = Hardware::h800();
        let slow = hw.derate(2.0);
        assert_eq!(slow.gpu_flops, hw.gpu_flops / 2.0);
        assert_eq!(slow.hbm_bw, hw.hbm_bw / 2.0);
        assert_eq!(slow.nvlink_bw, hw.nvlink_bw);
        assert_eq!(slow.ib_bw, hw.ib_bw);
        // factor 1.0 is an exact no-op (the fast-path dispatch relies
        // on it being bit-identical).
        let same = hw.derate(1.0);
        assert_eq!(same.gpu_flops.to_bits(), hw.gpu_flops.to_bits());
        assert_eq!(same.hbm_bw.to_bits(), hw.hbm_bw.to_bits());
    }

    #[test]
    fn time_helpers() {
        let hw = Hardware::h800();
        assert!((hw.compute_time(400e12) - 1.0).abs() < 1e-9);
        assert!(hw.memory_time(3.0e12) > 0.9);
        assert!(hw.bandwidth(LinkKind::IntraNode) > hw.bandwidth(LinkKind::InterNode));
    }
}
