//! Cost models driving the load-balancing algorithms and the simulator.
//!
//! * [`optim`] — per-parameter FLOPs / state-memory of the matrix-based
//!   optimizers (the non-linear, cubic costs of Appendix D.5).
//! * [`comm`] — α-β interconnect model with collective-specific volume
//!   formulas (NVLink intra-node vs InfiniBand inter-node).
//! * [`hardware`] — cluster profiles (per-GPU throughput, link speeds).

pub mod comm;
pub mod hardware;
pub mod optim;

pub use comm::{CollectiveKind, CommModel};
pub use hardware::{Hardware, LinkKind};
pub use optim::{CostMetric, OptimKind, OptimCost};
