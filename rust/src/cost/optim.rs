//! Optimizer cost models (paper Appendix D.5).
//!
//! The partitioners take a generic weight function `W(p)`; the paper's
//! default is the linear proxy `numel(p)` (its Fig. 16 ablation shows the
//! proxy is near-exact for Transformer shape censuses). The simulator
//! uses the *exact* non-linear FLOPs models below to time per-rank
//! optimizer execution — which is precisely how naive partitioning ends
//! up with 3.2x stragglers while numel-balanced plans stay near 1.0.

use crate::model::shapes::{Param, TensorShape};

/// The optimizers evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptimKind {
    Muon,
    Shampoo,
    Soap,
    AdamW,
}

impl OptimKind {
    pub fn label(&self) -> &'static str {
        match self {
            OptimKind::Muon => "Muon",
            OptimKind::Shampoo => "Shampoo",
            OptimKind::Soap => "SOAP",
            OptimKind::AdamW => "AdamW",
        }
    }

    pub fn parse(s: &str) -> Option<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "muon" => Some(OptimKind::Muon),
            "shampoo" => Some(OptimKind::Shampoo),
            "soap" => Some(OptimKind::Soap),
            "adamw" | "adam" => Some(OptimKind::AdamW),
            _ => None,
        }
    }

    /// Is this a matrix-based (atomicity-constrained) optimizer?
    pub fn is_matrix_based(&self) -> bool {
        !matches!(self, OptimKind::AdamW)
    }
}

/// Which scalar cost to extract (the paper balances on FLOPs and reports
/// memory ratios alongside).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CostMetric {
    /// numel(p) — the unified linear proxy (paper default).
    Numel,
    /// Exact per-step update FLOPs.
    Flops,
    /// Optimizer state bytes.
    StateBytes,
}

const NS_STEPS: f64 = 5.0;
const ROOT_ITERS: f64 = 25.0;
/// Amortization of Shampoo/SOAP root/eigen recomputation (every N steps).
const PRECOND_EVERY: f64 = 10.0;

/// Cost model: maps (optimizer, parameter shape) -> FLOPs / state bytes.
#[derive(Clone, Copy, Debug)]
pub struct OptimCost {
    pub kind: OptimKind,
}

impl OptimCost {
    pub fn new(kind: OptimKind) -> OptimCost {
        OptimCost { kind }
    }

    /// Exact per-step update FLOPs for one parameter.
    ///
    /// Matrix-based optimizers fall back to AdamW for non-matrix params
    /// (standard Muon/Shampoo practice, also what our L2 layer does).
    pub fn flops(&self, shape: &TensorShape) -> f64 {
        if !shape.is_matrix() || !self.kind.is_matrix_based() {
            return adamw_flops(shape.numel());
        }
        let m = shape.rows() as f64;
        let n = shape.cols() as f64;
        match self.kind {
            OptimKind::Muon => muon_flops(m, n),
            OptimKind::Shampoo => shampoo_flops(m, n),
            OptimKind::Soap => soap_flops(m, n),
            OptimKind::AdamW => unreachable!(),
        }
    }

    /// Optimizer state bytes for one parameter (fp32 states).
    pub fn state_bytes(&self, shape: &TensorShape) -> f64 {
        let numel = shape.numel() as f64;
        if !shape.is_matrix() || !self.kind.is_matrix_based() {
            return 2.0 * 4.0 * numel; // AdamW: m + v
        }
        let m = shape.rows() as f64;
        let n = shape.cols() as f64;
        match self.kind {
            // momentum
            OptimKind::Muon => 4.0 * numel,
            // momentum + L (m^2) + R (n^2)
            OptimKind::Shampoo => 4.0 * (numel + m * m + n * n),
            // m + v + L + R + QL + QR
            OptimKind::Soap => 4.0 * (2.0 * numel + 2.0 * (m * m + n * n)),
            OptimKind::AdamW => unreachable!(),
        }
    }

    /// Cost under the chosen metric.
    pub fn cost(&self, shape: &TensorShape, metric: CostMetric) -> f64 {
        match metric {
            CostMetric::Numel => shape.numel() as f64,
            CostMetric::Flops => self.flops(shape),
            CostMetric::StateBytes => self.state_bytes(shape),
        }
    }

    /// Weight function over placed census entries, as the partitioners
    /// expect it.
    pub fn weight_fn(&self, metric: CostMetric) -> impl Fn(&Param) -> f64 + '_ {
        move |p: &Param| self.cost(&p.shape, metric)
    }
}

fn adamw_flops(numel: usize) -> f64 {
    // ~12 elementwise ops per element (m, v updates, bias correction, step).
    12.0 * numel as f64
}

/// Muon: 5 Newton-Schulz iterations over the min-dimension Gram side.
/// Per iteration: X X^T (2 s^2 l) + A A (2 s^3) + poly @ X (2 s^2 l).
fn muon_flops(m: f64, n: f64) -> f64 {
    let s = m.min(n);
    let l = m.max(n);
    let per_iter = 4.0 * s * s * l + 2.0 * s * s * s;
    NS_STEPS * per_iter + 4.0 * m * n // momentum + weight update
}

/// Shampoo: gram statistics (every step) + inverse 4th roots (amortized
/// coupled-Newton, PRECOND_EVERY) + two-sided preconditioning.
fn shampoo_flops(m: f64, n: f64) -> f64 {
    let stats = 2.0 * m * m * n + 2.0 * n * n * m;
    let roots = ROOT_ITERS * 6.0 * (m * m * m + n * n * n) / PRECOND_EVERY;
    let precond = 2.0 * m * m * n + 2.0 * m * n * n;
    stats + roots + precond + 2.0 * m * n
}

/// SOAP: gram statistics + eigendecompositions (amortized) + basis
/// rotations + Adam in the rotated space.
fn soap_flops(m: f64, n: f64) -> f64 {
    let stats = 2.0 * m * m * n + 2.0 * n * n * m;
    let eig = 20.0 * (m * m * m + n * n * n) / PRECOND_EVERY;
    let rotations = 2.0 * (2.0 * m * m * n + 2.0 * m * n * n);
    stats + eig + rotations + 12.0 * m * n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_scaling_muon() {
        let c = OptimCost::new(OptimKind::Muon);
        let f1 = c.flops(&TensorShape::matrix(1024, 1024));
        let f2 = c.flops(&TensorShape::matrix(2048, 2048));
        // Square matrices: ~8x FLOPs when doubling dims.
        assert!((f2 / f1 - 8.0).abs() < 0.5, "{}", f2 / f1);
    }

    #[test]
    fn muon_gram_side_matters() {
        // A (256, 8192) matrix must be much cheaper than (8192, 8192):
        // NS runs on the 256-side Gram matrix.
        let c = OptimCost::new(OptimKind::Muon);
        let wide = c.flops(&TensorShape::matrix(256, 8192));
        let square = c.flops(&TensorShape::matrix(8192, 8192));
        assert!(square / wide > 30.0);
    }

    #[test]
    fn nonlinearity_vs_numel() {
        // Same numel, different shapes => different Muon FLOPs.
        let c = OptimCost::new(OptimKind::Muon);
        let a = c.flops(&TensorShape::matrix(4096, 1024));
        let b = c.flops(&TensorShape::matrix(2048, 2048));
        assert!((a - b).abs() / b > 0.1);
    }

    #[test]
    fn vectors_fall_back_to_adamw() {
        for kind in [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap] {
            let c = OptimCost::new(kind);
            let v = TensorShape::vector(4096);
            assert_eq!(c.flops(&v), 12.0 * 4096.0);
            assert_eq!(c.state_bytes(&v), 8.0 * 4096.0);
        }
    }

    #[test]
    fn shampoo_state_includes_preconditioners() {
        let c = OptimCost::new(OptimKind::Shampoo);
        let s = c.state_bytes(&TensorShape::matrix(100, 200));
        assert_eq!(s, 4.0 * (20_000.0 + 10_000.0 + 40_000.0));
    }

    #[test]
    fn metric_selector() {
        let c = OptimCost::new(OptimKind::Muon);
        let sh = TensorShape::matrix(64, 64);
        assert_eq!(c.cost(&sh, CostMetric::Numel), 4096.0);
        assert!(c.cost(&sh, CostMetric::Flops) > c.cost(&sh, CostMetric::Numel));
    }

    #[test]
    fn parse_labels() {
        assert_eq!(OptimKind::parse("muon"), Some(OptimKind::Muon));
        assert_eq!(OptimKind::parse("SOAP"), Some(OptimKind::Soap));
        assert_eq!(OptimKind::parse("sgd"), None);
        assert!(!OptimKind::AdamW.is_matrix_based());
    }
}
