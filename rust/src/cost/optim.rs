//! Optimizer cost models (paper Appendix D.5).
//!
//! The partitioners take a generic weight function `W(p)`; the paper's
//! default is the linear proxy `numel(p)` (its Fig. 16 ablation shows the
//! proxy is near-exact for Transformer shape censuses). The simulator
//! uses the *exact* non-linear FLOPs models below to time per-rank
//! optimizer execution — which is precisely how naive partitioning ends
//! up with 3.2x stragglers while numel-balanced plans stay near 1.0.

use crate::model::shapes::{Param, TensorShape};

/// The optimizers evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptimKind {
    Muon,
    Shampoo,
    Soap,
    AdamW,
}

impl OptimKind {
    pub fn label(&self) -> &'static str {
        match self {
            OptimKind::Muon => "Muon",
            OptimKind::Shampoo => "Shampoo",
            OptimKind::Soap => "SOAP",
            OptimKind::AdamW => "AdamW",
        }
    }

    pub fn parse(s: &str) -> Option<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "muon" => Some(OptimKind::Muon),
            "shampoo" => Some(OptimKind::Shampoo),
            "soap" => Some(OptimKind::Soap),
            "adamw" | "adam" => Some(OptimKind::AdamW),
            _ => None,
        }
    }

    /// Is this a matrix-based (atomicity-constrained) optimizer?
    pub fn is_matrix_based(&self) -> bool {
        !matches!(self, OptimKind::AdamW)
    }
}

/// Which scalar cost to extract (the paper balances on FLOPs and reports
/// memory ratios alongside).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CostMetric {
    /// numel(p) — the unified linear proxy (paper default).
    Numel,
    /// Exact per-step update FLOPs.
    Flops,
    /// Optimizer state bytes.
    StateBytes,
}

const NS_STEPS: f64 = 5.0;
const ROOT_ITERS: f64 = 25.0;
/// Amortization of Shampoo/SOAP root/eigen recomputation (every N steps).
const PRECOND_EVERY: f64 = 10.0;

/// Cost model: maps (optimizer, parameter shape) -> FLOPs / state bytes.
#[derive(Clone, Copy, Debug)]
pub struct OptimCost {
    pub kind: OptimKind,
}

impl OptimCost {
    pub fn new(kind: OptimKind) -> OptimCost {
        OptimCost { kind }
    }

    /// Exact per-step update FLOPs for one parameter.
    ///
    /// Matrix-based optimizers fall back to AdamW for non-matrix params
    /// (standard Muon/Shampoo practice, also what our L2 layer does).
    pub fn flops(&self, shape: &TensorShape) -> f64 {
        if !shape.is_matrix() || !self.kind.is_matrix_based() {
            return adamw_flops(shape.numel());
        }
        let m = shape.rows() as f64;
        let n = shape.cols() as f64;
        match self.kind {
            OptimKind::Muon => muon_flops(m, n),
            OptimKind::Shampoo => shampoo_flops(m, n),
            OptimKind::Soap => soap_flops(m, n),
            OptimKind::AdamW => unreachable!(),
        }
    }

    /// Optimizer state bytes for one parameter (fp32 states).
    pub fn state_bytes(&self, shape: &TensorShape) -> f64 {
        let numel = shape.numel() as f64;
        if !shape.is_matrix() || !self.kind.is_matrix_based() {
            return 2.0 * 4.0 * numel; // AdamW: m + v
        }
        let m = shape.rows() as f64;
        let n = shape.cols() as f64;
        match self.kind {
            // momentum
            OptimKind::Muon => 4.0 * numel,
            // momentum + L (m^2) + R (n^2)
            OptimKind::Shampoo => 4.0 * (numel + m * m + n * n),
            // m + v + L + R + QL + QR
            OptimKind::Soap => 4.0 * (2.0 * numel + 2.0 * (m * m + n * n)),
            OptimKind::AdamW => unreachable!(),
        }
    }

    /// Cost under the chosen metric.
    pub fn cost(&self, shape: &TensorShape, metric: CostMetric) -> f64 {
        match metric {
            CostMetric::Numel => shape.numel() as f64,
            CostMetric::Flops => self.flops(shape),
            CostMetric::StateBytes => self.state_bytes(shape),
        }
    }

    /// Weight function over placed census entries, as the partitioners
    /// expect it.
    pub fn weight_fn(&self, metric: CostMetric) -> impl Fn(&Param) -> f64 + '_ {
        move |p: &Param| self.cost(&p.shape, metric)
    }
}

fn adamw_flops(numel: usize) -> f64 {
    // ~12 elementwise ops per element (m, v updates, bias correction, step).
    12.0 * numel as f64
}

/// Muon: 5 Newton-Schulz iterations over the min-dimension Gram side.
/// Per iteration: X X^T (2 s^2 l) + A A (2 s^3) + poly @ X (2 s^2 l).
fn muon_flops(m: f64, n: f64) -> f64 {
    let s = m.min(n);
    let l = m.max(n);
    let per_iter = 4.0 * s * s * l + 2.0 * s * s * s;
    NS_STEPS * per_iter + 4.0 * m * n // momentum + weight update
}

/// Shampoo: gram statistics (every step) + inverse 4th roots (amortized
/// coupled-Newton, PRECOND_EVERY) + two-sided preconditioning.
fn shampoo_flops(m: f64, n: f64) -> f64 {
    let stats = 2.0 * m * m * n + 2.0 * n * n * m;
    let roots = ROOT_ITERS * 6.0 * (m * m * m + n * n * n) / PRECOND_EVERY;
    let precond = 2.0 * m * m * n + 2.0 * m * n * n;
    stats + roots + precond + 2.0 * m * n
}

/// SOAP: gram statistics + eigendecompositions (amortized) + basis
/// rotations + Adam in the rotated space.
fn soap_flops(m: f64, n: f64) -> f64 {
    let stats = 2.0 * m * m * n + 2.0 * n * n * m;
    let eig = 20.0 * (m * m * m + n * n * n) / PRECOND_EVERY;
    let rotations = 2.0 * (2.0 * m * m * n + 2.0 * m * n * n);
    stats + eig + rotations + 12.0 * m * n
}

/// The element-linear (per-matrix-element) coefficient of each
/// optimizer's FLOPs model — the `c` in the `c·m·n` term that each of
/// [`muon_flops`] (4), [`shampoo_flops`] (2), [`soap_flops`] (12) and
/// the AdamW fallback (12) contains. This is the only part of the
/// update that partitions exactly under MatrixFSDP's row sharding (the
/// preconditioner terms are recomputed redundantly per rank), so both
/// the simulator's `StrategyTable::Fsdp` arm and the MatrixFSDP
/// optimizer-latency bound (`sim::bounds`) price against it.
pub fn linear_flops_coeff(kind: OptimKind) -> f64 {
    match kind {
        OptimKind::Muon => 4.0,
        OptimKind::Shampoo => 2.0,
        OptimKind::Soap => 12.0,
        OptimKind::AdamW => 12.0,
    }
}

/// Dion's rank fraction: the low-rank dimension is
/// `ceil(frac · min(m, n))` of each matrix. The simulator evaluates at
/// this fixed fraction; the helpers below stay fraction-parameterized
/// so `tests/rivals_props.rs` can sweep the axis.
pub const DION_RANK_FRACTION: f64 = 0.25;

/// Dion low-rank dimension for an `(m, n)` matrix at rank fraction
/// `frac`, floored at 1.
pub fn dion_rank(m: f64, n: f64, frac: f64) -> f64 {
    (frac * m.min(n)).ceil().max(1.0)
}

/// Low-rank factor elements for one `(m, n)` matrix: `P (m×r)` and
/// `Q (n×r)`.
pub fn dion_factor_elems(m: f64, n: f64, frac: f64) -> f64 {
    dion_rank(m, n, frac) * (m + n)
}

/// Per-GPU Dion update FLOPs for one `(m, n)` matrix with the momentum
/// / error-feedback buffer ZeRO-sharded across `dp` ranks: the two
/// rank-`r` sketch GEMMs and the error-feedback update stream over the
/// local `m·n/dp` shard (`6·m·n·r/dp`), while the `r`-sided
/// orthonormalization work (`2·r²·(m+n)`) is replicated on every rank.
pub fn dion_flops(m: f64, n: f64, frac: f64, dp: usize) -> f64 {
    let r = dion_rank(m, n, frac);
    6.0 * m * n * r / dp as f64 + 2.0 * r * r * (m + n)
}

/// Per-DP-rank Dion optimizer state bytes for one `(m, n)` matrix: the
/// bf16 error-feedback buffer is ZeRO-sharded across `dp`; the fp32
/// low-rank factors are replicated (they are what the fused All-Reduce
/// synchronizes).
pub fn dion_state_bytes(m: f64, n: f64, frac: f64, dp: usize) -> f64 {
    2.0 * m * n / dp as f64 + 4.0 * dion_factor_elems(m, n, frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_scaling_muon() {
        let c = OptimCost::new(OptimKind::Muon);
        let f1 = c.flops(&TensorShape::matrix(1024, 1024));
        let f2 = c.flops(&TensorShape::matrix(2048, 2048));
        // Square matrices: ~8x FLOPs when doubling dims.
        assert!((f2 / f1 - 8.0).abs() < 0.5, "{}", f2 / f1);
    }

    #[test]
    fn muon_gram_side_matters() {
        // A (256, 8192) matrix must be much cheaper than (8192, 8192):
        // NS runs on the 256-side Gram matrix.
        let c = OptimCost::new(OptimKind::Muon);
        let wide = c.flops(&TensorShape::matrix(256, 8192));
        let square = c.flops(&TensorShape::matrix(8192, 8192));
        assert!(square / wide > 30.0);
    }

    #[test]
    fn nonlinearity_vs_numel() {
        // Same numel, different shapes => different Muon FLOPs.
        let c = OptimCost::new(OptimKind::Muon);
        let a = c.flops(&TensorShape::matrix(4096, 1024));
        let b = c.flops(&TensorShape::matrix(2048, 2048));
        assert!((a - b).abs() / b > 0.1);
    }

    #[test]
    fn vectors_fall_back_to_adamw() {
        for kind in [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap] {
            let c = OptimCost::new(kind);
            let v = TensorShape::vector(4096);
            assert_eq!(c.flops(&v), 12.0 * 4096.0);
            assert_eq!(c.state_bytes(&v), 8.0 * 4096.0);
        }
    }

    #[test]
    fn shampoo_state_includes_preconditioners() {
        let c = OptimCost::new(OptimKind::Shampoo);
        let s = c.state_bytes(&TensorShape::matrix(100, 200));
        assert_eq!(s, 4.0 * (20_000.0 + 10_000.0 + 40_000.0));
    }

    #[test]
    fn metric_selector() {
        let c = OptimCost::new(OptimKind::Muon);
        let sh = TensorShape::matrix(64, 64);
        assert_eq!(c.cost(&sh, CostMetric::Numel), 4096.0);
        assert!(c.cost(&sh, CostMetric::Flops) > c.cost(&sh, CostMetric::Numel));
    }

    #[test]
    fn linear_coeff_is_the_flops_models_linear_term() {
        // flops(m, n) - c·m·n must be the (non-negative) superlinear
        // remainder for every matrix optimizer; for AdamW it is exactly
        // zero (the model *is* the linear term).
        for (kind, flops_fn) in [
            (OptimKind::Muon, muon_flops as fn(f64, f64) -> f64),
            (OptimKind::Shampoo, shampoo_flops),
            (OptimKind::Soap, soap_flops),
        ] {
            let c = linear_flops_coeff(kind);
            for (m, n) in [(64.0, 64.0), (256.0, 8192.0), (4096.0, 1024.0)] {
                let rem = flops_fn(m, n) - c * m * n;
                assert!(rem > 0.0, "{kind:?} ({m},{n}): remainder {rem}");
            }
        }
        assert_eq!(
            adamw_flops(4096) - linear_flops_coeff(OptimKind::AdamW) * 4096.0,
            0.0
        );
    }

    #[test]
    fn dion_low_rank_state_below_full_rank() {
        // The factor split only pays off below full rank; at frac = 1.0
        // it degenerates to ≥ the momentum it replaces.
        let (m, n) = (4096.0, 1024.0);
        let quarter = dion_state_bytes(m, n, DION_RANK_FRACTION, 1);
        let full = dion_state_bytes(m, n, 1.0, 1);
        assert!(quarter < full);
        assert_eq!(dion_rank(m, n, 1.0), n);
        // r floors at 1 even for tiny fractions.
        assert_eq!(dion_rank(m, n, 1e-9), 1.0);
        // Sharding the EF buffer strictly reduces per-rank state.
        assert!(dion_state_bytes(m, n, 0.25, 8) < dion_state_bytes(m, n, 0.25, 1));
        // FLOPs: the m·n term shards, the factor term does not.
        assert!(dion_flops(m, n, 0.25, 8) < dion_flops(m, n, 0.25, 1));
        assert!(dion_flops(m, n, 0.25, 8) > 2.0 * dion_rank(m, n, 0.25).powi(2) * (m + n));
    }

    #[test]
    fn parse_labels() {
        assert_eq!(OptimKind::parse("muon"), Some(OptimKind::Muon));
        assert_eq!(OptimKind::parse("SOAP"), Some(OptimKind::Soap));
        assert_eq!(OptimKind::parse("sgd"), None);
        assert!(!OptimKind::AdamW.is_matrix_based());
    }
}
