//! Small statistics helpers shared by the simulator and benches.

/// Max / Avg load-balance ratio (paper Eq. 6). Returns 1.0 for empty input.
pub fn load_balance_ratio(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let max = loads.iter().cloned().fold(f64::MIN, f64::max);
    let avg = loads.iter().sum::<f64>() / loads.len() as f64;
    if avg <= 0.0 {
        1.0
    } else {
        max / avg
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    // total_cmp: NaN sorts to a fixed end instead of panicking.
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_ratio_balanced_is_one() {
        assert!((load_balance_ratio(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lb_ratio_straggler() {
        // one rank with 4x the average of the others
        let r = load_balance_ratio(&[8.0, 2.0, 2.0, 2.0]);
        assert!((r - 8.0 / 3.5).abs() < 1e-12);
    }

    #[test]
    fn lb_ratio_degenerate() {
        assert_eq!(load_balance_ratio(&[]), 1.0);
        assert_eq!(load_balance_ratio(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(max(&xs), 4.0);
        assert_eq!(min(&xs), 1.0);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_nan_does_not_panic() {
        // Pre-fix: partial_cmp().unwrap() panicked on the first NaN.
        // Positive NaN total_cmp-sorts above +inf, so low percentiles
        // still return the finite values.
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 51.0).abs() <= 1.0);
    }
}
