//! Hand-rolled bench timer (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! adaptive iteration count, median-of-samples reporting.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<48} median {:>12}  mean {:>12}  min {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            self.samples,
            self.iters_per_sample,
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling the per-sample iteration count so each
/// sample takes ≳1 ms, collecting `samples` samples after a warmup.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((1e6 / once).ceil() as usize).clamp(1, 1_000_000);
    for _ in 0..iters.min(100) {
        f();
    }

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = times[times.len() / 2];
    let mean_ns = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        median_ns,
        mean_ns,
        min_ns: times[0],
        samples,
        iters_per_sample: iters,
    };
    result.report();
    result
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop-ish", 5, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
