//! Minimal error handling (anyhow is unavailable offline).
//!
//! A single string-backed [`Error`] type plus the small surface the rest
//! of the crate uses from anyhow: the [`crate::err!`] / [`crate::bail!`] /
//! [`crate::ensure!`] macros and a [`Context`] extension trait for
//! `Result` and `Option`.

use std::fmt;

/// A boxed-string error with an optional context chain baked into the
/// message (`"context: cause"`).
#[derive(Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: Into<String>>(msg: M) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend a context layer, anyhow-style.
    pub fn wrap<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::str::Utf8Error> for Error {
    fn from(e: std::str::Utf8Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Attach context to a failure (`Result::Err` or `Option::None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Bail unless a condition holds (anyhow's `ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke with code {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "broke with code 7");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing key {:?}", "k")).unwrap_err();
        assert!(e.to_string().contains("missing key"));
    }

    #[test]
    fn conversions_via_question_mark() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert_eq!(parse("2.5").unwrap(), 2.5);
        assert!(parse("nope").is_err());
    }
}
