//! Minimal JSON parser + writer (no serde available offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); enough for the artifact manifests emitted by
//! `python/compile/aot.py` and for experiment-result dumps.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::error::Result;
use crate::{bail, err};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| err!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    // ---- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                // JSON has no NaN/Infinity literals — `Value::parse`
                // rejects them — so non-finite values serialize as null
                // to keep every emitted artifact re-parseable.
                if !n.is_finite() {
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| err!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    /// Four hex digits of a `\uXXXX` escape (the `\u` already consumed).
    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
        let code = u32::from_str_radix(hex, 16)?;
        self.pos += 4;
        Ok(code)
    }

    /// Decode one `\uXXXX` escape into a char. JSON encodes non-BMP code
    /// points as UTF-16 surrogate pairs (U+1F600 arrives as
    /// `\ud83d\ude00`), so a high surrogate must consume a following
    /// `\uDC00..\uDFFF` escape and combine; surrogates with no valid
    /// partner decode to U+FFFD.
    fn unicode_escape(&mut self) -> Result<char> {
        let code = self.hex4()?;
        match code {
            0xD800..=0xDBFF => {
                let save = self.pos;
                if self.bytes[self.pos..].starts_with(b"\\u") {
                    self.pos += 2;
                    let lo = self.hex4()?;
                    if (0xDC00..=0xDFFF).contains(&lo) {
                        let cp = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                        return Ok(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    // Not a low surrogate: rewind so the next escape is
                    // decoded on its own, and replace the lone high half.
                    self.pos = save;
                }
                Ok('\u{fffd}')
            }
            0xDC00..=0xDFFF => Ok('\u{fffd}'), // lone low surrogate
            c => Ok(char::from_u32(c).unwrap_or('\u{fffd}')),
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        // Re-parse the serialized form.
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{"params":[{"name":"embed.weight","shape":[256,64],"numel":16384}]}"#;
        let v = Value::parse(text).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "embed.weight");
        let shape: Vec<usize> = p
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|s| s.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![256, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        let v = Value::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn display_escapes() {
        let v = Value::Str("a\"b\\c\nd".to_string());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn surrogate_pairs_decode() {
        // Pre-fix: each half decoded independently to U+FFFD U+FFFD.
        let v = Value::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        // Case-insensitive hex, embedded in surrounding text.
        let v = Value::parse("\"a\\uD83D\\uDE00b\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "a\u{1F600}b");
        // Round trip: the writer emits raw UTF-8, the parser reads it back.
        let v = Value::str("\u{1F600} caf\u{e9}");
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn lone_surrogates_replace() {
        assert_eq!(Value::parse(r#""\ud800""#).unwrap().as_str().unwrap(), "\u{fffd}");
        assert_eq!(Value::parse(r#""\udc00""#).unwrap().as_str().unwrap(), "\u{fffd}");
        // High surrogate followed by a non-surrogate escape: replace the
        // lone half, then decode the second escape on its own.
        let v = Value::parse(r#""\ud800A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{fffd}A");
        // High surrogate followed by literal text.
        assert_eq!(Value::parse(r#""\ud800x""#).unwrap().as_str().unwrap(), "\u{fffd}x");
        // Truncated pair tail still errors like any truncated escape.
        assert!(Value::parse(r#""\ud83d\ud"#).is_err());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        // Pre-fix: "NaN"/"inf"/"-inf" — invalid JSON that Value::parse
        // itself rejects, silently breaking --baseline artifacts.
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_string(), "null");
        let v = Value::arr(vec![Value::Num(f64::NAN), Value::num(1.5)]);
        assert_eq!(
            Value::parse(&v.to_string()).unwrap(),
            Value::arr(vec![Value::Null, Value::num(1.5)]),
        );
    }

    /// A random `Value` tree; depth-limited so generation terminates.
    fn gen_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
        match rng.index(if depth >= 3 { 4 } else { 6 }) {
            0 => Value::Null,
            1 => Value::Bool(rng.index(2) == 0),
            2 => {
                let specials = [
                    f64::NAN, f64::INFINITY, f64::NEG_INFINITY,
                    0.0, -0.0, -1.5, 3.25e-4, 9.9e18, -9.9e18,
                ];
                if rng.index(3) == 0 {
                    Value::Num(specials[rng.index(specials.len())])
                } else {
                    Value::Num((rng.next_f64() - 0.5) * 1e6)
                }
            }
            3 => {
                let pool = ["", "plain", "esc\"\\\n\t\r", "caf\u{e9}",
                            "emoji \u{1F600}", "\u{fffd}", "nul\u{0}byte"];
                Value::str(pool[rng.index(pool.len())])
            }
            4 => Value::arr((0..rng.index(4)).map(|_| gen_value(rng, depth + 1))),
            _ => Value::Obj(
                (0..rng.index(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }

    /// What a serialize/parse round trip is specified to preserve:
    /// everything, except non-finite numbers collapse to null.
    fn normalize(v: &Value) -> Value {
        match v {
            Value::Num(n) if !n.is_finite() => Value::Null,
            Value::Arr(a) => Value::Arr(a.iter().map(normalize).collect()),
            Value::Obj(m) => {
                Value::Obj(m.iter().map(|(k, x)| (k.clone(), normalize(x))).collect())
            }
            other => other.clone(),
        }
    }

    #[test]
    fn roundtrip_property_generated_values() {
        let mut rng = crate::util::rng::Rng::new(2024);
        for _ in 0..300 {
            let v = gen_value(&mut rng, 0);
            let text = v.to_string();
            let back = Value::parse(&text)
                .unwrap_or_else(|e| panic!("unparseable {text:?}: {e}"));
            assert_eq!(back, normalize(&v), "round-trip mismatch for {text:?}");
        }
    }
}
