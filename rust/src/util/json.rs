//! Minimal JSON parser + writer (no serde available offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); enough for the artifact manifests emitted by
//! `python/compile/aot.py` and for experiment-result dumps.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::error::Result;
use crate::{bail, err};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| err!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    // ---- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| err!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        // Re-parse the serialized form.
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_manifest_like() {
        let text = r#"{"params":[{"name":"embed.weight","shape":[256,64],"numel":16384}]}"#;
        let v = Value::parse(text).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str().unwrap(), "embed.weight");
        let shape: Vec<usize> = p
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|s| s.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![256, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("nul").is_err());
        assert!(Value::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        let v = Value::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(vec![]));
    }

    #[test]
    fn display_escapes() {
        let v = Value::Str("a\"b\\c\nd".to_string());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }
}
