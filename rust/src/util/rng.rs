//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! Used for synthetic data generation, parameter initialization in the
//! numeric trainer, and the property-testing harness. Determinism across
//! runs and across "ranks" is load-bearing: the paper's Fig. 5 parity
//! claim is verified by comparing bitwise-identical training trajectories.

/// xoshiro256** — fast, high-quality, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give
    /// well-separated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (empty ranges return `lo`).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        if hi <= lo {
            return lo;
        }
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`; panics if `n == 0`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with `N(0, std^2)` f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Fork an independent stream (e.g. one per rank / per parameter).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Zipf-ish categorical sample over `n` items (exponent ~1), used by
    /// the synthetic-corpus generator.
    pub fn zipf(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF approximation for P(k) ∝ 1/(k+1).
        let hn = ((n + 1) as f64).ln();
        let u = self.next_f64();
        let k = ((u * hn).exp() - 1.0) as usize;
        k.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
        assert_eq!(r.range(5, 5), 5);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[r.zipf(50)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[45]);
    }

    #[test]
    fn fork_is_independent() {
        let mut r = Rng::new(1);
        let mut f1 = r.fork(0);
        let mut f2 = r.fork(0);
        // Different fork calls advance the parent => different streams.
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
