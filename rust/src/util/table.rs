//! Markdown table rendering for experiment harness output.
//!
//! Every paper-figure harness prints its rows through this so the
//! EXPERIMENTS.md entries can be pasted verbatim.

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let body: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |\n", body.join(" | "))
        };
        out += &fmt_row(&self.headers, &widths);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out += &format!("| {} |\n", sep.join(" | "));
        for row in &self.rows {
            out += &fmt_row(row, &widths);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV form (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out += &(row.join(",") + "\n");
        }
        out
    }
}

/// Format seconds with ms precision, e.g. `0.877s`.
pub fn secs(t: f64) -> String {
    format!("{t:.3}s")
}

/// Format a ratio, e.g. `1.57x`.
pub fn ratio(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| a"));
        assert!(s.contains("| 1"));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn formatters() {
        assert_eq!(secs(0.8774), "0.877s");
        assert_eq!(ratio(1.567), "1.57x");
    }
}
