//! Offline-environment substrates.
//!
//! Only `xla` and `anyhow` are available as external crates in this build
//! environment, so the usual ecosystem pieces (serde_json, clap, rand,
//! proptest, criterion) are implemented here from scratch, scoped to what
//! the rest of the crate needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
