//! Offline-environment substrates.
//!
//! No external crates are available in this build environment, so the
//! usual ecosystem pieces (anyhow, serde_json, clap, rand, proptest,
//! criterion, rayon) are implemented here from scratch, scoped to what
//! the rest of the crate needs.

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
