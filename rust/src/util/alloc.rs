//! Heap-allocation instrumentation: a counting [`GlobalAlloc`] wrapper
//! around the system allocator plus a per-thread counter.
//!
//! Registered as the crate's `#[global_allocator]` (see `lib.rs`), it
//! lets tests *prove* a code path performs zero heap allocations — the
//! contract the warm (plan-cache-hit) `simulate_iteration` path makes
//! (`tests/warm_alloc.rs`). The counter is thread-local, so
//! concurrently-running tests and pool workers never pollute each
//! other's measurements, and the per-allocation overhead is one
//! thread-local increment (negligible next to `malloc` itself).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Allocations (alloc / alloc_zeroed / realloc) on this thread.
    /// `const`-initialized so the TLS access itself never allocates.
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// The counting allocator. Forwards everything to [`System`], counting
/// each allocation (not deallocation) on the calling thread.
pub struct CountingAllocator;

#[inline]
fn bump() {
    // `try_with`: TLS is unavailable during thread teardown — counting
    // must never panic inside the allocator.
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

// SAFETY: pure pass-through to `System`; the counter has no effect on
// the returned memory.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Heap allocations performed by the current thread so far.
pub fn allocations_on_this_thread() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

/// Run `f` and report how many heap allocations it performed on this
/// thread (plus its result).
pub fn count_allocations<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = allocations_on_this_thread();
    let r = f();
    (allocations_on_this_thread() - before, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_an_allocation() {
        let (n, v) = count_allocations(|| vec![1u8, 2, 3]);
        assert!(n >= 1, "Vec construction must register");
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn pure_arithmetic_is_free() {
        let (n, x) = count_allocations(|| {
            let mut acc = 0.0f64;
            for i in 0..1000 {
                acc += (i as f64).sqrt();
            }
            acc
        });
        assert_eq!(n, 0, "scalar math must not allocate");
        assert!(x > 0.0);
    }

    #[test]
    fn vec_reuse_within_capacity_is_free() {
        // The pattern Breakdown reuse relies on: clear + refill within
        // capacity allocates nothing.
        let mut v: Vec<f64> = Vec::with_capacity(64);
        v.resize(64, 1.0);
        let (n, _) = count_allocations(|| {
            v.clear();
            v.extend_from_slice(&[2.0; 64]);
            v.len()
        });
        assert_eq!(n, 0);
    }
}
