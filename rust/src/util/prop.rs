//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! with a deterministic per-case seed; on failure it reports the seed so
//! the case can be replayed, and performs a simple halving shrink when the
//! generator supports resizing via the `Shrink` trait.

use crate::util::rng::Rng;

/// Run `prop` on `cases` inputs drawn from `gen`. Panics with the failing
/// seed + debug representation on the first counterexample.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  \
                 {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("sum-commutes", 50, |r| (r.range(0, 100), r.range(0, 100)),
              |&(a, b)| {
                  if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
              });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        check("always-fails", 5, |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<u64> = vec![];
        check("collect", 10, |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("collect", 10, |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
