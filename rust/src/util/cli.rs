//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use crate::util::error::Result;
use crate::{bail, err};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of arguments. `flag_names` lists options that take
    /// no value (everything else starting with `--` consumes one).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    // Check flag names on the key *before* routing to
                    // options: `--verbose=x` used to land in `options`
                    // silently, so `flag("verbose")` returned false.
                    if flag_names.contains(&k) {
                        bail!("flag --{k} takes no value (got --{k}={v})");
                    }
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| err!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options are not supported: {arg}");
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(argv("train --steps 10 --alpha=0.5 --verbose pos1"),
                            &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--steps"), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(argv("--steps abc"), &[]).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn eq_form_flag_errors() {
        // Pre-fix: `--verbose=1` landed in `options` and flag("verbose")
        // silently returned false. Now a valueless flag in `=` form is a
        // loud parse error.
        let e = Args::parse(argv("--verbose=1"), &["verbose"]).unwrap_err();
        assert!(e.to_string().contains("verbose"), "{e}");
        assert!(Args::parse(argv("run --verbose=true"), &["verbose"]).is_err());
        // Plain flags and `=`-form options still coexist.
        let a = Args::parse(argv("--verbose --alpha=0.5"), &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.5);
        // `=` in an ordinary option's value is untouched.
        let a = Args::parse(argv("--filter key=value"), &["verbose"]).unwrap();
        assert_eq!(a.get("filter"), Some("key=value"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(""), &[]).unwrap();
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert_eq!(a.get_or("mode", "dflt"), "dflt");
    }
}
