//! Work-stealing thread pool (rayon is unavailable offline).
//!
//! [`parallel_map`] fans a slice of work items out across OS threads.
//! Each worker owns a deque seeded with a contiguous block of indices;
//! when its deque drains it steals from the *back* of a victim's deque
//! (classic Chase-Lev discipline, here with a mutex per deque — the work
//! items are whole scenario simulations, so queue contention is
//! negligible next to task cost). Results are merged back in **input
//! order**, so the output is byte-for-byte independent of scheduling:
//! the property the sweep determinism tests pin down.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Worker count: `CANZONA_SWEEP_THREADS` overrides (min 1), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("CANZONA_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Apply `f` to every item on up to `threads` workers; returns results
/// in input order. Panics in `f` propagate to the caller.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(&f).collect();
    }

    // Seed each worker's deque with a contiguous block of indices.
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| {
            let lo = w * n / threads;
            let hi = (w + 1) * n / threads;
            Mutex::new((lo..hi).collect())
        })
        .collect();

    let worker_outputs: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let queues = &queues;
                let f = &f;
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        // Own queue first (front), then steal (back). The
                        // own-queue guard must drop before stealing: never
                        // hold two queue locks at once.
                        let own = queues[w].lock().unwrap().pop_front();
                        let next = own.or_else(|| {
                            (0..queues.len())
                                .filter(|&v| v != w)
                                .find_map(|v| queues[v].lock().unwrap().pop_back())
                        });
                        match next {
                            Some(idx) => out.push((idx, f(&items[idx]))),
                            // Every index is claimed under a lock before it
                            // runs and none respawn, so globally-empty
                            // queues mean the sweep is drained.
                            None => break,
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });

    // Deterministic merge: scatter by original index.
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (idx, r) in worker_outputs.into_iter().flatten() {
        debug_assert!(slots[idx].is_none(), "index {idx} executed twice");
        slots[idx] = Some(r);
    }
    slots.into_iter().map(|r| r.expect("work item dropped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_execution() {
        let items: Vec<u64> = (0..257).map(|i| i * 31 % 97).collect();
        let serial = parallel_map(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        let parallel = parallel_map(&items, 7, |&x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn imbalanced_work_is_stolen() {
        // Front-loaded costs: block seeding puts all heavy items on
        // worker 0; completion requires the others to steal.
        let hits = AtomicUsize::new(0);
        let items: Vec<u64> = (0..64).map(|i| if i < 8 { 3_000_000 } else { 10 }).collect();
        let out = parallel_map(&items, 4, |&spins| {
            let mut acc = 0u64;
            for i in 0..spins {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            hits.fetch_add(1, Ordering::Relaxed);
            acc
        });
        assert_eq!(out.len(), 64);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 8, |&x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], 8, |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1, 2, 3];
        assert_eq!(parallel_map(&items, 64, |&x| x), vec![1, 2, 3]);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}
