//! Work-stealing thread pool (rayon is unavailable offline), built
//! around a **persistent executor**.
//!
//! [`parallel_map`] fans a slice of work items out across OS threads.
//! Each job seeds one deque per participant with a contiguous block of
//! indices; a participant drains its own deque from the *front* and,
//! when it runs dry, steals from the *back* of a victim's deque
//! (classic Chase-Lev discipline, here with a mutex per deque — the
//! work items are whole scenario simulations, so queue contention is
//! negligible next to task cost). Results are written into per-index
//! output slots, so the merged output is byte-for-byte independent of
//! scheduling: the property the sweep determinism tests pin down.
//!
//! # The persistent executor
//!
//! Workers are **long-lived**: the first `parallel_map` call that needs
//! helpers lazily spawns them, and from then on the same OS threads
//! serve every later call — a *job* (one `parallel_map` invocation) is
//! pushed onto a process-wide injector, idle workers claim participant
//! slots in it, and the submitting caller participates too (as slot 0),
//! so a job always makes progress even when every worker is busy
//! elsewhere. The pool grows monotonically to the largest helper count
//! any call has requested ([`live_workers`] reports it) and never
//! shrinks; idle workers park on a condvar and cost nothing.
//!
//! Two things fall out of persistence:
//!
//! * **Scratch state survives across batches.** A worker is one OS
//!   thread that processes many items across many jobs, which makes
//!   `thread_local!` state the natural per-worker scratch mechanism:
//!   the first item a worker ever claims pays the allocation, and every
//!   later item — *in this batch or any later one* — reuses the warm
//!   buffers with no synchronization. The timeline simulator's
//!   `SimScratch` (see `sim::iteration`) relies on exactly this: scratch
//!   warm-up is paid once per process, not once per `parallel_map`
//!   call, so a whole `run("all")` or a long sweep session keeps its
//!   scratches (and the plan cache's per-worker L1, see
//!   `sweep::cache`) hot. Two properties keep that sound: a
//!   participant never runs two items concurrently (items are claimed
//!   and executed serially), and nested `parallel_map` calls run
//!   inline on the same thread (so a scratch is never borrowed
//!   re-entrantly from a second tier).
//! * **Dispatch is cheap.** Submitting a job is one lock + condvar
//!   notify instead of N `thread::spawn`/`join` pairs; the per-batch
//!   overhead the old scoped pool paid on every call (measured by
//!   `benches/bench_sweep.rs` against [`scoped_map`], the reference
//!   spawn-per-call implementation kept for differential tests) is paid
//!   once per process.
//!
//! # One shared executor
//!
//! The whole crate funnels its parallelism through this module.
//! Callers that used to nest pools route everything through one tier:
//! `experiments::run("all")` runs harnesses sequentially and lets each
//! scenario batch fan out N-wide here. As a guard, a `parallel_map`
//! issued from *inside* a job ([`on_worker`]) runs inline on that
//! thread rather than submitting a nested job, so the live thread count
//! stays bounded by the pool size regardless of nesting depth, and
//! workers can never deadlock waiting on each other. The merged output
//! is unchanged either way (results are index-merged, never
//! scheduling-dependent).
//!
//! # Panic discipline
//!
//! A panic inside the mapped closure is caught at the item boundary,
//! recorded on the job, and **re-raised on the submitting caller** with
//! its original payload once every participant has retired. The
//! executor itself is never poisoned: user code only ever runs outside
//! the executor and queue locks, remaining items of the panicked job are
//! abandoned, and the workers simply move on to the next job
//! (`tests/pool_lifecycle.rs` pins both properties).

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set while the current thread is executing as a pool participant.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Is the current thread executing pool work (a persistent worker
/// running a job, or a caller participating in its own job)? Nested
/// calls use this to run inline on the shared executor instead of
/// submitting a second tier of jobs.
pub fn on_worker() -> bool {
    IN_WORKER.with(|f| f.get())
}

/// Process-wide `--threads` override (0 = unset). Set once by the CLI;
/// takes precedence over `CANZONA_SWEEP_THREADS`.
static THREADS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the process-wide default worker count (the `--threads` CLI
/// flag). Takes precedence over `CANZONA_SWEEP_THREADS`; only affects
/// engines/pools sized *after* the call, so the CLI applies it before
/// touching `SweepEngine::global()`.
pub fn set_default_threads(n: usize) {
    THREADS_OVERRIDE.store(n.max(1), Ordering::Relaxed);
}

/// Worker count, in precedence order: [`set_default_threads`] (the
/// `--threads` flag) if called, else `CANZONA_SWEEP_THREADS` (min 1),
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    let over = THREADS_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    std::env::var("CANZONA_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Persistent workers spawned so far (the pool's high-water helper
/// count; it never shrinks). Diagnostic — the lifecycle tests assert
/// repeated batches at a fixed thread count cause no growth.
pub fn live_workers() -> usize {
    executor().state.lock().unwrap().live_workers
}

// --- job plumbing ------------------------------------------------------

/// Type-erased view of one in-flight `parallel_map`: participants claim
/// and execute items through this vtable without knowing `T`/`R`/`F`.
trait JobRun: Sync {
    /// Run the work-stealing loop as participant `slot` until the job
    /// has no runnable items left (drained, or abandoned after a panic).
    fn work(&self, slot: usize);
}

/// Output slots for one job. Safety: each index is claimed exactly once
/// (under a queue lock) before it runs, so at most one thread ever
/// writes a given slot, and the caller reads only after every
/// participant has retired.
struct OutSlots<'a, R>(&'a [std::cell::UnsafeCell<Option<R>>]);

// SAFETY: see `OutSlots` — disjoint writes, read-after-retire.
unsafe impl<R: Send> Sync for OutSlots<'_, R> {}

/// The caller-stack state of one job (items, closure, queues, outputs,
/// panic latch). Workers reach it through the erased pointer in
/// [`JobCtl`]; the caller keeps it alive until the job fully retires.
struct JobState<'a, T, R, F> {
    items: &'a [T],
    f: &'a F,
    out: OutSlots<'a, R>,
    /// One deque per participant slot, seeded with contiguous blocks.
    queues: Vec<Mutex<VecDeque<usize>>>,
    /// Set on the first panic; participants bail out at the next claim.
    panicked: AtomicBool,
    /// First panic payload, re-raised on the submitting caller.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl<T, R, F> JobRun for JobState<'_, T, R, F>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    fn work(&self, slot: usize) {
        let w = slot % self.queues.len();
        loop {
            if self.panicked.load(Ordering::Relaxed) {
                break;
            }
            // Own queue first (front), then steal (back). The own-queue
            // guard must drop before stealing: never hold two queue
            // locks at once.
            let own = self.queues[w].lock().unwrap().pop_front();
            let next = own.or_else(|| {
                (0..self.queues.len())
                    .filter(|&v| v != w)
                    .find_map(|v| self.queues[v].lock().unwrap().pop_back())
            });
            // Every index is claimed under a lock before it runs and
            // none respawn, so globally-empty queues mean the job is
            // drained.
            let Some(idx) = next else { break };
            match catch_unwind(AssertUnwindSafe(|| (self.f)(&self.items[idx]))) {
                // SAFETY: `idx` was claimed exactly once; no other
                // thread writes this slot.
                Ok(r) => unsafe { *self.out.0[idx].get() = Some(r) },
                Err(payload) => {
                    let mut first = self.panic.lock().unwrap();
                    if first.is_none() {
                        *first = Some(payload);
                    }
                    self.panicked.store(true, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Shared per-job control block: the erased job pointer plus the claim
/// counters, all mutated only under the executor lock.
struct JobCtl {
    /// Lifetime-erased pointer to the caller-stack [`JobState`].
    job: *const (dyn JobRun + 'static),
    /// Helper participant slots still unclaimed.
    claims: AtomicUsize,
    /// Participants currently inside `work()`.
    active: AtomicUsize,
    /// Next helper slot to hand out (slot 0 is the caller's).
    next_slot: AtomicUsize,
}

// SAFETY: the raw pointer is only dereferenced by participants that
// claimed the job through the injector, and `parallel_map` does not
// return (i.e. the pointee stays alive) until the job has left the
// injector *and* `active` has drained to zero — both observed under the
// executor lock, so no participant can touch a dead job.
unsafe impl Send for JobCtl {}
unsafe impl Sync for JobCtl {}

struct ExecState {
    /// Jobs still wanting helper participants, in submission order.
    injector: VecDeque<Arc<JobCtl>>,
    /// Persistent workers spawned so far.
    live_workers: usize,
}

/// The process-wide persistent executor.
struct Executor {
    state: Mutex<ExecState>,
    /// Wakes parked workers when a job arrives.
    work_cv: Condvar,
    /// Wakes submitters waiting for their job's participants to retire.
    done_cv: Condvar,
}

fn executor() -> &'static Executor {
    static EXEC: OnceLock<Executor> = OnceLock::new();
    EXEC.get_or_init(|| Executor {
        state: Mutex::new(ExecState { injector: VecDeque::new(), live_workers: 0 }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
    })
}

/// Hook run by every participant as it retires from a job — workers
/// before re-parking, the submitting caller after participating.
static RETIRE_HOOK: OnceLock<fn()> = OnceLock::new();

/// Register the participant-retire hook (first registration wins; later
/// calls are no-ops). The plan cache uses this to drop **stale**
/// thread-local L1 state when a participant goes idle: a parked worker
/// must not pin artifacts its cache has evicted (or a cache that has
/// been dropped) until some future batch happens to touch the cache
/// again. The hook runs outside every executor/queue lock and must not
/// panic.
pub fn set_participant_retire_hook(hook: fn()) {
    let _ = RETIRE_HOOK.set(hook);
}

fn run_retire_hook() {
    if let Some(h) = RETIRE_HOOK.get() {
        h();
    }
}

/// Claim one helper slot in the front-most job that still wants one.
/// Runs under the executor lock; pops fully-claimed jobs off the
/// injector.
fn claim_job(st: &mut ExecState) -> Option<(Arc<JobCtl>, usize)> {
    while let Some(ctl) = st.injector.front() {
        let claims = ctl.claims.load(Ordering::Relaxed);
        if claims == 0 {
            st.injector.pop_front();
            continue;
        }
        ctl.claims.store(claims - 1, Ordering::Relaxed);
        ctl.active.fetch_add(1, Ordering::Relaxed);
        let slot = ctl.next_slot.fetch_add(1, Ordering::Relaxed);
        let ctl = ctl.clone();
        if claims - 1 == 0 {
            st.injector.pop_front();
        }
        return Some((ctl, slot));
    }
    None
}

/// The persistent worker body: park until a job wants a participant,
/// run its work loop, retire, repeat — for the life of the process.
fn worker_loop() {
    let exec = executor();
    IN_WORKER.with(|f| f.set(true));
    loop {
        // Claim under the lock; park when nothing is claimable. The
        // retire hook also runs (lock released) before every park, so a
        // worker that wakes on a submission but claims no slot still
        // refreshes its thread-local state — it never sleeps on Arcs
        // its cache has evicted or that belong to a dropped cache. No
        // wakeup can be lost: the claim is re-checked under the lock
        // after the hook, and the wait holds that same lock.
        let claimed = {
            let mut st = exec.state.lock().unwrap();
            claim_job(&mut st)
        };
        let (ctl, slot) = match claimed {
            Some(claim) => claim,
            None => {
                run_retire_hook();
                let mut st = exec.state.lock().unwrap();
                match claim_job(&mut st) {
                    Some(claim) => claim,
                    None => {
                        let _parked = exec.work_cv.wait(st).unwrap();
                        continue;
                    }
                }
            }
        };
        // SAFETY: claimed through the injector under the lock; the
        // submitter keeps the pointee alive until `active` drains (see
        // `JobCtl`'s safety contract).
        let job = unsafe { &*ctl.job };
        job.work(slot);
        // Retire before signalling: the submitter can then rely on every
        // participant's hook having run once its job fully drains.
        run_retire_hook();
        {
            let _st = exec.state.lock().unwrap();
            ctl.active.fetch_sub(1, Ordering::Relaxed);
        }
        exec.done_cv.notify_all();
    }
}

/// Spawn persistent workers until at least `wanted` exist. Monotone:
/// the pool grows to the largest helper count ever requested and stays
/// there (repeated batches at one size never respawn — the property the
/// lifecycle stress test pins).
fn ensure_workers(st: &mut ExecState, wanted: usize) {
    while st.live_workers < wanted {
        st.live_workers += 1;
        std::thread::Builder::new()
            .name(format!("canzona-pool-{}", st.live_workers))
            .spawn(worker_loop)
            .expect("failed to spawn pool worker");
    }
}

/// Apply `f` to every item on up to `threads` participants of the
/// persistent executor; returns results in input order, independent of
/// scheduling. The submitting caller participates (so progress never
/// depends on worker availability); a panic in `f` is re-raised here
/// with its original payload once the job has fully retired, and the
/// executor survives to run the next job.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    // Single-thread request, or a nested call from inside a job: run
    // inline — the outermost call is the one shared executor tier.
    if threads == 1 || on_worker() {
        return items.iter().map(&f).collect();
    }

    let out: Vec<std::cell::UnsafeCell<Option<R>>> =
        (0..n).map(|_| std::cell::UnsafeCell::new(None)).collect();
    let state = JobState {
        items,
        f: &f,
        out: OutSlots(&out),
        // Seed each participant's deque with a contiguous block.
        queues: (0..threads)
            .map(|w| {
                let lo = w * n / threads;
                let hi = (w + 1) * n / threads;
                Mutex::new((lo..hi).collect())
            })
            .collect(),
        panicked: AtomicBool::new(false),
        panic: Mutex::new(None),
    };

    // Erase the job's lifetime for the worker-facing pointer. SAFETY:
    // this function keeps `state` alive (and does not return) until the
    // job has left the injector and every participant has retired.
    let short: *const (dyn JobRun + '_) = &state;
    let job: *const (dyn JobRun + 'static) = unsafe { std::mem::transmute(short) };
    let ctl = Arc::new(JobCtl {
        job,
        claims: AtomicUsize::new(threads - 1),
        active: AtomicUsize::new(0),
        next_slot: AtomicUsize::new(1),
    });

    let exec = executor();
    {
        let mut st = exec.state.lock().unwrap();
        ensure_workers(&mut st, threads - 1);
        st.injector.push_back(ctl.clone());
        exec.work_cv.notify_all();
    }

    // Participate as slot 0. `work` never unwinds (panics are caught at
    // the item boundary), so plain set/restore of the flag is sound.
    IN_WORKER.with(|flag| flag.set(true));
    state.work(0);
    IN_WORKER.with(|flag| flag.set(false));
    run_retire_hook();

    // Retire the job: pull it from the injector so no *new* participant
    // can claim it, then wait for the active ones to drain. After this
    // block no thread holds a reference into our stack.
    {
        let mut st = exec.state.lock().unwrap();
        if let Some(pos) = st.injector.iter().position(|j| Arc::ptr_eq(j, &ctl)) {
            let _ = st.injector.remove(pos);
        }
        while ctl.active.load(Ordering::Relaxed) > 0 {
            st = exec.done_cv.wait(st).unwrap();
        }
    }

    if let Some(payload) = state.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
    out.into_iter()
        .map(|slot| slot.into_inner().expect("work item dropped"))
        .collect()
}

/// The pre-persistent reference implementation: scoped threads spawned
/// and joined **per call** (the seed pool's behaviour). Kept for the
/// differential tests in `tests/pool_lifecycle.rs` (persistent output ==
/// scoped output) and for `benches/bench_sweep.rs`, which measures the
/// per-batch dispatch overhead the persistent executor removes. Panics
/// in `f` abort the process-visible worker and propagate as a generic
/// "pool worker panicked" — use [`parallel_map`] for payload-preserving
/// propagation.
pub fn scoped_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 || on_worker() {
        return items.iter().map(&f).collect();
    }
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| {
            let lo = w * n / threads;
            let hi = (w + 1) * n / threads;
            Mutex::new((lo..hi).collect())
        })
        .collect();
    let worker_outputs: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let queues = &queues;
                let f = &f;
                s.spawn(move || {
                    IN_WORKER.with(|flag| flag.set(true));
                    let mut out = Vec::new();
                    loop {
                        let own = queues[w].lock().unwrap().pop_front();
                        let next = own.or_else(|| {
                            (0..queues.len())
                                .filter(|&v| v != w)
                                .find_map(|v| queues[v].lock().unwrap().pop_back())
                        });
                        match next {
                            Some(idx) => out.push((idx, f(&items[idx]))),
                            None => break,
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (idx, r) in worker_outputs.into_iter().flatten() {
        debug_assert!(slots[idx].is_none(), "index {idx} executed twice");
        slots[idx] = Some(r);
    }
    slots.into_iter().map(|r| r.expect("work item dropped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_execution() {
        let items: Vec<u64> = (0..257).map(|i| i * 31 % 97).collect();
        let serial = parallel_map(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        let parallel = parallel_map(&items, 7, |&x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn matches_scoped_reference() {
        let items: Vec<u64> = (0..301).map(|i| i * 17 % 113).collect();
        let persistent = parallel_map(&items, 5, |&x| x.wrapping_mul(31).rotate_left(3));
        let scoped = scoped_map(&items, 5, |&x| x.wrapping_mul(31).rotate_left(3));
        assert_eq!(persistent, scoped);
    }

    #[test]
    fn imbalanced_work_is_stolen() {
        // Front-loaded costs: block seeding puts all heavy items on
        // participant 0; completion requires the others to steal.
        let hits = AtomicUsize::new(0);
        let items: Vec<u64> = (0..64).map(|i| if i < 8 { 3_000_000 } else { 10 }).collect();
        let out = parallel_map(&items, 4, |&spins| {
            let mut acc = 0u64;
            for i in 0..spins {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            hits.fetch_add(1, Ordering::Relaxed);
            acc
        });
        assert_eq!(out.len(), 64);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 8, |&x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], 8, |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1, 2, 3];
        assert_eq!(parallel_map(&items, 64, |&x| x), vec![1, 2, 3]);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn workers_persist_across_calls() {
        // The executor spawns once per high-water helper count and the
        // same OS threads serve later batches, which is what keeps
        // thread_local scratch state warm across batches. (The strict
        // no-growth-over-many-batches assertion lives in
        // tests/pool_lifecycle.rs, whose binary controls every
        // concurrent pool width; here other unit tests may legitimately
        // grow the pool mid-test.)
        let items: Vec<u32> = (0..32).collect();
        parallel_map(&items, 4, |&x| x);
        assert!(live_workers() >= 3, "threads=4 needs >= 3 helpers");
        // And the pool keeps serving correct, in-order results batch
        // after batch on those same workers.
        for round in 0..10 {
            let out = parallel_map(&items, 4, |&x| x + round);
            assert_eq!(out, items.iter().map(|x| x + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn panic_propagates_with_payload_and_pool_survives() {
        let items: Vec<u32> = (0..64).collect();
        let caught = std::panic::catch_unwind(|| {
            parallel_map(&items, 4, |&x| {
                if x == 13 {
                    panic!("boom at {x}");
                }
                x
            })
        });
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at 13"), "payload lost: {msg:?}");
        // Executor not poisoned: the next job runs clean on the same pool.
        let out = parallel_map(&items, 4, |&x| x + 1);
        assert_eq!(out, items.iter().map(|x| x + 1).collect::<Vec<_>>());
    }

    #[test]
    fn nested_calls_run_inline_on_the_shared_executor() {
        // A nested parallel_map from inside a job must not submit a
        // second tier: it runs inline on the calling participant
        // (on_worker() is visible there) and still merges correctly.
        assert!(!on_worker(), "test thread is not a worker");
        let outer: Vec<u32> = (0..8).collect();
        let out = parallel_map(&outer, 4, |&x| {
            assert!(on_worker(), "closure must run on a pool participant");
            let inner: Vec<u32> = (0..50).collect();
            let sums = parallel_map(&inner, 4, |&y| y + x);
            sums.iter().sum::<u32>()
        });
        let expect: Vec<u32> = (0..8).map(|x| (0..50).map(|y| y + x).sum()).collect();
        assert_eq!(out, expect);
        assert!(!on_worker(), "flag must not leak to the caller");
    }

    #[test]
    fn threads_override_takes_precedence() {
        // set_default_threads wins over the env/default path. Process
        // global, deliberately not reset: default_threads() stays valid
        // (>= 1) for every other test, and thread counts never change
        // results (the determinism suite pins that).
        set_default_threads(3);
        assert_eq!(default_threads(), 3);
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // Two non-worker threads submitting jobs at once: both complete
        // correctly (jobs queue on the injector; callers participate, so
        // neither can starve).
        let a = std::thread::spawn(|| {
            let items: Vec<u64> = (0..200).collect();
            parallel_map(&items, 4, |&x| x * 3)
        });
        let b = std::thread::spawn(|| {
            let items: Vec<u64> = (0..200).collect();
            parallel_map(&items, 4, |&x| x * 5)
        });
        assert_eq!(a.join().unwrap(), (0..200).map(|x| x * 3).collect::<Vec<u64>>());
        assert_eq!(b.join().unwrap(), (0..200).map(|x| x * 5).collect::<Vec<u64>>());
    }
}
