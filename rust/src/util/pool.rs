//! Work-stealing thread pool (rayon is unavailable offline).
//!
//! [`parallel_map`] fans a slice of work items out across OS threads.
//! Each worker owns a deque seeded with a contiguous block of indices;
//! when its deque drains it steals from the *back* of a victim's deque
//! (classic Chase-Lev discipline, here with a mutex per deque — the work
//! items are whole scenario simulations, so queue contention is
//! negligible next to task cost). Results are merged back in **input
//! order**, so the output is byte-for-byte independent of scheduling:
//! the property the sweep determinism tests pin down.
//!
//! # One shared executor
//!
//! The whole crate funnels its parallelism through this module, and the
//! *outermost* `parallel_map` on a thread is the executor. Callers that
//! used to nest pools route everything through one tier instead:
//! `experiments::run("all")` runs harnesses sequentially and lets each
//! scenario batch fan out N-wide here (it previously peaked at
//! ≈ N + 13·N live threads, one harness pool nesting a scenario pool
//! per harness). As a guard, a `parallel_map` issued from *inside* a
//! worker ([`on_worker`]) runs inline on that worker rather than
//! spawning a second tier of threads, so the live thread count is
//! bounded by the outer pool's N regardless of nesting depth. The
//! merged output is unchanged either way (results are index-merged,
//! never scheduling-dependent).
//!
//! # Workers as the unit of scratch reuse
//!
//! Each worker is one OS thread that processes many work items in a
//! loop, which makes `thread_local!` state the natural per-worker
//! scratch mechanism: the first item a worker claims pays the
//! allocation, every later item reuses the warm buffers, and no
//! synchronization is ever needed. The timeline simulator's
//! `SimScratch` (see `sim::iteration`) relies on exactly this — a warm
//! family sweep's steady state is allocation-free per scenario because
//! the scratch lives for the whole `parallel_map` call. Two properties
//! of this pool make that sound: a worker never runs two items
//! concurrently (items are claimed and executed serially), and nested
//! `parallel_map` calls run inline on the same thread (so a scratch is
//! never borrowed re-entrantly from a second tier). Note workers are
//! *scoped* threads: thread-locals warmed inside one `parallel_map`
//! call die with its workers, while state on the caller's own thread
//! (e.g. under `threads == 1` or inline nesting) persists across
//! calls.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::Mutex;

thread_local! {
    /// Set while the current thread is executing as a pool worker.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Is the current thread a `parallel_map` worker? Nested calls use this
/// to run inline on the shared executor instead of spawning threads.
pub fn on_worker() -> bool {
    IN_WORKER.with(|f| f.get())
}

/// Worker count: `CANZONA_SWEEP_THREADS` overrides (min 1), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("CANZONA_SWEEP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Apply `f` to every item on up to `threads` workers; returns results
/// in input order. Panics in `f` propagate to the caller.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    // Single-thread request, or a nested call from inside a worker: run
    // inline — the outermost pool is the one shared executor.
    if threads == 1 || on_worker() {
        return items.iter().map(&f).collect();
    }

    // Seed each worker's deque with a contiguous block of indices.
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| {
            let lo = w * n / threads;
            let hi = (w + 1) * n / threads;
            Mutex::new((lo..hi).collect())
        })
        .collect();

    let worker_outputs: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let queues = &queues;
                let f = &f;
                s.spawn(move || {
                    IN_WORKER.with(|flag| flag.set(true));
                    let mut out = Vec::new();
                    loop {
                        // Own queue first (front), then steal (back). The
                        // own-queue guard must drop before stealing: never
                        // hold two queue locks at once.
                        let own = queues[w].lock().unwrap().pop_front();
                        let next = own.or_else(|| {
                            (0..queues.len())
                                .filter(|&v| v != w)
                                .find_map(|v| queues[v].lock().unwrap().pop_back())
                        });
                        match next {
                            Some(idx) => out.push((idx, f(&items[idx]))),
                            // Every index is claimed under a lock before it
                            // runs and none respawn, so globally-empty
                            // queues mean the sweep is drained.
                            None => break,
                        }
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool worker panicked")).collect()
    });

    // Deterministic merge: scatter by original index.
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (idx, r) in worker_outputs.into_iter().flatten() {
        debug_assert!(slots[idx].is_none(), "index {idx} executed twice");
        slots[idx] = Some(r);
    }
    slots.into_iter().map(|r| r.expect("work item dropped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_execution() {
        let items: Vec<u64> = (0..257).map(|i| i * 31 % 97).collect();
        let serial = parallel_map(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        let parallel = parallel_map(&items, 7, |&x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn imbalanced_work_is_stolen() {
        // Front-loaded costs: block seeding puts all heavy items on
        // worker 0; completion requires the others to steal.
        let hits = AtomicUsize::new(0);
        let items: Vec<u64> = (0..64).map(|i| if i < 8 { 3_000_000 } else { 10 }).collect();
        let out = parallel_map(&items, 4, |&spins| {
            let mut acc = 0u64;
            for i in 0..spins {
                acc = acc.wrapping_add(i).rotate_left(1);
            }
            hits.fetch_add(1, Ordering::Relaxed);
            acc
        });
        assert_eq!(out.len(), 64);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 8, |&x| x).is_empty());
        assert_eq!(parallel_map(&[5u32], 8, |&x| x + 1), vec![6]);
    }

    #[test]
    fn more_threads_than_items() {
        let items = [1, 2, 3];
        assert_eq!(parallel_map(&items, 64, |&x| x), vec![1, 2, 3]);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn nested_calls_run_inline_on_the_shared_executor() {
        // A nested parallel_map from inside a worker must not spawn a
        // second tier of threads: it runs inline on the caller's worker
        // (on_worker() is visible there) and still merges correctly.
        assert!(!on_worker(), "test thread is not a worker");
        let outer: Vec<u32> = (0..8).collect();
        let out = parallel_map(&outer, 4, |&x| {
            assert!(on_worker(), "closure must run on a pool worker");
            let inner: Vec<u32> = (0..50).collect();
            let sums = parallel_map(&inner, 4, |&y| y + x);
            sums.iter().sum::<u32>()
        });
        let expect: Vec<u32> = (0..8).map(|x| (0..50).map(|y| y + x).sum()).collect();
        assert_eq!(out, expect);
        assert!(!on_worker(), "flag must not leak to the caller");
    }
}
