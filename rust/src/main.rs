fn main() -> canzona::util::error::Result<()> {
    canzona::coordinator::run_cli(std::env::args().skip(1).collect())
}
