//! Micro-Group construction with Greedy Rollback — paper Algorithms 2/3.
//!
//! TP fragments every matrix parameter; its holistic update is an atomic
//! "Compute Task" that must run on one Host Rank after a fused All-to-All
//! reconstructs its gradient. This module packs the task stream into
//! micro-groups: each group is one fused All-to-All + one balanced
//! compute phase. Packing is greedy under a capacity `C_max` on the
//! per-rank load, with the exact `MinHeapSolver` simulated at every step
//! (not a `ΣCost/R` estimate) and a rollback when the candidate overflows.
//!
//! # Plan encoding
//!
//! [`TpTask`] (which carries an owned `String` name) is the *transient*
//! build-time census; assembled [`TpPlan`]s store a compact form instead:
//! per-task [`TaskMeta`] records (a flat `Copy` struct) plus one
//! per-plan interned [`Symbols`] table holding each distinct task name
//! exactly once. [`TpPlan::assemble`] also precomputes the per-group
//! cost scalars ([`GroupCost`]) and per-rank FLOPs/state totals that the
//! simulator's warm path reads, so replaying a cached plan allocates
//! nothing. Cached `TpPlan`s dominated the sweep engine's footprint
//! (tens of MB of task-name `String`s for a DP=128 family sweep); the
//! compact encoding plus the cache's byte budget bounds that.

use crate::cost::optim::{CostMetric, OptimCost};
use crate::model::tp::TpShard;

use super::minheap::{min_heap_balance, HeapAssignment};

/// One TP-plane optimizer task: a fragmented matrix parameter.
///
/// This is the *builder-facing* record (owned name string); assembled
/// plans store the compact [`TaskMeta`] form instead.
#[derive(Clone, Debug)]
pub struct TpTask {
    /// Stable id (index in the fragmented-param census).
    pub id: usize,
    /// Parameter name (interned into [`Symbols`] at plan assembly).
    pub name: String,
    /// Balancing cost W(p) (paper default: numel of the full tensor).
    pub cost: f64,
    /// Bytes of gradient moved through the All-to-All for this tensor.
    pub comm_bytes: f64,
    /// Full-tensor update FLOPs (for the simulator's exact timing).
    pub flops: f64,
    /// Optimizer state bytes resident on the host rank.
    pub state_bytes: f64,
}

/// Interned task-name symbol id (index into a [`Symbols`] table).
pub type Sym = u32;

/// A per-plan interned string table: each distinct task name is stored
/// once as a `Box<str>` (no capacity slack) and referenced by [`Sym`]
/// index from [`TaskMeta::name`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Symbols {
    names: Vec<Box<str>>,
}

impl Symbols {
    /// An empty table.
    pub fn new() -> Symbols {
        Symbols::default()
    }

    /// Intern `s`, returning its symbol id. Exact duplicates share one
    /// entry (linear probe — plan-assembly is cold-path only).
    pub fn intern(&mut self, s: &str) -> Sym {
        if let Some(i) = self.names.iter().position(|n| &**n == s) {
            return i as Sym;
        }
        self.names.push(s.into());
        (self.names.len() - 1) as Sym
    }

    /// Resolve a symbol id; out-of-range ids (e.g. hand-built test plans
    /// with an empty table) render as `"?"` rather than panicking.
    pub fn name(&self, id: Sym) -> &str {
        self.names.get(id as usize).map(|s| &**s).unwrap_or("?")
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Approximate heap bytes held by the table (pointers + characters).
    pub fn heap_bytes(&self) -> usize {
        self.names.len() * std::mem::size_of::<Box<str>>()
            + self.names.iter().map(|n| n.len()).sum::<usize>()
    }
}

/// Compact per-task record stored inside an assembled [`TpPlan`]: the
/// [`TpTask`] cost fields with the name replaced by a [`Sym`] into the
/// plan's [`Symbols`] table. Field names match `TpTask`, so cost
/// extractors (`|t| t.flops`) work against either.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskMeta {
    /// Stable id (index in the fragmented-param census).
    pub id: usize,
    /// Interned name (resolve via [`TpPlan::task_name`]).
    pub name: Sym,
    /// Balancing cost W(p).
    pub cost: f64,
    /// Gradient bytes through the fused All-to-All.
    pub comm_bytes: f64,
    /// Full-tensor update FLOPs.
    pub flops: f64,
    /// Optimizer state bytes on the host rank.
    pub state_bytes: f64,
}

/// One micro-group: tasks + their host-rank assignment.
#[derive(Clone, Debug)]
pub struct MicroGroup {
    /// (task index into `TpPlan::tasks`, host rank).
    pub assignments: Vec<(usize, usize)>,
    /// Per-rank load (under the balancing cost) inside this group.
    pub rank_loads: Vec<f64>,
    /// Makespan of the group.
    pub max_load: f64,
    /// Total gradient bytes the fused All-to-All moves.
    pub comm_bytes: f64,
}

/// Precomputed cost scalars of one micro-group, derived at
/// [`TpPlan::assemble`] time so the simulator's warm path can time the
/// group's fused All-to-All and balanced compute without building
/// per-rank vectors (the allocation-free warm-path contract).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GroupCost {
    /// Sum of per-rank hosted gradient bytes (== total group bytes).
    pub total_bytes: f64,
    /// Minimum per-rank hosted bytes (ranks hosting nothing count 0) —
    /// the `min_shard` of the variable-size collective formula.
    pub min_rank_bytes: f64,
    /// Maximum per-rank hosted FLOPs — the group's compute makespan
    /// numerator.
    pub max_rank_flops: f64,
}

/// The full TP execution plan (the sequence M of Section 4.2), in the
/// compact encoding: [`TaskMeta`] records + one interned [`Symbols`]
/// table instead of per-task `String`s, plus precomputed group/rank
/// cost aggregates. Construct via [`TpPlan::assemble`].
#[derive(Clone, Debug)]
pub struct TpPlan {
    /// TP group size.
    pub ranks: usize,
    /// The capacity the plan was built under (0.0 for No-Fuse plans).
    pub c_max: f64,
    /// Compact task census (indices are the `assignments` task ids).
    pub tasks: Vec<TaskMeta>,
    /// Interned task names (see [`TpPlan::task_name`]).
    pub symbols: Symbols,
    /// The micro-group sequence.
    pub groups: Vec<MicroGroup>,
    /// Per-group precomputed cost scalars (parallel to `groups`).
    pub group_cost: Vec<GroupCost>,
    /// Per-rank hosted FLOPs over the whole plan.
    pub rank_flops: Vec<f64>,
    /// Per-rank hosted optimizer state bytes over the whole plan.
    pub rank_state: Vec<f64>,
}

/// Build the TP task census from fragmented shards.
pub fn tasks_from_shards(shards: &[TpShard], optim: &OptimCost, metric: CostMetric) -> Vec<TpTask> {
    shards
        .iter()
        .enumerate()
        .map(|(id, s)| TpTask {
            id,
            name: s.param.name.clone(),
            cost: optim.cost(&s.param.shape, metric),
            comm_bytes: 2.0 * s.param.numel() as f64, // bf16 gradients
            flops: optim.flops(&s.param.shape),
            state_bytes: optim.state_bytes(&s.param.shape),
        })
        .collect()
}

/// Paper Algorithm 3 (the detailed form of Algorithm 2).
///
/// `c_max` caps the per-rank load of a group, in the same units as
/// `TpTask::cost`. Panics if a single task exceeds `c_max` (the paper's
/// explicit error case, Alg. 3 line 21).
pub fn build_micro_groups(tasks: Vec<TpTask>, ranks: usize, c_max: f64) -> TpPlan {
    assert!(ranks >= 1);
    // Phase 1: deterministic global LPT sort on (cost, id).
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[b]
            .cost
            .partial_cmp(&tasks[a].cost)
            .unwrap()
            .then(tasks[a].id.cmp(&tasks[b].id))
    });

    let solve = |members: &[usize]| -> HeapAssignment {
        let costs: Vec<f64> = members.iter().map(|&i| tasks[i].cost).collect();
        min_heap_balance(&costs, ranks)
    };

    let finalize = |members: &[usize], groups: &mut Vec<MicroGroup>| {
        if members.is_empty() {
            return;
        }
        let a = solve(members);
        let mut assignments = Vec::with_capacity(members.len());
        for (r, items) in a.items_per_rank.iter().enumerate() {
            for &local in items {
                assignments.push((members[local], r));
            }
        }
        assignments.sort_by_key(|&(t, _)| t);
        let comm_bytes = members.iter().map(|&i| tasks[i].comm_bytes).sum();
        groups.push(MicroGroup {
            assignments,
            rank_loads: a.loads,
            max_load: a.max_load,
            comm_bytes,
        });
    };

    // Phase 2: greedy packing with rollback.
    let mut groups: Vec<MicroGroup> = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut idx = 0usize;
    while idx < order.len() {
        let cand = order[idx];
        current.push(cand);
        let a = solve(&current);
        if a.max_load <= c_max {
            // Valid: accept and continue accumulating.
            idx += 1;
        } else {
            // Rollback: remove the overflowing item, finalize, reseed.
            current.pop();
            if current.is_empty() {
                panic!(
                    "task {:?} (cost {}) alone exceeds C_max {}",
                    tasks[cand].name, tasks[cand].cost, c_max
                );
            }
            finalize(&current, &mut groups);
            current.clear();
            // Do not advance idx: the item seeds the next group.
        }
    }
    finalize(&current, &mut groups);

    TpPlan::assemble(ranks, c_max, tasks, groups)
}

impl TpPlan {
    /// Assemble the compact plan from a build-time task census and its
    /// micro-group sequence: intern names into a per-plan [`Symbols`]
    /// table, strip tasks down to [`TaskMeta`], and precompute the
    /// [`GroupCost`] scalars and per-rank FLOPs/state totals the warm
    /// simulation path reads allocation-free.
    pub fn assemble(
        ranks: usize,
        c_max: f64,
        tasks: Vec<TpTask>,
        groups: Vec<MicroGroup>,
    ) -> TpPlan {
        let mut symbols = Symbols::new();
        let metas: Vec<TaskMeta> = tasks
            .iter()
            .map(|t| TaskMeta {
                id: t.id,
                name: symbols.intern(&t.name),
                cost: t.cost,
                comm_bytes: t.comm_bytes,
                flops: t.flops,
                state_bytes: t.state_bytes,
            })
            .collect();

        let mut group_cost = Vec::with_capacity(groups.len());
        let mut rank_flops = vec![0.0; ranks];
        let mut rank_state = vec![0.0; ranks];
        let mut hosted_bytes = vec![0.0f64; ranks];
        let mut hosted_flops = vec![0.0f64; ranks];
        for g in &groups {
            hosted_bytes.iter_mut().for_each(|b| *b = 0.0);
            hosted_flops.iter_mut().for_each(|b| *b = 0.0);
            for &(t, r) in &g.assignments {
                hosted_bytes[r] += metas[t].comm_bytes;
                hosted_flops[r] += metas[t].flops;
                rank_flops[r] += metas[t].flops;
                rank_state[r] += metas[t].state_bytes;
            }
            group_cost.push(GroupCost {
                total_bytes: hosted_bytes.iter().sum(),
                min_rank_bytes: hosted_bytes.iter().cloned().fold(f64::INFINITY, f64::min),
                max_rank_flops: hosted_flops.iter().cloned().fold(0.0, f64::max),
            });
        }
        TpPlan { ranks, c_max, tasks: metas, symbols, groups, group_cost, rank_flops, rank_state }
    }

    /// Resolve the interned name of task `t`.
    pub fn task_name(&self, t: usize) -> &str {
        self.symbols.name(self.tasks[t].name)
    }

    /// Every task appears exactly once across all groups?
    pub fn is_complete(&self) -> bool {
        let mut seen = vec![false; self.tasks.len()];
        for g in &self.groups {
            for &(t, _) in &g.assignments {
                if seen[t] {
                    return false;
                }
                seen[t] = true;
            }
        }
        seen.into_iter().all(|s| s)
    }

    /// Aggregate per-rank load over the whole plan, under a cost
    /// extractor (e.g. FLOPs for the simulator, state bytes for memory).
    /// The FLOPs/state specializations are precomputed at assembly as
    /// [`TpPlan::rank_flops`] / [`TpPlan::rank_state`].
    pub fn rank_totals<F: Fn(&TaskMeta) -> f64>(&self, f: F) -> Vec<f64> {
        let mut loads = vec![0.0; self.ranks];
        for g in &self.groups {
            for &(t, r) in &g.assignments {
                loads[r] += f(&self.tasks[t]);
            }
        }
        loads
    }

    /// Sum of per-group makespans — the compute part of the TP optimizer
    /// step's critical path.
    pub fn total_makespan(&self) -> f64 {
        self.groups.iter().map(|g| g.max_load).sum()
    }

    /// Approximate heap bytes held by the plan (the cache's byte-budget
    /// accounting unit).
    pub fn heap_bytes(&self) -> usize {
        use std::mem::size_of;
        self.tasks.len() * size_of::<TaskMeta>()
            + self.symbols.heap_bytes()
            + self.groups.len() * size_of::<MicroGroup>()
            + self
                .groups
                .iter()
                .map(|g| {
                    g.assignments.len() * size_of::<(usize, usize)>()
                        + g.rank_loads.len() * size_of::<f64>()
                })
                .sum::<usize>()
            + self.group_cost.len() * size_of::<GroupCost>()
            + (self.rank_flops.len() + self.rank_state.len()) * size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::{CostMetric, OptimCost, OptimKind};
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::tp::{fragmented_matrix_params, tp_split};

    fn toy_tasks(costs: &[f64]) -> Vec<TpTask> {
        costs
            .iter()
            .enumerate()
            .map(|(id, &c)| TpTask {
                id,
                name: format!("t{id}"),
                cost: c,
                comm_bytes: c * 2.0,
                flops: c * 10.0,
                state_bytes: c * 4.0,
            })
            .collect()
    }

    #[test]
    fn completeness_and_capacity() {
        let plan = build_micro_groups(toy_tasks(&[9.0, 7.0, 5.0, 3.0, 3.0, 2.0, 1.0]), 2, 10.0);
        assert!(plan.is_complete());
        for g in &plan.groups {
            assert!(g.max_load <= 10.0 + 1e-9);
        }
    }

    #[test]
    fn rollback_creates_multiple_groups() {
        // Capacity 10 with per-rank loads: must split.
        let plan = build_micro_groups(toy_tasks(&[9.0, 9.0, 9.0, 9.0]), 2, 10.0);
        assert!(plan.groups.len() >= 2, "groups: {}", plan.groups.len());
        assert!(plan.is_complete());
    }

    #[test]
    #[should_panic(expected = "exceeds C_max")]
    fn oversized_task_panics() {
        build_micro_groups(toy_tasks(&[100.0]), 2, 10.0);
    }

    #[test]
    fn saturation_prefers_fewer_groups() {
        // Generous capacity => one group.
        let plan = build_micro_groups(toy_tasks(&[1.0; 20]), 4, 1e9);
        assert_eq!(plan.groups.len(), 1);
    }

    #[test]
    fn group_loads_balanced() {
        let plan = build_micro_groups(toy_tasks(&[5.0, 5.0, 5.0, 5.0]), 2, 10.0);
        for g in &plan.groups {
            let max = g.rank_loads.iter().cloned().fold(0.0, f64::max);
            let min = g.rank_loads.iter().cloned().fold(f64::MAX, f64::min);
            assert!(max - min <= 5.0);
        }
    }

    #[test]
    fn real_census_plan() {
        let params = qwen3(Qwen3Size::S1_7B);
        let shards = tp_split(&params, 8);
        let frag = fragmented_matrix_params(&shards, 8);
        let optim = OptimCost::new(OptimKind::Muon);
        let tasks = tasks_from_shards(&frag, &optim, CostMetric::Numel);
        // C_max = 64 MB of gradient bytes => 32M numel per-rank cap.
        let c_max = 64e6 / 2.0;
        let plan = build_micro_groups(tasks, 8, c_max);
        assert!(plan.is_complete());
        assert!(plan.groups.len() > 1);
        // Balanced within every group.
        for g in &plan.groups {
            assert!(g.max_load <= c_max + 1e-6);
        }
    }

    #[test]
    fn deterministic() {
        let t = || toy_tasks(&[3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let a = build_micro_groups(t(), 3, 10.0);
        let b = build_micro_groups(t(), 3, 10.0);
        let flat = |p: &TpPlan| -> Vec<(usize, usize)> {
            p.groups.iter().flat_map(|g| g.assignments.clone()).collect()
        };
        assert_eq!(flat(&a), flat(&b));
    }

    #[test]
    fn empty_tasks() {
        let plan = build_micro_groups(vec![], 4, 10.0);
        assert!(plan.groups.is_empty());
        assert!(plan.is_complete());
    }

    #[test]
    fn symbols_intern_and_resolve() {
        let mut syms = Symbols::new();
        let a = syms.intern("layers.0.attn.wq");
        let b = syms.intern("layers.0.attn.wk");
        let a2 = syms.intern("layers.0.attn.wq");
        assert_eq!(a, a2, "duplicates must share one entry");
        assert_ne!(a, b);
        assert_eq!(syms.len(), 2);
        assert_eq!(syms.name(a), "layers.0.attn.wq");
        assert_eq!(syms.name(999), "?", "out-of-range ids render as ?");
        assert!(syms.heap_bytes() >= "layers.0.attn.wq".len());
    }

    #[test]
    fn assembled_plan_interns_names_and_drops_strings() {
        let plan = build_micro_groups(toy_tasks(&[4.0, 3.0, 2.0, 1.0]), 2, 100.0);
        assert_eq!(plan.symbols.len(), 4);
        for t in 0..plan.tasks.len() {
            assert_eq!(plan.task_name(t), format!("t{}", plan.tasks[t].id));
        }
        // Compact encoding: the per-task record is a flat Copy struct.
        fn assert_copy<T: Copy>() {}
        assert_copy::<TaskMeta>();
    }

    #[test]
    fn assemble_precomputes_group_and_rank_aggregates() {
        let plan = build_micro_groups(toy_tasks(&[9.0, 7.0, 5.0, 3.0]), 2, 12.0);
        assert_eq!(plan.group_cost.len(), plan.groups.len());
        for (g, gc) in plan.groups.iter().zip(&plan.group_cost) {
            // Rebuild the per-rank hosted vectors and check the scalars.
            let mut bytes = vec![0.0; plan.ranks];
            let mut flops = vec![0.0; plan.ranks];
            for &(t, r) in &g.assignments {
                bytes[r] += plan.tasks[t].comm_bytes;
                flops[r] += plan.tasks[t].flops;
            }
            let total: f64 = bytes.iter().sum();
            let min = bytes.iter().cloned().fold(f64::INFINITY, f64::min);
            let max_f = flops.iter().cloned().fold(0.0, f64::max);
            assert_eq!(gc.total_bytes.to_bits(), total.to_bits());
            assert_eq!(gc.min_rank_bytes.to_bits(), min.to_bits());
            assert_eq!(gc.max_rank_flops.to_bits(), max_f.to_bits());
        }
        let flops = plan.rank_totals(|t| t.flops);
        let state = plan.rank_totals(|t| t.state_bytes);
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&plan.rank_flops), bits(&flops));
        assert_eq!(bits(&plan.rank_state), bits(&state));
        assert!(plan.heap_bytes() > 0);
    }
}
