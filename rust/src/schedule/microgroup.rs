//! Micro-Group construction with Greedy Rollback — paper Algorithms 2/3.
//!
//! TP fragments every matrix parameter; its holistic update is an atomic
//! "Compute Task" that must run on one Host Rank after a fused All-to-All
//! reconstructs its gradient. This module packs the task stream into
//! micro-groups: each group is one fused All-to-All + one balanced
//! compute phase. Packing is greedy under a capacity `C_max` on the
//! per-rank load, with the exact `MinHeapSolver` simulated at every step
//! (not a `ΣCost/R` estimate) and a rollback when the candidate overflows.

use crate::cost::optim::{CostMetric, OptimCost};
use crate::model::tp::TpShard;

use super::minheap::{min_heap_balance, HeapAssignment};

/// One TP-plane optimizer task: a fragmented matrix parameter.
#[derive(Clone, Debug)]
pub struct TpTask {
    /// Stable id (index in the fragmented-param census).
    pub id: usize,
    pub name: String,
    /// Balancing cost W(p) (paper default: numel of the full tensor).
    pub cost: f64,
    /// Bytes of gradient moved through the All-to-All for this tensor.
    pub comm_bytes: f64,
    /// Full-tensor update FLOPs (for the simulator's exact timing).
    pub flops: f64,
    /// Optimizer state bytes resident on the host rank.
    pub state_bytes: f64,
}

/// One micro-group: tasks + their host-rank assignment.
#[derive(Clone, Debug)]
pub struct MicroGroup {
    /// (task index into `TpPlan::tasks`, host rank).
    pub assignments: Vec<(usize, usize)>,
    /// Per-rank load (under the balancing cost) inside this group.
    pub rank_loads: Vec<f64>,
    /// Makespan of the group.
    pub max_load: f64,
    /// Total gradient bytes the fused All-to-All moves.
    pub comm_bytes: f64,
}

/// The full TP execution plan (the sequence M of Section 4.2).
#[derive(Clone, Debug)]
pub struct TpPlan {
    pub ranks: usize,
    pub c_max: f64,
    pub tasks: Vec<TpTask>,
    pub groups: Vec<MicroGroup>,
}

/// Build the TP task census from fragmented shards.
pub fn tasks_from_shards(shards: &[TpShard], optim: &OptimCost, metric: CostMetric) -> Vec<TpTask> {
    shards
        .iter()
        .enumerate()
        .map(|(id, s)| TpTask {
            id,
            name: s.param.name.clone(),
            cost: optim.cost(&s.param.shape, metric),
            comm_bytes: 2.0 * s.param.numel() as f64, // bf16 gradients
            flops: optim.flops(&s.param.shape),
            state_bytes: optim.state_bytes(&s.param.shape),
        })
        .collect()
}

/// Paper Algorithm 3 (the detailed form of Algorithm 2).
///
/// `c_max` caps the per-rank load of a group, in the same units as
/// `TpTask::cost`. Panics if a single task exceeds `c_max` (the paper's
/// explicit error case, Alg. 3 line 21).
pub fn build_micro_groups(tasks: Vec<TpTask>, ranks: usize, c_max: f64) -> TpPlan {
    assert!(ranks >= 1);
    // Phase 1: deterministic global LPT sort on (cost, id).
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[b]
            .cost
            .partial_cmp(&tasks[a].cost)
            .unwrap()
            .then(tasks[a].id.cmp(&tasks[b].id))
    });

    let solve = |members: &[usize]| -> HeapAssignment {
        let costs: Vec<f64> = members.iter().map(|&i| tasks[i].cost).collect();
        min_heap_balance(&costs, ranks)
    };

    let finalize = |members: &[usize], groups: &mut Vec<MicroGroup>| {
        if members.is_empty() {
            return;
        }
        let a = solve(members);
        let mut assignments = Vec::with_capacity(members.len());
        for (r, items) in a.items_per_rank.iter().enumerate() {
            for &local in items {
                assignments.push((members[local], r));
            }
        }
        assignments.sort_by_key(|&(t, _)| t);
        let comm_bytes = members.iter().map(|&i| tasks[i].comm_bytes).sum();
        groups.push(MicroGroup {
            assignments,
            rank_loads: a.loads,
            max_load: a.max_load,
            comm_bytes,
        });
    };

    // Phase 2: greedy packing with rollback.
    let mut groups: Vec<MicroGroup> = Vec::new();
    let mut current: Vec<usize> = Vec::new();
    let mut idx = 0usize;
    while idx < order.len() {
        let cand = order[idx];
        current.push(cand);
        let a = solve(&current);
        if a.max_load <= c_max {
            // Valid: accept and continue accumulating.
            idx += 1;
        } else {
            // Rollback: remove the overflowing item, finalize, reseed.
            current.pop();
            if current.is_empty() {
                panic!(
                    "task {:?} (cost {}) alone exceeds C_max {}",
                    tasks[cand].name, tasks[cand].cost, c_max
                );
            }
            finalize(&current, &mut groups);
            current.clear();
            // Do not advance idx: the item seeds the next group.
        }
    }
    finalize(&current, &mut groups);

    TpPlan { ranks, c_max, tasks, groups }
}

impl TpPlan {
    /// Every task appears exactly once across all groups?
    pub fn is_complete(&self) -> bool {
        let mut seen = vec![false; self.tasks.len()];
        for g in &self.groups {
            for &(t, _) in &g.assignments {
                if seen[t] {
                    return false;
                }
                seen[t] = true;
            }
        }
        seen.into_iter().all(|s| s)
    }

    /// Aggregate per-rank load over the whole plan, under a cost
    /// extractor (e.g. FLOPs for the simulator, state bytes for memory).
    pub fn rank_totals<F: Fn(&TpTask) -> f64>(&self, f: F) -> Vec<f64> {
        let mut loads = vec![0.0; self.ranks];
        for g in &self.groups {
            for &(t, r) in &g.assignments {
                loads[r] += f(&self.tasks[t]);
            }
        }
        loads
    }

    /// Sum of per-group makespans — the compute part of the TP optimizer
    /// step's critical path.
    pub fn total_makespan(&self) -> f64 {
        self.groups.iter().map(|g| g.max_load).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::{CostMetric, OptimCost, OptimKind};
    use crate::model::qwen3::{qwen3, Qwen3Size};
    use crate::model::tp::{fragmented_matrix_params, tp_split};

    fn toy_tasks(costs: &[f64]) -> Vec<TpTask> {
        costs
            .iter()
            .enumerate()
            .map(|(id, &c)| TpTask {
                id,
                name: format!("t{id}"),
                cost: c,
                comm_bytes: c * 2.0,
                flops: c * 10.0,
                state_bytes: c * 4.0,
            })
            .collect()
    }

    #[test]
    fn completeness_and_capacity() {
        let plan = build_micro_groups(toy_tasks(&[9.0, 7.0, 5.0, 3.0, 3.0, 2.0, 1.0]), 2, 10.0);
        assert!(plan.is_complete());
        for g in &plan.groups {
            assert!(g.max_load <= 10.0 + 1e-9);
        }
    }

    #[test]
    fn rollback_creates_multiple_groups() {
        // Capacity 10 with per-rank loads: must split.
        let plan = build_micro_groups(toy_tasks(&[9.0, 9.0, 9.0, 9.0]), 2, 10.0);
        assert!(plan.groups.len() >= 2, "groups: {}", plan.groups.len());
        assert!(plan.is_complete());
    }

    #[test]
    #[should_panic(expected = "exceeds C_max")]
    fn oversized_task_panics() {
        build_micro_groups(toy_tasks(&[100.0]), 2, 10.0);
    }

    #[test]
    fn saturation_prefers_fewer_groups() {
        // Generous capacity => one group.
        let plan = build_micro_groups(toy_tasks(&[1.0; 20]), 4, 1e9);
        assert_eq!(plan.groups.len(), 1);
    }

    #[test]
    fn group_loads_balanced() {
        let plan = build_micro_groups(toy_tasks(&[5.0, 5.0, 5.0, 5.0]), 2, 10.0);
        for g in &plan.groups {
            let max = g.rank_loads.iter().cloned().fold(0.0, f64::max);
            let min = g.rank_loads.iter().cloned().fold(f64::MAX, f64::min);
            assert!(max - min <= 5.0);
        }
    }

    #[test]
    fn real_census_plan() {
        let params = qwen3(Qwen3Size::S1_7B);
        let shards = tp_split(&params, 8);
        let frag = fragmented_matrix_params(&shards, 8);
        let optim = OptimCost::new(OptimKind::Muon);
        let tasks = tasks_from_shards(&frag, &optim, CostMetric::Numel);
        // C_max = 64 MB of gradient bytes => 32M numel per-rank cap.
        let c_max = 64e6 / 2.0;
        let plan = build_micro_groups(tasks, 8, c_max);
        assert!(plan.is_complete());
        assert!(plan.groups.len() > 1);
        // Balanced within every group.
        for g in &plan.groups {
            assert!(g.max_load <= c_max + 1e-6);
        }
    }

    #[test]
    fn deterministic() {
        let t = || toy_tasks(&[3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]);
        let a = build_micro_groups(t(), 3, 10.0);
        let b = build_micro_groups(t(), 3, 10.0);
        let flat = |p: &TpPlan| -> Vec<(usize, usize)> {
            p.groups.iter().flat_map(|g| g.assignments.clone()).collect()
        };
        assert_eq!(flat(&a), flat(&b));
    }

    #[test]
    fn empty_tasks() {
        let plan = build_micro_groups(vec![], 4, 10.0);
        assert!(plan.groups.is_empty());
        assert!(plan.is_complete());
    }
}
