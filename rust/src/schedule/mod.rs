//! TP-plane scheduling (paper Section 4).
//!
//! * [`minheap`] — the `MinHeapSolver` LPT subroutine (paper Alg. 4).
//! * [`microgroup`] — Micro-Group construction with greedy rollback
//!   (paper Algs. 2/3): packs TP-fragmented optimizer tasks into fused
//!   All-to-All groups under a capacity `C_max`, balancing host-rank
//!   loads inside each group.
//! * [`tp_sc`] — the synchronous baseline: every rank all-gathers and
//!   redundantly updates every tensor.

pub mod microgroup;
pub mod minheap;
pub mod tp_sc;

pub use microgroup::{build_micro_groups, GroupCost, MicroGroup, Sym, Symbols, TaskMeta, TpPlan, TpTask};
pub use minheap::{min_heap_balance, HeapAssignment};
