//! TP Synchronous Compute baseline (paper Fig. 2, left).
//!
//! Every TP rank all-gathers every fragmented tensor and performs the
//! identical full-tensor update — redundant compute, blocking collectives,
//! no load balancing. Used by the simulator as the SC reference point.

use crate::schedule::microgroup::TpTask;

/// Cost summary of the synchronous baseline.
#[derive(Clone, Debug)]
pub struct TpScCost {
    /// Per-rank compute (identical on every rank): the FULL task list.
    pub compute_flops_per_rank: f64,
    /// Per-tensor All-Gather message sizes (bytes) — not fused.
    pub gather_sizes: Vec<f64>,
    /// Redundancy factor vs. a perfectly-partitioned execution.
    pub redundancy: f64,
}

pub fn tp_sc_cost(tasks: &[TpTask], ranks: usize) -> TpScCost {
    let total: f64 = tasks.iter().map(|t| t.flops).sum();
    TpScCost {
        compute_flops_per_rank: total,
        gather_sizes: tasks.iter().map(|t| t.comm_bytes).collect(),
        redundancy: ranks as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(costs: &[f64]) -> Vec<TpTask> {
        costs
            .iter()
            .enumerate()
            .map(|(id, &c)| TpTask {
                id,
                name: format!("t{id}"),
                cost: c,
                comm_bytes: c,
                flops: c,
                state_bytes: c,
            })
            .collect()
    }

    #[test]
    fn every_rank_does_everything() {
        let tasks = toy(&[1.0, 2.0, 3.0]);
        let sc = tp_sc_cost(&tasks, 8);
        assert_eq!(sc.compute_flops_per_rank, 6.0);
        assert_eq!(sc.redundancy, 8.0);
        assert_eq!(sc.gather_sizes.len(), 3);
    }
}
