//! MinHeapSolver — paper Algorithm 4.
//!
//! Classic LPT multiprocessor scheduling: sort items by descending cost,
//! repeatedly assign to the least-loaded rank (min-heap). Deterministic
//! tie-breaking on (load, rank) keeps every rank computing the identical
//! plan offline, which the paper relies on (no plan exchange needed).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Result of balancing a set of items over R ranks.
#[derive(Clone, Debug)]
pub struct HeapAssignment {
    /// `items_per_rank[r]` = indices (into the input slice) on rank r.
    pub items_per_rank: Vec<Vec<usize>>,
    /// Final load per rank.
    pub loads: Vec<f64>,
    /// max_r load (the makespan L_max of Alg. 4).
    pub max_load: f64,
}

/// Heap key over `f64` loads. `total_cmp` gives NaN a fixed position in
/// the order instead of the `partial_cmp().unwrap()` panic — a NaN cost
/// produces a (degenerate but deterministic) plan rather than unwinding
/// out of the planner.
struct F(f64);
impl PartialEq for F {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0).is_eq()
    }
}
impl Eq for F {}
impl PartialOrd for F {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// LPT-balance `costs` over `ranks` ranks.
pub fn min_heap_balance(costs: &[f64], ranks: usize) -> HeapAssignment {
    assert!(ranks >= 1);
    // Local LPT sort (descending cost, stable on index).
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]).then(a.cmp(&b)));

    let mut heap: BinaryHeap<Reverse<(F, usize)>> =
        (0..ranks).map(|r| Reverse((F(0.0), r))).collect();
    let mut items_per_rank = vec![Vec::new(); ranks];
    let mut loads = vec![0.0; ranks];
    for idx in order {
        let Reverse((F(l), r)) = heap.pop().unwrap();
        items_per_rank[r].push(idx);
        loads[r] = l + costs[idx];
        heap.push(Reverse((F(loads[r]), r)));
    }
    let max_load = loads.iter().cloned().fold(0.0, f64::max);
    HeapAssignment { items_per_rank, loads, max_load }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_items_assigned_once() {
        let costs = [5.0, 3.0, 8.0, 1.0, 2.0];
        let a = min_heap_balance(&costs, 2);
        let mut seen: Vec<usize> = a.items_per_rank.concat();
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn loads_consistent() {
        let costs = [5.0, 3.0, 8.0, 1.0, 2.0];
        let a = min_heap_balance(&costs, 3);
        for r in 0..3 {
            let sum: f64 = a.items_per_rank[r].iter().map(|&i| costs[i]).sum();
            assert!((sum - a.loads[r]).abs() < 1e-12);
        }
        assert_eq!(a.max_load, a.loads.iter().cloned().fold(0.0, f64::max));
    }

    #[test]
    fn lpt_guarantee() {
        // Graham's bound: LPT makespan <= (4/3 - 1/(3R)) * OPT, and OPT >=
        // max(total/R, max_item). Check the bound on random instances.
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n = 2 + rng.index(40);
            let r = 1 + rng.index(8);
            let costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.next_f64() * 99.0).collect();
            let a = min_heap_balance(&costs, r);
            let total: f64 = costs.iter().sum();
            let max_item = costs.iter().cloned().fold(0.0, f64::max);
            let opt_lb = (total / r as f64).max(max_item);
            let bound = (4.0 / 3.0 - 1.0 / (3.0 * r as f64)) * opt_lb;
            assert!(a.max_load <= bound + 1e-9,
                    "makespan {} > bound {}", a.max_load, bound);
        }
    }

    #[test]
    fn perfect_split_when_possible() {
        let costs = [4.0, 4.0, 4.0, 4.0];
        let a = min_heap_balance(&costs, 4);
        assert_eq!(a.max_load, 4.0);
    }

    #[test]
    fn deterministic() {
        let costs: Vec<f64> = (0..100).map(|i| ((i * 37) % 13) as f64 + 1.0).collect();
        let a = min_heap_balance(&costs, 7);
        let b = min_heap_balance(&costs, 7);
        assert_eq!(a.items_per_rank, b.items_per_rank);
    }

    #[test]
    fn nan_cost_does_not_panic() {
        // Pre-fix: both the LPT sort and F::cmp called
        // partial_cmp().unwrap() and panicked on the first NaN cost.
        // total_cmp gives NaN a fixed sort position, so balancing
        // completes deterministically and every item is still assigned
        // exactly once — the caller surfaces bad costs as an error
        // instead of unwinding out of the planner.
        let costs = [5.0, f64::NAN, 3.0, 1.0];
        let a = min_heap_balance(&costs, 2);
        let mut seen: Vec<usize> = a.items_per_rank.concat();
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        // Deterministic across repeated runs.
        let b = min_heap_balance(&costs, 2);
        assert_eq!(a.items_per_rank, b.items_per_rank);
    }

    #[test]
    fn empty_input() {
        let a = min_heap_balance(&[], 4);
        assert_eq!(a.max_load, 0.0);
        assert!(a.items_per_rank.iter().all(|v| v.is_empty()));
    }
}
