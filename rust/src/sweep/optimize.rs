//! Best-first branch-and-bound configuration search (`canzona optimize`).
//!
//! Given a [`SweepGrid`] (the model / cluster-shape / optimizer /
//! strategy / α / C_max space) and an [`Objective`], find the grid's
//! argmin without exhaustively simulating it. Every leaf gets an
//! admissible lower bound from [`ScenarioBounds`] (cheap closed-form
//! census arithmetic); leaves are then evaluated best-bound-first
//! through the engine's warm zero-alloc path
//! ([`SweepEngine::eval`] → `simulate_iteration_into` on the
//! persistent `util::pool` workers, plan-cache L1 reads), and the
//! search stops at the first leaf whose bound exceeds the incumbent —
//! in bound order, every later leaf is pruned too. Each eval batch
//! inherits the engine's batched SoA tier ([`crate::sim::batch`]),
//! both arms: leaves that share a plan fingerprint × schedule shape
//! and differ only in the lane knobs (`C_max`, `straggler`) are
//! evaluated as one multi-lane call — closed-form recurrences at
//! `pp = 1`, schedule-tape timeline replay on the `pp > 1` /
//! micro-batched / straggler arm — bit-identical to the scalar arm, so
//! the winner, frontier, and artifact bytes are unchanged by
//! `--no-batch`. Since PR 9 the timeline arm also carries a positive
//! optimizer-latency bound (min-over-stages step floor), so
//! deep-pipeline grids prune instead of degenerating to exhaustion.
//!
//! **Exactness.** Pruning is on strict `bound > incumbent`, and bounds
//! never exceed true values, so a pruned leaf's value is `>` the final
//! incumbent: it can't win, not even a tie. Ties among *evaluated*
//! leaves break on the smaller grid index — exactly the exhaustive
//! `run_grid` + argmin rule — so the winner is bit-identical to the
//! exhaustive one for *any* batch size. The set of *evaluated* leaves
//! (and hence the reported frontier) does depend on the batch size;
//! tests that pin the frontier pin [`OptimizeOptions::batch`] too.
//! `tests/optimize_differential.rs` enforces both properties.
//!
//! The result carries a Pareto frontier over the evaluated leaves
//! (iteration time × optimizer-state memory × bubble fraction) plus the
//! winner; [`render_optimize_json`] reuses the sweep's
//! [`render_json`] row shape so `canzona optimize --baseline` joins
//! through the same [`SweepDiff`] machinery as `sweep`.
//!
//! [`SweepDiff`]: crate::sweep::SweepDiff

use std::cmp::Ordering;

use crate::sim::{Breakdown, Scenario, ScenarioBounds};
use crate::util::error::Result;
use crate::util::json::Value;
use crate::util::stats::load_balance_ratio;
use crate::util::table::{ratio, secs, Table};
use crate::{bail, err};

use super::engine::{render_json, SweepEngine};
use super::grid::SweepGrid;

/// What the search minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// End-to-end iteration time (`Breakdown::total_s`).
    IterTime,
    /// Optimizer step wall time (`Breakdown::optimizer_s`).
    OptimizerLatency,
    /// Pacing stage's worst per-DP-rank optimizer state bytes
    /// (`max(Breakdown::dp_loads_state)`).
    Memory,
}

impl Objective {
    /// Parse a `--objective` value (`iter-time` / `optimizer-latency` /
    /// `memory`).
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "iter-time" => Some(Objective::IterTime),
            "optimizer-latency" => Some(Objective::OptimizerLatency),
            "memory" => Some(Objective::Memory),
            _ => None,
        }
    }

    /// CLI / artifact label.
    pub fn label(self) -> &'static str {
        match self {
            Objective::IterTime => "iter-time",
            Objective::OptimizerLatency => "optimizer-latency",
            Objective::Memory => "memory",
        }
    }

    /// The objective's value on a simulated breakdown.
    pub fn value(self, b: &Breakdown) -> f64 {
        match self {
            Objective::IterTime => b.total_s,
            Objective::OptimizerLatency => b.optimizer_s,
            Objective::Memory => b.dp_loads_state.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// The objective's admissible lower bound for a scenario.
    pub fn bound(self, bounds: &mut ScenarioBounds, s: &Scenario) -> f64 {
        match self {
            Objective::IterTime => bounds.iter_time(s),
            Objective::OptimizerLatency => bounds.optimizer_latency(s),
            Objective::Memory => bounds.memory(s),
        }
    }
}

/// Search knobs beyond the grid itself.
#[derive(Clone, Copy, Debug)]
pub struct OptimizeOptions {
    /// What to minimize.
    pub objective: Objective,
    /// Keep only scenarios with exactly this many GPUs (`dp*tp*pp`).
    pub gpus: Option<usize>,
    /// `false` = evaluate the whole space (exact frontier, no pruning)
    /// — the `--exhaustive` mode and the differential tests' oracle.
    pub prune: bool,
    /// Leaves evaluated per engine batch (`0` = the engine's worker
    /// count). The winner is batch-size-invariant; the evaluated set
    /// is not (a larger batch can evaluate leaves a smaller one would
    /// have pruned).
    pub batch: usize,
}

impl Default for OptimizeOptions {
    fn default() -> OptimizeOptions {
        OptimizeOptions { objective: Objective::IterTime, gpus: None, prune: true, batch: 0 }
    }
}

/// One simulated leaf of the search.
#[derive(Clone, Debug)]
pub struct EvaluatedScenario {
    /// Index into the grid's [`SweepGrid::scenarios`] expansion.
    pub grid_index: usize,
    /// The scenario itself.
    pub scenario: Scenario,
    /// Its full simulation result.
    pub breakdown: Breakdown,
    /// The objective's value on `breakdown`.
    pub value: f64,
    /// The admissible lower bound the search ordered this leaf by.
    pub bound: f64,
}

/// Outcome of one [`optimize`] search.
#[derive(Clone, Debug)]
pub struct OptimizeResult {
    /// What was minimized.
    pub objective: Objective,
    /// Full grid cross-product size (before the `--gpus` filter).
    pub grid_len: usize,
    /// Search-space size after the `--gpus` filter.
    pub space: usize,
    /// Every simulated leaf, sorted by grid index.
    pub evaluated: Vec<EvaluatedScenario>,
    /// Index into `evaluated` of the objective argmin (exhaustive-
    /// identical: min value, ties to the smallest grid index).
    pub winner: usize,
    /// Indices into `evaluated` forming the Pareto frontier over
    /// (total time, optimizer-state memory, bubble fraction). Exact
    /// duplicates keep their first grid index; the winner is always
    /// included even if a tied leaf dominates it on secondary metrics.
    /// Globally exact only when `prune` was off — under pruning it is
    /// the frontier *of the evaluated set*.
    pub frontier: Vec<usize>,
    /// Leaves skipped by the bound cut (`space - evaluated.len()`).
    pub pruned: usize,
}

/// The (minimize-all) metric triple the frontier is computed over.
fn frontier_metrics(b: &Breakdown) -> [f64; 3] {
    let mem = b.dp_loads_state.iter().cloned().fold(0.0, f64::max);
    let bubble_frac = if b.fwd_bwd_s > 0.0 { b.bubble_s / b.fwd_bwd_s } else { 0.0 };
    [b.total_s, mem, bubble_frac]
}

/// `a` Pareto-dominates `b`: no worse everywhere, better somewhere.
fn dominates(a: &[f64; 3], b: &[f64; 3]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

/// Non-dominated indices of `evaluated` (first grid index kept among
/// exact-duplicate triples), with `winner` force-included.
fn pareto_frontier(evaluated: &[EvaluatedScenario], winner: usize) -> Vec<usize> {
    let ms: Vec<[f64; 3]> = evaluated.iter().map(|e| frontier_metrics(&e.breakdown)).collect();
    let mut out = Vec::new();
    'cand: for i in 0..ms.len() {
        for j in 0..ms.len() {
            if j != i && dominates(&ms[j], &ms[i]) {
                continue 'cand;
            }
            if j < i && ms[j] == ms[i] {
                continue 'cand; // duplicate triple: keep the first
            }
        }
        out.push(i);
    }
    if !out.contains(&winner) {
        let at = out.partition_point(|&i| i < winner);
        out.insert(at, winner);
    }
    out
}

/// The objective's value with the search's finiteness contract: a NaN
/// or infinite simulated value is a loud error, not a silent winner —
/// the surfacing end of the planners' `total_cmp` hardening.
fn finite_value(objective: Objective, b: &Breakdown, s: &Scenario) -> Result<f64> {
    let v = objective.value(b);
    if !v.is_finite() {
        bail!(
            "optimize: non-finite {} value {v} for {} dp{} tp{} pp{} {} {}",
            objective.label(),
            s.label,
            s.dp,
            s.tp,
            s.pp,
            s.optim.label(),
            s.strategy.label()
        );
    }
    Ok(v)
}

/// Run the best-first search (see the module docs for the exactness
/// argument). Errors on an empty grid, an unsatisfiable `--gpus`
/// filter, or a non-finite objective value.
pub fn optimize(
    engine: &SweepEngine,
    grid: &SweepGrid,
    opts: &OptimizeOptions,
) -> Result<OptimizeResult> {
    let all = grid.scenarios();
    let grid_len = all.len();
    if grid_len == 0 {
        bail!("optimize: empty grid");
    }
    let leaves: Vec<(usize, Scenario)> = all
        .into_iter()
        .enumerate()
        .filter(|(_, s)| opts.gpus.is_none_or(|g| s.gpus() == g))
        .collect();
    if leaves.is_empty() {
        let g = opts.gpus.unwrap_or(0);
        bail!("optimize: no grid point has dp*tp*pp == {g} (--gpus)");
    }
    let space = leaves.len();

    // Bound every leaf, then visit in (bound, grid index) order: the
    // first leaf whose bound exceeds the incumbent ends the search.
    let mut bounds = ScenarioBounds::new();
    let bound_of: Vec<f64> =
        leaves.iter().map(|(_, s)| opts.objective.bound(&mut bounds, s)).collect();
    let mut order: Vec<usize> = (0..space).collect();
    order.sort_by(|&a, &b| {
        bound_of[a].total_cmp(&bound_of[b]).then(leaves[a].0.cmp(&leaves[b].0))
    });

    let batch = if opts.batch == 0 { engine.threads() } else { opts.batch };
    let mut evaluated: Vec<EvaluatedScenario> = Vec::new();
    // (value, grid index) — the exhaustive argmin's tie-break key. The
    // value component only decreases, so the bound cut is final.
    let mut incumbent: Option<(f64, usize)> = None;
    let mut cursor = 0usize;
    let mut cut = false;
    while cursor < order.len() && !cut {
        let mut batch_ids: Vec<usize> = Vec::with_capacity(batch);
        while cursor < order.len() && batch_ids.len() < batch {
            let li = order[cursor];
            if opts.prune {
                if let Some((inc, _)) = incumbent {
                    if bound_of[li] > inc {
                        cut = true; // sorted: every later leaf prunes too
                        break;
                    }
                }
            }
            batch_ids.push(li);
            cursor += 1;
        }
        if batch_ids.is_empty() {
            break;
        }
        let scens: Vec<Scenario> = batch_ids.iter().map(|&li| leaves[li].1.clone()).collect();
        let breaks = engine.eval(&scens);
        for ((&li, scenario), breakdown) in batch_ids.iter().zip(scens).zip(breaks) {
            let grid_index = leaves[li].0;
            let value = finite_value(opts.objective, &breakdown, &scenario)?;
            let better = match incumbent {
                None => true,
                Some((inc, wgi)) => match value.total_cmp(&inc) {
                    Ordering::Less => true,
                    Ordering::Equal => grid_index < wgi,
                    Ordering::Greater => false,
                },
            };
            if better {
                incumbent = Some((value, grid_index));
            }
            evaluated
                .push(EvaluatedScenario { grid_index, scenario, breakdown, value, bound: bound_of[li] });
        }
    }

    evaluated.sort_by_key(|e| e.grid_index);
    let pruned = space - evaluated.len();
    let (_, winner_gi) = incumbent.ok_or_else(|| err!("optimize: nothing evaluated"))?;
    let winner = evaluated
        .iter()
        .position(|e| e.grid_index == winner_gi)
        .expect("winner is an evaluated leaf");
    let frontier = pareto_frontier(&evaluated, winner);
    Ok(OptimizeResult {
        objective: opts.objective,
        grid_len,
        space,
        evaluated,
        winner,
        frontier,
        pruned,
    })
}

/// Render the frontier (winner starred) as one Markdown table.
pub fn render_optimize_table(r: &OptimizeResult) -> Table {
    let mut t = Table::new(
        &format!(
            "Optimize [{}] — {} evaluated / {} space ({} pruned)",
            r.objective.label(),
            r.evaluated.len(),
            r.space,
            r.pruned
        ),
        &["", "model", "DP", "TP", "PP", "mb", "optim", "strategy", "alpha", "C_max",
          "fwd-bwd", "optimizer", "total", "bubble", "state/rank", "DP LB", "value",
          "bound"],
    );
    for &i in &r.frontier {
        let e = &r.evaluated[i];
        let (s, b) = (&e.scenario, &e.breakdown);
        let mem = b.dp_loads_state.iter().cloned().fold(0.0, f64::max);
        t.row(vec![
            if i == r.winner { "*".into() } else { String::new() },
            s.label.clone(),
            s.dp.to_string(),
            s.tp.to_string(),
            s.pp.to_string(),
            s.micro_batches.to_string(),
            s.optim.label().into(),
            s.strategy.label().into(),
            format!("{:.2}", s.alpha),
            match s.c_max_bytes {
                None => "no-fuse".into(),
                Some(c) => format!("{:.0}MB", c / 1e6),
            },
            secs(b.fwd_bwd_s),
            secs(b.optimizer_s),
            secs(b.total_s),
            secs(b.bubble_s),
            format!("{:.2}GB", mem / 1e9),
            ratio(load_balance_ratio(&b.dp_loads_flops)),
            secs(e.value),
            secs(e.bound),
        ]);
    }
    t
}

/// Render the search as a JSON artifact. The frontier rows live under
/// `"scenarios"` in the sweep's exact [`render_json`] row shape, so a
/// saved artifact feeds straight back into `--baseline` joins
/// ([`crate::sweep::SweepDiff`]); `"winner"`, `"objective"`, and the
/// `"search"` counters ride alongside.
pub fn render_optimize_json(r: &OptimizeResult) -> Value {
    let scens: Vec<Scenario> =
        r.frontier.iter().map(|&i| r.evaluated[i].scenario.clone()).collect();
    let breaks: Vec<Breakdown> =
        r.frontier.iter().map(|&i| r.evaluated[i].breakdown.clone()).collect();
    let mut v = render_json(&scens, &breaks);
    let w = &r.evaluated[r.winner];
    let winner_row = render_json(
        std::slice::from_ref(&w.scenario),
        std::slice::from_ref(&w.breakdown),
    )
    .get("scenarios")
    .and_then(|rows| Ok(rows.as_arr()?[0].clone()))
    .expect("render_json yields one row per scenario");
    if let Value::Obj(m) = &mut v {
        m.insert("objective".to_string(), Value::str(r.objective.label()));
        m.insert("winner".to_string(), winner_row);
        m.insert(
            "search".to_string(),
            Value::obj(vec![
                ("grid", Value::num(r.grid_len as f64)),
                ("space", Value::num(r.space as f64)),
                ("evaluated", Value::num(r.evaluated.len() as f64)),
                ("pruned", Value::num(r.pruned as f64)),
            ]),
        );
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::{CostMetric, OptimKind};
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;
    use crate::sim::PipelineSchedule;

    fn small_grid() -> SweepGrid {
        SweepGrid {
            models: vec![Qwen3Size::S1_7B],
            dp: vec![4],
            tp: vec![2],
            pp: vec![1],
            micro_batches: vec![1],
            schedules: vec![PipelineSchedule::OneFOneB],
            stragglers: vec![1.0],
            optims: vec![OptimKind::Muon],
            strategies: DpStrategy::ALL.to_vec(),
            alphas: vec![1.0],
            c_max_mb: vec![Some(256.0)],
            heteros: vec![crate::sim::HeteroSpec::None],
            fail_ranks: vec![None],
            mttfs: vec![None],
            ckpt_intervals: vec![1],
            metric: CostMetric::Numel,
            fault_seed: 0,
        }
    }

    #[test]
    fn objective_parse_and_labels() {
        for o in [Objective::IterTime, Objective::OptimizerLatency, Objective::Memory] {
            assert_eq!(Objective::parse(o.label()), Some(o));
        }
        assert_eq!(Objective::parse("ITER-TIME"), Some(Objective::IterTime));
        assert_eq!(Objective::parse("vibes"), None);
    }

    #[test]
    fn non_finite_value_is_an_error() {
        let s = Scenario::paper_default();
        let mut b = Breakdown { total_s: f64::NAN, ..Breakdown::default() };
        assert!(finite_value(Objective::IterTime, &b, &s).is_err());
        b.total_s = f64::INFINITY;
        assert!(finite_value(Objective::IterTime, &b, &s).is_err());
        b.total_s = 1.5;
        assert_eq!(finite_value(Objective::IterTime, &b, &s).unwrap(), 1.5);
    }

    #[test]
    fn search_finds_a_winner_and_accounts_for_every_leaf() {
        let engine = SweepEngine::new(2);
        let opts = OptimizeOptions {
            objective: Objective::OptimizerLatency,
            batch: 1,
            ..OptimizeOptions::default()
        };
        let r = optimize(&engine, &small_grid(), &opts).unwrap();
        assert_eq!(r.grid_len, DpStrategy::ALL.len());
        assert_eq!(r.space, DpStrategy::ALL.len());
        assert_eq!(r.evaluated.len() + r.pruned, r.space);
        assert!(r.frontier.contains(&r.winner));
        let w = &r.evaluated[r.winner];
        for e in &r.evaluated {
            assert!(
                (w.value, w.grid_index) <= (e.value, e.grid_index),
                "winner not minimal"
            );
            assert!(e.bound <= e.value + 1e-12, "inadmissible bound for #{}", e.grid_index);
        }
    }

    #[test]
    fn fault_axes_search_stays_exact_and_admissible() {
        // Failure rate and checkpoint interval as grid axes: fault
        // costs are strictly >= 0, so the fault-free bounds stay
        // admissible and the pruned search still finds the exhaustive
        // argmin (which here is the clean, densely-checkpointed point).
        let engine = SweepEngine::new(2);
        let mut grid = small_grid();
        grid.strategies = vec![DpStrategy::LbAsc];
        grid.mttfs = vec![None, Some(3600.0), Some(600.0)];
        grid.ckpt_intervals = vec![1, 8];
        let opts = OptimizeOptions { batch: 1, ..OptimizeOptions::default() };
        let pruned = optimize(&engine, &grid, &opts).unwrap();
        let exhaustive = optimize(
            &engine,
            &grid,
            &OptimizeOptions { prune: false, ..opts },
        )
        .unwrap();
        assert_eq!(pruned.space, 6);
        assert_eq!(
            pruned.evaluated[pruned.winner].grid_index,
            exhaustive.evaluated[exhaustive.winner].grid_index,
        );
        for e in &exhaustive.evaluated {
            assert!(e.bound <= e.value + 1e-12, "inadmissible bound for #{}", e.grid_index);
        }
        let w = &exhaustive.evaluated[exhaustive.winner].scenario;
        assert_eq!((w.mttf_s, w.ckpt_interval), (None, 1), "faults only add cost");
    }

    #[test]
    fn gpus_filter_restricts_and_errors_when_empty() {
        let engine = SweepEngine::new(2);
        let mut grid = small_grid();
        grid.dp = vec![4, 8];
        let opts =
            OptimizeOptions { gpus: Some(8), batch: 1, ..OptimizeOptions::default() };
        let r = optimize(&engine, &grid, &opts).unwrap();
        assert_eq!(r.grid_len, 2 * DpStrategy::ALL.len());
        assert_eq!(r.space, DpStrategy::ALL.len());
        assert!(r.evaluated.iter().all(|e| e.scenario.gpus() == 8));
        let bad = OptimizeOptions { gpus: Some(7), ..OptimizeOptions::default() };
        assert!(optimize(&engine, &grid, &bad).is_err());
    }

    #[test]
    fn json_artifact_shape_round_trips() {
        let engine = SweepEngine::new(2);
        let opts = OptimizeOptions { batch: 1, ..OptimizeOptions::default() };
        let r = optimize(&engine, &small_grid(), &opts).unwrap();
        let v = render_optimize_json(&r);
        assert_eq!(v.get("objective").unwrap().as_str().unwrap(), "iter-time");
        let rows = v.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), r.frontier.len());
        assert!(v.get("winner").unwrap().get("total_s").unwrap().as_f64().unwrap() > 0.0);
        let search = v.get("search").unwrap();
        assert_eq!(search.get("space").unwrap().as_usize().unwrap(), r.space);
        assert_eq!(
            search.get("evaluated").unwrap().as_usize().unwrap(),
            r.evaluated.len()
        );
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
        // Table renders one line per frontier row.
        let t = render_optimize_table(&r);
        assert!(t.render().contains("Optimize [iter-time]"));
    }

    #[test]
    fn dominance_and_duplicates() {
        let mk = |total: f64, mem: f64| {
            let mut b = Breakdown { total_s: total, fwd_bwd_s: 1.0, ..Breakdown::default() };
            b.dp_loads_state = vec![mem];
            b
        };
        let a = frontier_metrics(&mk(1.0, 5.0));
        let b = frontier_metrics(&mk(2.0, 5.0));
        let c = frontier_metrics(&mk(2.0, 4.0));
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&b, &c) && !dominates(&c, &b));
        assert!(!dominates(&a, &a), "no self-domination on equal triples");
    }
}
