//! Sweep baseline diffing: join a fresh sweep against a prior JSON
//! artifact, emit per-scenario speedup columns, and gate on regressions.
//!
//! `canzona sweep --json base.json` captures a baseline;
//! `canzona sweep --baseline base.json` re-runs the grid, prints a diff
//! table (baseline vs. current `total_s` / `optimizer_s`, speedup
//! columns where > 1.00x means the current code is faster), and exits
//! nonzero when any matched scenario's `total_s` regressed beyond the
//! threshold (`--regress-pct`, default 2%). The timing model is pure
//! f64 arithmetic over the census, so identical code diffs clean at a
//! 0% threshold — any drift is a real model change, which makes the
//! sweep artifact a CI regression gate (see `.github/workflows/ci.yml`).
//!
//! Rows are joined on the full scenario fingerprint (model, DP/TP/PP,
//! optimizer, strategy, α, `C_max`, and the fault/heterogeneity knobs,
//! which zero-default so pre-fault artifacts still join); baseline rows
//! with no counterpart
//! in the current grid (and vice versa) are counted, reported, and
//! excluded from the verdict.

use std::collections::BTreeMap;

use crate::bail;
use crate::sim::{Breakdown, Scenario};
use crate::util::error::Result;
use crate::util::json::Value;
use crate::util::table::{ratio, secs, Table};

use super::cache::CacheStats;

/// One matched scenario: baseline vs. current timings.
#[derive(Clone, Debug)]
pub struct DiffRow {
    /// Human-readable scenario fingerprint (the join key).
    pub key: String,
    /// Baseline end-to-end iteration time (s).
    pub base_total_s: f64,
    /// Current end-to-end iteration time (s).
    pub cur_total_s: f64,
    /// Baseline optimizer-step time (s).
    pub base_optimizer_s: f64,
    /// Current optimizer-step time (s).
    pub cur_optimizer_s: f64,
}

impl DiffRow {
    /// Baseline / current total time: > 1.0 means the current code is
    /// faster.
    pub fn total_speedup(&self) -> f64 {
        self.base_total_s / self.cur_total_s
    }

    /// Baseline / current optimizer-step time.
    pub fn optimizer_speedup(&self) -> f64 {
        self.base_optimizer_s / self.cur_optimizer_s
    }

    /// Did `total_s` regress beyond `threshold_pct` percent?
    pub fn regressed(&self, threshold_pct: f64) -> bool {
        self.cur_total_s > self.base_total_s * (1.0 + threshold_pct / 100.0)
    }
}

/// A sweep-vs-baseline comparison (see the module docs).
#[derive(Clone, Debug)]
pub struct SweepDiff {
    /// Matched scenarios, in current-sweep order.
    pub rows: Vec<DiffRow>,
    /// Current scenarios the baseline did not contain.
    pub missing_in_baseline: usize,
    /// Baseline scenarios the current sweep did not run.
    pub extra_in_baseline: usize,
    /// Regression threshold in percent (on `total_s`).
    pub threshold_pct: f64,
    /// The baseline artifact's `cache` block (plan-cache + timeline
    /// counters), every field zero-defaulted — artifacts written before
    /// a counter existed (or without a `cache` block at all) still
    /// join.
    pub base_cache: CacheStats,
}

/// The join key of one current-sweep scenario. Numeric fields are
/// formatted with `{}` (shortest round-trip), which is exactly how the
/// JSON artifact serializes them — so keys built from either side match
/// byte-for-byte.
pub fn scenario_key(s: &Scenario) -> String {
    format!(
        "{} dp{} tp{} pp{} mb{} {} x{} {} {} a={} c={} h={} fs={} fr={} mttf={} k={}",
        s.label,
        s.dp,
        s.tp,
        s.pp,
        s.micro_batches,
        s.schedule.label(),
        s.straggler,
        s.optim.label(),
        s.strategy.label(),
        s.alpha,
        match s.c_max_bytes {
            None => "none".to_string(),
            Some(b) => format!("{b}"),
        },
        s.hetero,
        s.fault_seed,
        match s.fail_rank {
            None => "none".to_string(),
            Some(f) => f.to_string(),
        },
        match s.mttf_s {
            None => "none".to_string(),
            Some(m) => format!("{m}"),
        },
        s.ckpt_interval,
    )
}

/// The join key of one baseline JSON row. Pipeline fields absent from
/// pre-timeline baselines fall back to their defaults (`mb1 1f1b x1`),
/// and fault fields absent from pre-fault baselines fall back to the
/// homogeneous never-failing defaults (`h=none fs=0 fr=none mttf=none
/// k=1`) — so old artifacts keep joining against default-grid sweeps.
fn row_key(v: &Value) -> Result<String> {
    let c_max = match v.get("c_max_bytes")? {
        Value::Null => "none".to_string(),
        other => format!("{}", other.as_f64()?),
    };
    let mb = match v.opt("micro_batches") {
        Some(x) => x.as_f64()?,
        None => 1.0,
    };
    let sched = match v.opt("schedule") {
        Some(x) => x.as_str()?.to_string(),
        None => "1f1b".to_string(),
    };
    let straggler = match v.opt("straggler") {
        Some(x) => x.as_f64()?,
        None => 1.0,
    };
    let hetero = match v.opt("hetero") {
        Some(x) => x.as_str()?.to_string(),
        None => "none".to_string(),
    };
    let fault_seed = match v.opt("fault_seed") {
        Some(x) => x.as_f64()?,
        None => 0.0,
    };
    // Nullable fields: `Null` (written by fault-aware sweeps with the
    // knob off) and absent (pre-fault artifacts) both mean "none".
    let fail = match v.opt("fail_rank") {
        Some(Value::Null) | None => "none".to_string(),
        Some(x) => x.as_str()?.to_string(),
    };
    let mttf = match v.opt("mttf_s") {
        Some(Value::Null) | None => "none".to_string(),
        Some(x) => format!("{}", x.as_f64()?),
    };
    let ckpt = match v.opt("ckpt_interval") {
        Some(x) => x.as_f64()?,
        None => 1.0,
    };
    Ok(format!(
        "{} dp{} tp{} pp{} mb{} {} x{} {} {} a={} c={} h={} fs={} fr={} mttf={} k={}",
        v.get("model")?.as_str()?,
        v.get("dp")?.as_f64()?,
        v.get("tp")?.as_f64()?,
        v.get("pp")?.as_f64()?,
        mb,
        sched,
        straggler,
        v.get("optim")?.as_str()?,
        v.get("strategy")?.as_str()?,
        v.get("alpha")?.as_f64()?,
        c_max,
        hetero,
        fault_seed,
        fail,
        mttf,
        ckpt,
    ))
}

impl SweepDiff {
    /// Join a baseline artifact (the `render_json` format) against a
    /// fresh sweep's scenarios/breakdowns.
    pub fn compare(
        baseline: &Value,
        scenarios: &[Scenario],
        breakdowns: &[Breakdown],
        threshold_pct: f64,
    ) -> Result<SweepDiff> {
        assert_eq!(scenarios.len(), breakdowns.len());
        let mut base: BTreeMap<String, (f64, f64)> = BTreeMap::new();
        for row in baseline.get("scenarios")?.as_arr()? {
            base.insert(
                row_key(row)?,
                (row.get("total_s")?.as_f64()?, row.get("optimizer_s")?.as_f64()?),
            );
        }
        let mut rows = Vec::with_capacity(scenarios.len());
        let mut missing = 0usize;
        for (s, b) in scenarios.iter().zip(breakdowns) {
            let key = scenario_key(s);
            match base.remove(&key) {
                Some((base_total_s, base_optimizer_s)) => rows.push(DiffRow {
                    key,
                    base_total_s,
                    cur_total_s: b.total_s,
                    base_optimizer_s,
                    cur_optimizer_s: b.optimizer_s,
                }),
                None => missing += 1,
            }
        }
        Ok(SweepDiff {
            rows,
            missing_in_baseline: missing,
            extra_in_baseline: base.len(),
            threshold_pct,
            base_cache: baseline
                .opt("cache")
                .map(CacheStats::from_json)
                .unwrap_or_default(),
        })
    }

    /// The matched rows whose `total_s` regressed beyond the threshold.
    pub fn regressions(&self) -> Vec<&DiffRow> {
        self.rows.iter().filter(|r| r.regressed(self.threshold_pct)).collect()
    }

    /// Render the diff as a Markdown table with speedup columns and a
    /// per-row verdict.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Sweep vs baseline — {} matched, {} new, {} dropped (threshold {}%)",
                self.rows.len(),
                self.missing_in_baseline,
                self.extra_in_baseline,
                self.threshold_pct,
            ),
            &["scenario", "base total", "total", "speedup",
              "base optim", "optim", "opt speedup", "verdict"],
        );
        for r in &self.rows {
            t.row(vec![
                r.key.clone(),
                secs(r.base_total_s),
                secs(r.cur_total_s),
                ratio(r.total_speedup()),
                secs(r.base_optimizer_s),
                secs(r.cur_optimizer_s),
                ratio(r.optimizer_speedup()),
                if r.regressed(self.threshold_pct) { "REGRESSED".into() } else { "ok".into() },
            ]);
        }
        t
    }

    /// The regression gate: `Err` (→ nonzero process exit) when any
    /// matched scenario regressed beyond the threshold, or when the
    /// baseline shares no scenarios with this sweep at all.
    pub fn verdict(&self) -> Result<()> {
        if self.rows.is_empty() {
            bail!(
                "baseline shares no scenarios with this sweep \
                 ({} baseline rows unmatched) — same grid flags required",
                self.extra_in_baseline,
            );
        }
        let bad = self.regressions();
        if !bad.is_empty() {
            let worst = bad
                .iter()
                .map(|r| r.cur_total_s / r.base_total_s)
                .fold(0.0f64, f64::max);
            bail!(
                "sweep regression: {}/{} scenarios slower than baseline by > {}% \
                 (worst {:.2}x); first: {}",
                bad.len(),
                self.rows.len(),
                self.threshold_pct,
                worst,
                bad[0].key,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::{CostMetric, OptimKind};
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;
    use crate::sweep::{render_json, SweepEngine, SweepGrid};

    fn grid() -> SweepGrid {
        SweepGrid {
            models: vec![Qwen3Size::S1_7B],
            dp: vec![4, 8],
            tp: vec![2],
            pp: vec![1],
            micro_batches: vec![1],
            schedules: vec![crate::sim::PipelineSchedule::OneFOneB],
            stragglers: vec![1.0],
            optims: vec![OptimKind::Muon],
            strategies: vec![DpStrategy::Asc, DpStrategy::LbAsc],
            alphas: vec![1.0],
            c_max_mb: vec![Some(256.0)],
            heteros: vec![crate::sim::HeteroSpec::None],
            fail_ranks: vec![None],
            mttfs: vec![None],
            ckpt_intervals: vec![1],
            metric: CostMetric::Numel,
            fault_seed: 0,
        }
    }

    #[test]
    fn self_diff_is_clean_at_zero_threshold() {
        let engine = SweepEngine::new(2);
        let (scens, res) = engine.run_grid(&grid());
        let baseline = render_json(&scens, &res);
        let diff = SweepDiff::compare(&baseline, &scens, &res, 0.0).unwrap();
        assert_eq!(diff.rows.len(), scens.len());
        assert_eq!(diff.missing_in_baseline, 0);
        assert_eq!(diff.extra_in_baseline, 0);
        for r in &diff.rows {
            assert_eq!(r.total_speedup(), 1.0, "{}", r.key);
        }
        diff.verdict().unwrap();
        assert!(diff.table().render().contains("ok"));
    }

    #[test]
    fn keys_survive_json_round_trip() {
        // The artifact is re-parsed from its serialized bytes — numeric
        // formatting must agree between both key builders.
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        let reparsed = Value::parse(&render_json(&scens, &res).to_string()).unwrap();
        let diff = SweepDiff::compare(&reparsed, &scens, &res, 0.0).unwrap();
        assert_eq!(diff.rows.len(), scens.len());
        assert_eq!(diff.missing_in_baseline + diff.extra_in_baseline, 0);
    }

    #[test]
    fn pre_timeline_baselines_still_join() {
        // Artifacts written before the timeline engine lack the
        // micro_batches/schedule/straggler fields; they must still join
        // against a default-grid sweep via the fallback defaults.
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        let mut baseline = render_json(&scens, &res);
        if let Value::Obj(m) = &mut baseline {
            let Some(Value::Arr(rows)) = m.get_mut("scenarios") else { panic!() };
            for row in rows {
                if let Value::Obj(r) = row {
                    r.remove("micro_batches");
                    r.remove("schedule");
                    r.remove("straggler");
                    r.remove("bubble_s");
                }
            }
        }
        let diff = SweepDiff::compare(&baseline, &scens, &res, 0.0).unwrap();
        assert_eq!(diff.rows.len(), scens.len());
        assert_eq!(diff.missing_in_baseline + diff.extra_in_baseline, 0);
        diff.verdict().unwrap();
    }

    #[test]
    fn pre_fault_baselines_still_join() {
        // Artifacts written before the elastic fault model lack the
        // hetero/fault_seed/fail_rank/mttf_s/ckpt_interval/recovery_s
        // fields; they must still join against a fault-free sweep via
        // the zero-defaults in `row_key`.
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        let mut baseline = render_json(&scens, &res);
        if let Value::Obj(m) = &mut baseline {
            let Some(Value::Arr(rows)) = m.get_mut("scenarios") else { panic!() };
            for row in rows {
                if let Value::Obj(r) = row {
                    r.remove("hetero");
                    r.remove("fault_seed");
                    r.remove("fail_rank");
                    r.remove("mttf_s");
                    r.remove("ckpt_interval");
                    r.remove("recovery_s");
                }
            }
        }
        let diff = SweepDiff::compare(&baseline, &scens, &res, 0.0).unwrap();
        assert_eq!(diff.rows.len(), scens.len());
        assert_eq!(diff.missing_in_baseline + diff.extra_in_baseline, 0);
        diff.verdict().unwrap();
    }

    #[test]
    fn faulted_rows_join_only_their_own_kind() {
        // A faulted scenario must never silently match a fault-free
        // baseline row of the same shape — the fingerprints differ.
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        let baseline = render_json(&scens, &res);
        let mut faulted = grid();
        faulted.heteros = vec![crate::sim::HeteroSpec::parse("slow:1:1.5").unwrap()];
        let (scens2, res2) = engine.run_grid(&faulted);
        let diff = SweepDiff::compare(&baseline, &scens2, &res2, 0.0).unwrap();
        assert!(diff.rows.is_empty());
        assert_eq!(diff.missing_in_baseline, scens2.len());
        // And a faulted self-join is exact.
        let fb = render_json(&scens2, &res2);
        let self_diff = SweepDiff::compare(&fb, &scens2, &res2, 0.0).unwrap();
        assert_eq!(self_diff.rows.len(), scens2.len());
        self_diff.verdict().unwrap();
    }

    #[test]
    fn baseline_cache_counters_join_with_defaults() {
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        // No cache block at all (render_json never adds one; the CLI
        // does) -> all-zero counters, join unaffected.
        let bare = render_json(&scens, &res);
        let diff = SweepDiff::compare(&bare, &scens, &res, 0.0).unwrap();
        assert_eq!(diff.base_cache, CacheStats::default());
        diff.verdict().unwrap();
        // A cache block with only the pre-timeline keys: old counters
        // surface, new ones default to zero.
        let mut with_cache = render_json(&scens, &res);
        if let Value::Obj(m) = &mut with_cache {
            m.insert(
                "cache".into(),
                Value::obj(vec![("hits", Value::num(7.0)), ("solves", Value::num(3.0))]),
            );
        }
        let diff = SweepDiff::compare(&with_cache, &scens, &res, 0.0).unwrap();
        assert_eq!((diff.base_cache.hits, diff.base_cache.solves), (7, 3));
        assert_eq!(diff.base_cache.timeline_tasks, 0);
        diff.verdict().unwrap();
    }

    #[test]
    fn injected_regression_trips_the_gate() {
        let engine = SweepEngine::new(2);
        let (scens, res) = engine.run_grid(&grid());
        let mut baseline = render_json(&scens, &res);
        // Pretend the baseline was 20% faster on one scenario: the
        // current run now reads as a regression.
        if let Value::Obj(m) = &mut baseline {
            let Some(Value::Arr(rows)) = m.get_mut("scenarios") else { panic!() };
            let Some(Value::Obj(row)) = rows.first_mut() else { panic!() };
            let t = row.get("total_s").unwrap().as_f64().unwrap();
            row.insert("total_s".into(), Value::num(t * 0.8));
        }
        let diff = SweepDiff::compare(&baseline, &scens, &res, 2.0).unwrap();
        assert_eq!(diff.regressions().len(), 1);
        let err = diff.verdict().unwrap_err().to_string();
        assert!(err.contains("regression"), "{err}");
        assert!(diff.table().render().contains("REGRESSED"));
        // A generous threshold forgives it.
        let lax = SweepDiff::compare(&baseline, &scens, &res, 50.0).unwrap();
        lax.verdict().unwrap();
    }

    #[test]
    fn disjoint_grids_are_reported_not_matched() {
        let engine = SweepEngine::new(1);
        let (scens, res) = engine.run_grid(&grid());
        let baseline = render_json(&scens, &res);
        let mut other = grid();
        other.tp = vec![4]; // disjoint fingerprints
        let (scens2, res2) = engine.run_grid(&other);
        let diff = SweepDiff::compare(&baseline, &scens2, &res2, 2.0).unwrap();
        assert!(diff.rows.is_empty());
        assert_eq!(diff.missing_in_baseline, scens2.len());
        assert_eq!(diff.extra_in_baseline, scens.len());
        assert!(diff.verdict().is_err(), "no overlap must fail loudly");
    }
}
