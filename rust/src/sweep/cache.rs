//! Plan cache: memoized `DpPlan` / `TpPlan` artifacts keyed by scenario
//! fingerprint.
//!
//! The offline planner (paper Appendix D.1) is deterministic and pure in
//! the scenario, so its outputs are cacheable across `simulate_iteration`
//! calls. Keys capture exactly the inputs a plan depends on:
//!
//! * **DP plans** — model (census), PP stage, grid, strategy, α, cost
//!   metric, bucket size. The optimizer enters the key only when the
//!   metric is optimizer-dependent: under the paper-default `Numel`
//!   proxy, every optimizer weighs a tensor identically, so e.g. the
//!   AdamW anchors of Fig. 7 share DP plans with the Muon runs.
//! * **TP plans** — additionally the DP rank (host-task sets differ per
//!   rank), `C_max`, and always the optimizer (task FLOPs/state models
//!   are optimizer-specific).
//!
//! The fingerprint assumes `Scenario::census` is derived from the model
//! label (true for every constructor); hardware profiles are deliberately
//! excluded — plans are hardware-independent.
//!
//! Concurrency: maps sit behind mutexes; a solve runs *outside* the lock,
//! so two threads racing on one key may both solve — the algorithms are
//! deterministic, so either result is structurally identical and the
//! first insert wins. Hit/solve counters are exact (a "solve" increments
//! only when a closure actually ran), which is what the cache-statistics
//! assertions in `tests/sweep_determinism.rs` rely on.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::optim::{CostMetric, OptimKind};
use crate::partition::{DpPlan, DpStrategy, LayerwisePlan};
use crate::schedule::microgroup::TpPlan;
use crate::sim::Scenario;

/// Fingerprint of one DP-plane planning problem.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct DpKey {
    pub model: String,
    pub stage: usize,
    pub pp: usize,
    pub dp: usize,
    pub tp: usize,
    pub strategy: DpStrategy,
    /// `None` under optimizer-agnostic metrics (Numel).
    pub optim: Option<OptimKind>,
    pub metric: CostMetric,
    /// `f64::to_bits` of α (0 for strategies that ignore it).
    pub alpha_bits: u64,
    pub bucket_elems: usize,
}

impl DpKey {
    pub fn for_scenario(s: &Scenario, stage: usize) -> DpKey {
        DpKey {
            model: s.label.clone(),
            stage,
            pp: s.pp,
            dp: s.dp,
            tp: s.tp,
            strategy: s.strategy,
            optim: match s.metric {
                CostMetric::Numel => None,
                _ => Some(s.optim),
            },
            metric: s.metric,
            alpha_bits: if s.strategy == DpStrategy::LbAsc { s.alpha.to_bits() } else { 0 },
            bucket_elems: s.bucket_elems,
        }
    }
}

/// Fingerprint of one TP-plane scheduling problem (per DP rank).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TpKey {
    pub dp_key: DpKey,
    pub rank: usize,
    /// `f64::to_bits` of `C_max` in bytes; `None` = No-Fuse.
    pub c_max_bits: Option<u64>,
    /// Task costs always depend on the optimizer.
    pub optim: OptimKind,
}

impl TpKey {
    pub fn for_scenario(s: &Scenario, stage: usize, rank: usize) -> TpKey {
        TpKey {
            dp_key: DpKey::for_scenario(s, stage),
            rank,
            c_max_bits: s.c_max_bytes.map(f64::to_bits),
            optim: s.optim,
        }
    }
}

/// Cache hit/solve statistics snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    /// Number of solver closures actually executed (cold paths).
    pub solves: u64,
}

/// Thread-safe memoization of partition and schedule artifacts.
#[derive(Default)]
pub struct PlanCache {
    dp: Mutex<HashMap<DpKey, Arc<DpPlan>>>,
    layerwise: Mutex<HashMap<DpKey, Arc<LayerwisePlan>>>,
    tp: Mutex<HashMap<TpKey, Arc<TpPlan>>>,
    hits: AtomicU64,
    solves: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    fn get_or_solve<K, V, F>(
        &self,
        map: &Mutex<HashMap<K, Arc<V>>>,
        key: &K,
        solve: F,
    ) -> Arc<V>
    where
        K: Clone + std::hash::Hash + Eq,
        F: FnOnce() -> V,
    {
        if let Some(hit) = map.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit.clone();
        }
        self.solves.fetch_add(1, Ordering::Relaxed);
        let solved = Arc::new(solve());
        map.lock().unwrap().entry(key.clone()).or_insert(solved).clone()
    }

    /// Memoized DP partition plan (α-balanced / naive-atomic).
    pub fn dp_plan<F: FnOnce() -> DpPlan>(&self, key: &DpKey, solve: F) -> Arc<DpPlan> {
        self.get_or_solve(&self.dp, key, solve)
    }

    /// Memoized NV-layerwise ownership plan.
    pub fn layerwise_plan<F: FnOnce() -> LayerwisePlan>(
        &self,
        key: &DpKey,
        solve: F,
    ) -> Arc<LayerwisePlan> {
        self.get_or_solve(&self.layerwise, key, solve)
    }

    /// Memoized TP micro-group plan for one DP rank.
    pub fn tp_plan<F: FnOnce() -> TpPlan>(&self, key: &TpKey, solve: F) -> Arc<TpPlan> {
        self.get_or_solve(&self.tp, key, solve)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            solves: self.solves.load(Ordering::Relaxed),
        }
    }

    /// Number of cached plans across all maps.
    pub fn len(&self) -> usize {
        self.dp.lock().unwrap().len()
            + self.layerwise.lock().unwrap().len()
            + self.tp.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&self) {
        self.dp.lock().unwrap().clear();
        self.layerwise.lock().unwrap().clear();
        self.tp.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;

    fn scen() -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    #[test]
    fn keys_normalize_optimizer_under_numel() {
        let a = DpKey::for_scenario(&scen(), 0);
        let b = DpKey::for_scenario(&scen().with_optim(OptimKind::Shampoo), 0);
        assert_eq!(a, b, "Numel metric must be optimizer-agnostic");
        let c = DpKey::for_scenario(
            &scen().with_metric(CostMetric::Flops), 0);
        let d = DpKey::for_scenario(
            &scen().with_metric(CostMetric::Flops).with_optim(OptimKind::Shampoo), 0);
        assert_ne!(c, d, "Flops metric is optimizer-specific");
    }

    #[test]
    fn tp_keys_always_carry_optimizer() {
        let a = TpKey::for_scenario(&scen(), 0, 3);
        let b = TpKey::for_scenario(&scen().with_optim(OptimKind::Shampoo), 0, 3);
        assert_ne!(a, b);
        assert_ne!(a, TpKey::for_scenario(&scen(), 0, 4));
    }

    #[test]
    fn alpha_ignored_for_non_lb_strategies() {
        let asc = scen().with_strategy(DpStrategy::Asc);
        let a = DpKey::for_scenario(&asc.clone().with_alpha(0.25), 0);
        let b = DpKey::for_scenario(&asc.with_alpha(0.75), 0);
        assert_eq!(a, b);
    }

    #[test]
    fn c_max_outside_dp_key() {
        let a = DpKey::for_scenario(&scen().with_c_max(None), 0);
        let b = DpKey::for_scenario(&scen().with_c_max(Some(64e6)), 0);
        assert_eq!(a, b, "C_max is a TP-plane knob");
    }

    #[test]
    fn hit_skips_solve() {
        let cache = PlanCache::new();
        let key = DpKey::for_scenario(&scen(), 0);
        let mk = || DpPlan {
            ranks: 1,
            cuts: vec![vec![0, 10]],
            atomicity: crate::partition::Atomicity::None,
        };
        let first = cache.dp_plan(&key, mk);
        assert_eq!(cache.stats(), CacheStats { hits: 0, solves: 1 });
        let second = cache.dp_plan(&key, || panic!("must not re-solve"));
        assert_eq!(cache.stats(), CacheStats { hits: 1, solves: 1 });
        assert_eq!(first.cuts, second.cuts);
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }
}
