//! Plan cache: memoized `DpPlan` / `TpPlan` / `LayerwisePlan` /
//! `StageTable` artifacts keyed by scenario fingerprint, bounded by an
//! LRU byte budget.
//!
//! The offline planner (paper Appendix D.1) is deterministic and pure in
//! the scenario, so its outputs are cacheable across `simulate_iteration`
//! calls. Keys capture exactly the inputs a plan depends on:
//!
//! * **DP plans** — model (census), PP stage, grid, strategy, α, cost
//!   metric, bucket size. The optimizer enters the key only when the
//!   metric is optimizer-dependent: under the paper-default `Numel`
//!   proxy, every optimizer weighs a tensor identically, so e.g. the
//!   AdamW anchors of Fig. 7 share DP plans with the Muon runs.
//! * **TP plans** — additionally the DP rank (host-task sets differ per
//!   rank), `C_max`, and always the optimizer (task FLOPs/state models
//!   are optimizer-specific).
//! * **Stage tables** ([`crate::sim::iteration::StageTable`]) — the
//!   hoisted per-stage census/geometry/task tables the warm simulation
//!   path reads; keyed like a DP plan plus the optimizer (task costs),
//!   but *not* `C_max` (fusion only shapes TP plans).
//!
//! Keys are flat `Copy` structs (the model enters as [`Qwen3Size`], not
//! a label string), so building a key on the warm path allocates
//! nothing. The fingerprint assumes `Scenario::census` is derived from
//! `Scenario::size` (true for every constructor); hardware profiles are
//! deliberately excluded — plans are hardware-independent.
//!
//! # Stage canonicalization (reuse across PP stages)
//!
//! The PP stage index enters every key through [`DpKey::stage`] — but
//! it is *canonicalized* by [`canonical_stage`] first: interior stages
//! (no embedding, no head) that host the same number of transformer
//! layers have identical shape censuses up to layer numbering, so their
//! DP plans, TP plans and stage tables are structurally identical. All
//! such stages share the first equivalent stage's index, which turns a
//! `pp = 8` sweep's eight stage solves into three (first, interior,
//! last) — plan/stage-table reuse across stages for free.
//!
//! # Byte budget and eviction
//!
//! Without a bound, per-rank `TpPlan`s dominate (~tens of MB for a
//! DP=128 family sweep) and a long-lived engine grows forever. Every
//! entry is weighed on insert (shallow struct size + `heap_bytes()` of
//! the plan + key/entry/LRU-node overhead); when the resident total
//! exceeds the budget, least-recently-used entries are evicted — across
//! all four maps — until it fits. Recency is tracked by an intrusive
//! doubly-linked list threading all four maps (each entry holds its
//! node index): a hit moves the node to the front in O(1) and an
//! eviction pops the global tail in O(1), replacing the old
//! O(entries) min-tick scan per eviction (a ROADMAP item — the scan was
//! fine at hundreds of plans, not at the ~10⁵ a family × DP sweep can
//! reach). A solved plan whose weight alone exceeds the budget is
//! handed to the caller *uncached*, so the resident total never exceeds
//! the budget. The default budget is [`DEFAULT_BUDGET_BYTES`];
//! `CANZONA_CACHE_BUDGET_MB` (0 = unbounded) overrides it process-wide
//! and `canzona sweep --cache-budget-mb` per-invocation. Eviction is
//! semantically invisible: an evicted key is simply re-solved on next
//! use, and the solvers are deterministic.
//!
//! # Two-level read path (lock-free warm reads)
//!
//! The shared mutex above is the **L2**. On top of it every thread owns
//! an **L1**: a `thread_local!` map of `Arc`-cloned artifacts populated
//! on L2 hits/inserts. A warm lookup (the steady state of a family
//! sweep, where every worker reads the same few hundred hot plans and
//! stage tables thousands of times) is served entirely from the L1 —
//! one atomic epoch load, one hash probe, one `Arc` clone, **no lock**
//! — so N sweep workers no longer serialize on the cache mutex.
//!
//! Three rules keep the two levels coherent with the L2's contracts:
//!
//! * **Epoch invalidation.** The cache carries a shared epoch counter,
//!   bumped whenever an eviction (or `clear`) removes entries. Each L1
//!   records the epoch it was filled under and wholesale-clears itself
//!   when the counter moves, so an L1 can never pin evicted artifacts
//!   past the next access, and the byte budget stays a property of the
//!   L2 ledger alone. For threads that might *not* access the cache
//!   again — a pool worker parking after a batch — the same check runs
//!   as `util::pool`'s participant-retire hook (`l1_park`, via a
//!   `Weak` handle to the epoch counter): stale or orphaned L1s are
//!   released at batch end, warm ones survive to the next batch.
//!   (Values are immutable and solvers deterministic, so even a read
//!   that races an eviction returns bytes identical to a fresh
//!   re-solve — `tests/cache_coherence.rs` pins this under randomized
//!   eviction schedules.)
//! * **Batched recency touches.** An L1 hit cannot move the entry's LRU
//!   node (that needs the lock), so it records the touch in a
//!   per-thread buffer instead; the buffer is flushed to the shared
//!   clock — in recorded order, validated by key so stale touches are
//!   skipped — whenever the thread next takes the L2 lock (any miss)
//!   and synchronously when full. Since evictions only happen at
//!   inserts, i.e. misses, every touch a thread recorded is applied
//!   before any eviction it could influence: for a thread interacting
//!   with one L1-enabled cache (every engine/sweep workload),
//!   single-threaded eviction order is **bit-identical** to the old
//!   always-locked path (the shadow-LRU differential in
//!   `tests/cache_lru.rs` runs unchanged). The one exception is a
//!   thread *alternating between two L1-enabled caches*: rebinding
//!   drops the first cache's un-flushed touches (there is no cache
//!   reference left to flush into), so its recency can lag by up to
//!   one hit-streak — values and the byte budget are unaffected
//!   (evicted keys re-solve deterministically), only which key evicts
//!   first may differ from the always-locked order.
//! * **Uncached stays uncached.** Oversize artifacts that bypass the L2
//!   never enter an L1, so the "re-solved on every use" contract holds.
//!
//! The L1 belongs to one cache at a time (keyed by a unique cache id):
//! touching a different `PlanCache` from the same thread clears it.
//! Counters: an L1 hit increments `hits` (it *is* a cache hit) and the
//! separate `l1_hits` diagnostic; `PlanCache::with_options(.., false)`
//! disables the L1 entirely (every read takes the mutex) for A/B
//! benchmarking of the read paths.
//!
//! Concurrency: one mutex guards all maps plus the LRU list and byte
//! ledger; a solve runs *outside* the lock, so two threads racing on one
//! key may both solve — the algorithms are deterministic, so either
//! result is structurally identical and the first insert wins. Hit/solve
//! counters are exact (a "solve" increments only when a closure actually
//! ran), which is what the cache-statistics assertions in
//! `tests/sweep_determinism.rs` rely on.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::Qwen3Size;
use crate::partition::{DpPlan, DpStrategy, LayerwisePlan};
use crate::schedule::microgroup::TpPlan;
use crate::sim::iteration::StageTable;
use crate::sim::Scenario;
use crate::util::json::Value;

/// Default in-memory budget for cached plans: 256 MiB. Override with
/// `CANZONA_CACHE_BUDGET_MB` (0 disables the bound) or
/// `canzona sweep --cache-budget-mb`.
pub const DEFAULT_BUDGET_BYTES: usize = 256 * 1024 * 1024;

/// Convert a budget expressed in MiB (the `CANZONA_CACHE_BUDGET_MB` /
/// `--cache-budget-mb` unit — `256` is exactly the default) to bytes.
/// `0` and negative values mean unbounded; non-finite values (NaN/inf)
/// are rejected with `None` so a typo can never silently disable the
/// bound.
pub fn budget_mb_to_bytes(mb: f64) -> Option<usize> {
    if !mb.is_finite() {
        return None;
    }
    Some(if mb <= 0.0 { 0 } else { (mb * (1 << 20) as f64) as usize })
}

/// The process-wide budget: `CANZONA_CACHE_BUDGET_MB` if set and valid
/// (MiB, via [`budget_mb_to_bytes`]), else [`DEFAULT_BUDGET_BYTES`] —
/// unparseable or non-finite values fall back to the (bounded) default,
/// never to unbounded.
pub fn budget_from_env() -> usize {
    std::env::var("CANZONA_CACHE_BUDGET_MB")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .and_then(budget_mb_to_bytes)
        .unwrap_or(DEFAULT_BUDGET_BYTES)
}

/// The canonical form of PP stage `stage`: itself for the first and
/// last stages (embedding / head parameters make them unique), else the
/// first *interior* stage hosting the same number of transformer layers
/// — whose census is shape-identical, so every derived plan and table
/// can be shared (see the module docs). Allocation-free, O(pp): layer
/// counts come from the split rule shared with `stage_census`
/// ([`crate::sim::iteration::stage_layer_count`]) over the cached
/// [`Scenario::n_layers`].
pub fn canonical_stage(s: &Scenario, stage: usize) -> usize {
    let pp = s.pp.max(1);
    let stage = stage.min(pp - 1);
    if stage == 0 || stage == pp - 1 {
        return stage;
    }
    let count = |si| crate::sim::iteration::stage_layer_count(s.n_layers, pp, si);
    let c = count(stage);
    for sj in 1..stage {
        if count(sj) == c {
            return sj;
        }
    }
    stage
}

/// Fingerprint of one DP-plane planning problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DpKey {
    /// Model family member (stands in for the census).
    pub model: Qwen3Size,
    /// Canonical PP stage index (see [`canonical_stage`]).
    pub stage: usize,
    /// PP group size.
    pub pp: usize,
    /// DP group size.
    pub dp: usize,
    /// TP group size (shard shapes enter the stage census).
    pub tp: usize,
    /// DP strategy.
    pub strategy: DpStrategy,
    /// `None` under optimizer-agnostic metrics (Numel).
    pub optim: Option<OptimKind>,
    /// Balancing cost metric.
    pub metric: CostMetric,
    /// `f64::to_bits` of α (0 for strategies that ignore it).
    pub alpha_bits: u64,
    /// Flat-buffer bucket size (elements).
    pub bucket_elems: usize,
}

impl DpKey {
    /// The DP-plane fingerprint of `s` at PP stage `stage` (stage index
    /// canonicalized — shape-identical interior stages share keys).
    pub fn for_scenario(s: &Scenario, stage: usize) -> DpKey {
        DpKey {
            model: s.size,
            stage: canonical_stage(s, stage),
            pp: s.pp,
            dp: s.dp,
            tp: s.tp,
            strategy: s.strategy,
            optim: match s.metric {
                CostMetric::Numel => None,
                _ => Some(s.optim),
            },
            metric: s.metric,
            alpha_bits: if s.strategy == DpStrategy::LbAsc { s.alpha.to_bits() } else { 0 },
            bucket_elems: s.bucket_elems,
        }
    }
}

/// Fingerprint of one TP-plane scheduling problem (per DP rank).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TpKey {
    /// The enclosing DP-plane fingerprint.
    pub dp_key: DpKey,
    /// DP rank (host-task sets differ per rank).
    pub rank: usize,
    /// `f64::to_bits` of `C_max` in bytes; `None` = No-Fuse.
    pub c_max_bits: Option<u64>,
    /// Task costs always depend on the optimizer.
    pub optim: OptimKind,
}

impl TpKey {
    /// The TP-plane fingerprint of `s` at stage `stage`, DP rank `rank`.
    pub fn for_scenario(s: &Scenario, stage: usize, rank: usize) -> TpKey {
        TpKey {
            dp_key: DpKey::for_scenario(s, stage),
            rank,
            c_max_bits: s.c_max_bytes.map(f64::to_bits),
            optim: s.optim,
        }
    }
}

/// Fingerprint of one hoisted per-stage table
/// ([`crate::sim::iteration::StageTable`]): a DP-plane fingerprint plus
/// the optimizer (the task FLOPs/state tables are optimizer-specific).
/// `C_max` is excluded — fusion only shapes TP plans.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StageKey {
    /// The enclosing DP-plane fingerprint.
    pub dp_key: DpKey,
    /// The optimizer whose cost model fills the task tables.
    pub optim: OptimKind,
}

impl StageKey {
    /// The stage-table fingerprint of `s` at PP stage `stage`.
    pub fn for_scenario(s: &Scenario, stage: usize) -> StageKey {
        StageKey { dp_key: DpKey::for_scenario(s, stage), optim: s.optim }
    }
}

/// Cache statistics snapshot — the plan-cache counters plus the warm
/// timeline-path counters the simulator reports through its cache
/// handle (task throughput, scratch reuse, schedule-order interning).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache (both levels; L1 hits included).
    pub hits: u64,
    /// The subset of `hits` served lock-free from a per-thread L1 (see
    /// the module docs). Like the scratch/order counters this is a
    /// per-thread diagnostic: it varies with `--threads` and
    /// work-stealing order while the sweep rows stay byte-identical.
    pub l1_hits: u64,
    /// Number of solver closures actually executed (cold paths).
    pub solves: u64,
    /// Entries evicted to respect the byte budget.
    pub evictions: u64,
    /// Bytes currently resident across all maps.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes`.
    pub peak_bytes: u64,
    /// The configured budget (0 = unbounded).
    pub budget_bytes: u64,
    /// Tasks scheduled by the event-driven timeline engine, summed over
    /// every playback evaluated against this cache.
    pub timeline_tasks: u64,
    /// Timeline playbacks that reused an already-warm per-worker
    /// `SimScratch` (vs. first use on a thread). Scratch warmth is
    /// per *thread*, not per cache or per batch: the pool's workers are
    /// persistent, so a scratch warmed by an earlier batch — or an
    /// earlier engine — on the same thread counts as a reuse for the
    /// next one (the counter describes the allocation behavior the
    /// sweep actually saw, which is what the zero-alloc contract cares
    /// about; cross-batch reuse is pinned by `tests/pool_lifecycle.rs`).
    pub scratch_reuses: u64,
    /// Pipeline schedule-order tables served from a per-worker interned
    /// cache instead of being re-derived (per-thread, like
    /// `scratch_reuses`).
    pub order_hits: u64,
    /// Scenarios evaluated through the batched SoA closed-form tier
    /// ([`crate::sim::batch`]) — one per lane, summed over every batch
    /// run against this cache. `0` means every leaf took the scalar or
    /// timeline arm (e.g. `--no-batch`, or no shared-fingerprint
    /// groups). Row bytes are identical either way; this is the
    /// diagnostic that says which arm did the work.
    pub batched_evals: u64,
    /// Scenarios evaluated through the batched timeline tier — lanes
    /// replayed over a cached schedule tape ([`crate::sim::batch`]
    /// again, pp>1 / micro-batched / straggler arm), one per lane,
    /// summed over every batch run against this cache. Split from
    /// `batched_evals` so the summary line can say which *arm* the
    /// batch tier accelerated; the same byte-identity caveats apply.
    pub batched_timeline_evals: u64,
}

impl CacheStats {
    /// JSON form for sweep artifacts (stable key order). Note the
    /// counters are *diagnostics*, not pinned outputs: `hits`/`solves`
    /// can vary under solve races, and the per-thread
    /// `scratch_reuses`/`order_hits` vary with `--threads` and
    /// work-stealing order — which is why `render_json` (the
    /// byte-determinism surface) excludes this block and only the CLI
    /// attaches it to `--json` artifacts.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("hits", Value::num(self.hits as f64)),
            ("l1_hits", Value::num(self.l1_hits as f64)),
            ("solves", Value::num(self.solves as f64)),
            ("evictions", Value::num(self.evictions as f64)),
            ("resident_bytes", Value::num(self.resident_bytes as f64)),
            ("peak_bytes", Value::num(self.peak_bytes as f64)),
            ("budget_bytes", Value::num(self.budget_bytes as f64)),
            ("timeline_tasks", Value::num(self.timeline_tasks as f64)),
            ("scratch_reuses", Value::num(self.scratch_reuses as f64)),
            ("order_hits", Value::num(self.order_hits as f64)),
            ("batched_evals", Value::num(self.batched_evals as f64)),
            (
                "batched_timeline_evals",
                Value::num(self.batched_timeline_evals as f64),
            ),
        ])
    }

    /// Parse a sweep artifact's `cache` block. Every counter defaults
    /// to zero when absent, so artifacts written before a counter
    /// existed (e.g. pre-timeline `--json` baselines) still load — the
    /// tolerance `sweep --baseline` relies on.
    pub fn from_json(v: &Value) -> CacheStats {
        let num = |k: &str| {
            v.opt(k)
                .and_then(|x| x.as_f64().ok())
                .map(|x| x as u64)
                .unwrap_or(0)
        };
        CacheStats {
            hits: num("hits"),
            l1_hits: num("l1_hits"),
            solves: num("solves"),
            evictions: num("evictions"),
            resident_bytes: num("resident_bytes"),
            peak_bytes: num("peak_bytes"),
            budget_bytes: num("budget_bytes"),
            timeline_tasks: num("timeline_tasks"),
            scratch_reuses: num("scratch_reuses"),
            order_hits: num("order_hits"),
            batched_evals: num("batched_evals"),
            batched_timeline_evals: num("batched_timeline_evals"),
        }
    }
}

/// One cached artifact plus its intrusive-LRU node index.
struct Entry<V> {
    value: Arc<V>,
    bytes: usize,
    node: u32,
}

/// Which map a cached artifact lives in, plus its key — the LRU node's
/// payload, so a popped tail can be resolved back to its map entry.
#[derive(Clone, Copy, Debug)]
enum AnyKey {
    Dp(DpKey),
    Layerwise(DpKey),
    Tp(TpKey),
    Stage(StageKey),
}

const NIL: u32 = u32::MAX;

struct LruNode {
    key: AnyKey,
    prev: u32,
    next: u32,
}

/// Intrusive doubly-linked recency list threading all four maps: O(1)
/// front-move on a hit, O(1) pop of the global LRU on eviction. Node
/// slots are recycled through a free list, so the slab never grows past
/// the high-water entry count.
struct LruList {
    nodes: Vec<LruNode>,
    head: u32,
    tail: u32,
    free: Vec<u32>,
}

impl Default for LruList {
    fn default() -> LruList {
        LruList { nodes: Vec::new(), head: NIL, tail: NIL, free: Vec::new() }
    }
}

impl LruList {
    /// Insert a fresh node at the MRU position; returns its slot index.
    fn push_front(&mut self, key: AnyKey) -> u32 {
        let node = LruNode { key, prev: NIL, next: self.head };
        let id = match self.free.pop() {
            Some(id) => {
                self.nodes[id as usize] = node;
                id
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        if self.head != NIL {
            self.nodes[self.head as usize].prev = id;
        }
        self.head = id;
        if self.tail == NIL {
            self.tail = id;
        }
        id
    }

    /// Detach `id` from the list (slot not recycled — caller relinks or
    /// frees it).
    fn unlink(&mut self, id: u32) {
        let (prev, next) = {
            let n = &self.nodes[id as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Move an existing node to the MRU position (a cache hit). A
    /// single-element list returns at the `head == id` check, so after
    /// `unlink` the list is guaranteed non-empty.
    fn touch(&mut self, id: u32) {
        if self.head == id {
            return;
        }
        self.unlink(id);
        let old_head = self.head;
        self.nodes[id as usize].prev = NIL;
        self.nodes[id as usize].next = old_head;
        self.nodes[old_head as usize].prev = id;
        self.head = id;
    }

    /// Pop the LRU node, recycling its slot; `None` when empty.
    fn pop_tail(&mut self) -> Option<AnyKey> {
        if self.tail == NIL {
            return None;
        }
        let id = self.tail;
        self.unlink(id);
        self.free.push(id);
        Some(self.nodes[id as usize].key)
    }

    fn clear(&mut self) {
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

/// All four maps plus the shared intrusive LRU list and byte ledger —
/// guarded by one mutex so cross-map eviction is race-free.
#[derive(Default)]
struct Maps {
    dp: HashMap<DpKey, Entry<DpPlan>>,
    layerwise: HashMap<DpKey, Entry<LayerwisePlan>>,
    tp: HashMap<TpKey, Entry<TpPlan>>,
    stage: HashMap<StageKey, Entry<StageTable>>,
    lru: LruList,
    bytes: usize,
}

impl Maps {
    fn len(&self) -> usize {
        self.dp.len() + self.layerwise.len() + self.tp.len() + self.stage.len()
    }

    /// Evict the globally least-recently-used entry; returns the bytes
    /// freed (0 when every map is empty). O(1): pop the list tail and
    /// remove the map entry it names. Every resident entry holds exactly
    /// one list node and vice versa, so the removal cannot miss — a
    /// desync is a bug worth failing loudly over, not papering over.
    fn evict_lru(&mut self) -> usize {
        let Some(key) = self.lru.pop_tail() else { return 0 };
        let freed = match key {
            AnyKey::Dp(k) => self.dp.remove(&k).map(|e| e.bytes),
            AnyKey::Layerwise(k) => self.layerwise.remove(&k).map(|e| e.bytes),
            AnyKey::Tp(k) => self.tp.remove(&k).map(|e| e.bytes),
            AnyKey::Stage(k) => self.stage.remove(&k).map(|e| e.bytes),
        }
        .expect("LRU tail names a live cache entry");
        self.bytes -= freed.min(self.bytes);
        freed
    }
}

/// Recency touches an L1 can batch before it must flush to the shared
/// LRU clock. The buffer is pre-reserved once per thread, so recording
/// a touch on the warm path never allocates; a full buffer flushes
/// synchronously (one lock per `PENDING_CAP` warm hits, amortized away).
const PENDING_CAP: usize = 512;

/// Total entries a thread's L1 may hold across all four maps before it
/// wholesale-clears (a backstop against per-thread map growth on very
/// large sweeps; values are shared `Arc`s, so only map overhead is at
/// stake).
const L1_MAX_ENTRIES: usize = 1 << 16;

/// One thread's L1 over a single [`PlanCache`]: `Arc`-cloned hot
/// artifacts plus the recency touches not yet flushed to the shared
/// clock. See the module docs ("Two-level read path") for the
/// epoch-invalidation and flush rules.
struct L1 {
    /// Which cache these entries belong to (an L1 serves one cache at a
    /// time; a different cache id wholesale-clears it).
    cache_id: u64,
    /// The owner cache's epoch these entries were filled under.
    epoch: u64,
    /// Weak handle to the owner cache's epoch counter, so the pool's
    /// participant-retire hook ([`l1_park`]) can detect — without a
    /// cache reference — that the cache was dropped or has evicted
    /// since, and release the Arcs instead of pinning them on a parked
    /// worker.
    epoch_handle: std::sync::Weak<AtomicU64>,
    dp: HashMap<DpKey, Arc<DpPlan>>,
    layerwise: HashMap<DpKey, Arc<LayerwisePlan>>,
    tp: HashMap<TpKey, Arc<TpPlan>>,
    stage: HashMap<StageKey, Arc<StageTable>>,
    /// L1-hit recency touches awaiting the shared clock, in hit order.
    pending: Vec<AnyKey>,
}

impl L1 {
    fn new() -> L1 {
        L1 {
            cache_id: 0,
            epoch: 0,
            epoch_handle: std::sync::Weak::new(),
            dp: HashMap::new(),
            layerwise: HashMap::new(),
            tp: HashMap::new(),
            stage: HashMap::new(),
            pending: Vec::with_capacity(PENDING_CAP),
        }
    }

    fn entries(&self) -> usize {
        self.dp.len() + self.layerwise.len() + self.tp.len() + self.stage.len()
    }

    /// Drop every cached Arc (capacity kept; `pending` untouched —
    /// flushes validate by key, so stale touches are harmless).
    fn clear_maps(&mut self) {
        self.dp.clear();
        self.layerwise.clear();
        self.tp.clear();
        self.stage.clear();
    }
}

thread_local! {
    /// The calling thread's L1 (pool workers and direct callers alike).
    static L1_TLS: std::cell::RefCell<L1> = std::cell::RefCell::new(L1::new());
}

/// Source of unique per-cache ids for L1 ownership checks.
static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

/// The pool's participant-retire hook (registered once, at the first
/// `PlanCache` construction): whenever a participant goes idle — a
/// worker finishing a job, a worker waking on a submission without
/// claiming a slot (the pool runs the hook before every park), or the
/// submitting caller after participating — release the thread's L1
/// Arcs if they are **stale**: the owner cache was dropped, or its
/// epoch moved (something was evicted) since the L1 was filled. A
/// parked worker therefore never pins evicted artifacts or a dead
/// cache's memory past its next wake-up (every job submission wakes
/// all workers), while warm L1s (no eviction, cache alive — the steady
/// state) survive across batches. `pending` is kept either way: the
/// touch records are `Copy` keys (no pinning) and flushes validate by
/// key.
fn l1_park() {
    // try_with / try_borrow: must never panic — the hook can run during
    // thread teardown, and the L1 may be borrowed if a mapped closure
    // itself unwound mid-access (the pool catches panics at the item
    // boundary).
    let _ = L1_TLS.try_with(|cell| {
        if let Ok(mut l1) = cell.try_borrow_mut() {
            let stale = match l1.epoch_handle.upgrade() {
                None => l1.entries() > 0, // owner cache dropped
                Some(e) => e.load(Ordering::Acquire) != l1.epoch,
            };
            if stale {
                l1.clear_maps();
            }
        }
    });
}

/// Thread-safe, byte-bounded memoization of partition, schedule and
/// stage-table artifacts, read through a lock-free per-thread L1 over
/// the shared mutex-guarded L2. See the module docs for keying,
/// eviction and coherence rules.
pub struct PlanCache {
    maps: Mutex<Maps>,
    /// Byte budget (0 = unbounded).
    budget: usize,
    /// Unique id binding thread L1s to this cache.
    id: u64,
    /// Bumped (under the lock) whenever eviction or `clear` removes
    /// entries; L1s wholesale-invalidate when it moves. `Arc`'d so each
    /// L1 can hold a `Weak` handle for the retire-time staleness check
    /// ([`l1_park`]) without keeping a dropped cache alive.
    epoch: Arc<AtomicU64>,
    /// Per-thread L1s enabled? (`false` = every read takes the mutex —
    /// the pre-two-level behaviour, kept for A/B benchmarks.)
    l1_enabled: bool,
    hits: AtomicU64,
    l1_hits: AtomicU64,
    solves: AtomicU64,
    evictions: AtomicU64,
    peak_bytes: AtomicU64,
    // Warm timeline-path counters (reported by the simulator through
    // its cache handle; see `CacheStats` for meanings).
    timeline_tasks: AtomicU64,
    scratch_reuses: AtomicU64,
    order_hits: AtomicU64,
    batched_evals: AtomicU64,
    batched_timeline_evals: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> PlanCache {
        PlanCache::new()
    }
}

impl PlanCache {
    /// A cache bounded by the environment's budget (see
    /// [`budget_from_env`]).
    pub fn new() -> PlanCache {
        PlanCache::with_budget(budget_from_env())
    }

    /// A cache with an explicit byte budget (0 = unbounded).
    pub fn with_budget(budget_bytes: usize) -> PlanCache {
        PlanCache::with_options(budget_bytes, true)
    }

    /// A cache with an explicit byte budget and an explicit L1 policy.
    /// `l1_enabled = false` forces every read through the shared mutex
    /// (the pre-two-level path) — results are identical either way
    /// (`tests/cache_coherence.rs`); the knob exists so
    /// `benches/bench_sweep.rs` can A/B the read paths.
    pub fn with_options(budget_bytes: usize, l1_enabled: bool) -> PlanCache {
        // Parked pool participants must release stale L1 state; register
        // the hook once, with the first cache (idempotent after that).
        crate::util::pool::set_participant_retire_hook(l1_park);
        PlanCache {
            maps: Mutex::new(Maps::default()),
            budget: budget_bytes,
            id: NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Arc::new(AtomicU64::new(1)),
            l1_enabled,
            hits: AtomicU64::new(0),
            l1_hits: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
            timeline_tasks: AtomicU64::new(0),
            scratch_reuses: AtomicU64::new(0),
            order_hits: AtomicU64::new(0),
            batched_evals: AtomicU64::new(0),
            batched_timeline_evals: AtomicU64::new(0),
        }
    }

    /// An unbounded cache (no eviction).
    pub fn unbounded() -> PlanCache {
        PlanCache::with_budget(0)
    }

    /// The configured byte budget (0 = unbounded).
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bind the calling thread's L1 to this cache and the current
    /// epoch, wholesale-clearing it when either moved (different cache:
    /// pending touches are dropped too, they name the old cache's keys;
    /// epoch bump: pending is kept — flushes validate by key, and the
    /// touched entries may well have survived the eviction).
    fn l1_sync(&self, l1: &mut L1) {
        let epoch = self.epoch.load(Ordering::Acquire);
        if l1.cache_id != self.id {
            l1.clear_maps();
            l1.pending.clear();
            l1.cache_id = self.id;
            l1.epoch = epoch;
            l1.epoch_handle = Arc::downgrade(&self.epoch);
        } else if l1.epoch != epoch {
            l1.clear_maps();
            l1.epoch = epoch;
        }
    }

    /// Apply batched recency touches to the shared LRU clock, in
    /// recorded order. Runs under the L2 lock; touches whose entries
    /// were evicted meanwhile are skipped (the key lookup validates
    /// each one — node indices are recycled, so a stale node id must
    /// never be touched directly). `drain` keeps the buffer's capacity,
    /// so the synchronous-overflow flush on the warm path allocates
    /// nothing.
    fn apply_touches(m: &mut Maps, pending: &mut Vec<AnyKey>) {
        for k in pending.drain(..) {
            let node = match k {
                AnyKey::Dp(k) => m.dp.get(&k).map(|e| e.node),
                AnyKey::Layerwise(k) => m.layerwise.get(&k).map(|e| e.node),
                AnyKey::Tp(k) => m.tp.get(&k).map(|e| e.node),
                AnyKey::Stage(k) => m.stage.get(&k).map(|e| e.node),
            };
            if let Some(node) = node {
                m.lru.touch(node);
            }
        }
    }

    /// Flush the calling thread's batched recency touches into the
    /// shared LRU clock (no-op when the thread's L1 belongs to another
    /// cache — its touches name that cache's keys).
    fn flush_pending_into(&self, m: &mut Maps) {
        if !self.l1_enabled {
            return;
        }
        L1_TLS.with(|cell| {
            let mut l1 = cell.borrow_mut();
            if l1.cache_id != self.id {
                return;
            }
            Self::apply_touches(m, &mut l1.pending);
        });
    }

    /// Publish an L2-resident value into the calling thread's L1 (only
    /// resident values — oversize bypasses must stay uncached at both
    /// levels). `observed_epoch` is the epoch read **under the L2 lock**
    /// at the moment the value was known resident: if an eviction raced
    /// in between (bumping the epoch), the value may already be gone
    /// from the L2 and publishing it under the *new* epoch would pin it
    /// invisibly to every invalidation check — skip the store instead
    /// (the next read simply goes through the L2 again).
    fn l1_store<K, V>(
        &self,
        l1_proj: fn(&mut L1) -> &mut HashMap<K, Arc<V>>,
        key: &K,
        value: &Arc<V>,
        observed_epoch: u64,
    ) where
        K: Copy + Eq + std::hash::Hash,
    {
        if !self.l1_enabled {
            return;
        }
        L1_TLS.with(|cell| {
            let mut l1 = cell.borrow_mut();
            self.l1_sync(&mut l1);
            if l1.epoch != observed_epoch {
                return;
            }
            if l1.entries() >= L1_MAX_ENTRIES {
                l1.clear_maps();
            }
            l1_proj(&mut l1).insert(*key, value.clone());
        });
    }

    /// The two-level lookup/insert core. `proj`/`l1_proj` select the L2
    /// and L1 maps and `wrap` tags the key for the shared LRU list
    /// (plain `fn`s so the higher-ranked borrows are explicit); `weigh`
    /// reports the solved value's heap bytes.
    ///
    /// The warm path is the L1 block at the top: one epoch load, one
    /// hash probe, one `Arc` clone and a buffered recency touch — no
    /// lock, no allocation. Everything below it (L2 hit, solve, insert,
    /// eviction) first flushes this thread's buffered touches so the
    /// single-thread recency order seen by the eviction logic is
    /// bit-identical to the always-locked path.
    fn get_or_solve<K, V, F>(
        &self,
        proj: fn(&mut Maps) -> &mut HashMap<K, Entry<V>>,
        l1_proj: fn(&mut L1) -> &mut HashMap<K, Arc<V>>,
        wrap: fn(K) -> AnyKey,
        key: &K,
        weigh: fn(&V) -> usize,
        solve: F,
    ) -> Arc<V>
    where
        K: Copy + Eq + std::hash::Hash,
        F: FnOnce() -> V,
    {
        if self.l1_enabled {
            let l1_hit = L1_TLS.with(|cell| {
                let mut l1 = cell.borrow_mut();
                self.l1_sync(&mut l1);
                let found = l1_proj(&mut l1).get(key).cloned();
                if found.is_some() {
                    if l1.pending.len() == l1.pending.capacity() {
                        // Full: flush synchronously so the push below
                        // never grows the buffer (keeps the warm path
                        // allocation-free). The L1 borrow is already
                        // held, so apply directly — `flush_pending_into`
                        // would re-borrow the TLS cell.
                        let mut m = self.maps.lock().unwrap();
                        Self::apply_touches(&mut m, &mut l1.pending);
                    }
                    l1.pending.push(wrap(*key));
                }
                found
            });
            if let Some(v) = l1_hit {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.l1_hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
        }
        {
            let mut m = self.maps.lock().unwrap();
            self.flush_pending_into(&mut m);
            let found = proj(&mut m).get(key).map(|e| (e.value.clone(), e.node));
            if let Some((v, node)) = found {
                m.lru.touch(node);
                // Epoch while the entry is provably resident (evictions
                // happen under this lock) — the L1 store's race guard.
                let epoch_seen = self.epoch.load(Ordering::Relaxed);
                drop(m);
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.l1_store(l1_proj, key, &v, epoch_seen);
                return v;
            }
        }
        // Solve outside the lock (deterministic solvers: a racing
        // duplicate is structurally identical; first insert wins).
        self.solves.fetch_add(1, Ordering::Relaxed);
        let solved = Arc::new(solve());
        let entry_bytes = std::mem::size_of::<(K, Entry<V>)>()
            + std::mem::size_of::<V>()
            + std::mem::size_of::<LruNode>()
            + weigh(&solved);
        if self.budget != 0 && entry_bytes > self.budget {
            // Alone it would blow the budget: hand it back uncached so
            // the resident total never exceeds the bound. Not L1-stored
            // either — "oversize is re-solved on every use" is a
            // counter contract the tests pin.
            return solved;
        }
        let mut m = self.maps.lock().unwrap();
        self.flush_pending_into(&mut m);
        let raced = proj(&mut m).get(key).map(|e| (e.value.clone(), e.node));
        if let Some((v, node)) = raced {
            // Another thread inserted while we solved: theirs wins.
            m.lru.touch(node);
            let epoch_seen = self.epoch.load(Ordering::Relaxed);
            drop(m);
            self.l1_store(l1_proj, key, &v, epoch_seen);
            return v;
        }
        let node = m.lru.push_front(wrap(*key));
        proj(&mut m).insert(*key, Entry { value: solved.clone(), bytes: entry_bytes, node });
        m.bytes += entry_bytes;
        let mut evicted = 0u64;
        if self.budget != 0 {
            while m.bytes > self.budget {
                if m.evict_lru() == 0 {
                    break;
                }
                evicted += 1;
            }
        }
        if evicted > 0 {
            // Entries left the L2: move the epoch (under the lock) so
            // every thread's L1 invalidates at its next access.
            self.epoch.fetch_add(1, Ordering::Release);
        }
        // Our fresh entry sits at the LRU front, so it survived any
        // eviction loop above: it is resident under this (possibly just
        // bumped) epoch, which is the one the L1 store must match.
        let epoch_seen = self.epoch.load(Ordering::Relaxed);
        self.peak_bytes.fetch_max(m.bytes as u64, Ordering::Relaxed);
        drop(m);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        self.l1_store(l1_proj, key, &solved, epoch_seen);
        solved
    }

    /// Memoized DP partition plan (α-balanced / naive-atomic).
    pub fn dp_plan<F: FnOnce() -> DpPlan>(&self, key: &DpKey, solve: F) -> Arc<DpPlan> {
        self.get_or_solve(|m| &mut m.dp, |l| &mut l.dp, AnyKey::Dp, key,
                          DpPlan::heap_bytes, solve)
    }

    /// Memoized NV-layerwise ownership plan.
    pub fn layerwise_plan<F: FnOnce() -> LayerwisePlan>(
        &self,
        key: &DpKey,
        solve: F,
    ) -> Arc<LayerwisePlan> {
        self.get_or_solve(|m| &mut m.layerwise, |l| &mut l.layerwise, AnyKey::Layerwise,
                          key, LayerwisePlan::heap_bytes, solve)
    }

    /// Memoized TP micro-group plan for one DP rank.
    pub fn tp_plan<F: FnOnce() -> TpPlan>(&self, key: &TpKey, solve: F) -> Arc<TpPlan> {
        self.get_or_solve(|m| &mut m.tp, |l| &mut l.tp, AnyKey::Tp, key,
                          TpPlan::heap_bytes, solve)
    }

    /// Memoized hoisted stage table (census geometry + task tables).
    pub fn stage_table<F: FnOnce() -> StageTable>(
        &self,
        key: &StageKey,
        solve: F,
    ) -> Arc<StageTable> {
        self.get_or_solve(|m| &mut m.stage, |l| &mut l.stage, AnyKey::Stage, key,
                          StageTable::heap_bytes, solve)
    }

    /// Is a DP plan resident? (No LRU touch — for tests/diagnostics.)
    pub fn contains_dp(&self, key: &DpKey) -> bool {
        self.maps.lock().unwrap().dp.contains_key(key)
    }

    /// Is a TP plan resident? (No LRU touch — for tests/diagnostics.)
    pub fn contains_tp(&self, key: &TpKey) -> bool {
        self.maps.lock().unwrap().tp.contains_key(key)
    }

    /// Record `n` tasks scheduled by one timeline playback (feeds the
    /// `timeline_tasks` counter; allocation-free, called on the warm
    /// path).
    pub fn note_timeline_tasks(&self, n: u64) {
        self.timeline_tasks.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a timeline playback that reused an already-warm
    /// per-worker `SimScratch`.
    pub fn note_scratch_reuse(&self) {
        self.scratch_reuses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a pipeline schedule-order table served from a per-worker
    /// interned cache instead of being re-derived.
    pub fn note_order_hit(&self) {
        self.order_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` lanes evaluated by one batched SoA closed-form run
    /// ([`crate::sim::batch`]; allocation-free, called once per batch).
    pub fn note_batched_evals(&self, n: u64) {
        self.batched_evals.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` lanes evaluated by one batched timeline (schedule
    /// tape) run ([`crate::sim::batch`]; allocation-free, called once
    /// per batch).
    pub fn note_batched_timeline_evals(&self, n: u64) {
        self.batched_timeline_evals.fetch_add(n, Ordering::Relaxed);
    }

    /// Statistics snapshot (counters + byte ledger).
    pub fn stats(&self) -> CacheStats {
        let resident = self.maps.lock().unwrap().bytes as u64;
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            l1_hits: self.l1_hits.load(Ordering::Relaxed),
            solves: self.solves.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: resident,
            peak_bytes: self.peak_bytes.load(Ordering::Relaxed).max(resident),
            budget_bytes: self.budget as u64,
            timeline_tasks: self.timeline_tasks.load(Ordering::Relaxed),
            scratch_reuses: self.scratch_reuses.load(Ordering::Relaxed),
            order_hits: self.order_hits.load(Ordering::Relaxed),
            batched_evals: self.batched_evals.load(Ordering::Relaxed),
            batched_timeline_evals: self.batched_timeline_evals.load(Ordering::Relaxed),
        }
    }

    /// Number of cached plans across all maps.
    pub fn len(&self) -> usize {
        self.maps.lock().unwrap().len()
    }

    /// Whether no plans are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters are kept; the byte ledger
    /// resets; the epoch moves so per-thread L1s invalidate too).
    pub fn clear(&self) {
        let mut m = self.maps.lock().unwrap();
        m.dp.clear();
        m.layerwise.clear();
        m.tp.clear();
        m.stage.clear();
        m.lru.clear();
        m.bytes = 0;
        self.epoch.fetch_add(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;

    fn scen() -> Scenario {
        Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
    }

    #[test]
    fn keys_normalize_optimizer_under_numel() {
        let a = DpKey::for_scenario(&scen(), 0);
        let b = DpKey::for_scenario(&scen().with_optim(OptimKind::Shampoo), 0);
        assert_eq!(a, b, "Numel metric must be optimizer-agnostic");
        let c = DpKey::for_scenario(
            &scen().with_metric(CostMetric::Flops), 0);
        let d = DpKey::for_scenario(
            &scen().with_metric(CostMetric::Flops).with_optim(OptimKind::Shampoo), 0);
        assert_ne!(c, d, "Flops metric is optimizer-specific");
    }

    #[test]
    fn tp_keys_always_carry_optimizer() {
        let a = TpKey::for_scenario(&scen(), 0, 3);
        let b = TpKey::for_scenario(&scen().with_optim(OptimKind::Shampoo), 0, 3);
        assert_ne!(a, b);
        assert_ne!(a, TpKey::for_scenario(&scen(), 0, 4));
    }

    #[test]
    fn stage_keys_carry_optimizer_but_not_c_max() {
        let a = StageKey::for_scenario(&scen(), 0);
        let b = StageKey::for_scenario(&scen().with_optim(OptimKind::Shampoo), 0);
        assert_ne!(a, b, "task tables are optimizer-specific");
        let c = StageKey::for_scenario(&scen().with_c_max(None), 0);
        let d = StageKey::for_scenario(&scen().with_c_max(Some(64e6)), 0);
        assert_eq!(c, d, "C_max only shapes TP plans");
    }

    #[test]
    fn alpha_ignored_for_non_lb_strategies() {
        let asc = scen().with_strategy(DpStrategy::Asc);
        let a = DpKey::for_scenario(&asc.clone().with_alpha(0.25), 0);
        let b = DpKey::for_scenario(&asc.with_alpha(0.75), 0);
        assert_eq!(a, b);
    }

    #[test]
    fn c_max_outside_dp_key() {
        let a = DpKey::for_scenario(&scen().with_c_max(None), 0);
        let b = DpKey::for_scenario(&scen().with_c_max(Some(64e6)), 0);
        assert_eq!(a, b, "C_max is a TP-plane knob");
    }

    fn toy_plan(ranks: usize) -> DpPlan {
        DpPlan {
            ranks,
            cuts: vec![(0..=ranks).map(|r| r * 10).collect()],
            atomicity: crate::partition::Atomicity::None,
        }
    }

    #[test]
    fn hit_skips_solve() {
        let cache = PlanCache::unbounded();
        let key = DpKey::for_scenario(&scen(), 0);
        let first = cache.dp_plan(&key, || toy_plan(1));
        let s = cache.stats();
        assert_eq!((s.hits, s.solves), (0, 1));
        let second = cache.dp_plan(&key, || panic!("must not re-solve"));
        let s = cache.stats();
        assert_eq!((s.hits, s.solves, s.evictions), (1, 1, 0));
        assert!(s.resident_bytes > 0);
        assert_eq!(first.cuts, second.cuts);
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.stats().resident_bytes, 0);
    }

    #[test]
    fn budget_evicts_lru_first() {
        // Weigh one toy entry, then budget for exactly two of them.
        let probe = PlanCache::unbounded();
        let mk_key = |stage: usize| DpKey { stage, ..DpKey::for_scenario(&scen(), 0) };
        probe.dp_plan(&mk_key(0), || toy_plan(4));
        let per_entry = probe.stats().resident_bytes as usize;
        assert!(per_entry > 0);

        let cache = PlanCache::with_budget(2 * per_entry);
        cache.dp_plan(&mk_key(0), || toy_plan(4));
        cache.dp_plan(&mk_key(1), || toy_plan(4));
        assert_eq!(cache.len(), 2);
        // Touch key 0 so key 1 is the LRU, then overflow.
        cache.dp_plan(&mk_key(0), || panic!("hit expected"));
        cache.dp_plan(&mk_key(2), || toy_plan(4));
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.resident_bytes <= s.budget_bytes, "{s:?}");
        assert!(cache.contains_dp(&mk_key(0)), "recently-used entry evicted");
        assert!(!cache.contains_dp(&mk_key(1)), "LRU entry survived");
        assert!(cache.contains_dp(&mk_key(2)));
        assert!(s.peak_bytes <= s.budget_bytes);
    }

    #[test]
    fn oversize_entries_bypass_the_cache() {
        let cache = PlanCache::with_budget(64); // smaller than any entry
        let key = DpKey::for_scenario(&scen(), 0);
        let a = cache.dp_plan(&key, || toy_plan(64));
        assert_eq!(a.ranks, 64);
        assert_eq!(cache.len(), 0, "oversize entry must not be cached");
        assert_eq!(cache.stats().resident_bytes, 0);
        // Re-solved on next use (still correct, still uncached).
        let b = cache.dp_plan(&key, || toy_plan(64));
        assert_eq!(a.cuts, b.cuts);
        assert_eq!(cache.stats().solves, 2);
    }

    #[test]
    fn env_budget_parsing_shapes() {
        // Constructors only (env vars are process-global; don't set them
        // here): explicit budgets round-trip, 0 = unbounded.
        assert_eq!(PlanCache::with_budget(123).budget_bytes(), 123);
        assert_eq!(PlanCache::unbounded().budget_bytes(), 0);
        // MiB conversion: 256 is exactly the default; 0/negative mean
        // unbounded; NaN/inf are rejected (never silently unbounded).
        assert_eq!(budget_mb_to_bytes(256.0), Some(DEFAULT_BUDGET_BYTES));
        assert_eq!(budget_mb_to_bytes(1.0), Some(1 << 20));
        assert_eq!(budget_mb_to_bytes(0.0), Some(0));
        assert_eq!(budget_mb_to_bytes(-3.0), Some(0));
        assert_eq!(budget_mb_to_bytes(f64::NAN), None);
        assert_eq!(budget_mb_to_bytes(f64::INFINITY), None);
    }

    #[test]
    fn canonical_stage_shares_interior_stages() {
        // Qwen3-1.7B has 28 layers; pp = 8 -> per_stage 4: stage 0
        // (embed), interior 1..=6 all host 4 layers, stage 7 (head).
        let mut s = scen();
        s.pp = 8;
        assert_eq!(canonical_stage(&s, 0), 0);
        for si in 1..=6 {
            assert_eq!(canonical_stage(&s, si), 1, "stage {si}");
        }
        assert_eq!(canonical_stage(&s, 7), 7);
        // pp = 1 is the identity.
        assert_eq!(canonical_stage(&scen(), 0), 0);
        // Uneven split: 28 layers over pp = 5 -> per_stage 6; interior
        // stages 1..=3 host 6 layers each (stage 4 takes the rest).
        let mut s5 = scen();
        s5.pp = 5;
        assert_eq!(canonical_stage(&s5, 2), 1);
        assert_eq!(canonical_stage(&s5, 3), 1);
        assert_eq!(canonical_stage(&s5, 4), 4);
        // Keys built through for_scenario collapse accordingly.
        assert_eq!(DpKey::for_scenario(&s, 3), DpKey::for_scenario(&s, 5));
        assert_ne!(DpKey::for_scenario(&s, 0), DpKey::for_scenario(&s, 1));
    }

    #[test]
    fn lru_list_order_and_recycling() {
        let mut l = LruList::default();
        let keyed = |stage| AnyKey::Dp(DpKey { stage, ..DpKey::for_scenario(&scen(), 0) });
        let stage_of = |k: AnyKey| match k {
            AnyKey::Dp(d) => d.stage,
            _ => unreachable!(),
        };
        let a = l.push_front(keyed(1));
        let b = l.push_front(keyed(2));
        let c = l.push_front(keyed(3));
        // Order (MRU..LRU): 3, 2, 1. Touch the oldest -> 1, 3, 2.
        l.touch(a);
        assert_eq!(stage_of(l.pop_tail().unwrap()), 2, "untouched LRU goes first");
        assert_eq!(stage_of(l.pop_tail().unwrap()), 3, "then the middle");
        assert_eq!(stage_of(l.pop_tail().unwrap()), 1, "the touched node last");
        assert!(l.pop_tail().is_none());
        // Slots recycle through the free list.
        let d = l.push_front(keyed(4));
        assert!(d == a || d == b || d == c, "freed slot reused");
        assert_eq!(l.nodes.len(), 3);
    }

    #[test]
    fn l1_serves_repeat_hits() {
        // First get: solve (L2 insert + L1 publish). Every later get on
        // this thread is an L1 hit — counted both as a hit and in the
        // l1_hits diagnostic.
        let cache = PlanCache::unbounded();
        let key = DpKey::for_scenario(&scen(), 0);
        let first = cache.dp_plan(&key, || toy_plan(3));
        assert_eq!(cache.stats().l1_hits, 0);
        for _ in 0..5 {
            let again = cache.dp_plan(&key, || panic!("must not re-solve"));
            assert!(Arc::ptr_eq(&first, &again), "L1 must serve the same Arc");
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.l1_hits, s.solves), (5, 5, 1));
    }

    #[test]
    fn eviction_bumps_epoch_and_invalidates_l1() {
        // Budget fits ~one entry: inserting B evicts A and moves the
        // epoch, so this thread's L1 copy of A must NOT be served — the
        // next get re-solves through the L2, exactly like the
        // always-locked path would.
        let probe = PlanCache::unbounded();
        let mk_key = |stage: usize| DpKey { stage, ..DpKey::for_scenario(&scen(), 0) };
        probe.dp_plan(&mk_key(0), || toy_plan(4));
        let per_entry = probe.stats().resident_bytes as usize;

        let cache = PlanCache::with_budget(per_entry + 64);
        cache.dp_plan(&mk_key(0), || toy_plan(4));
        cache.dp_plan(&mk_key(0), || panic!("hit expected")); // L1-resident
        cache.dp_plan(&mk_key(1), || toy_plan(4)); // evicts key 0
        assert!(cache.stats().evictions >= 1);
        assert!(!cache.contains_dp(&mk_key(0)));
        let solves = cache.stats().solves;
        cache.dp_plan(&mk_key(0), || toy_plan(4));
        assert_eq!(
            cache.stats().solves,
            solves + 1,
            "epoch bump must invalidate the stale L1 entry",
        );
    }

    #[test]
    fn clear_invalidates_l1() {
        let cache = PlanCache::unbounded();
        let key = DpKey::for_scenario(&scen(), 0);
        cache.dp_plan(&key, || toy_plan(2));
        cache.dp_plan(&key, || panic!("hit expected"));
        cache.clear();
        let solves = cache.stats().solves;
        cache.dp_plan(&key, || toy_plan(2));
        assert_eq!(cache.stats().solves, solves + 1, "cleared entry served from L1");
    }

    #[test]
    fn l1_is_per_cache() {
        // Two caches touched alternately from one thread: each get must
        // resolve against its own cache (the L1 rebinds on cache switch,
        // never serving cache A's artifact for cache B's key).
        let a = PlanCache::unbounded();
        let b = PlanCache::unbounded();
        let key = DpKey::for_scenario(&scen(), 0);
        let va = a.dp_plan(&key, || toy_plan(2));
        let vb = b.dp_plan(&key, || toy_plan(7));
        assert_eq!(va.ranks, 2);
        assert_eq!(vb.ranks, 7);
        // Re-reads after the switches still return the right plans
        // (via L2 — the L1 rebinds each time).
        assert_eq!(a.dp_plan(&key, || panic!("a must hit")).ranks, 2);
        assert_eq!(b.dp_plan(&key, || panic!("b must hit")).ranks, 7);
    }

    #[test]
    fn mutex_only_cache_disables_l1() {
        let cache = PlanCache::with_options(0, false);
        let key = DpKey::for_scenario(&scen(), 0);
        cache.dp_plan(&key, || toy_plan(2));
        cache.dp_plan(&key, || panic!("hit expected"));
        cache.dp_plan(&key, || panic!("hit expected"));
        let s = cache.stats();
        assert_eq!((s.hits, s.l1_hits, s.solves), (2, 0, 1), "L1 must be off");
    }

    #[test]
    fn pending_touch_overflow_flushes_without_losing_recency() {
        // More L1 hits than PENDING_CAP between two inserts: the buffer
        // flushes synchronously mid-stream and the hot key's recency
        // still protects it from eviction.
        let probe = PlanCache::unbounded();
        let mk_key = |stage: usize| DpKey { stage, ..DpKey::for_scenario(&scen(), 0) };
        probe.dp_plan(&mk_key(0), || toy_plan(4));
        let per_entry = probe.stats().resident_bytes as usize;

        let cache = PlanCache::with_budget(2 * per_entry + 64);
        cache.dp_plan(&mk_key(0), || toy_plan(4));
        cache.dp_plan(&mk_key(1), || toy_plan(4));
        for _ in 0..(PENDING_CAP + 17) {
            cache.dp_plan(&mk_key(0), || panic!("hit expected"));
        }
        cache.dp_plan(&mk_key(2), || toy_plan(4)); // overflow: evicts one
        assert!(cache.stats().evictions >= 1);
        assert!(cache.contains_dp(&mk_key(0)), "hot key evicted despite touches");
        assert!(!cache.contains_dp(&mk_key(1)), "cold key must go first");
    }

    #[test]
    fn stats_json_shape() {
        let cache = PlanCache::with_budget(1 << 20);
        cache.dp_plan(&DpKey::for_scenario(&scen(), 0), || toy_plan(2));
        let v = cache.stats().to_json();
        assert_eq!(v.get("solves").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.get("budget_bytes").unwrap().as_usize().unwrap(),
            1 << 20,
        );
        assert!(v.get("resident_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(v.get("timeline_tasks").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn timeline_counters_round_trip_and_default() {
        let cache = PlanCache::unbounded();
        cache.note_timeline_tasks(42);
        cache.note_timeline_tasks(8);
        cache.note_scratch_reuse();
        cache.note_order_hit();
        cache.note_order_hit();
        let s = cache.stats();
        assert_eq!(
            (s.timeline_tasks, s.scratch_reuses, s.order_hits),
            (50, 1, 2),
        );
        // to_json -> from_json is lossless for every counter.
        assert_eq!(CacheStats::from_json(&s.to_json()), s);
        // Artifacts written before the timeline counters existed (only
        // the original six keys — or no recognizable keys at all) still
        // parse, with zero defaults: the `--baseline` join tolerance.
        let old = Value::obj(vec![
            ("hits", Value::num(3.0)),
            ("solves", Value::num(2.0)),
            ("evictions", Value::num(0.0)),
            ("resident_bytes", Value::num(100.0)),
            ("peak_bytes", Value::num(100.0)),
            ("budget_bytes", Value::num(0.0)),
        ]);
        let parsed = CacheStats::from_json(&old);
        assert_eq!((parsed.hits, parsed.solves), (3, 2));
        assert_eq!(
            (parsed.timeline_tasks, parsed.scratch_reuses, parsed.order_hits),
            (0, 0, 0),
        );
        assert_eq!(parsed.batched_evals, 0);
        assert_eq!(parsed.batched_timeline_evals, 0);
        assert_eq!(CacheStats::from_json(&Value::Null), CacheStats::default());
    }

    #[test]
    fn every_counter_survives_emit_parse_and_zero_defaults() {
        // Table over every CacheStats field: each (key, accessor) pair
        // must (a) survive to_json -> from_json with a distinct value,
        // and (b) zero-default when its key is stripped from the
        // artifact — the `--baseline` join tolerance for artifacts
        // written before that counter existed (e.g. pre-batch baselines
        // lacking `batched_evals`). A new counter added to the struct
        // without a row here fails the exhaustiveness check below.
        let fields: Vec<(&str, fn(&CacheStats) -> u64)> = vec![
            ("hits", |s| s.hits),
            ("l1_hits", |s| s.l1_hits),
            ("solves", |s| s.solves),
            ("evictions", |s| s.evictions),
            ("resident_bytes", |s| s.resident_bytes),
            ("peak_bytes", |s| s.peak_bytes),
            ("budget_bytes", |s| s.budget_bytes),
            ("timeline_tasks", |s| s.timeline_tasks),
            ("scratch_reuses", |s| s.scratch_reuses),
            ("order_hits", |s| s.order_hits),
            ("batched_evals", |s| s.batched_evals),
            ("batched_timeline_evals", |s| s.batched_timeline_evals),
        ];
        let full = CacheStats {
            hits: 1,
            l1_hits: 2,
            solves: 3,
            evictions: 4,
            resident_bytes: 5,
            peak_bytes: 6,
            budget_bytes: 7,
            timeline_tasks: 8,
            scratch_reuses: 9,
            order_hits: 10,
            batched_evals: 11,
            batched_timeline_evals: 12,
        };
        // Exhaustiveness: the table covers every emitted key and every
        // field value 1..=N appears exactly once.
        let v = full.to_json();
        assert_eq!(CacheStats::from_json(&v), full);
        let mut seen: Vec<u64> = fields.iter().map(|(_, get)| get(&full)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (1..=fields.len() as u64).collect::<Vec<_>>());
        for &(key, get) in &fields {
            // (a) the emitted artifact carries the field's value.
            assert_eq!(
                v.get(key).unwrap().as_usize().unwrap() as u64,
                get(&full),
                "{key} lost in emit",
            );
            // (b) stripping the key zero-defaults only that field.
            let stripped = Value::obj(
                fields
                    .iter()
                    .filter(|(k, _)| *k != key)
                    .map(|(k, g)| (*k, Value::num(g(&full) as f64)))
                    .collect(),
            );
            let parsed = CacheStats::from_json(&stripped);
            assert_eq!(get(&parsed), 0, "{key} must zero-default when absent");
            for &(other, g) in &fields {
                if other != key {
                    assert_eq!(g(&parsed), g(&full), "{other} perturbed by dropping {key}");
                }
            }
        }
    }
}
