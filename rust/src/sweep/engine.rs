//! The sweep engine: plan-cached, work-stealing scenario evaluation.
//!
//! One [`SweepEngine`] owns a [`PlanCache`] and a worker count; `eval`
//! fans scenarios out over `util::pool` and merges [`Breakdown`]s back in
//! scenario order. A process-wide [`SweepEngine::global`] instance backs
//! the figure harnesses, so `experiments::run("all")` shares one warm
//! cache across all fifteen harnesses.
//!
//! Warm-path mechanics: `util::pool`'s workers are **persistent**
//! (long-lived threads serving every batch for the life of the
//! process), so the per-worker state that makes the warm path cheap
//! survives across `eval` calls — the reusable `SimScratch`
//! (thread-local in `sim::iteration`) and the plan cache's per-worker
//! L1 (`sweep::cache`) are warmed once per process, not once per
//! batch, and a batch's warm lookups never take the cache mutex. The
//! scratch reports its reuse/order-cache/task counters through the
//! engine's cache, visible in [`SweepEngine::cache_stats`] alongside
//! the plan-cache counters (including `l1_hits`, the lock-free share).

use std::collections::HashMap;
use std::sync::OnceLock;

use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::Qwen3Size;
use crate::partition::DpStrategy;
use crate::sim::batch::{simulate_batch_scatter, ScenarioBatch};
use crate::sim::iteration::closed_form_path;
use crate::sim::{simulate_iteration_cached, Breakdown, PipelineSchedule, Scenario};
use crate::util::json::Value;
use crate::util::pool;
use crate::util::stats::load_balance_ratio;
use crate::util::table::{ratio, secs, Table};

use super::cache::{CacheStats, PlanCache};
use super::grid::SweepGrid;

/// A plan-cached, work-stealing scenario evaluator (see module docs).
pub struct SweepEngine {
    cache: PlanCache,
    threads: usize,
    /// Route shared-fingerprint groups through the batched SoA tier
    /// (`sim::batch`) — both the closed-form arm and the schedule-tape
    /// timeline arm? Default on; `--no-batch` turns it off. Row bytes
    /// are identical either way (the batch tier is bit-exact, pinned by
    /// `tests/batch_differential.rs`).
    batching: bool,
}

impl SweepEngine {
    /// An engine with its own cold cache (byte budget from the
    /// environment — see [`crate::sweep::cache::budget_from_env`]).
    pub fn new(threads: usize) -> SweepEngine {
        SweepEngine { cache: PlanCache::new(), threads: threads.max(1), batching: true }
    }

    /// An engine whose cache has an explicit byte budget (0 = unbounded)
    /// — the `canzona sweep --cache-budget-mb` path.
    pub fn with_budget(threads: usize, budget_bytes: usize) -> SweepEngine {
        SweepEngine {
            cache: PlanCache::with_budget(budget_bytes),
            threads: threads.max(1),
            batching: true,
        }
    }

    /// An engine over a caller-constructed cache (e.g. an L1-disabled
    /// `PlanCache::with_options(.., false)` for A/B read-path
    /// benchmarks).
    pub fn with_cache(threads: usize, cache: PlanCache) -> SweepEngine {
        SweepEngine { cache, threads: threads.max(1), batching: true }
    }

    /// Enable or disable the batched evaluation tier (the CLI's
    /// `--no-batch`; benchmarks A/B the two arms with this).
    pub fn set_batching(&mut self, on: bool) {
        self.batching = on;
    }

    /// Is the batched evaluation tier enabled?
    pub fn batching(&self) -> bool {
        self.batching
    }

    /// The shared process-wide engine (thread count from
    /// `CANZONA_SWEEP_THREADS`, default: available parallelism).
    pub fn global() -> &'static SweepEngine {
        static GLOBAL: OnceLock<SweepEngine> = OnceLock::new();
        GLOBAL.get_or_init(|| SweepEngine::new(pool::default_threads()))
    }

    /// Worker count used by [`SweepEngine::eval`].
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The engine's plan cache.
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Cache counters snapshot (hits / solves / evictions / bytes).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Evaluate one scenario against the shared cache.
    pub fn eval_one(&self, s: &Scenario) -> Breakdown {
        simulate_iteration_cached(s, &self.cache)
    }

    /// Evaluate a scenario batch in parallel; results come back in input
    /// order, independent of worker scheduling (and of whether the
    /// batched tier is on — results are bit-identical either way).
    ///
    /// Dispatch: scenarios sharing a plan fingerprint × schedule shape
    /// (everything but the per-lane hardware knobs — see [`GroupKey`])
    /// are grouped and evaluated through the batched SoA tier
    /// ([`crate::sim::batch`]): chunked closed-form recurrences on the
    /// `pp = 1` arm, schedule-tape timeline replay on the `pp > 1` /
    /// micro-batched / straggler arm. Fingerprint singletons take the
    /// scalar arm.
    pub fn eval(&self, scenarios: &[Scenario]) -> Vec<Breakdown> {
        if !self.batching || scenarios.len() < 2 {
            return pool::parallel_map(scenarios, self.threads, |s| {
                simulate_iteration_cached(s, &self.cache)
            });
        }
        let units = group_units(scenarios);
        if units.len() == scenarios.len() {
            // No multi-lane group formed: skip the scatter pass.
            return pool::parallel_map(scenarios, self.threads, |s| {
                simulate_iteration_cached(s, &self.cache)
            });
        }
        let results = pool::parallel_map(&units, self.threads, |unit| match unit {
            EvalUnit::Scalar(i) => {
                vec![simulate_iteration_cached(&scenarios[*i], &self.cache)]
            }
            EvalUnit::Group(idxs) => self.eval_group(scenarios, idxs),
        });
        // Scatter unit results back to input order.
        let mut out: Vec<Option<Breakdown>> = vec![None; scenarios.len()];
        for (unit, res) in units.iter().zip(results) {
            match unit {
                EvalUnit::Scalar(i) => {
                    out[*i] = res.into_iter().next();
                }
                EvalUnit::Group(idxs) => {
                    for (&i, b) in idxs.iter().zip(res) {
                        out[i] = Some(b);
                    }
                }
            }
        }
        out.into_iter().map(|b| b.expect("every scenario owned by exactly one unit")).collect()
    }

    /// Evaluate one shared-fingerprint group through the batch tier,
    /// falling back to the scalar arm if batch construction refuses the
    /// base (results are identical; the batch is an optimization, never
    /// a semantic gate).
    fn eval_group(&self, scenarios: &[Scenario], idxs: &[usize]) -> Vec<Breakdown> {
        let build = || -> crate::util::error::Result<Vec<Breakdown>> {
            let mut batch = ScenarioBatch::new(scenarios[idxs[0]].clone())?;
            for &i in idxs {
                batch.push_scenario(&scenarios[i])?;
            }
            let mut outs = vec![Breakdown::default(); idxs.len()];
            simulate_batch_scatter(&batch, &self.cache, &mut outs);
            Ok(outs)
        };
        build().unwrap_or_else(|_| {
            idxs.iter()
                .map(|&i| simulate_iteration_cached(&scenarios[i], &self.cache))
                .collect()
        })
    }

    /// Expand and evaluate a grid.
    pub fn run_grid(&self, grid: &SweepGrid) -> (Vec<Scenario>, Vec<Breakdown>) {
        let scenarios = grid.scenarios();
        let breakdowns = self.eval(&scenarios);
        (scenarios, breakdowns)
    }
}

/// One work item of a grouped [`SweepEngine::eval`]: a scalar scenario
/// (a fingerprint singleton) or a shared-fingerprint group routed
/// through the batch tier (either arm). Indices refer to the input
/// slice; every input index appears in exactly one unit.
enum EvalUnit {
    Scalar(usize),
    Group(Vec<usize>),
}

/// The batch grouping rule: everything the evaluators read *except*
/// the per-lane knobs (`c_max_bytes`, `straggler`). Two scenarios with
/// equal keys share a `StageTable`/plan fingerprint *and* — since PR 9
/// — a schedule shape (`schedule`, `pp`, `micro_batches`), so one
/// batched call covers both: closed-form recurrences or one schedule
/// tape, selected by the `closed` arm bit. The arm bit is required
/// precisely because `straggler` is a lane knob: at `pp = 1,
/// micro_batches = 1` a straggler-free leaf takes the closed form while
/// its `straggler > 1` sibling takes the timeline, and the two arms
/// must never share a batch. Hardware is compared by exact bits — a
/// derated or edited profile splits the group rather than risking a
/// mismatched lane.
#[derive(Hash, PartialEq, Eq)]
struct GroupKey {
    size: Qwen3Size,
    dp: usize,
    tp: usize,
    pp: usize,
    micro_batches: usize,
    schedule: PipelineSchedule,
    closed: bool,
    optim: OptimKind,
    strategy: DpStrategy,
    metric: CostMetric,
    alpha_bits: u64,
    seq_len: usize,
    batch_per_dp: usize,
    bucket_elems: usize,
    hw_name: &'static str,
    gpus_per_node: usize,
    hw_bits: [u64; 7],
    /// Fault/heterogeneity config (PR 10): a faulted base is rejected
    /// by `ScenarioBatch::new` (lane columns carry no per-rank
    /// profile), so mixed fault configs must never share a group —
    /// a faulted lane under a fault-free base would silently evaluate
    /// without its faults.
    hetero_bits: [u64; 5],
    fault_seed: u64,
    fail_bits: (usize, u64),
    mttf_bits: u64,
    ckpt_interval: usize,
}

impl GroupKey {
    fn for_scenario(s: &Scenario) -> GroupKey {
        GroupKey {
            size: s.size,
            dp: s.dp,
            tp: s.tp,
            pp: s.pp,
            micro_batches: s.micro_batches,
            schedule: s.schedule,
            closed: closed_form_path(s),
            optim: s.optim,
            strategy: s.strategy,
            metric: s.metric,
            alpha_bits: s.alpha.to_bits(),
            seq_len: s.seq_len,
            batch_per_dp: s.batch_per_dp,
            bucket_elems: s.bucket_elems,
            hw_name: s.hw.name,
            gpus_per_node: s.hw.gpus_per_node,
            hw_bits: [
                s.hw.gpu_flops.to_bits(),
                s.hw.hbm_bw.to_bits(),
                s.hw.nvlink_bw.to_bits(),
                s.hw.ib_bw.to_bits(),
                s.hw.nvlink_lat.to_bits(),
                s.hw.ib_lat.to_bits(),
                s.hw.launch_overhead.to_bits(),
            ],
            hetero_bits: s.hetero.key_bits(),
            fault_seed: s.fault_seed,
            fail_bits: s
                .fail_rank
                .map(|f| (f.rank, f.at.to_bits()))
                .unwrap_or((usize::MAX, u64::MAX)),
            mttf_bits: s.mttf_s.map(f64::to_bits).unwrap_or(u64::MAX),
            ckpt_interval: s.ckpt_interval,
        }
    }
}

/// Partition `scenarios` into [`EvalUnit`]s: scenarios sharing a
/// [`GroupKey`] form one `Group` (anchored at the first member's
/// position, lanes in input order), on both dispatch arms; fingerprint
/// singletons stay `Scalar`. Deterministic for a given input (no
/// map-iteration order dependence).
fn group_units(scenarios: &[Scenario]) -> Vec<EvalUnit> {
    let mut members: HashMap<GroupKey, Vec<usize>> = HashMap::new();
    for (i, s) in scenarios.iter().enumerate() {
        members.entry(GroupKey::for_scenario(s)).or_default().push(i);
    }
    let mut units = Vec::new();
    for (i, s) in scenarios.iter().enumerate() {
        let group = &members[&GroupKey::for_scenario(s)];
        if group[0] != i {
            continue; // emitted at the first member's position
        }
        if group.len() >= 2 {
            units.push(EvalUnit::Group(group.clone()));
        } else {
            units.push(EvalUnit::Scalar(i));
        }
    }
    units
}

/// Render a sweep as one Markdown table, one row per scenario, in
/// scenario order.
pub fn render_table(scenarios: &[Scenario], breakdowns: &[Breakdown]) -> Table {
    assert_eq!(scenarios.len(), breakdowns.len());
    let mut t = Table::new(
        &format!("Sweep — {} scenarios", scenarios.len()),
        &["model", "DP", "TP", "PP", "mb", "sched", "strag", "hetero", "optim",
          "strategy", "alpha", "C_max", "fwd-bwd", "optimizer", "total",
          "recovery", "bubble", "DP LB", "TP LB", "groups"],
    );
    for (s, b) in scenarios.iter().zip(breakdowns) {
        t.row(vec![
            s.label.clone(),
            s.dp.to_string(),
            s.tp.to_string(),
            s.pp.to_string(),
            s.micro_batches.to_string(),
            s.schedule.label().into(),
            format!("{:.2}", s.straggler),
            s.hetero.to_string(),
            s.optim.label().into(),
            s.strategy.label().into(),
            format!("{:.2}", s.alpha),
            match s.c_max_bytes {
                None => "no-fuse".into(),
                Some(b) => format!("{:.0}MB", b / 1e6),
            },
            secs(b.fwd_bwd_s),
            secs(b.optimizer_s),
            secs(b.total_s),
            secs(b.recovery_s),
            secs(b.bubble_s),
            ratio(load_balance_ratio(&b.dp_loads_flops)),
            ratio(load_balance_ratio(&b.tp_loads_flops)),
            b.n_micro_groups.to_string(),
        ]);
    }
    t
}

/// Render a sweep as a JSON artifact (stable key order via
/// `util::json`'s BTreeMap objects).
pub fn render_json(scenarios: &[Scenario], breakdowns: &[Breakdown]) -> Value {
    assert_eq!(scenarios.len(), breakdowns.len());
    let rows = scenarios.iter().zip(breakdowns).map(|(s, b)| {
        Value::obj(vec![
            ("model", Value::str(&s.label)),
            ("dp", Value::num(s.dp as f64)),
            ("tp", Value::num(s.tp as f64)),
            ("pp", Value::num(s.pp as f64)),
            ("micro_batches", Value::num(s.micro_batches as f64)),
            ("schedule", Value::str(s.schedule.label())),
            ("straggler", Value::num(s.straggler)),
            ("hetero", Value::str(&s.hetero.to_string())),
            ("fault_seed", Value::num(s.fault_seed as f64)),
            (
                "fail_rank",
                s.fail_rank
                    .map(|f| Value::str(&f.to_string()))
                    .unwrap_or(Value::Null),
            ),
            ("mttf_s", s.mttf_s.map(Value::num).unwrap_or(Value::Null)),
            ("ckpt_interval", Value::num(s.ckpt_interval as f64)),
            ("optim", Value::str(s.optim.label())),
            ("strategy", Value::str(s.strategy.label())),
            ("alpha", Value::num(s.alpha)),
            ("c_max_bytes", s.c_max_bytes.map(Value::num).unwrap_or(Value::Null)),
            ("fwd_bwd_s", Value::num(b.fwd_bwd_s)),
            ("optimizer_s", Value::num(b.optimizer_s)),
            ("total_s", Value::num(b.total_s)),
            ("recovery_s", Value::num(b.recovery_s)),
            ("bubble_s", Value::num(b.bubble_s)),
            ("exposed_comm_s", Value::num(b.exposed_comm_s)),
            ("dp_lb_ratio", Value::num(load_balance_ratio(&b.dp_loads_flops))),
            ("tp_lb_ratio", Value::num(load_balance_ratio(&b.tp_loads_flops))),
            ("micro_groups", Value::num(b.n_micro_groups as f64)),
        ])
    });
    Value::obj(vec![("scenarios", Value::arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::optim::OptimKind;
    use crate::model::qwen3::Qwen3Size;
    use crate::partition::DpStrategy;

    fn small_grid() -> SweepGrid {
        SweepGrid {
            models: vec![Qwen3Size::S1_7B],
            dp: vec![4, 8],
            tp: vec![2],
            pp: vec![1],
            micro_batches: vec![1],
            schedules: vec![crate::sim::PipelineSchedule::OneFOneB],
            stragglers: vec![1.0],
            optims: vec![OptimKind::Muon],
            strategies: vec![DpStrategy::Asc, DpStrategy::LbAsc],
            alphas: vec![1.0],
            c_max_mb: vec![Some(256.0)],
            heteros: vec![crate::sim::HeteroSpec::None],
            fail_ranks: vec![None],
            mttfs: vec![None],
            ckpt_intervals: vec![1],
            metric: crate::cost::optim::CostMetric::Numel,
            fault_seed: 0,
        }
    }

    #[test]
    fn parallel_matches_serial_tables() {
        let grid = small_grid();
        let serial = SweepEngine::new(1);
        let parallel = SweepEngine::new(4);
        let (scens_a, res_a) = serial.run_grid(&grid);
        let (scens_b, res_b) = parallel.run_grid(&grid);
        assert_eq!(
            render_table(&scens_a, &res_a).render(),
            render_table(&scens_b, &res_b).render(),
        );
    }

    #[test]
    fn repeated_grid_hits_cache() {
        // Unbounded: an env budget override must not evict between runs.
        let engine = SweepEngine::with_budget(2, 0);
        let grid = small_grid();
        engine.run_grid(&grid);
        let solves = engine.cache_stats().solves;
        assert!(solves > 0);
        engine.run_grid(&grid);
        let stats = engine.cache_stats();
        assert_eq!(stats.solves, solves, "second run must be all hits");
        // The warm path touches one stage table + one TP plan per rank
        // per scenario; it never re-fetches the DP/layerwise plans the
        // stage build folded in, so hits < solves — but never zero.
        assert!(stats.hits > 0);
        assert_eq!(stats.evictions, 0, "unbounded cache must not evict");
        assert!(stats.resident_bytes > 0);
    }

    #[test]
    fn json_artifact_shape() {
        let engine = SweepEngine::new(2);
        let grid = small_grid();
        let (scens, res) = engine.run_grid(&grid);
        let v = render_json(&scens, &res);
        let rows = v.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].get("model").unwrap().as_str().unwrap(), "Qwen3-1.7B");
        assert!(rows[0].get("total_s").unwrap().as_f64().unwrap() > 0.0);
        // Round-trips through the serializer.
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn global_engine_is_shared() {
        assert!(std::ptr::eq(SweepEngine::global(), SweepEngine::global()));
    }

    /// A grid whose leaves share one fingerprint and vary only C_max —
    /// the shape the batch tier exists for.
    fn cmax_grid() -> SweepGrid {
        SweepGrid {
            c_max_mb: vec![None, Some(64.0), Some(128.0), Some(256.0), Some(512.0)],
            ..small_grid()
        }
    }

    #[test]
    fn batching_on_off_renders_identical_artifacts() {
        // The CLI-level guarantee behind `--no-batch` and the
        // `--baseline --regress-pct 0` CI round-trip: both arms must
        // produce byte-identical tables AND json, over a grid that
        // exercises multi-lane groups on both dispatch arms (pp=2 ×
        // mb=4 × straggler rows take the schedule-tape timeline tier).
        let mut grid = cmax_grid();
        grid.pp = vec![1, 2];
        grid.micro_batches = vec![1, 4];
        grid.stragglers = vec![1.0, 1.3];
        let on = SweepEngine::new(4);
        let mut off = SweepEngine::new(4);
        off.set_batching(false);
        assert!(on.batching() && !off.batching());
        let (sa, ra) = on.run_grid(&grid);
        let (sb, rb) = off.run_grid(&grid);
        assert_eq!(render_table(&sa, &ra).render(), render_table(&sb, &rb).render());
        assert_eq!(render_json(&sa, &ra).to_string(), render_json(&sb, &rb).to_string());
        let on_stats = on.cache_stats();
        assert!(on_stats.batched_evals > 0, "closed-form groups must take the batch tier");
        assert!(
            on_stats.batched_timeline_evals > 0,
            "timeline groups must take the schedule-tape tier"
        );
        let off_stats = off.cache_stats();
        assert_eq!(off_stats.batched_evals, 0, "--no-batch must not batch");
        assert_eq!(off_stats.batched_timeline_evals, 0, "--no-batch must not tape");
    }

    #[test]
    fn grouping_partitions_every_index_once() {
        let mut grid = cmax_grid();
        grid.pp = vec![1, 2];
        grid.micro_batches = vec![1, 4];
        grid.stragglers = vec![1.0, 1.3];
        let scens = grid.scenarios();
        let units = group_units(&scens);
        let mut seen = vec![0usize; scens.len()];
        for u in &units {
            match u {
                EvalUnit::Scalar(i) => seen[*i] += 1,
                EvalUnit::Group(idxs) => {
                    assert!(idxs.len() >= 2, "groups of one must stay scalar");
                    // One dispatch arm per group: the base's arm decides
                    // the evaluator, so every member must share it.
                    let arm = closed_form_path(&scens[idxs[0]]);
                    for &i in idxs {
                        assert_eq!(
                            closed_form_path(&scens[i]),
                            arm,
                            "mixed-arm group at index {i}"
                        );
                        seen[i] += 1;
                    }
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        // straggler and c_max are the lane knobs: every leaf shares its
        // (schedule shape × fingerprint × arm) key with at least the
        // other c_max choices, so nothing stays scalar on this grid.
        let grouped: usize = units
            .iter()
            .map(|u| match u {
                EvalUnit::Group(v) => v.len(),
                EvalUnit::Scalar(_) => 0,
            })
            .sum();
        assert_eq!(grouped, scens.len());
    }
}
