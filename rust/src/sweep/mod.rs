//! Batch scenario evaluation: the plan-cached, parallel sweep engine.
//!
//! The paper's evaluation is hundreds of (model × DP/TP/PP grid ×
//! optimizer × strategy) scenarios. This subsystem turns the one-off
//! figure harnesses into a reusable batch-evaluation service:
//!
//! * [`cache`] — memoized `DpPlan` / `TpPlan` / `LayerwisePlan` /
//!   `StageTable` artifacts keyed by scenario fingerprint and bounded by
//!   an LRU byte budget, so repeated `simulate_iteration` calls reuse
//!   partitions, micro-group schedules and hoisted census tables instead
//!   of re-solving LPT (the same amortize-the-planning move Dion/DMuon
//!   make across steps) — without growing forever.
//! * [`grid`] — declarative scenario grids with deterministic expansion
//!   order.
//! * [`engine`] — the work-stealing runner (over [`crate::util::pool`])
//!   that fans a grid across cores and merges results in scenario order,
//!   plus table/JSON artifact rendering.
//! * [`diff`] — baseline diffing: join a sweep against a prior JSON
//!   artifact, print speedup columns, exit nonzero on regression
//!   (`canzona sweep --baseline`).
//! * [`optimize`] — best-first branch-and-bound search over a grid
//!   (`canzona optimize`): admissible lower bounds from
//!   [`crate::sim::bounds`] prune the space while returning the exact
//!   exhaustive argmin, plus a Pareto frontier artifact.
//!
//! Every `experiments::figures` harness runs on [`engine::SweepEngine::global`],
//! and the `canzona sweep` CLI subcommand exposes ad-hoc grids.

#![warn(missing_docs)]

pub mod cache;
pub mod diff;
pub mod engine;
pub mod grid;
pub mod optimize;

pub use cache::{CacheStats, DpKey, PlanCache, StageKey, TpKey};
pub use diff::{DiffRow, SweepDiff};
pub use engine::{render_json, render_table, SweepEngine};
pub use grid::SweepGrid;
pub use optimize::{
    optimize, render_optimize_json, render_optimize_table, EvaluatedScenario, Objective,
    OptimizeOptions, OptimizeResult,
};
