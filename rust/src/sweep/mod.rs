//! Batch scenario evaluation: the plan-cached, parallel sweep engine.
//!
//! The paper's evaluation is hundreds of (model × DP/TP/PP grid ×
//! optimizer × strategy) scenarios. This subsystem turns the one-off
//! figure harnesses into a reusable batch-evaluation service:
//!
//! * [`cache`] — memoized `DpPlan` / `TpPlan` artifacts keyed by scenario
//!   fingerprint, so repeated `simulate_iteration` calls reuse partitions
//!   and micro-group schedules instead of re-solving LPT (the same
//!   amortize-the-planning move Dion/DMuon make across steps).
//! * [`grid`] — declarative scenario grids with deterministic expansion
//!   order.
//! * [`engine`] — the work-stealing runner (over [`crate::util::pool`])
//!   that fans a grid across cores and merges results in scenario order,
//!   plus table/JSON artifact rendering.
//!
//! Every `experiments::figures` harness runs on [`engine::SweepEngine::global`],
//! and the `canzona sweep` CLI subcommand exposes ad-hoc grids.

pub mod cache;
pub mod engine;
pub mod grid;

pub use cache::{CacheStats, DpKey, PlanCache, TpKey};
pub use engine::{render_json, render_table, SweepEngine};
pub use grid::SweepGrid;
