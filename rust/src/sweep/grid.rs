//! Scenario grid specification for batch sweeps.
//!
//! A [`SweepGrid`] is the cross product of the axes a paper experiment
//! varies (model × DP × TP × PP × micro-batches × schedule × straggler
//! × optimizer × strategy × α × C_max × hetero × fail-rank × mttf ×
//! checkpoint interval). [`SweepGrid::scenarios`]
//! expands it in a fixed axis order, so a grid always yields the same
//! scenario sequence — the deterministic merge order of the parallel
//! runner.

use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::Qwen3Size;
use crate::partition::DpStrategy;
use crate::sim::{FailSpec, HeteroSpec, PipelineSchedule, Scenario};
use crate::util::cli::Args;
use crate::util::error::Result;
use crate::{bail, err};

/// One sweep's axes. Empty axes are invalid; single-element axes pin a
/// dimension.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepGrid {
    /// Model family members to sweep.
    pub models: Vec<Qwen3Size>,
    /// DP group sizes.
    pub dp: Vec<usize>,
    /// TP group sizes.
    pub tp: Vec<usize>,
    /// PP group sizes.
    pub pp: Vec<usize>,
    /// Micro-batch counts per iteration.
    pub micro_batches: Vec<usize>,
    /// Pipeline schedules (1F1B / GPipe).
    pub schedules: Vec<PipelineSchedule>,
    /// Straggler factors (last-stage compute derate; 1.0 = homogeneous).
    pub stragglers: Vec<f64>,
    /// Optimizers.
    pub optims: Vec<OptimKind>,
    /// DP strategies.
    pub strategies: Vec<DpStrategy>,
    /// α values (LB-ASC balance factor).
    pub alphas: Vec<f64>,
    /// `C_max` values in MB; `None` entries mean No-Fuse.
    pub c_max_mb: Vec<Option<f64>>,
    /// Per-rank heterogeneity specs (`HeteroSpec::None` = homogeneous).
    pub heteros: Vec<HeteroSpec>,
    /// Rank-failure injections; `None` entries mean no failure.
    pub fail_ranks: Vec<Option<FailSpec>>,
    /// Mean-time-to-failure rates (s); `None` entries disable the rate.
    pub mttfs: Vec<Option<f64>>,
    /// Checkpoint intervals in iterations (`1` = every iteration).
    pub ckpt_intervals: Vec<usize>,
    /// Balancing cost metric (one per grid).
    pub metric: CostMetric,
    /// Fault/heterogeneity draw seed (one per grid, like `metric`).
    pub fault_seed: u64,
}

impl Default for SweepGrid {
    /// The paper's main-results configuration as a 1-point grid.
    fn default() -> SweepGrid {
        SweepGrid {
            models: vec![Qwen3Size::S32B],
            dp: vec![32],
            tp: vec![8],
            pp: vec![1],
            micro_batches: vec![1],
            schedules: vec![PipelineSchedule::OneFOneB],
            stragglers: vec![1.0],
            optims: vec![OptimKind::Muon],
            strategies: vec![DpStrategy::LbAsc],
            alphas: vec![1.0],
            c_max_mb: vec![Some(512.0)],
            heteros: vec![HeteroSpec::None],
            fail_ranks: vec![None],
            mttfs: vec![None],
            ckpt_intervals: vec![1],
            metric: CostMetric::Numel,
            fault_seed: 0,
        }
    }
}

fn parse_list<T, F: Fn(&str) -> Option<T>>(
    raw: &str,
    what: &str,
    parse: F,
) -> Result<Vec<T>> {
    // Empty segments are an error everywhere, not just when the whole
    // list is empty: `--dp 1,,2` used to silently drop the hole while
    // `--dp ,` errored. A `split(',')` always yields at least one
    // segment, so this also covers the empty-list case.
    let segments: Vec<&str> = raw.split(',').map(str::trim).collect();
    if segments.iter().any(|s| s.is_empty()) {
        bail!("--{what} has an empty element in {raw:?}");
    }
    segments
        .iter()
        .map(|s| parse(s).ok_or_else(|| err!("invalid {what} value {s:?}")))
        .collect()
}

/// Positive integer axis value (0 would panic deep in the planners).
fn parse_dim(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().filter(|&n| n >= 1)
}

/// An integer axis list with inclusive range segments: each segment is
/// either a positive integer or `a..b` (expanding to `a, a+1, …, b`).
/// `--dp 1,4..6,16` ⇒ `[1, 4, 5, 6, 16]`. Empty segments, zeros,
/// and reversed ranges (`6..4`) are errors, mirroring [`parse_list`].
fn parse_dims(raw: &str, what: &str) -> Result<Vec<usize>> {
    let lists = parse_list(raw, what, |seg| match seg.split_once("..") {
        None => parse_dim(seg).map(|n| vec![n]),
        Some((a, b)) => {
            let (lo, hi) = (parse_dim(a.trim())?, parse_dim(b.trim())?);
            if lo > hi {
                return None;
            }
            Some((lo..=hi).collect())
        }
    })?;
    Ok(lists.into_iter().flatten().collect())
}

impl SweepGrid {
    /// Parse grid axes from CLI options; absent options keep defaults.
    ///
    /// `--models 8b,32b --dp 16,32 --tp 1,2,4,8 --pp 1 --optims muon,soap
    ///  --strategies sc,asc,lb-asc --alphas 0.5,1.0 --c-max-mb 512,none
    ///  --metric numel`
    pub fn parse(args: &Args) -> Result<SweepGrid> {
        let mut g = SweepGrid::default();
        if let Some(raw) = args.get("models") {
            g.models = parse_list(raw, "models", Qwen3Size::parse)?;
        }
        if let Some(raw) = args.get("dp") {
            g.dp = parse_dims(raw, "dp")?;
        }
        if let Some(raw) = args.get("tp") {
            g.tp = parse_dims(raw, "tp")?;
        }
        if let Some(raw) = args.get("pp") {
            g.pp = parse_dims(raw, "pp")?;
        }
        if let Some(raw) = args.get("micro-batches") {
            g.micro_batches = parse_dims(raw, "micro-batches")?;
        }
        if let Some(raw) = args.get("schedule") {
            g.schedules = parse_list(raw, "schedule", PipelineSchedule::parse)?;
        }
        if let Some(raw) = args.get("straggler") {
            g.stragglers = parse_list(raw, "straggler", |s| {
                s.parse::<f64>().ok().filter(|f| f.is_finite() && *f >= 1.0)
            })?;
        }
        if let Some(raw) = args.get("optims") {
            g.optims = parse_list(raw, "optims", OptimKind::parse)?;
        }
        if let Some(raw) = args.get("strategies") {
            g.strategies = parse_list(raw, "strategies", DpStrategy::parse)?;
        }
        if let Some(raw) = args.get("alphas") {
            g.alphas = parse_list(raw, "alphas", |s| {
                s.parse::<f64>().ok().filter(|a| (0.0..=1.0).contains(a))
            })?;
        }
        if let Some(raw) = args.get("c-max-mb") {
            g.c_max_mb = parse_list(raw, "c-max-mb", |s| {
                if s.eq_ignore_ascii_case("none") || s == "0" {
                    Some(None)
                } else {
                    s.parse::<f64>().ok().filter(|mb| *mb > 0.0).map(Some)
                }
            })?;
        }
        if let Some(raw) = args.get("hetero") {
            g.heteros = parse_list(raw, "hetero", |s| HeteroSpec::parse(s).ok())?;
        }
        if let Some(raw) = args.get("fail-rank") {
            g.fail_ranks = parse_list(raw, "fail-rank", |s| {
                if s.eq_ignore_ascii_case("none") {
                    Some(None)
                } else {
                    FailSpec::parse(s).ok().map(Some)
                }
            })?;
        }
        if let Some(raw) = args.get("mttf") {
            g.mttfs = parse_list(raw, "mttf", |s| {
                if s.eq_ignore_ascii_case("none") {
                    Some(None)
                } else {
                    s.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0).map(Some)
                }
            })?;
        }
        if let Some(raw) = args.get("ckpt-interval") {
            g.ckpt_intervals = parse_dims(raw, "ckpt-interval")?;
        }
        if let Some(raw) = args.get("fault-seed") {
            g.fault_seed = raw
                .parse::<u64>()
                .map_err(|_| err!("invalid fault-seed value {raw:?}"))?;
        }
        if let Some(raw) = args.get("metric") {
            g.metric = match raw.to_ascii_lowercase().as_str() {
                "numel" => CostMetric::Numel,
                "flops" => CostMetric::Flops,
                "state" | "state-bytes" => CostMetric::StateBytes,
                _ => bail!("unknown metric {raw:?} (numel/flops/state)"),
            };
        }
        Ok(g)
    }

    /// Cross-product size.
    pub fn len(&self) -> usize {
        self.models.len()
            * self.dp.len()
            * self.tp.len()
            * self.pp.len()
            * self.micro_batches.len()
            * self.schedules.len()
            * self.stragglers.len()
            * self.optims.len()
            * self.strategies.len()
            * self.alphas.len()
            * self.c_max_mb.len()
            * self.heteros.len()
            * self.fail_ranks.len()
            * self.mttfs.len()
            * self.ckpt_intervals.len()
    }

    /// Whether the cross product is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grid in fixed axis order (model → dp → tp → pp →
    /// micro-batches → schedule → straggler → optim → strategy → α →
    /// C_max → hetero → fail-rank → mttf → ckpt-interval). The fault
    /// axes are innermost and default to single neutral values, so
    /// fault-free grids expand to exactly the pre-fault sequence.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        for &model in &self.models {
            for &dp in &self.dp {
                for &tp in &self.tp {
                    for &pp in &self.pp {
                        for &mb in &self.micro_batches {
                            for &sched in &self.schedules {
                                for &strag in &self.stragglers {
                                    for &optim in &self.optims {
                                        for &strategy in &self.strategies {
                                            for &alpha in &self.alphas {
                                                for &c_mb in &self.c_max_mb {
                                                    let base = Scenario::new(
                                                        model, dp, tp, pp, optim, strategy,
                                                    )
                                                    .with_alpha(alpha)
                                                    .with_c_max(c_mb.map(|x| x * 1e6))
                                                    .with_metric(self.metric)
                                                    .with_micro_batches(mb)
                                                    .with_schedule(sched)
                                                    .with_straggler(strag)
                                                    .with_fault_seed(self.fault_seed);
                                                    self.push_fault_axes(&base, &mut out);
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The innermost fault-axis expansion of [`SweepGrid::scenarios`],
    /// split out to keep the nesting readable.
    fn push_fault_axes(&self, base: &Scenario, out: &mut Vec<Scenario>) {
        for &hetero in &self.heteros {
            for &fail in &self.fail_ranks {
                for &mttf in &self.mttfs {
                    for &ckpt in &self.ckpt_intervals {
                        out.push(
                            base.clone()
                                .with_hetero(hetero)
                                .with_fail_rank(fail)
                                .with_mttf(mttf)
                                .with_ckpt_interval(ckpt),
                        );
                    }
                }
            }
        }
    }

    /// Render the grid back to the CLI argument strings that reproduce
    /// it: `SweepGrid::parse` of the result is `==` to `self` (the
    /// round-trip `tests/grid_roundtrip.rs` pins). Every axis is
    /// emitted explicitly (canonical form — no reliance on defaults),
    /// as comma-joined lists; f64 values use Rust's shortest
    /// round-trip `Display`, so re-parsing recovers identical bits.
    pub fn to_cli_args(&self) -> Vec<String> {
        fn join<T, F: Fn(&T) -> String>(items: &[T], f: F) -> String {
            items.iter().map(f).collect::<Vec<_>>().join(",")
        }
        let metric = match self.metric {
            CostMetric::Numel => "numel",
            CostMetric::Flops => "flops",
            CostMetric::StateBytes => "state",
        };
        vec![
            "--models".into(),
            join(&self.models, |m| m.label().to_ascii_lowercase()),
            "--dp".into(),
            join(&self.dp, usize::to_string),
            "--tp".into(),
            join(&self.tp, usize::to_string),
            "--pp".into(),
            join(&self.pp, usize::to_string),
            "--micro-batches".into(),
            join(&self.micro_batches, usize::to_string),
            "--schedule".into(),
            join(&self.schedules, |s| s.label().to_string()),
            "--straggler".into(),
            join(&self.stragglers, f64::to_string),
            "--optims".into(),
            join(&self.optims, |o| o.label().to_ascii_lowercase()),
            "--strategies".into(),
            join(&self.strategies, |s| s.label().to_ascii_lowercase()),
            "--alphas".into(),
            join(&self.alphas, f64::to_string),
            "--c-max-mb".into(),
            join(&self.c_max_mb, |c| match c {
                None => "none".to_string(),
                Some(mb) => mb.to_string(),
            }),
            "--hetero".into(),
            join(&self.heteros, |h| h.to_string()),
            "--fail-rank".into(),
            join(&self.fail_ranks, |f| match f {
                None => "none".to_string(),
                Some(spec) => spec.to_string(),
            }),
            "--mttf".into(),
            join(&self.mttfs, |m| match m {
                None => "none".to_string(),
                Some(s) => s.to_string(),
            }),
            "--ckpt-interval".into(),
            join(&self.ckpt_intervals, usize::to_string),
            "--fault-seed".into(),
            self.fault_seed.to_string(),
            "--metric".into(),
            metric.to_string(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()), &[]).unwrap()
    }

    #[test]
    fn default_grid_is_paper_main() {
        let g = SweepGrid::default();
        assert_eq!(g.len(), 1);
        let s = &g.scenarios()[0];
        assert_eq!(s.dp, 32);
        assert_eq!(s.tp, 8);
        assert_eq!(s.strategy, DpStrategy::LbAsc);
    }

    #[test]
    fn parses_axes_and_expands_in_order() {
        let g = SweepGrid::parse(&argv(
            "--models 1.7b,8b --tp 2,4 --strategies asc,lb-asc")).unwrap();
        assert_eq!(g.len(), 8);
        let scens = g.scenarios();
        assert_eq!(scens.len(), 8);
        // Axis order: model varies slowest, strategy fastest here.
        assert_eq!(scens[0].label, "Qwen3-1.7B");
        assert_eq!(scens[0].tp, 2);
        assert_eq!(scens[0].strategy, DpStrategy::Asc);
        assert_eq!(scens[1].strategy, DpStrategy::LbAsc);
        assert_eq!(scens[4].label, "Qwen3-8B");
    }

    #[test]
    fn c_max_none_disables_fusion() {
        let g = SweepGrid::parse(&argv("--c-max-mb none,256")).unwrap();
        let scens = g.scenarios();
        assert_eq!(scens[0].c_max_bytes, None);
        assert_eq!(scens[1].c_max_bytes, Some(256e6));
    }

    #[test]
    fn rejects_bad_axes() {
        assert!(SweepGrid::parse(&argv("--models 70b")).is_err());
        assert!(SweepGrid::parse(&argv("--strategies warp")).is_err());
        assert!(SweepGrid::parse(&argv("--metric vibes")).is_err());
        assert!(SweepGrid::parse(&argv("--dp ,")).is_err());
        // Values that would panic deep in the planners must error here.
        assert!(SweepGrid::parse(&argv("--dp 0")).is_err());
        assert!(SweepGrid::parse(&argv("--tp 0,2")).is_err());
        assert!(SweepGrid::parse(&argv("--pp 0")).is_err());
        assert!(SweepGrid::parse(&argv("--alphas 1.5")).is_err());
        assert!(SweepGrid::parse(&argv("--alphas -0.1")).is_err());
        assert!(SweepGrid::parse(&argv("--micro-batches 0")).is_err());
        assert!(SweepGrid::parse(&argv("--schedule zigzag")).is_err());
        assert!(SweepGrid::parse(&argv("--straggler 0.5")).is_err());
        assert!(SweepGrid::parse(&argv("--straggler nan")).is_err());
        // Fault axes reject malformed values the same way.
        assert!(SweepGrid::parse(&argv("--hetero bogus")).is_err());
        assert!(SweepGrid::parse(&argv("--hetero slow:2:1.5")).is_err());
        assert!(SweepGrid::parse(&argv("--fail-rank 3@2")).is_err());
        assert!(SweepGrid::parse(&argv("--fail-rank x")).is_err());
        assert!(SweepGrid::parse(&argv("--mttf 0")).is_err());
        assert!(SweepGrid::parse(&argv("--mttf nan")).is_err());
        assert!(SweepGrid::parse(&argv("--ckpt-interval 0")).is_err());
        assert!(SweepGrid::parse(&argv("--fault-seed abc")).is_err());
    }

    #[test]
    fn integer_axes_accept_inclusive_ranges() {
        let g = SweepGrid::parse(&argv("--dp 1,4..6,16 --tp 2..2 --pp 1..3")).unwrap();
        assert_eq!(g.dp, vec![1, 4, 5, 6, 16]);
        assert_eq!(g.tp, vec![2]);
        assert_eq!(g.pp, vec![1, 2, 3]);
        // Degenerate/reversed/zero-anchored ranges are errors, not
        // silent empties — an empty axis would zero the cross product.
        assert!(SweepGrid::parse(&argv("--dp 6..4")).is_err());
        assert!(SweepGrid::parse(&argv("--dp 0..2")).is_err());
        assert!(SweepGrid::parse(&argv("--dp 1..")).is_err());
        assert!(SweepGrid::parse(&argv("--dp ..4")).is_err());
        assert!(SweepGrid::parse(&argv("--micro-batches 1..2,,4")).is_err());
    }

    #[test]
    fn cli_args_round_trip_is_identity() {
        // The deterministic companion of tests/grid_roundtrip.rs's
        // property sweep: a hand-built grid survives
        // to_cli_args -> parse exactly (PartialEq, f64 bits included).
        let g = SweepGrid {
            models: vec![Qwen3Size::S1_7B, Qwen3Size::S32B],
            dp: vec![4, 8, 32],
            tp: vec![1, 8],
            pp: vec![1, 2],
            micro_batches: vec![1, 8],
            schedules: vec![PipelineSchedule::OneFOneB, PipelineSchedule::GPipe],
            stragglers: vec![1.0, 1.25],
            optims: vec![OptimKind::Muon, OptimKind::AdamW],
            strategies: vec![DpStrategy::Sc, DpStrategy::NvLayerwise, DpStrategy::LbAsc],
            alphas: vec![0.0, 0.5, 1.0],
            c_max_mb: vec![None, Some(64.0), Some(512.5)],
            heteros: vec![
                HeteroSpec::None,
                HeteroSpec::parse("last:1.25").unwrap(),
                HeteroSpec::parse("slow:0.1:2+link:0.25:4").unwrap(),
            ],
            fail_ranks: vec![None, Some(FailSpec { rank: 3, at: 0.25 })],
            mttfs: vec![None, Some(1800.0)],
            ckpt_intervals: vec![1, 8],
            metric: CostMetric::StateBytes,
            fault_seed: 7,
        };
        let cli = g.to_cli_args();
        let reparsed =
            SweepGrid::parse(&Args::parse(cli.into_iter(), &[]).unwrap()).unwrap();
        assert_eq!(reparsed, g);
    }

    #[test]
    fn rejects_interior_empty_segments() {
        // Pre-fix: empty segments were filtered before validation, so
        // `--dp 1,,2` passed while `--dp ,` errored.
        assert!(SweepGrid::parse(&argv("--dp 1,,2")).is_err());
        assert!(SweepGrid::parse(&argv("--tp 2,")).is_err());
        assert!(SweepGrid::parse(&argv("--optims ,muon")).is_err());
        assert!(SweepGrid::parse(&argv("--alphas 0.5,,1.0")).is_err());
        // Well-formed lists still parse.
        assert!(SweepGrid::parse(&argv("--dp 1,2")).is_ok());
    }

    #[test]
    fn parses_pipeline_axes() {
        let g = SweepGrid::parse(&argv(
            "--pp 1,2,4 --micro-batches 1,8 --schedule 1f1b,gpipe --straggler 1.0,1.5",
        ))
        .unwrap();
        assert_eq!(g.len(), 3 * 2 * 2 * 2);
        let scens = g.scenarios();
        assert_eq!(scens.len(), 24);
        // Axis order: pp slowest of the four, straggler fastest.
        assert_eq!(scens[0].pp, 1);
        assert_eq!(scens[0].micro_batches, 1);
        assert_eq!(scens[0].schedule, PipelineSchedule::OneFOneB);
        assert_eq!(scens[0].straggler, 1.0);
        assert_eq!(scens[1].straggler, 1.5);
        assert_eq!(scens[2].schedule, PipelineSchedule::GPipe);
        assert_eq!(scens[4].micro_batches, 8);
        assert_eq!(scens[8].pp, 2);
    }
}
