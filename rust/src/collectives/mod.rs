//! In-memory collectives over thread "ranks".
//!
//! The numeric training path (paper Fig. 5 parity) runs DP ranks as OS
//! threads sharing a [`Group`]. Collectives rendezvous on barriers and
//! reduce in **fixed rank order**, so results are bitwise deterministic —
//! the property that lets the parity tests compare SC vs LB-ASC runs
//! exactly. Variable-size Reduce-Scatter / All-Gather mirror the
//! non-uniform shard geometry of Section 3.3; byte counters feed the
//! communication-volume assertions (All-Reduce = 2x Reduce-Scatter).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex, RwLock};

/// Shared state of one communicator group.
pub struct Group {
    ranks: usize,
    barrier: Barrier,
    deposits: RwLock<Vec<Option<Vec<f32>>>>,
    /// Per-rank partial results (each rank reduces / assembles its own
    /// disjoint segment in parallel — the §Perf optimization that
    /// replaced the original rank-0 sequential reduction).
    partials: Vec<Mutex<Vec<f32>>>,
    result: Mutex<Vec<f32>>,
    /// All-to-all mailbox: `mail[src][dst]`.
    mail: Mutex<Vec<Vec<Option<Vec<f32>>>>>,
    pub bytes_reduce_scatter: AtomicU64,
    pub bytes_all_gather: AtomicU64,
    pub bytes_all_reduce: AtomicU64,
    pub bytes_all_to_all: AtomicU64,
    pub bytes_broadcast: AtomicU64,
}

impl Group {
    pub fn new(ranks: usize) -> Arc<Group> {
        Arc::new(Group {
            ranks,
            barrier: Barrier::new(ranks),
            deposits: RwLock::new(vec![None; ranks]),
            partials: (0..ranks).map(|_| Mutex::new(Vec::new())).collect(),
            result: Mutex::new(Vec::new()),
            mail: Mutex::new(vec![vec![None; ranks]; ranks]),
            bytes_reduce_scatter: AtomicU64::new(0),
            bytes_all_gather: AtomicU64::new(0),
            bytes_all_reduce: AtomicU64::new(0),
            bytes_all_to_all: AtomicU64::new(0),
            bytes_broadcast: AtomicU64::new(0),
        })
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Total bytes across all collectives (per-GPU wire estimate).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_reduce_scatter.load(Ordering::Relaxed)
            + self.bytes_all_gather.load(Ordering::Relaxed)
            + self.bytes_all_reduce.load(Ordering::Relaxed)
            + self.bytes_all_to_all.load(Ordering::Relaxed)
            + self.bytes_broadcast.load(Ordering::Relaxed)
    }
}

/// One rank's handle on the group.
#[derive(Clone)]
pub struct Communicator {
    pub rank: usize,
    pub group: Arc<Group>,
}

impl Communicator {
    pub fn new(group: Arc<Group>, rank: usize) -> Communicator {
        assert!(rank < group.ranks());
        Communicator { rank, group }
    }

    pub fn ranks(&self) -> usize {
        self.group.ranks
    }

    pub fn barrier(&self) {
        self.group.barrier.wait();
    }

    /// All-Reduce (sum). `2·B·(R-1)/R` wire bytes accounted per rank.
    ///
    /// §Perf: each rank reduces a disjoint 1/R segment in parallel
    /// (fixed rank-order sum per element => bitwise deterministic), then
    /// assembles the full vector from the per-rank partials. ~R× faster
    /// than the original rank-0 sequential reduction.
    pub fn all_reduce(&self, data: &[f32]) -> Vec<f32> {
        let ranks = self.group.ranks;
        let r64 = ranks as u64;
        self.group.bytes_all_reduce.fetch_add(
            2 * (data.len() as u64 * 4) * (r64 - 1) / r64, Ordering::Relaxed);
        let n = data.len();
        {
            let mut dep = self.group.deposits.write().unwrap();
            dep[self.rank] = Some(data.to_vec());
        }
        self.group.barrier.wait();
        // Parallel phase: reduce my segment from all deposits.
        let seg = n.div_ceil(ranks);
        let lo = (self.rank * seg).min(n);
        let hi = ((self.rank + 1) * seg).min(n);
        {
            let dep = self.group.deposits.read().unwrap();
            let mut acc = vec![0.0f32; hi - lo];
            for r in 0..ranks {
                let contrib = dep[r].as_ref().expect("missing deposit");
                debug_assert_eq!(contrib.len(), n, "length mismatch in reduce");
                for (a, c) in acc.iter_mut().zip(&contrib[lo..hi]) {
                    *a += c;
                }
            }
            *self.group.partials[self.rank].lock().unwrap() = acc;
        }
        self.group.barrier.wait();
        // Assemble the full vector from partials (parallel reads).
        let mut out = Vec::with_capacity(n);
        for r in 0..ranks {
            out.extend_from_slice(&self.group.partials[r].lock().unwrap());
        }
        self.group.barrier.wait();
        out
    }

    /// Variable-size Reduce-Scatter: reduce `data` (the whole bucket),
    /// return this rank's `sizes[rank]`-sized shard.
    ///
    /// §Perf: each rank reduces **only its own shard** — the work is the
    /// plan's shard distribution, exactly like the real collective, and
    /// no full-buffer result is ever materialised.
    pub fn reduce_scatter_v(&self, data: &[f32], sizes: &[usize]) -> Vec<f32> {
        assert_eq!(sizes.len(), self.group.ranks);
        assert_eq!(sizes.iter().sum::<usize>(), data.len(), "shard sizes != buffer");
        let r64 = self.group.ranks as u64;
        self.group.bytes_reduce_scatter.fetch_add(
            (data.len() as u64 * 4) * (r64 - 1) / r64, Ordering::Relaxed);
        {
            let mut dep = self.group.deposits.write().unwrap();
            dep[self.rank] = Some(data.to_vec());
        }
        self.group.barrier.wait();
        let start: usize = sizes[..self.rank].iter().sum();
        let end = start + sizes[self.rank];
        let mut acc = vec![0.0f32; end - start];
        {
            let dep = self.group.deposits.read().unwrap();
            for r in 0..self.group.ranks {
                let contrib = dep[r].as_ref().expect("missing deposit");
                for (a, c) in acc.iter_mut().zip(&contrib[start..end]) {
                    *a += c;
                }
            }
        }
        self.group.barrier.wait();
        acc
    }

    /// Variable-size All-Gather: concatenate per-rank shards in rank
    /// order. `shard.len()` must equal `sizes[rank]`.
    ///
    /// §Perf: every rank assembles its own copy directly from the
    /// deposits (parallel), instead of a rank-0 assembly + broadcast.
    pub fn all_gather_v(&self, shard: &[f32], sizes: &[usize]) -> Vec<f32> {
        assert_eq!(sizes.len(), self.group.ranks);
        assert_eq!(shard.len(), sizes[self.rank], "shard size mismatch");
        let total: usize = sizes.iter().sum();
        let r64 = self.group.ranks as u64;
        self.group.bytes_all_gather.fetch_add(
            (total as u64 * 4) * (r64 - 1) / r64, Ordering::Relaxed);
        {
            let mut dep = self.group.deposits.write().unwrap();
            dep[self.rank] = Some(shard.to_vec());
        }
        self.group.barrier.wait();
        let mut out = Vec::with_capacity(total);
        {
            let dep = self.group.deposits.read().unwrap();
            for r in 0..self.group.ranks {
                let s = dep[r].as_ref().expect("missing shard");
                assert_eq!(s.len(), sizes[r]);
                out.extend_from_slice(s);
            }
        }
        self.group.barrier.wait();
        out
    }

    /// Fused All-to-All: `sends[d]` goes to rank d; returns what every
    /// rank sent to us, indexed by source.
    pub fn all_to_all(&self, sends: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        assert_eq!(sends.len(), self.group.ranks);
        let bytes: u64 = sends
            .iter()
            .enumerate()
            .filter(|(d, _)| *d != self.rank)
            .map(|(_, v)| v.len() as u64 * 4)
            .sum();
        self.group.bytes_all_to_all.fetch_add(bytes, Ordering::Relaxed);
        {
            let mut mail = self.group.mail.lock().unwrap();
            for (d, payload) in sends.into_iter().enumerate() {
                mail[self.rank][d] = Some(payload);
            }
        }
        self.group.barrier.wait();
        let mut received = Vec::with_capacity(self.group.ranks);
        {
            let mut mail = self.group.mail.lock().unwrap();
            for src in 0..self.group.ranks {
                received.push(mail[src][self.rank].take().expect("missing mail"));
            }
        }
        self.group.barrier.wait();
        received
    }

    /// Broadcast from `root`.
    pub fn broadcast(&self, data: Option<&[f32]>, root: usize) -> Vec<f32> {
        if self.rank == root {
            let payload = data.expect("root must provide data");
            self.group.bytes_broadcast.fetch_add(payload.len() as u64 * 4,
                                                 Ordering::Relaxed);
            *self.group.result.lock().unwrap() = payload.to_vec();
        }
        self.group.barrier.wait();
        let out = self.group.result.lock().unwrap().clone();
        self.group.barrier.wait();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_ranks<F, T>(ranks: usize, f: F) -> Vec<T>
    where
        F: Fn(Communicator) -> T + Send + Sync + 'static,
        T: Send + 'static,
    {
        let group = Group::new(ranks);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..ranks)
            .map(|r| {
                let comm = Communicator::new(group.clone(), r);
                let f = f.clone();
                thread::spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums() {
        let results = run_ranks(4, |c| {
            let data = vec![c.rank as f32 + 1.0; 8];
            c.all_reduce(&data)
        });
        for r in results {
            assert_eq!(r, vec![10.0; 8]); // 1+2+3+4
        }
    }

    #[test]
    fn reduce_scatter_v_shards() {
        let sizes = [2usize, 0, 3, 1];
        let results = run_ranks(4, move |c| {
            let data: Vec<f32> = (0..6).map(|i| (i as f32) * (c.rank as f32 + 1.0)).collect();
            c.reduce_scatter_v(&data, &sizes)
        });
        // Sum over ranks: factor 1+2+3+4 = 10 -> [0, 10, 20, 30, 40, 50]
        assert_eq!(results[0], vec![0.0, 10.0]);
        assert_eq!(results[1], Vec::<f32>::new());
        assert_eq!(results[2], vec![20.0, 30.0, 40.0]);
        assert_eq!(results[3], vec![50.0]);
    }

    #[test]
    fn all_gather_v_concatenates() {
        let sizes = [1usize, 3, 0, 2];
        let results = run_ranks(4, move |c| {
            let shard = vec![c.rank as f32; sizes[c.rank]];
            c.all_gather_v(&shard, &sizes)
        });
        for r in results {
            assert_eq!(r, vec![0.0, 1.0, 1.0, 1.0, 3.0, 3.0]);
        }
    }

    #[test]
    fn rs_then_ag_is_identity_of_sum() {
        let sizes = [3usize, 2, 1, 2];
        let results = run_ranks(4, move |c| {
            let data: Vec<f32> = (0..8).map(|i| i as f32 + c.rank as f32).collect();
            let shard = c.reduce_scatter_v(&data, &sizes);
            c.all_gather_v(&shard, &sizes)
        });
        let expect: Vec<f32> = (0..8).map(|i| 4.0 * i as f32 + 6.0).collect();
        for r in results {
            assert_eq!(r, expect);
        }
    }

    #[test]
    fn all_to_all_routes() {
        let results = run_ranks(3, |c| {
            let sends: Vec<Vec<f32>> = (0..3)
                .map(|d| vec![(c.rank * 10 + d) as f32])
                .collect();
            c.all_to_all(sends)
        });
        // results[receiver][src] == src*10 + receiver
        for (recv, inbox) in results.iter().enumerate() {
            for (src, payload) in inbox.iter().enumerate() {
                assert_eq!(payload, &vec![(src * 10 + recv) as f32]);
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let results = run_ranks(4, |c| {
            if c.rank == 2 {
                c.broadcast(Some(&[7.0, 8.0]), 2)
            } else {
                c.broadcast(None, 2)
            }
        });
        for r in results {
            assert_eq!(r, vec![7.0, 8.0]);
        }
    }

    #[test]
    fn byte_accounting_ar_is_2x_rs() {
        let group = Group::new(4);
        let g2 = group.clone();
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let comm = Communicator::new(g2.clone(), r);
                thread::spawn(move || {
                    let data = vec![1.0f32; 100];
                    comm.all_reduce(&data);
                    comm.reduce_scatter_v(&data, &[25, 25, 25, 25]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ar = group.bytes_all_reduce.load(Ordering::Relaxed);
        let rs = group.bytes_reduce_scatter.load(Ordering::Relaxed);
        assert_eq!(ar, 2 * rs);
    }

    #[test]
    fn deterministic_reduction_order() {
        // Sum of floats depends on order; fixed order => identical bits
        // across repeated runs.
        let run = || {
            run_ranks(4, |c| {
                let data: Vec<f32> = (0..64)
                    .map(|i| ((i * (c.rank + 7)) as f32 * 0.1).sin())
                    .collect();
                c.all_reduce(&data)
            })
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }
}
