//! Core tensor / parameter descriptors.

use std::fmt;

/// A (possibly 1-D) tensor shape. Matrix-based optimizers act on 2-D
/// shapes; 1-D shapes route to the element-wise optimizer.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TensorShape(pub Vec<usize>);

impl TensorShape {
    pub fn matrix(m: usize, n: usize) -> TensorShape {
        TensorShape(vec![m, n])
    }

    pub fn vector(n: usize) -> TensorShape {
        TensorShape(vec![n])
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn is_matrix(&self) -> bool {
        self.0.len() == 2
    }

    /// Rows of a 2-D shape (panics on 1-D).
    pub fn rows(&self) -> usize {
        assert!(self.is_matrix());
        self.0[0]
    }

    /// Cols of a 2-D shape (panics on 1-D).
    pub fn cols(&self) -> usize {
        assert!(self.is_matrix());
        self.0[1]
    }
}

impl fmt::Display for TensorShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.0.iter().map(|d| d.to_string()).collect();
        write!(f, "[{}]", dims.join("x"))
    }
}

/// Parameter classification — decides optimizer routing (standard Muon
/// practice: embeddings/head/norms go to AdamW, hidden matrices to the
/// matrix-based optimizer) and init scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ParamKind {
    /// 2-D hidden matrix — updated by the matrix-based optimizer.
    Matrix,
    /// Embedding-class 2-D tensor (embed / lm_head) — AdamW.
    Embed,
    /// 1-D tensor (norm weights, biases) — AdamW.
    Vector,
}

/// One named parameter in the census. `start` is its offset in the
/// flattened `param_and_grad_buffer` (filled by `buffer::FlatBuffer`).
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub shape: TensorShape,
    pub kind: ParamKind,
    /// Layer index (None for embed/head/final-norm) — used by the
    /// layerwise baseline partitioner.
    pub layer: Option<usize>,
}

impl Param {
    pub fn new(name: &str, shape: TensorShape, kind: ParamKind, layer: Option<usize>) -> Param {
        Param { name: name.to_string(), shape, kind, layer }
    }

    pub fn numel(&self) -> usize {
        self.shape.numel()
    }

    /// Whether the matrix-based optimizer (Muon/Shampoo/SOAP) owns this
    /// parameter's update.
    pub fn is_matrix_opt(&self) -> bool {
        self.kind == ParamKind::Matrix
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_accessors() {
        let s = TensorShape::matrix(4, 6);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.rows(), 4);
        assert_eq!(s.cols(), 6);
        assert!(s.is_matrix());
        assert!(!TensorShape::vector(5).is_matrix());
    }

    #[test]
    fn display() {
        assert_eq!(TensorShape::matrix(2, 3).to_string(), "[2x3]");
        assert_eq!(TensorShape::vector(7).to_string(), "[7]");
    }

    #[test]
    fn kind_routing() {
        let p = Param::new("w", TensorShape::matrix(8, 8), ParamKind::Matrix, Some(0));
        assert!(p.is_matrix_opt());
        let e = Param::new("e", TensorShape::matrix(100, 8), ParamKind::Embed, None);
        assert!(!e.is_matrix_opt());
    }
}
