//! Megatron tensor-parallel splitting rules.
//!
//! Column parallelism splits the *output* dimension (wq/wk/wv, mlp
//! gate/up); row parallelism splits the *input* dimension (wo, mlp down);
//! the vocabulary dimension of embedding/head is split across ranks.
//! Norm vectors are replicated. Each TP shard of a matrix parameter is the
//! fragment the paper's TP-ASC pipeline must reassemble (via fused
//! All-to-All) before the matrix-based optimizer can update it.

use super::shapes::{Param, ParamKind, TensorShape};

/// How a parameter is laid out across the TP group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpSplit {
    /// Output-dim split: shard shape = (rows, cols / tp).
    Column,
    /// Input-dim split: shard shape = (rows / tp, cols).
    Row,
    /// Vocab-dim split (embedding / lm_head): (rows / tp, cols).
    Vocab,
    /// Replicated on every TP rank (norms, small vectors).
    Replicated,
}

/// One parameter's TP placement: the split rule and the per-rank shard.
#[derive(Clone, Debug)]
pub struct TpShard {
    pub param: Param,
    pub split: TpSplit,
    /// Shape of the local shard on one TP rank.
    pub shard_shape: TensorShape,
    /// numel of the local shard.
    pub shard_numel: usize,
}

/// Classify a parameter under Megatron's split rules.
pub fn split_rule(p: &Param) -> TpSplit {
    match p.kind {
        ParamKind::Vector => TpSplit::Replicated,
        ParamKind::Embed => TpSplit::Vocab,
        ParamKind::Matrix => {
            if p.name.ends_with("attn.wo") || p.name.ends_with("mlp.down") {
                TpSplit::Row
            } else {
                TpSplit::Column
            }
        }
    }
}

/// Split a census across `tp` ranks. Panics if a split dimension is not
/// divisible by `tp` (Megatron requires divisibility; the Qwen3 dims are
/// chosen so tp in {1, 2, 4, 8} divides everything).
pub fn tp_split(params: &[Param], tp: usize) -> Vec<TpShard> {
    assert!(tp >= 1);
    params
        .iter()
        .map(|p| {
            let split = split_rule(p);
            let shard_shape = match split {
                TpSplit::Replicated => p.shape.clone(),
                TpSplit::Column => {
                    assert_eq!(p.shape.cols() % tp, 0,
                               "{}: cols {} not divisible by tp {tp}", p.name, p.shape.cols());
                    TensorShape::matrix(p.shape.rows(), p.shape.cols() / tp)
                }
                TpSplit::Row | TpSplit::Vocab => {
                    assert_eq!(p.shape.rows() % tp, 0,
                               "{}: rows {} not divisible by tp {tp}", p.name, p.shape.rows());
                    TensorShape::matrix(p.shape.rows() / tp, p.shape.cols())
                }
            };
            let shard_numel = shard_shape.numel();
            TpShard { param: p.clone(), split, shard_shape, shard_numel }
        })
        .collect()
}

/// The TP-plane optimizer tasks: matrix parameters that are fragmented
/// (i.e. actually split) and therefore need reconstruction before a
/// holistic update. Replicated params and tp=1 shards are excluded.
pub fn fragmented_matrix_params(shards: &[TpShard], tp: usize) -> Vec<TpShard> {
    shards
        .iter()
        .filter(|s| {
            s.param.is_matrix_opt() && tp > 1 && s.split != TpSplit::Replicated
        })
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::qwen3::{qwen3, Qwen3Size};

    #[test]
    fn shard_numel_sums_to_full() {
        let params = qwen3(Qwen3Size::S1_7B);
        for tp in [1, 2, 4, 8] {
            let shards = tp_split(&params, tp);
            for s in &shards {
                match s.split {
                    TpSplit::Replicated => assert_eq!(s.shard_numel, s.param.numel()),
                    _ => assert_eq!(s.shard_numel * tp, s.param.numel(), "{}", s.param.name),
                }
            }
        }
    }

    #[test]
    fn split_rules() {
        let params = qwen3(Qwen3Size::S4B);
        let shards = tp_split(&params, 4);
        let find = |n: &str| shards.iter().find(|s| s.param.name.ends_with(n)).unwrap();
        assert_eq!(find("attn.wq").split, TpSplit::Column);
        assert_eq!(find("attn.wo").split, TpSplit::Row);
        assert_eq!(find("mlp.gate").split, TpSplit::Column);
        assert_eq!(find("mlp.down").split, TpSplit::Row);
        assert_eq!(find("embed.weight").split, TpSplit::Vocab);
        assert_eq!(find("attn_norm.weight").split, TpSplit::Replicated);
    }

    #[test]
    fn column_split_shapes() {
        let params = qwen3(Qwen3Size::S8B);
        let shards = tp_split(&params, 8);
        let wq = shards.iter().find(|s| s.param.name == "layers.0.attn.wq").unwrap();
        assert_eq!(wq.shard_shape.rows(), wq.param.shape.rows());
        assert_eq!(wq.shard_shape.cols() * 8, wq.param.shape.cols());
    }

    #[test]
    fn fragmented_excludes_replicated_and_tp1() {
        let params = qwen3(Qwen3Size::S1_7B);
        let shards1 = tp_split(&params, 1);
        assert!(fragmented_matrix_params(&shards1, 1).is_empty());
        let shards4 = tp_split(&params, 4);
        let frag = fragmented_matrix_params(&shards4, 4);
        assert!(!frag.is_empty());
        assert!(frag.iter().all(|s| s.param.is_matrix_opt()));
    }
}
