//! Qwen3 family architecture census (1.7B … 32B).
//!
//! Dimensions follow the published Qwen3 technical report configurations
//! (GQA with 8 KV heads, head_dim 128, untied heads for the larger
//! models, QK-norm vectors). Minor details (e.g. tie-embedding on the
//! smallest models) are noted inline; the load-balance experiments only
//! depend on the shape census, which these match.

use super::shapes::{Param, ParamKind, TensorShape};

/// The model sizes evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Qwen3Size {
    S1_7B,
    S4B,
    S8B,
    S14B,
    S32B,
}

impl Qwen3Size {
    pub fn all() -> [Qwen3Size; 5] {
        [Qwen3Size::S1_7B, Qwen3Size::S4B, Qwen3Size::S8B, Qwen3Size::S14B, Qwen3Size::S32B]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Qwen3Size::S1_7B => "Qwen3-1.7B",
            Qwen3Size::S4B => "Qwen3-4B",
            Qwen3Size::S8B => "Qwen3-8B",
            Qwen3Size::S14B => "Qwen3-14B",
            Qwen3Size::S32B => "Qwen3-32B",
        }
    }

    pub fn parse(s: &str) -> Option<Qwen3Size> {
        match s.to_ascii_lowercase().as_str() {
            "1.7b" | "qwen3-1.7b" => Some(Qwen3Size::S1_7B),
            "4b" | "qwen3-4b" => Some(Qwen3Size::S4B),
            "8b" | "qwen3-8b" => Some(Qwen3Size::S8B),
            "14b" | "qwen3-14b" => Some(Qwen3Size::S14B),
            "32b" | "qwen3-32b" => Some(Qwen3Size::S32B),
            _ => None,
        }
    }
}

/// Architecture hyper-parameters of one family member.
#[derive(Clone, Debug)]
pub struct Arch {
    pub name: &'static str,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
}

pub fn arch(size: Qwen3Size) -> Arch {
    // Qwen3 technical report, Table 1 (dense models).
    match size {
        Qwen3Size::S1_7B => Arch { name: "Qwen3-1.7B", vocab: 151_936, hidden: 2048,
            layers: 28, heads: 16, kv_heads: 8, head_dim: 128, intermediate: 6144 },
        Qwen3Size::S4B => Arch { name: "Qwen3-4B", vocab: 151_936, hidden: 2560,
            layers: 36, heads: 32, kv_heads: 8, head_dim: 128, intermediate: 9728 },
        Qwen3Size::S8B => Arch { name: "Qwen3-8B", vocab: 151_936, hidden: 4096,
            layers: 36, heads: 32, kv_heads: 8, head_dim: 128, intermediate: 12_288 },
        Qwen3Size::S14B => Arch { name: "Qwen3-14B", vocab: 151_936, hidden: 5120,
            layers: 40, heads: 40, kv_heads: 8, head_dim: 128, intermediate: 17_408 },
        Qwen3Size::S32B => Arch { name: "Qwen3-32B", vocab: 151_936, hidden: 5120,
            layers: 64, heads: 64, kv_heads: 8, head_dim: 128, intermediate: 25_600 },
    }
}

/// Full ordered parameter census for one family member, in registration
/// order (the order Megatron packs them into the flat buffer).
pub fn qwen3(size: Qwen3Size) -> Vec<Param> {
    let a = arch(size);
    let mut params = Vec::new();
    let d = a.hidden;
    let q_out = a.heads * a.head_dim;
    let kv_out = a.kv_heads * a.head_dim;

    params.push(Param::new("embed.weight", TensorShape::matrix(a.vocab, d),
                           ParamKind::Embed, None));
    for i in 0..a.layers {
        let p = |suffix: &str| format!("layers.{i}.{suffix}");
        let mat = |name: String, m: usize, n: usize| {
            Param::new(&name, TensorShape::matrix(m, n), ParamKind::Matrix, Some(i))
        };
        let vec_ = |name: String, n: usize| {
            Param::new(&name, TensorShape::vector(n), ParamKind::Vector, Some(i))
        };
        params.push(vec_(p("attn_norm.weight"), d));
        params.push(mat(p("attn.wq"), d, q_out));
        params.push(mat(p("attn.wk"), d, kv_out));
        params.push(mat(p("attn.wv"), d, kv_out));
        // Qwen3 QK-norm: per-head-dim RMSNorm weights.
        params.push(vec_(p("attn.q_norm"), a.head_dim));
        params.push(vec_(p("attn.k_norm"), a.head_dim));
        params.push(mat(p("attn.wo"), q_out, d));
        params.push(vec_(p("mlp_norm.weight"), d));
        params.push(mat(p("mlp.gate"), d, a.intermediate));
        params.push(mat(p("mlp.up"), d, a.intermediate));
        params.push(mat(p("mlp.down"), a.intermediate, d));
    }
    params.push(Param::new("final_norm.weight", TensorShape::vector(d),
                           ParamKind::Vector, None));
    params.push(Param::new("lm_head.weight", TensorShape::matrix(a.vocab, d),
                           ParamKind::Embed, None));
    params
}

/// Total parameter count of a census.
pub fn total_params(params: &[Param]) -> usize {
    params.iter().map(|p| p.numel()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_sizes_roughly_match_names() {
        // Untied lm_head inflates the nominal size; require ballpark match.
        let cases = [
            (Qwen3Size::S1_7B, 1.7e9, 2.6e9),
            (Qwen3Size::S4B, 3.5e9, 5.2e9),
            (Qwen3Size::S8B, 7.0e9, 9.6e9),
            (Qwen3Size::S14B, 13.0e9, 16.5e9),
            (Qwen3Size::S32B, 30.0e9, 35.0e9),
        ];
        for (size, lo, hi) in cases {
            let n = total_params(&qwen3(size)) as f64;
            assert!(n > lo && n < hi, "{}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]",
                    size.label());
        }
    }

    #[test]
    fn census_structure() {
        let params = qwen3(Qwen3Size::S1_7B);
        let a = arch(Qwen3Size::S1_7B);
        // embed + head + final norm + 11 per layer
        assert_eq!(params.len(), 3 + a.layers * 11);
        // unique names
        let mut names: Vec<&str> = params.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), params.len());
    }

    #[test]
    fn kind_classification() {
        for p in qwen3(Qwen3Size::S4B) {
            match p.kind {
                ParamKind::Matrix => {
                    assert!(p.shape.is_matrix());
                    assert!(p.layer.is_some());
                }
                ParamKind::Embed => assert!(p.name.contains("embed") || p.name.contains("lm_head")),
                ParamKind::Vector => assert_eq!(p.shape.0.len(), 1),
            }
        }
    }

    #[test]
    fn gqa_shapes() {
        let params = qwen3(Qwen3Size::S32B);
        let wq = params.iter().find(|p| p.name == "layers.0.attn.wq").unwrap();
        let wk = params.iter().find(|p| p.name == "layers.0.attn.wk").unwrap();
        assert_eq!(wq.shape.cols(), 64 * 128);
        assert_eq!(wk.shape.cols(), 8 * 128); // 8 KV heads
    }

    #[test]
    fn parse_labels() {
        assert_eq!(Qwen3Size::parse("32b"), Some(Qwen3Size::S32B));
        assert_eq!(Qwen3Size::parse("Qwen3-1.7B"), Some(Qwen3Size::S1_7B));
        assert_eq!(Qwen3Size::parse("70b"), None);
    }

    #[test]
    fn heterogeneity_exists() {
        // The paper's premise: parameter sizes vary widely (embedding vs
        // norm vectors) => naive atomic assignment imbalances.
        let params = qwen3(Qwen3Size::S1_7B);
        let max = params.iter().map(|p| p.numel()).max().unwrap();
        let min = params.iter().map(|p| p.numel()).min().unwrap();
        assert!(max / min > 1000, "max {max} min {min}");
    }
}
