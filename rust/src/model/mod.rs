//! Model catalog: parameter shape census + tensor-parallel splitting.
//!
//! The paper's load-balancing problem is entirely determined by the
//! *shape inventory* of the trained model (Appendix D.5: cost metrics are
//! functions of tensor shapes). This module reproduces the Qwen3 family's
//! inventory and Megatron's column/row TP split rules.

pub mod qwen3;
pub mod shapes;
pub mod tp;

pub use qwen3::{qwen3, Qwen3Size};
pub use shapes::{Param, ParamKind, TensorShape};
pub use tp::{tp_split, TpShard, TpSplit};
