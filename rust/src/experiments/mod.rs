//! Paper-figure reproduction harnesses.
//!
//! One function per table/figure in the paper's evaluation. Each returns
//! rendered Markdown tables (via [`crate::util::table::Table`]) whose rows
//! mirror what the paper reports; `canzona experiment <id>` prints them
//! and `benches/paper_experiments.rs` regenerates them under `cargo
//! bench`. Expected *shapes* (who wins, by roughly what factor) are
//! documented per harness and recorded in EXPERIMENTS.md.

pub mod figures;
pub mod registry;

pub use registry::{list, run};
