//! Experiment registry: id -> harness, for the CLI and the bench driver.

use crate::bail;
use crate::util::error::Result;
use crate::util::table::Table;

use super::figures;

/// All registered experiments: (id, description, harness).
pub fn catalog() -> Vec<(&'static str, &'static str, fn() -> Vec<Table>)> {
    vec![
        ("fig3a", "Optimizer makespan: SC vs ASC vs LB-ASC", figures::fig3a),
        ("fig3bc", "DP/TP load-balance ratios with and without balancing", figures::fig3bc),
        ("fig4", "End-to-end iteration vs NV-layerwise", figures::fig4),
        ("fig6", "Family sweep vs NV-layerwise", figures::fig6),
        ("fig7", "Fwd-bwd comm efficiency vs AdamW anchors", figures::fig7),
        ("fig8", "DP / TP parallelism scaling", figures::fig8),
        ("fig9", "Model-size scaling of LB ratios", figures::fig9),
        ("fig10-11", "Shampoo & SOAP generality (efficiency)", figures::fig10_11),
        ("fig12", "Shampoo/SOAP load-balance ratios", figures::fig12),
        ("fig13", "Alpha ablation", figures::fig13),
        ("fig14", "C_max micro-group fusion ablation", figures::fig14),
        ("fig16", "Cost metric ablation (numel vs FLOPs)", figures::fig16),
        ("fig_pp", "PP sweep on the 1F1B timeline engine", figures::fig_pp),
        ("fig_optimize", "Search-derived best 256-GPU configs + headline speedups",
         figures::fig_optimize),
        ("fig_rivals", "Strategy zoo head-to-head: ladder vs MatrixFSDP/DMuon/Dion",
         figures::fig_rivals),
        ("fig_elastic", "Strategy zoo under slow nodes, degraded links, and failures",
         figures::fig_elastic),
        ("planning", "Appendix D.1 offline planning latency", figures::planning_latency),
    ]
}

/// List experiment ids + descriptions.
pub fn list() -> Vec<(&'static str, &'static str)> {
    catalog().into_iter().map(|(id, d, _)| (id, d)).collect()
}

/// Run one experiment (or "all") and return the rendered tables.
///
/// "all" runs the harnesses **sequentially in catalog order**; each
/// harness's scenario batches fan out N-wide over the shared sweep
/// engine's pool. All parallelism therefore routes through one
/// `util::pool` executor: live threads stay bounded by the pool's N
/// with full N-wide utilization inside each batch, instead of the old
/// harness-level pool nesting a scenario-level pool per harness
/// (threads ≈ N + 13·N worst case). Output bytes are independent of
/// scheduling either way (batches merge in input order).
pub fn run(id: &str) -> Result<Vec<Table>> {
    if id == "all" {
        let mut out = Vec::new();
        for (_, _, f) in catalog() {
            out.extend(f());
        }
        return Ok(out);
    }
    for (eid, _, f) in catalog() {
        if eid == id {
            return Ok(f());
        }
    }
    bail!("unknown experiment {id:?}; known: {:?}",
          list().iter().map(|(i, _)| *i).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_complete() {
        // Every table and figure in the paper's evaluation has a harness.
        let ids: Vec<&str> = list().iter().map(|(i, _)| *i).collect();
        for required in ["fig3a", "fig3bc", "fig4", "fig6", "fig7", "fig8",
                         "fig9", "fig10-11", "fig12", "fig13", "fig14",
                         "fig16", "fig_pp", "fig_optimize", "fig_rivals",
                         "fig_elastic", "planning"] {
            assert!(ids.contains(&required), "{required} missing");
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99").is_err());
    }

    #[test]
    fn fig3a_runs() {
        let tables = run("fig3a").unwrap();
        assert!(!tables.is_empty());
        assert!(tables[0].render().contains("LB-ASC"));
    }
}
