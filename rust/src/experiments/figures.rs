//! The per-figure harnesses (see module docs in [`super`]).
//!
//! Every harness is a thin table-assembly layer over the sweep engine:
//! it declares its scenario batch, evaluates it through
//! [`SweepEngine::global`] (parallel, plan-cached — `run("all")` shares
//! one warm cache across all fifteen harnesses), and formats rows from
//! the returned breakdowns in a fixed order. To add a new figure, build
//! the scenario list, call `eval`, and index the results; see
//! README.md § "Adding a figure harness".

use crate::cost::optim::{CostMetric, OptimKind};
use crate::model::qwen3::Qwen3Size;
use crate::partition::DpStrategy;
use crate::sim::{Breakdown, Scenario};
use crate::sweep::SweepEngine;
use crate::util::stats::load_balance_ratio;
use crate::util::table::{ratio, secs, Table};

fn strategies() -> [DpStrategy; 4] {
    [DpStrategy::Sc, DpStrategy::NvLayerwise, DpStrategy::Asc, DpStrategy::LbAsc]
}

/// Evaluate a scenario batch on the shared engine.
fn eval(scenarios: &[Scenario]) -> Vec<Breakdown> {
    SweepEngine::global().eval(scenarios)
}

/// Fig. 3a — optimizer makespan: SC vs ASC vs LB-ASC (Qwen3-32B,
/// DP=32, TP=8, Muon). Expected: LB-ASC < ASC << SC.
pub fn fig3a() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 3a — Optimizer makespan (Qwen3-32B, DP=32, TP=8, Muon)",
        &["strategy", "optimizer step", "vs LB-ASC"],
    );
    let strats = [DpStrategy::Sc, DpStrategy::Asc, DpStrategy::LbAsc];
    let scens: Vec<Scenario> = strats
        .iter()
        .map(|&s| Scenario::paper_default().with_strategy(s))
        .collect();
    let res = eval(&scens);
    let lb = &res[2];
    for (strat, b) in strats.iter().zip(&res) {
        t.row(vec![
            strat.label().into(),
            secs(b.optimizer_s),
            ratio(b.optimizer_s / lb.optimizer_s),
        ]);
    }
    vec![t]
}

/// Fig. 3b/3c — per-rank load distributions with and without balancing.
/// Paper: DP naive 3.24x FLOPs / 2.46x mem -> ours 1.43x / 1.11x;
/// TP naive 3.24x -> 2.46x FLOPs, 1.16x mem.
pub fn fig3bc() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 3b/3c — Load-balance ratios Max/Avg (Qwen3-32B, DP=32, TP=8, Muon)",
        &["plane", "strategy", "FLOPs ratio", "Memory ratio"],
    );
    let cases = [("naive (ASC)", DpStrategy::Asc), ("ours (LB-ASC)", DpStrategy::LbAsc)];
    let scens: Vec<Scenario> = cases
        .iter()
        .map(|&(_, s)| Scenario::paper_default().with_strategy(s))
        .collect();
    let res = eval(&scens);
    for ((label, _), b) in cases.iter().zip(&res) {
        t.row(vec![
            "DP".into(),
            (*label).into(),
            ratio(load_balance_ratio(&b.dp_loads_flops)),
            ratio(load_balance_ratio(&b.dp_loads_state)),
        ]);
        t.row(vec![
            "TP".into(),
            (*label).into(),
            ratio(load_balance_ratio(&b.tp_loads_flops)),
            ratio(load_balance_ratio(&b.tp_loads_state)),
        ]);
    }
    vec![t]
}

/// Fig. 4 — end-to-end iteration vs NV-layerwise (Qwen3-32B, DP=32,
/// TP=8). Paper: total 1.57x, optimizer 5.8x, fwd-bwd 1.23x.
pub fn fig4() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 4 — End-to-end iteration breakdown (Qwen3-32B, DP=32, TP=8, Muon)",
        &["strategy", "fwd-bwd", "optimizer", "total"],
    );
    let scens = vec![
        Scenario::paper_default().with_strategy(DpStrategy::NvLayerwise),
        Scenario::paper_default(),
    ];
    let res = eval(&scens);
    let (nv, lb) = (&res[0], &res[1]);
    for (label, b) in [("NV-layerwise", nv), ("LB-ASC (ours)", lb)] {
        t.row(vec![label.into(), secs(b.fwd_bwd_s), secs(b.optimizer_s), secs(b.total_s)]);
    }
    t.row(vec![
        "speedup".into(),
        ratio(nv.fwd_bwd_s / lb.fwd_bwd_s),
        ratio(nv.optimizer_s / lb.optimizer_s),
        ratio(nv.total_s / lb.total_s),
    ]);
    vec![t]
}

/// Fig. 6 — family sweep (1.7B..32B) x parallelism configs vs
/// NV-layerwise. Expected: gap widens with model size.
pub fn fig6() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 6 — Step latency breakdown across the Qwen3 family (Muon)",
        &["model", "grid", "strategy", "fwd-bwd", "optimizer", "total", "opt speedup"],
    );
    let configs: [(Qwen3Size, usize, usize); 6] = [
        (Qwen3Size::S1_7B, 32, 4), (Qwen3Size::S4B, 32, 4),
        (Qwen3Size::S8B, 32, 4), (Qwen3Size::S14B, 32, 8),
        (Qwen3Size::S32B, 16, 8), (Qwen3Size::S32B, 32, 8),
    ];
    let mut scens = Vec::with_capacity(configs.len() * 2);
    for (size, dp, tp) in configs {
        let base = Scenario::new(size, dp, tp, 1, OptimKind::Muon, DpStrategy::NvLayerwise);
        scens.push(base.clone());
        scens.push(base.with_strategy(DpStrategy::LbAsc));
    }
    let res = eval(&scens);
    for (i, (size, dp, tp)) in configs.iter().enumerate() {
        let (nv, lb) = (&res[2 * i], &res[2 * i + 1]);
        let grid = format!("DP{dp}-TP{tp}");
        t.row(vec![size.label().into(), grid.clone(), "NV-layerwise".into(),
                   secs(nv.fwd_bwd_s), secs(nv.optimizer_s), secs(nv.total_s), "".into()]);
        t.row(vec![size.label().into(), grid, "LB-ASC".into(),
                   secs(lb.fwd_bwd_s), secs(lb.optimizer_s), secs(lb.total_s),
                   ratio(nv.optimizer_s / lb.optimizer_s)]);
    }
    vec![t]
}

/// Fig. 7 — fwd-bwd communication efficiency: ours tracks the
/// AdamW+Reduce-Scatter anchor, NV-layerwise tracks AdamW+All-Reduce.
pub fn fig7() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 7 — Fwd-Bwd latency vs AdamW communication anchors",
        &["model", "AdamW+RS", "AdamW+AR", "ours", "NV-layerwise"],
    );
    let sizes = [Qwen3Size::S1_7B, Qwen3Size::S8B, Qwen3Size::S32B];
    let mut scens = Vec::with_capacity(sizes.len() * 4);
    for &size in &sizes {
        // AdamW anchors: same model, AdamW optimizer, RS vs AR paths.
        scens.push(Scenario::new(size, 32, 8, 1, OptimKind::AdamW, DpStrategy::LbAsc));
        scens.push(Scenario::new(size, 32, 8, 1, OptimKind::AdamW, DpStrategy::Sc));
        scens.push(Scenario::new(size, 32, 8, 1, OptimKind::Muon, DpStrategy::LbAsc));
        scens.push(Scenario::new(size, 32, 8, 1, OptimKind::Muon, DpStrategy::NvLayerwise));
    }
    let res = eval(&scens);
    for (i, size) in sizes.iter().enumerate() {
        let row = &res[4 * i..4 * i + 4];
        t.row(vec![
            size.label().into(),
            secs(row[0].fwd_bwd_s),
            secs(row[1].fwd_bwd_s),
            secs(row[2].fwd_bwd_s),
            secs(row[3].fwd_bwd_s),
        ]);
    }
    vec![t]
}

/// Fig. 8 — parallelism scaling. (a) DP 16..128 at TP=4;
/// (b) TP 2..8 at PP=4, DP=4. LB ratio stays ~1 for LB-ASC.
pub fn fig8() -> Vec<Table> {
    let mut a = Table::new(
        "Fig 8a — DP scaling (Qwen3-32B, TP=4, Muon)",
        &["DP", "strategy", "opt time", "FLOPs LB ratio", "Mem LB ratio"],
    );
    let dps = [16, 32, 64, 128];
    let strats = [DpStrategy::Asc, DpStrategy::LbAsc];
    let scens_a: Vec<Scenario> = dps
        .iter()
        .flat_map(|&dp| {
            strats.iter().map(move |&strat| {
                Scenario::new(Qwen3Size::S32B, dp, 4, 1, OptimKind::Muon, strat)
            })
        })
        .collect();
    let res_a = eval(&scens_a);
    for (i, &dp) in dps.iter().enumerate() {
        for (j, strat) in strats.iter().enumerate() {
            let b = &res_a[i * strats.len() + j];
            a.row(vec![
                dp.to_string(),
                strat.label().into(),
                secs(b.optimizer_s),
                ratio(load_balance_ratio(&b.dp_loads_flops)),
                ratio(load_balance_ratio(&b.dp_loads_state)),
            ]);
        }
    }
    let mut b_t = Table::new(
        "Fig 8b — TP scaling (Qwen3-32B, PP=4, DP=4, Muon)",
        &["TP", "strategy", "opt time", "TP FLOPs LB ratio"],
    );
    let tps = [2, 4, 8];
    let scens_b: Vec<Scenario> = tps
        .iter()
        .flat_map(|&tp| {
            strats.iter().map(move |&strat| {
                Scenario::new(Qwen3Size::S32B, 4, tp, 4, OptimKind::Muon, strat)
            })
        })
        .collect();
    let res_b = eval(&scens_b);
    for (i, &tp) in tps.iter().enumerate() {
        for (j, strat) in strats.iter().enumerate() {
            let b = &res_b[i * strats.len() + j];
            b_t.row(vec![
                tp.to_string(),
                strat.label().into(),
                secs(b.optimizer_s),
                ratio(load_balance_ratio(&b.tp_loads_flops)),
            ]);
        }
    }
    vec![a, b_t]
}

/// Fig. 9 — model-size scaling of the load-balance ratio (DP=16, TP=4).
pub fn fig9() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 9 — Load-balance ratio across model sizes (DP=16, TP=4, Muon)",
        &["model", "strategy", "DP FLOPs ratio", "DP Mem ratio", "TP FLOPs ratio"],
    );
    let strats = [DpStrategy::Asc, DpStrategy::LbAsc];
    let scens: Vec<Scenario> = Qwen3Size::all()
        .iter()
        .flat_map(|&size| {
            strats.iter().map(move |&strat| {
                Scenario::new(size, 16, 4, 1, OptimKind::Muon, strat)
            })
        })
        .collect();
    let res = eval(&scens);
    for (i, size) in Qwen3Size::all().iter().enumerate() {
        for (j, strat) in strats.iter().enumerate() {
            let b = &res[i * strats.len() + j];
            t.row(vec![
                size.label().into(),
                strat.label().into(),
                ratio(load_balance_ratio(&b.dp_loads_flops)),
                ratio(load_balance_ratio(&b.dp_loads_state)),
                ratio(load_balance_ratio(&b.tp_loads_flops)),
            ]);
        }
    }
    vec![t]
}

/// Figs. 10a/11a — generality: Shampoo / SOAP efficiency on Qwen3-14B
/// (PP=2, DP=32, TP=4). Paper: SC 3.313s -> ours 0.110s (Shampoo).
pub fn fig10_11() -> Vec<Table> {
    let mut t = Table::new(
        "Figs 10a/11a — Shampoo & SOAP step time (Qwen3-14B, PP=2, DP=32, TP=4)",
        &["optimizer", "strategy", "optimizer step", "vs LB-ASC"],
    );
    let optims = [OptimKind::Shampoo, OptimKind::Soap];
    let scens: Vec<Scenario> = optims
        .iter()
        .flat_map(|&optim| {
            strategies().into_iter().map(move |strat| {
                Scenario::new(Qwen3Size::S14B, 32, 4, 2, optim, strat)
            })
        })
        .collect();
    let res = eval(&scens);
    for (i, optim) in optims.iter().enumerate() {
        let block = &res[i * 4..i * 4 + 4];
        let lb = &block[3]; // strategies() ends with LbAsc
        for (strat, b) in strategies().iter().zip(block) {
            t.row(vec![
                optim.label().into(),
                strat.label().into(),
                secs(b.optimizer_s),
                ratio(b.optimizer_s / lb.optimizer_s),
            ]);
        }
    }
    vec![t]
}

/// Fig. 12 — Shampoo/SOAP load-balance ratios.
pub fn fig12() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 12 — Load-balance ratios for Shampoo / SOAP (Qwen3-14B, DP=32, TP=4)",
        &["optimizer", "strategy", "DP FLOPs", "DP Mem", "TP FLOPs", "TP Mem"],
    );
    let optims = [OptimKind::Shampoo, OptimKind::Soap];
    let strats = [DpStrategy::Asc, DpStrategy::LbAsc];
    let scens: Vec<Scenario> = optims
        .iter()
        .flat_map(|&optim| {
            strats.iter().map(move |&strat| {
                Scenario::new(Qwen3Size::S14B, 32, 4, 2, optim, strat)
            })
        })
        .collect();
    let res = eval(&scens);
    for (i, optim) in optims.iter().enumerate() {
        for (j, strat) in strats.iter().enumerate() {
            let b = &res[i * strats.len() + j];
            t.row(vec![
                optim.label().into(),
                strat.label().into(),
                ratio(load_balance_ratio(&b.dp_loads_flops)),
                ratio(load_balance_ratio(&b.dp_loads_state)),
                ratio(load_balance_ratio(&b.tp_loads_flops)),
                ratio(load_balance_ratio(&b.tp_loads_state)),
            ]);
        }
    }
    vec![t]
}

/// Fig. 13 — α ablation on 128 GPUs. Muon time decreases monotonically
/// in α; fwd-bwd stays stable (overlap hides the comm imbalance).
/// Adaptation: the paper's PP=8/DP=16 grid leaves TP=1, where the 32B
/// census' largest tensors exceed a 40M bucket and every bucket becomes
/// single-atom (degenerate for *all* atomic strategies); we use the
/// DP=16 x TP=8 face of the same 128-GPU cluster, which preserves the
/// ablation's subject (α's compute/comm trade-off).
pub fn fig13() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 13 — Sensitivity to the DP balance factor α (Qwen3-32B, DP=16, TP=8)",
        &["alpha", "fwd-bwd", "optimizer", "total"],
    );
    let alphas = [0.0, 0.25, 0.5, 0.75, 1.0];
    let scens: Vec<Scenario> = alphas
        .iter()
        .map(|&alpha| {
            Scenario::new(Qwen3Size::S32B, 16, 8, 1, OptimKind::Muon, DpStrategy::LbAsc)
                .with_alpha(alpha)
        })
        .collect();
    let res = eval(&scens);
    for (alpha, b) in alphas.iter().zip(&res) {
        t.row(vec![
            format!("{alpha:.2}"),
            secs(b.fwd_bwd_s),
            secs(b.optimizer_s),
            secs(b.total_s),
        ]);
    }
    vec![t]
}

/// Fig. 14 — C_max micro-group fusion ablation (128 GPUs, DP=16, TP=8).
/// No-Fuse is slow (launch overhead); latency plateaus at large C_max.
pub fn fig14() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 14 — TP micro-group fusion: optimizer time vs C_max (Qwen3-32B, DP=16, TP=8)",
        &["C_max", "optimizer step", "micro groups"],
    );
    let base = Scenario::new(Qwen3Size::S32B, 16, 8, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let caps = [64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0];
    let mut scens = vec![base.clone().with_c_max(None)];
    scens.extend(caps.iter().map(|&mb| base.clone().with_c_max(Some(mb * 1e6))));
    let res = eval(&scens);
    t.row(vec!["No-Fuse".into(), secs(res[0].optimizer_s),
               res[0].n_micro_groups.to_string()]);
    for (mb, b) in caps.iter().zip(&res[1..]) {
        t.row(vec![format!("{mb:.0}MB"), secs(b.optimizer_s),
                   b.n_micro_groups.to_string()]);
    }
    vec![t]
}

/// Fig. 16 — cost-metric ablation: numel proxy vs exact FLOPs.
/// Paper: 0.0718s vs 0.0717s (negligible).
pub fn fig16() -> Vec<Table> {
    let mut t = Table::new(
        "Fig 16 — Cost metric ablation (Qwen3-32B, DP=16, TP=8, Muon)",
        &["metric", "optimizer step"],
    );
    let cases = [("numel", CostMetric::Numel), ("exact FLOPs", CostMetric::Flops)];
    let scens: Vec<Scenario> = cases
        .iter()
        .map(|&(_, metric)| {
            Scenario::new(Qwen3Size::S32B, 16, 8, 1, OptimKind::Muon, DpStrategy::LbAsc)
                .with_metric(metric)
        })
        .collect();
    let res = eval(&scens);
    for ((label, _), b) in cases.iter().zip(&res) {
        t.row(vec![(*label).into(), secs(b.optimizer_s)]);
    }
    vec![t]
}

/// PP sweep — the 1F1B timeline engine: pp ∈ {1, 2, 4, 8} × strategy
/// (Qwen3-8B, DP=8, TP=4, 8 micro-batches, Muon). Expected shapes: the
/// pipeline bubble fraction tracks (pp-1)/(m+pp-1); LB-ASC's optimizer
/// advantage over NV-layerwise persists across pp because the
/// asynchronous optimizer consumes cooldown slack. Note each
/// micro-batch carries a full `Scenario::tokens` of work, so absolute
/// times grow with m — the comparable column is the bubble fraction.
pub fn fig_pp() -> Vec<Table> {
    let mut t = Table::new(
        "PP sweep — 1F1B timeline engine (Qwen3-8B, DP=8, TP=4, mb=8, Muon)",
        &["PP", "strategy", "fwd-bwd", "optimizer", "total", "bubble", "bubble %"],
    );
    let pps = [1usize, 2, 4, 8];
    let strats = [DpStrategy::NvLayerwise, DpStrategy::LbAsc];
    let mut scens = Vec::with_capacity(pps.len() * strats.len());
    for &pp in &pps {
        for &strategy in &strats {
            scens.push(
                Scenario::new(Qwen3Size::S8B, 8, 4, pp, OptimKind::Muon, strategy)
                    .with_micro_batches(8),
            );
        }
    }
    let res = eval(&scens);
    for (s, b) in scens.iter().zip(&res) {
        t.row(vec![
            s.pp.to_string(),
            s.strategy.label().into(),
            secs(b.fwd_bwd_s),
            secs(b.optimizer_s),
            secs(b.total_s),
            secs(b.bubble_s),
            format!("{:.1}%", 100.0 * b.bubble_s / b.fwd_bwd_s.max(1e-12)),
        ]);
    }
    vec![t]
}

/// Appendix D.1 — offline planning latency across the family.
///
/// Note: on a warm plan cache this reports the *memoized* planning
/// latency (microseconds); run it on a cold engine for the cold-solve
/// numbers the appendix quotes.
pub fn planning_latency() -> Vec<Table> {
    let mut t = Table::new(
        "App D.1 — Offline planning latency (DP=32, TP=8)",
        &["model", "planning time"],
    );
    let scens: Vec<Scenario> = Qwen3Size::all()
        .iter()
        .map(|&size| Scenario::new(size, 32, 8, 1, OptimKind::Muon, DpStrategy::LbAsc))
        .collect();
    let res = eval(&scens);
    for (size, b) in Qwen3Size::all().iter().zip(&res) {
        t.row(vec![size.label().into(), format!("{:.1} ms", b.planning_s * 1e3)]);
    }
    vec![t]
}

/// `canzona optimize` as a harness: search the paper's 256-GPU
/// Qwen3-32B shape space (DP × TP × PP with `dp*tp*pp == 256`) once
/// per strategy and derive the headline speedups (paper: total 1.57x,
/// optimizer 5.8x) as a ratio of search argmins — the best
/// NV-layerwise deployment vs the best LB-ASC one, rather than a
/// hand-picked config pair.
pub fn fig_optimize() -> Vec<Table> {
    use crate::sim::PipelineSchedule;
    use crate::sweep::{optimize, Objective, OptimizeOptions, SweepGrid};
    let shape_grid = |strategy: DpStrategy| SweepGrid {
        models: vec![Qwen3Size::S32B],
        dp: vec![16, 32, 64],
        tp: vec![4, 8],
        pp: vec![1, 2],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![strategy],
        alphas: vec![1.0],
        c_max_mb: vec![Some(512.0)],
        heteros: vec![crate::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    // batch = 1 pins the evaluated set; the winner is batch-invariant.
    let opts = OptimizeOptions {
        objective: Objective::IterTime,
        gpus: Some(256),
        prune: true,
        batch: 1,
    };
    let engine = SweepEngine::global();
    let mut t = Table::new(
        "Optimize — best 256-GPU Qwen3-32B deployment per strategy (Muon, iter-time)",
        &["strategy", "grid", "searched", "fwd-bwd", "optimizer", "total"],
    );
    let mut best = Vec::new();
    for strategy in [DpStrategy::NvLayerwise, DpStrategy::LbAsc] {
        let r = optimize(engine, &shape_grid(strategy), &opts)
            .expect("the 256-GPU shape space is non-empty");
        let w = r.evaluated[r.winner].clone();
        t.row(vec![
            strategy.label().into(),
            format!("DP{}-TP{}-PP{}", w.scenario.dp, w.scenario.tp, w.scenario.pp),
            format!("{}/{}", r.evaluated.len(), r.space),
            secs(w.breakdown.fwd_bwd_s),
            secs(w.breakdown.optimizer_s),
            secs(w.breakdown.total_s),
        ]);
        best.push(w);
    }
    let (nv, lb) = (&best[0].breakdown, &best[1].breakdown);
    t.row(vec![
        "speedup".into(),
        "".into(),
        "".into(),
        ratio(nv.fwd_bwd_s / lb.fwd_bwd_s),
        ratio(nv.optimizer_s / lb.optimizer_s),
        ratio(nv.total_s / lb.total_s),
    ]);
    vec![t]
}

/// Rivals head-to-head — all seven strategies (Canzona's ladder plus
/// MatrixFSDP, DMuon, Dion) on the paper's 256-GPU cluster. Table 1
/// runs the closed-form arm across the Qwen3 family (DP=32, TP=8) and
/// derives per-strategy optimizer speedup vs LB-ASC and the pacing
/// stage's max per-DP-rank optimizer state; table 2 runs the same
/// strategy zoo through the 1F1B timeline engine (DP=8, TP=8, PP=4,
/// mb=8, Qwen3-32B) for the pipelined bubble comparison — both dispatch
/// arms, one harness.
pub fn fig_rivals() -> Vec<Table> {
    let mut head = Table::new(
        "Rivals — strategy zoo head-to-head (Qwen3 family, DP=32, TP=8, Muon)",
        &["model", "strategy", "fwd-bwd", "optimizer", "vs LB-ASC", "max DP state"],
    );
    let sizes = [Qwen3Size::S1_7B, Qwen3Size::S8B, Qwen3Size::S32B];
    let strats = DpStrategy::ALL;
    let scens: Vec<Scenario> = sizes
        .iter()
        .flat_map(|&size| {
            strats
                .iter()
                .map(move |&strat| Scenario::new(size, 32, 8, 1, OptimKind::Muon, strat))
        })
        .collect();
    let res = eval(&scens);
    for (i, size) in sizes.iter().enumerate() {
        let block = &res[i * strats.len()..(i + 1) * strats.len()];
        let lb = &block[DpStrategy::LbAsc.ordinal()];
        for (strat, b) in strats.iter().zip(block) {
            let state = b.dp_loads_state.iter().cloned().fold(0.0, f64::max);
            head.row(vec![
                size.label().into(),
                strat.label().into(),
                secs(b.fwd_bwd_s),
                secs(b.optimizer_s),
                ratio(b.optimizer_s / lb.optimizer_s.max(1e-12)),
                format!("{:.2} GB", state / 1e9),
            ]);
        }
    }

    let mut pipe = Table::new(
        "Rivals — pipelined (Qwen3-32B, DP=8, TP=8, PP=4, mb=8, Muon)",
        &["strategy", "fwd-bwd", "optimizer", "total", "bubble", "bubble %"],
    );
    let scens_pp: Vec<Scenario> = strats
        .iter()
        .map(|&strat| {
            Scenario::new(Qwen3Size::S32B, 8, 8, 4, OptimKind::Muon, strat)
                .with_micro_batches(8)
        })
        .collect();
    let res_pp = eval(&scens_pp);
    for (strat, b) in strats.iter().zip(&res_pp) {
        pipe.row(vec![
            strat.label().into(),
            secs(b.fwd_bwd_s),
            secs(b.optimizer_s),
            secs(b.total_s),
            secs(b.bubble_s),
            format!("{:.1}%", 100.0 * b.bubble_s / b.fwd_bwd_s.max(1e-12)),
        ]);
    }
    vec![head, pipe]
}

/// Elastic-cluster stress — all seven strategies on the paper's 256-GPU
/// point (Qwen3-32B, DP=32, TP=8, Muon) under four cluster conditions:
/// clean (the pre-fault baseline bytes), a 5% slow-node mix, a
/// congested cluster (every node mildly derated, every inter-node link
/// at 1/64 bandwidth), and a failing cluster (10-minute MTTF,
/// checkpoint every 8 iterations). The strategy ordering *crosses over*
/// between clean and congested: DMuon's gather/scatter optimizer rides
/// the inter-node fabric (fastest when links are healthy), while
/// MatrixFSDP's update is communication-free (redundant preconditioner
/// compute, but immune to link degradation) — the direction pin in the
/// tests below. Faulted conditions dispatch through the scalar timeline
/// arm; `recovery` surfaces `Breakdown::recovery_s`.
pub fn fig_elastic() -> Vec<Table> {
    use crate::sim::HeteroSpec;
    let mut t = Table::new(
        "Elastic — strategy zoo under degraded clusters (Qwen3-32B, DP=32, TP=8, Muon)",
        &["condition", "strategy", "fwd-bwd", "optimizer", "total", "recovery"],
    );
    let conditions: [(&str, &str, Option<f64>, usize); 4] = [
        ("clean", "none", None, 1),
        ("slow-5%", "slow:0.05:1.5", None, 1),
        ("congested", "slow:1:1.25+link:1:64", None, 1),
        ("failing", "slow:0.05:1.5", Some(600.0), 8),
    ];
    let strats = DpStrategy::ALL;
    let scens: Vec<Scenario> = conditions
        .iter()
        .flat_map(|&(_, spec, mttf, ckpt)| {
            strats.iter().map(move |&strat| {
                Scenario::new(Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, strat)
                    .with_hetero(HeteroSpec::parse(spec).expect("static spec"))
                    .with_fault_seed(7)
                    .with_mttf(mttf)
                    .with_ckpt_interval(ckpt)
            })
        })
        .collect();
    let res = eval(&scens);
    for (i, &(cond, ..)) in conditions.iter().enumerate() {
        let block = &res[i * strats.len()..(i + 1) * strats.len()];
        for (strat, b) in strats.iter().zip(block) {
            t.row(vec![
                cond.into(),
                strat.label().into(),
                secs(b.fwd_bwd_s),
                secs(b.optimizer_s),
                secs(b.total_s),
                secs(b.recovery_s),
            ]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_rivals_covers_the_zoo_and_pins_directions() {
        let tables = fig_rivals();
        let head = tables[0].render();
        // Every strategy appears in the head-to-head table.
        for strat in DpStrategy::ALL {
            assert!(head.contains(strat.label()), "{} missing:\n{head}", strat.label());
        }
        // Direction pins at Qwen3-32B: LB-ASC beats MatrixFSDP (redundant
        // preconditioners) and SC (fully redundant update) on the
        // optimizer step. Parse the CSV for the 32B block.
        let csv = tables[0].to_csv();
        let opt = |strategy: &str| -> f64 {
            csv.lines()
                .skip(1)
                .map(|l| l.split(',').collect::<Vec<_>>())
                .find(|c| c[0] == "Qwen3-32B" && c[1] == strategy)
                .map(|c| c[3].trim_end_matches('s').parse().unwrap())
                .unwrap()
        };
        assert!(opt("LB-ASC") < opt("MatrixFSDP"), "{csv}");
        assert!(opt("LB-ASC") < opt("SC"), "{csv}");
        // The pipelined table exercises the timeline arm for all seven.
        let pipe = tables[1].to_csv();
        assert_eq!(pipe.lines().count(), 1 + DpStrategy::ALL.len());
    }

    #[test]
    fn fig_elastic_pins_the_strategy_crossover() {
        let tables = fig_elastic();
        let csv = tables[0].to_csv();
        let cell = |cond: &str, strategy: &str, col: usize| -> f64 {
            csv.lines()
                .skip(1)
                .map(|l| l.split(',').collect::<Vec<_>>())
                .find(|c| c[0] == cond && c[1] == strategy)
                .map(|c| c[col].trim_end_matches('s').parse().unwrap())
                .unwrap()
        };
        // The acceptance crossover: DMuon's inter-node gather/scatter
        // beats MatrixFSDP's redundant preconditioners on a healthy
        // fabric, and loses to it when every link runs at 1/64.
        let total = 4;
        assert!(cell("clean", "DMuon", total) < cell("clean", "MatrixFSDP", total), "{csv}");
        assert!(
            cell("congested", "DMuon", total) > cell("congested", "MatrixFSDP", total),
            "{csv}"
        );
        // Degradation only adds: every strategy's congested total is
        // strictly above its clean total.
        for strat in DpStrategy::ALL {
            assert!(
                cell("congested", strat.label(), total) > cell("clean", strat.label(), total),
                "{} got faster under congestion:\n{csv}",
                strat.label()
            );
        }
        // Recovery surfaces only on the failing condition, and pushes
        // its total above the matching fault-free (slow-5%) rows.
        let recovery = 5;
        for strat in DpStrategy::ALL {
            assert_eq!(cell("clean", strat.label(), recovery), 0.0);
            assert!(cell("failing", strat.label(), recovery) > 0.0, "{csv}");
            assert!(
                cell("failing", strat.label(), total) > cell("slow-5%", strat.label(), total),
                "{csv}"
            );
        }
        // Full zoo coverage under every condition.
        assert_eq!(csv.lines().count(), 1 + 4 * DpStrategy::ALL.len());
    }

    #[test]
    fn fig_optimize_search_derived_speedups_exceed_one() {
        // The paper's 1.57x / 5.8x claims, derived as a ratio of search
        // argmins; the harness only pins the *direction*, not the
        // magnitude (the simulator is a model, not the measured A100s).
        let tables = fig_optimize();
        let text = tables[0].render();
        let line = text.lines().find(|l| l.contains("speedup")).unwrap();
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        let opt_speedup: f64 = cells[5].trim_end_matches('x').parse().unwrap();
        assert!(opt_speedup > 1.0, "{opt_speedup}");
        let total_speedup: f64 = cells[6].trim_end_matches('x').parse().unwrap();
        assert!(total_speedup > 1.0, "{total_speedup}");
        // Both searches pruned or evaluated every leaf of the 4-point
        // 256-GPU space — the "searched" column is n/4.
        assert!(text.contains("/4"), "{text}");
    }

    #[test]
    fn fig4_speedups_paper_shaped() {
        let tables = fig4();
        let text = tables[0].render();
        assert!(text.contains("speedup"));
        // Extract the optimizer-speedup cell and require > 2x (paper 5.8x).
        let line = text.lines().find(|l| l.contains("speedup")).unwrap();
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        let opt_speedup: f64 = cells[3].trim_end_matches('x').parse().unwrap();
        assert!(opt_speedup > 2.0, "{opt_speedup}");
        let total_speedup: f64 = cells[4].trim_end_matches('x').parse().unwrap();
        assert!(total_speedup > 1.2, "{total_speedup}");
    }

    #[test]
    fn fig13_monotone_in_alpha() {
        let t = &fig13()[0];
        let csv = t.to_csv();
        let opt_times: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(2).unwrap().trim_end_matches('s').parse().unwrap())
            .collect();
        // Optimizer time must not increase with alpha.
        for w in opt_times.windows(2) {
            assert!(w[1] <= w[0] + 1e-4, "{opt_times:?}");
        }
    }

    #[test]
    fn fig14_no_fuse_is_worst() {
        let t = &fig14()[0];
        let csv = t.to_csv();
        let times: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(1).unwrap().trim_end_matches('s').parse().unwrap())
            .collect();
        let nofuse = times[0];
        for &fused in &times[1..] {
            assert!(fused < nofuse, "fused {fused} vs no-fuse {nofuse}");
        }
        // Plateau: the largest two capacities within 20%.
        let n = times.len();
        assert!((times[n - 1] - times[n - 2]).abs() / times[n - 2] < 0.2);
    }

    #[test]
    fn fig16_metrics_agree() {
        let t = &fig16()[0];
        let csv = t.to_csv();
        let times: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(1).unwrap().trim_end_matches('s').parse().unwrap())
            .collect();
        let rel = (times[0] - times[1]).abs() / times[1].max(1e-9);
        assert!(rel < 0.25, "numel vs flops diverge: {times:?}");
    }

    #[test]
    fn fig_pp_pipeline_bubble_grows_with_depth() {
        let t = &fig_pp()[0];
        let csv = t.to_csv();
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        let bubble = |pp: &str| -> f64 {
            rows.iter()
                .find(|r| r[0] == pp && r[1].contains("LB-ASC"))
                .map(|r| r[5].trim_end_matches('s').parse().unwrap())
                .unwrap()
        };
        assert!(bubble("8") > bubble("1"), "{} vs {}", bubble("8"), bubble("1"));
        assert!(bubble("4") > 0.0);
    }

    #[test]
    fn harnesses_are_deterministic_across_cache_states() {
        // Cold first call warms the global cache; warm second call must
        // render the identical bytes (the plan cache is semantically
        // invisible). planning_latency is excluded: it reports wall time.
        for f in [fig3a, fig4, fig13, fig_pp] {
            let a: String = f().iter().map(|t| t.render()).collect();
            let b: String = f().iter().map(|t| t.render()).collect();
            assert_eq!(a, b);
        }
    }
}
