//! Run configuration: a typed view over JSON config files.
//!
//! `canzona` commands accept flags directly; long-lived setups can store
//! them in a JSON file loaded here (`--config run.json` semantics are
//! provided by merging file values under CLI overrides).

use std::path::Path;

use crate::util::error::Result;
use crate::util::json::Value;

/// A loosely-typed configuration bag backed by JSON.
#[derive(Clone, Debug, Default)]
pub struct Config {
    root: Option<Value>,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config { root: Some(Value::parse(&text)?) })
    }

    pub fn empty() -> Config {
        Config { root: None }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.root
            .as_ref()
            .and_then(|r| r.opt(key))
            .and_then(|v| v.as_str().ok())
            .unwrap_or(default)
            .to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.root
            .as_ref()
            .and_then(|r| r.opt(key))
            .and_then(|v| v.as_f64().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.root
            .as_ref()
            .and_then(|r| r.opt(key))
            .and_then(|v| v.as_usize().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_on_empty() {
        let c = Config::empty();
        assert_eq!(c.get_str("x", "d"), "d");
        assert_eq!(c.get_usize("n", 7), 7);
    }

    #[test]
    fn loads_json() {
        let dir = std::env::temp_dir().join("canzona_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"preset": "e2e", "ranks": 8, "alpha": 0.5}"#).unwrap();
        let c = Config::load(&path).unwrap();
        assert_eq!(c.get_str("preset", ""), "e2e");
        assert_eq!(c.get_usize("ranks", 0), 8);
        assert_eq!(c.get_f64("alpha", 0.0), 0.5);
    }
}
