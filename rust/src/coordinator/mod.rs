//! Coordinator: configuration + the `canzona` CLI.
//!
//! Subcommands:
//! * `plan`       — compute + report a partition plan for a model/grid.
//! * `simulate`   — run the cluster simulator for one scenario.
//! * `sweep`      — evaluate a scenario grid on the parallel, plan-cached
//!   sweep engine and emit one table / JSON artifact.
//! * `optimize`   — branch-and-bound search of a scenario grid for the
//!   configuration minimizing an objective; emits the Pareto frontier.
//! * `experiment` — reproduce a paper figure (`fig4`, `fig13`, … or `all`).
//! * `train`      — run the real distributed trainer on AOT artifacts.
//! * `list`       — list registered experiments.

pub mod config;

use crate::cost::optim::OptimKind;
use crate::experiments;
use crate::model::qwen3::Qwen3Size;
use crate::partition::DpStrategy;
use crate::sim::{simulate_iteration, Scenario};
use crate::sweep::{
    optimize, render_json, render_optimize_json, render_optimize_table, render_table,
    Objective, OptimizeOptions, SweepDiff, SweepEngine, SweepGrid,
};
use crate::util::json::Value;
use crate::train::{train, TrainConfig};
use crate::util::cli::Args;
use crate::util::error::Result;
use crate::util::pool;
use crate::util::stats::load_balance_ratio;
use crate::util::table::Table;
use crate::{bail, err};

pub use config::Config;

const USAGE: &str = "\
canzona — unified, asynchronous, load-balanced distributed matrix-based optimizers

USAGE:
  canzona plan       --model 32b --dp 32 --tp 8 [--alpha 1.0] [--strategy lb-asc]
  canzona simulate   --model 32b --dp 32 --tp 8 [--pp 1] [--micro-batches 1]
                     [--schedule 1f1b|gpipe] [--straggler 1.0]
                     [--hetero none|last:F|slow:R:F|link:R:F|slow:R:F+link:R:F]
                     [--fault-seed 0] [--fail-rank r@0.5] [--mttf seconds]
                     [--ckpt-interval 1] [--optim muon] [--strategy lb-asc]
  canzona sweep      [--models 1.7b,8b,32b] [--dp 16,32] [--tp 1,2,4,8] [--pp 1,2,4,8]
                     [--micro-batches 1,8] [--schedule 1f1b,gpipe] [--straggler 1.0,1.5]
                     [--optims muon,shampoo,soap,adamw]
                     [--strategies sc,nv-layerwise,asc,lb-asc,matrix-fsdp,dmuon,dion]
                     [--alphas 0.5,1.0] [--c-max-mb 512,none] [--metric numel]
                     [--hetero none,slow:0.05:1.5] [--fail-rank none,3@0.5]
                     [--mttf none,1800] [--ckpt-interval 1,8] [--fault-seed 0]
                     [--threads N] [--cache-budget-mb 256] [--no-batch]
                     [--json out.json] [--csv]
                     [--baseline prior.json] [--regress-pct 2.0]
  canzona optimize   [sweep grid axes, as above]
                     [--objective iter-time|optimizer-latency|memory] [--gpus 256]
                     [--batch N] [--exhaustive] [--threads N] [--cache-budget-mb 256]
                     [--no-batch] [--json out.json] [--csv]
                     [--baseline prior.json] [--regress-pct 2.0]
  canzona experiment <fig3a|fig3bc|fig4|fig6|fig7|fig8|fig9|fig10-11|fig12|fig13|fig14|fig16|fig_pp|fig_optimize|fig_rivals|fig_elastic|planning|all>
                     [--threads N]
  canzona train      [--preset e2e] [--ranks 4] [--steps 100] [--strategy lb-asc] [--alpha 1.0]
                     [--seed 42] [--artifacts artifacts] [--log-every 10]
  canzona list
";

/// CLI entry point.
pub fn run_cli(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv, &["verbose", "csv", "exhaustive", "no-batch"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "plan" => cmd_plan(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "optimize" => cmd_optimize(&args),
        "experiment" => cmd_experiment(&args),
        "train" => cmd_train(&args),
        "list" => {
            for (id, desc) in experiments::list() {
                println!("{id:<12} {desc}");
            }
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn parse_scenario(args: &Args) -> Result<Scenario> {
    let model = args.get_or("model", "32b");
    let size = Qwen3Size::parse(model)
        .ok_or_else(|| err!("unknown model {model:?} (1.7b/4b/8b/14b/32b)"))?;
    let strategy = DpStrategy::parse(args.get_or("strategy", "lb-asc"))
        .ok_or_else(|| {
            err!("unknown strategy (sc/nv-layerwise/asc/lb-asc/matrix-fsdp/dmuon/dion)")
        })?;
    let optim = OptimKind::parse(args.get_or("optim", "muon"))
        .ok_or_else(|| err!("unknown optimizer (muon/shampoo/soap/adamw)"))?;
    let (dp, tp, pp) = (
        args.get_usize("dp", 32)?,
        args.get_usize("tp", 8)?,
        args.get_usize("pp", 1)?,
    );
    if dp < 1 || tp < 1 || pp < 1 {
        bail!("--dp/--tp/--pp must be >= 1 (got dp={dp} tp={tp} pp={pp})");
    }
    let mut s = Scenario::new(size, dp, tp, pp, optim, strategy);
    s.alpha = args.get_f64("alpha", 1.0)?;
    if let Some(cb) = args.get("c-max-mb") {
        let mb: f64 = cb.parse()?;
        s.c_max_bytes = if mb <= 0.0 { None } else { Some(mb * 1e6) };
    }
    s.micro_batches = args.get_usize("micro-batches", 1)?;
    if s.micro_batches < 1 {
        bail!("--micro-batches must be >= 1");
    }
    if let Some(raw) = args.get("schedule") {
        s.schedule = crate::sim::PipelineSchedule::parse(raw)
            .ok_or_else(|| err!("unknown schedule {raw:?} (1f1b/gpipe)"))?;
    }
    s.straggler = args.get_f64("straggler", 1.0)?;
    if !s.straggler.is_finite() || s.straggler < 1.0 {
        bail!("--straggler expects a finite factor >= 1.0, got {}", s.straggler);
    }
    if let Some(raw) = args.get("hetero") {
        s.hetero = crate::sim::HeteroSpec::parse(raw)?;
    }
    s.fault_seed = args.get_usize("fault-seed", 0)? as u64;
    if let Some(raw) = args.get("fail-rank") {
        if !raw.eq_ignore_ascii_case("none") {
            s.fail_rank = Some(crate::sim::FailSpec::parse(raw)?);
        }
    }
    if let Some(raw) = args.get("mttf") {
        if !raw.eq_ignore_ascii_case("none") {
            let mttf: f64 = raw
                .parse()
                .map_err(|_| err!("--mttf expects seconds or none, got {raw:?}"))?;
            s.mttf_s = Some(mttf);
        }
    }
    s.ckpt_interval = args.get_usize("ckpt-interval", 1)?;
    // Catch everything the per-flag checks above don't (alpha range,
    // C_max sign, hardware knobs) with one named `invalid scenario:`
    // error — NaN/inf rows must never enter a sweep (the total_cmp
    // sort paths would rank them instead of crashing).
    s.validate()?;
    Ok(s)
}

fn cmd_plan(args: &Args) -> Result<()> {
    let s = parse_scenario(args)?;
    let b = simulate_iteration(&s);
    let mut t = Table::new(
        &format!("Partition plan — {} DP{} TP{} PP{} {} ({})",
                 s.label, s.dp, s.tp, s.pp, s.optim.label(), s.strategy.label()),
        &["metric", "value"],
    );
    t.row(vec!["DP FLOPs LB ratio".into(),
               format!("{:.3}", load_balance_ratio(&b.dp_loads_flops))]);
    t.row(vec!["DP state LB ratio".into(),
               format!("{:.3}", load_balance_ratio(&b.dp_loads_state))]);
    t.row(vec!["TP FLOPs LB ratio".into(),
               format!("{:.3}", load_balance_ratio(&b.tp_loads_flops))]);
    t.row(vec!["micro groups".into(), b.n_micro_groups.to_string()]);
    t.row(vec!["planning time".into(), format!("{:.2} ms", b.planning_s * 1e3)]);
    t.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let s = parse_scenario(args)?;
    let b = simulate_iteration(&s);
    let mut t = Table::new(
        &format!("Simulated iteration — {} DP{} TP{} PP{} {} ({})",
                 s.label, s.dp, s.tp, s.pp, s.optim.label(), s.strategy.label()),
        &["phase", "time"],
    );
    t.row(vec!["fwd-bwd".into(), format!("{:.4}s", b.fwd_bwd_s)]);
    t.row(vec!["optimizer".into(), format!("{:.4}s", b.optimizer_s)]);
    t.row(vec!["total".into(), format!("{:.4}s", b.total_s)]);
    t.row(vec!["recovery".into(), format!("{:.4}s", b.recovery_s)]);
    t.row(vec!["exposed comm".into(), format!("{:.4}s", b.exposed_comm_s)]);
    t.row(vec!["schedule bubble".into(), format!("{:.4}s", b.bubble_s)]);
    t.row(vec!["AdamW reference".into(), format!("{:.4}s", b.adamw_ref_s)]);
    t.print();
    Ok(())
}

/// Build a sweep engine from `--threads` / `--cache-budget-mb` /
/// `--no-batch` (shared by `sweep` and `optimize`); returns the thread
/// count alongside for the summary lines.
fn engine_from_args(args: &Args) -> Result<(SweepEngine, usize)> {
    let threads = args.get_usize("threads", pool::default_threads())?.max(1);
    let mut engine = match args.get("cache-budget-mb") {
        None => SweepEngine::new(threads),
        Some(raw) => {
            let mb: f64 = raw
                .parse()
                .map_err(|_| err!("--cache-budget-mb expects a number, got {raw:?}"))?;
            // MiB, matching CANZONA_CACHE_BUDGET_MB and the 256 default.
            let budget = crate::sweep::cache::budget_mb_to_bytes(mb)
                .ok_or_else(|| err!("--cache-budget-mb must be finite, got {raw:?}"))?;
            SweepEngine::with_budget(threads, budget)
        }
    };
    // Rows are bit-identical either way (tests/batch_differential.rs);
    // the flag exists for A/B timing and for bisecting regressions.
    engine.set_batching(!args.flag("no-batch"));
    Ok((engine, threads))
}

/// Evaluate a scenario grid on the sweep engine; emit one table (or CSV)
/// plus an optional JSON artifact, and — with `--baseline prior.json` —
/// a diff table gated on regressions (nonzero exit beyond
/// `--regress-pct`, default 2%).
fn cmd_sweep(args: &Args) -> Result<()> {
    let grid = SweepGrid::parse(args)?;
    let (engine, threads) = engine_from_args(args)?;
    let t0 = std::time::Instant::now();
    let (scenarios, breakdowns) = engine.run_grid(&grid);
    let wall_s = t0.elapsed().as_secs_f64();
    let table = render_table(&scenarios, &breakdowns);
    if args.flag("csv") {
        print!("{}", table.to_csv());
    } else {
        table.print();
    }
    let stats = engine.cache_stats();
    if let Some(path) = args.get("json") {
        // The artifact carries the cache counters alongside the rows, so
        // sweep JSON doubles as a cache-behaviour record.
        let mut artifact = render_json(&scenarios, &breakdowns);
        if let Value::Obj(m) = &mut artifact {
            m.insert("cache".into(), stats.to_json());
        }
        std::fs::write(path, artifact.to_string())?;
        println!("wrote {path}");
    }
    const MIB: f64 = (1 << 20) as f64;
    println!(
        "\n{} scenarios in {wall_s:.2}s on {threads} threads \
         (plan cache: {} hits ({} lock-free L1) / {} solves / {} evictions, \
         {:.1} MiB resident of {} budget)",
        scenarios.len(),
        stats.hits,
        stats.l1_hits,
        stats.solves,
        stats.evictions,
        stats.resident_bytes as f64 / MIB,
        if stats.budget_bytes == 0 {
            "unbounded".to_string()
        } else {
            format!("{:.0} MiB", stats.budget_bytes as f64 / MIB)
        },
    );
    if stats.timeline_tasks > 0 {
        println!(
            "timeline: {} tasks scheduled ({:.0} tasks/s), \
             {} scratch reuses, {} schedule-order cache hits",
            stats.timeline_tasks,
            stats.timeline_tasks as f64 / wall_s.max(1e-9),
            stats.scratch_reuses,
            stats.order_hits,
        );
    }
    if stats.batched_evals + stats.batched_timeline_evals > 0 {
        println!(
            "batch tier: {} scenarios evaluated batched \
             ({} closed-form + {} timeline, {:.0} evals/s)",
            stats.batched_evals + stats.batched_timeline_evals,
            stats.batched_evals,
            stats.batched_timeline_evals,
            (stats.batched_evals + stats.batched_timeline_evals) as f64 / wall_s.max(1e-9),
        );
    }
    if let Some(path) = args.get("baseline") {
        let baseline = Value::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| e.wrap(format!("parsing baseline {path}")))?;
        let threshold = args.get_f64("regress-pct", 2.0)?;
        let diff = SweepDiff::compare(&baseline, &scenarios, &breakdowns, threshold)?;
        if args.flag("csv") {
            print!("{}", diff.table().to_csv());
        } else {
            diff.table().print();
        }
        // Old artifacts default these to zero (CacheStats::from_json);
        // only report when the baseline actually recorded them — and
        // *before* the verdict, so the diagnostic survives a failing
        // gate (a timeline-path slowdown is exactly when you want it).
        if diff.base_cache.timeline_tasks > 0 {
            println!(
                "baseline timeline counters: {} tasks / {} scratch reuses / \
                 {} order hits (current: {} / {} / {})",
                diff.base_cache.timeline_tasks,
                diff.base_cache.scratch_reuses,
                diff.base_cache.order_hits,
                stats.timeline_tasks,
                stats.scratch_reuses,
                stats.order_hits,
            );
        }
        diff.verdict()?;
        println!("\nbaseline check passed: no regression beyond {threshold}% vs {path}");
    }
    Ok(())
}

/// Branch-and-bound search of a scenario grid for the configuration
/// minimizing `--objective`; prints the Pareto frontier (winner
/// starred) plus search counters. `--exhaustive` disables pruning (the
/// exact-frontier mode); `--baseline prior.json` diffs the frontier
/// rows against a stored `optimize --json` artifact through the same
/// join as `sweep --baseline`.
fn cmd_optimize(args: &Args) -> Result<()> {
    let grid = SweepGrid::parse(args)?;
    let objective = match args.get("objective") {
        None => Objective::IterTime,
        Some(raw) => Objective::parse(raw).ok_or_else(
            || err!("unknown objective {raw:?} (iter-time/optimizer-latency/memory)"),
        )?,
    };
    let gpus = match args.get("gpus") {
        None => None,
        Some(_) => Some(args.get_usize("gpus", 0)?),
    };
    let opts = OptimizeOptions {
        objective,
        gpus,
        prune: !args.flag("exhaustive"),
        batch: args.get_usize("batch", 0)?,
    };
    let (engine, threads) = engine_from_args(args)?;
    let t0 = std::time::Instant::now();
    let result = optimize(&engine, &grid, &opts)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let table = render_optimize_table(&result);
    if args.flag("csv") {
        print!("{}", table.to_csv());
    } else {
        table.print();
    }
    let stats = engine.cache_stats();
    if let Some(path) = args.get("json") {
        let mut artifact = render_optimize_json(&result);
        if let Value::Obj(m) = &mut artifact {
            m.insert("cache".into(), stats.to_json());
        }
        std::fs::write(path, artifact.to_string())?;
        println!("wrote {path}");
    }
    let w = &result.evaluated[result.winner];
    println!(
        "\nwinner [{}]: {} dp{} tp{} pp{} mb{} {} {} a={} -> total {:.6}s, value {:.6}",
        objective.label(),
        w.scenario.label,
        w.scenario.dp,
        w.scenario.tp,
        w.scenario.pp,
        w.scenario.micro_batches,
        w.scenario.optim.label(),
        w.scenario.strategy.label(),
        w.scenario.alpha,
        w.breakdown.total_s,
        w.value,
    );
    println!(
        "searched {} of {} scenarios ({} pruned, {:.0}% of the space) in {wall_s:.2}s \
         on {threads} threads",
        result.evaluated.len(),
        result.space,
        result.pruned,
        100.0 * result.pruned as f64 / result.space.max(1) as f64,
    );
    if let Some(path) = args.get("baseline") {
        let baseline = Value::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| e.wrap(format!("parsing baseline {path}")))?;
        let threshold = args.get_f64("regress-pct", 2.0)?;
        let scens: Vec<Scenario> = result
            .frontier
            .iter()
            .map(|&i| result.evaluated[i].scenario.clone())
            .collect();
        let breaks: Vec<crate::sim::Breakdown> = result
            .frontier
            .iter()
            .map(|&i| result.evaluated[i].breakdown.clone())
            .collect();
        let diff = SweepDiff::compare(&baseline, &scens, &breaks, threshold)?;
        if args.flag("csv") {
            print!("{}", diff.table().to_csv());
        } else {
            diff.table().print();
        }
        diff.verdict()?;
        println!("\nbaseline check passed: no regression beyond {threshold}% vs {path}");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(id) = args.positional.get(1) else {
        bail!("experiment id required; see `canzona list`");
    };
    // `--threads` overrides CANZONA_SWEEP_THREADS process-wide; applied
    // before the first `SweepEngine::global()` touch so the shared
    // engine (and the persistent executor it sizes) picks it up. Parsed
    // and clamped exactly like `sweep --threads` (0 clamps to 1).
    if args.get("threads").is_some() {
        pool::set_default_threads(args.get_usize("threads", 1)?.max(1));
    }
    for table in experiments::run(id)? {
        if args.flag("csv") {
            print!("{}", table.to_csv());
        } else {
            table.print();
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::new(args.get_or("preset", "e2e"));
    cfg.artifacts_dir = args.get_or("artifacts", "artifacts").into();
    cfg.ranks = args.get_usize("ranks", 4)?;
    cfg.steps = args.get_usize("steps", 100)?;
    cfg.alpha = args.get_f64("alpha", 1.0)?;
    cfg.seed = args.get_usize("seed", 42)? as u64;
    cfg.log_every = args.get_usize("log-every", 10)?;
    cfg.strategy = DpStrategy::parse(args.get_or("strategy", "lb-asc"))
        .ok_or_else(|| err!("trainer strategies: sc/asc/lb-asc"))?;
    println!(
        "training preset={} ranks={} steps={} strategy={}",
        cfg.preset, cfg.ranks, cfg.steps, cfg.strategy.label()
    );
    let r = train(&cfg)?;
    let n = r.losses.len();
    println!(
        "done: loss {:.4} -> {:.4} | mean step {:.3}s (opt {:.3}s) | comm {:.1} MB | params hash {:016x}",
        r.losses.first().copied().unwrap_or(f32::NAN),
        r.losses.last().copied().unwrap_or(f32::NAN),
        crate::util::stats::mean(&r.step_times),
        crate::util::stats::mean(&r.opt_times),
        r.comm_bytes as f64 / 1e6,
        r.params_hash,
    );
    // Loss curve CSV for EXPERIMENTS.md / plotting.
    if let Some(path) = args.get("loss-out") {
        let mut csv = String::from("step,loss\n");
        for (i, l) in r.losses.iter().enumerate() {
            csv += &format!("{},{}\n", i + 1, l);
        }
        std::fs::write(path, csv)?;
        println!("wrote loss curve to {path} ({n} steps)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_covers_every_strategy_and_experiment() {
        // The CLI-docs half of the DpStrategy exhaustiveness pin: every
        // variant's lowercase label must be a valid `--strategies` token
        // *and* appear in the usage text, so a new strategy cannot land
        // undocumented. Same for experiment ids.
        // Hyphen-insensitive: the label "MatrixFSDP" is documented as
        // the token "matrix-fsdp" (both parse).
        let usage_squashed = USAGE.to_ascii_lowercase().replace('-', "");
        for s in DpStrategy::ALL {
            let token = s.label().to_ascii_lowercase();
            assert_eq!(DpStrategy::parse(&token), Some(s));
            assert!(
                usage_squashed.contains(&token.replace('-', "")),
                "{token} missing from USAGE"
            );
        }
        for (id, _) in experiments::list() {
            assert!(USAGE.contains(id), "experiment {id} missing from USAGE");
        }
    }
}
