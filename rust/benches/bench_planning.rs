//! Offline planning latency (paper Appendix D.1: "completes in
//! milliseconds"). Benchmarks the α-balanced DP partitioner, the naive
//! stride rule, the layerwise LPT and the TP micro-group scheduler on
//! every Qwen3 family member.

use canzona::buffer::FlatBuffer;
use canzona::cost::optim::{CostMetric, OptimCost, OptimKind};
use canzona::model::qwen3::{qwen3, Qwen3Size};
use canzona::model::tp::{fragmented_matrix_params, tp_split};
use canzona::partition::{alpha_balanced, layerwise, naive_atomic};
use canzona::schedule::microgroup::{build_micro_groups, tasks_from_shards};
use canzona::util::bench::{bench, black_box};

fn main() {
    println!("# Planning latency benchmarks (Appendix D.1 target: ms-scale)\n");
    for size in Qwen3Size::all() {
        let census = qwen3(size);
        let fb = FlatBuffer::build(&census, 40_000_000);
        let w = |p: &canzona::buffer::PlacedParam| p.numel() as f64;

        bench(&format!("{} buffer build", size.label()), 10, || {
            black_box(FlatBuffer::build(&census, 40_000_000));
        });
        bench(&format!("{} alpha_balanced DP=32", size.label()), 10, || {
            black_box(alpha_balanced(&fb, 32, 1.0, true, w));
        });
        bench(&format!("{} naive_atomic DP=32", size.label()), 10, || {
            black_box(naive_atomic(&fb, 32));
        });
        bench(&format!("{} layerwise DP=32", size.label()), 10, || {
            black_box(layerwise(&fb, 32, w));
        });

        let shards = tp_split(&census, 8);
        let frag = fragmented_matrix_params(&shards, 8);
        let optim = OptimCost::new(OptimKind::Muon);
        bench(&format!("{} micro_groups TP=8", size.label()), 10, || {
            let tasks = tasks_from_shards(&frag, &optim, CostMetric::Numel);
            black_box(build_micro_groups(tasks, 8, 256e6));
        });
        println!();
    }
}
