//! `cargo bench` driver that regenerates every paper table and figure.
//!
//! Each experiment harness is also timed (the simulator itself must stay
//! fast enough for interactive sweeps). Output is the same Markdown that
//! EXPERIMENTS.md records.

use std::time::Instant;

fn main() {
    println!("# Canzona — paper experiment reproduction (cargo bench)\n");
    let mut total = 0.0;
    for (id, desc) in canzona::experiments::list() {
        let t0 = Instant::now();
        let tables = canzona::experiments::run(id).expect(id);
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("\n---\n## {id} — {desc}  (generated in {dt:.2}s)");
        for t in tables {
            t.print();
        }
    }
    println!("\n---\nall experiments regenerated in {total:.2}s");
}
