//! Hot-path microbenchmarks: the L3 pieces that execute per training
//! step (collectives, simulator playback, minheap solver) plus — when
//! artifacts are present — the PJRT execution path itself.

use std::sync::Arc;

use canzona::collectives::{Communicator, Group};
use canzona::schedule::minheap::min_heap_balance;
use canzona::sim::{simulate_iteration, Scenario};
use canzona::util::bench::{bench, black_box};

fn bench_collectives() {
    println!("## in-memory collectives (4 thread ranks)\n");
    for n in [1_000usize, 1_000_000] {
        // Persistent rank threads driven through channels would be ideal;
        // here each sample spawns fresh threads, so results include the
        // spawn cost — dominated by the 1M-element payloads anyway.
        bench(&format!("all_reduce {n} f32 x4 ranks (incl. spawn)"), 10, || {
            let group = Group::new(4);
            let handles: Vec<_> = (0..4)
                .map(|r| {
                    let c = Communicator::new(group.clone(), r);
                    std::thread::spawn(move || {
                        let data = vec![1.0f32; n];
                        black_box(c.all_reduce(&data));
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
    println!();
}

fn bench_simulator() {
    println!("## simulator playback\n");
    let s = Scenario::paper_default();
    bench("simulate_iteration 32B DP32 TP8 LB-ASC", 10, || {
        black_box(simulate_iteration(&s));
    });
    println!();
}

fn bench_minheap() {
    println!("## minheap solver\n");
    let costs: Vec<f64> = (0..448).map(|i| ((i * 37) % 97) as f64 + 1.0).collect();
    bench("min_heap_balance 448 tasks x 8 ranks", 20, || {
        black_box(min_heap_balance(&costs, 8));
    });
    println!();
}

fn bench_runtime() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest__tiny.json").exists() {
        println!("## PJRT runtime: skipped (run `make artifacts`)\n");
        return;
    }
    println!("## PJRT runtime (tiny preset)\n");
    use canzona::runtime::{literal_f32, literal_scalar, Manifest, Runtime};
    let m = Manifest::load(&dir, "tiny").unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let p = m.params.iter().find(|p| p.optim == "muon").unwrap().clone();
    let file = m.artifact_file(&p.artifact).unwrap().to_string();
    let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
    let w = vec![0.01f32; p.numel];
    // Warm the compilation cache before timing execution.
    rt.load(&file).unwrap();
    bench(&format!("muon update exec {}x{}", p.shape[0], p.shape[1]), 10, || {
        let outs = rt
            .execute(&file, &[
                literal_f32(&w, &dims).unwrap(),
                literal_f32(&w, &dims).unwrap(),
                literal_f32(&w, &dims).unwrap(),
                literal_scalar(0.02),
                literal_scalar(0.95),
            ])
            .unwrap();
        black_box(outs);
    });

    let group = Arc::new(());
    let _ = group;
    println!();
}

fn main() {
    println!("# Hot-path microbenchmarks\n");
    bench_minheap();
    bench_simulator();
    bench_collectives();
    bench_runtime();
}
