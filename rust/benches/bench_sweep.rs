//! Sweep-engine benchmarks: quantifies the two claims behind the sweep
//! subsystem — (1) the work-stealing runner beats the sequential seed
//! path (one cold `simulate_iteration` per scenario, in order), (2) a
//! warm plan cache collapses repeated planning to hash lookups.
//!
//! ```bash
//! cargo bench --bench bench_sweep
//! ```

use std::time::Instant;

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{simulate_iteration, simulate_iteration_cached, PipelineSchedule, Scenario};
use canzona::sweep::{optimize, Objective, OptimizeOptions, PlanCache, SweepEngine, SweepGrid};
use canzona::util::bench::{bench, black_box, fmt_ns};
use canzona::util::pool;

fn main() {
    println!("# Sweep engine benchmarks\n");

    // A Fig. 6/8-shaped batch: family x grid x strategy.
    let grid = SweepGrid {
        models: vec![Qwen3Size::S8B, Qwen3Size::S32B],
        dp: vec![16, 32],
        tp: vec![2, 4, 8],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::Asc, DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(512.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let scens = grid.scenarios();

    // --- sequential seed path vs the engine ----------------------------
    // Seed behaviour: strictly sequential, every plan re-solved from
    // scratch on each call.
    let t0 = Instant::now();
    for s in &scens {
        black_box(simulate_iteration(s));
    }
    let seq_s = t0.elapsed().as_secs_f64();
    println!("{:>3} scenarios, sequential cold (seed path) : {seq_s:>7.2}s", scens.len());

    let engine = SweepEngine::new(pool::default_threads());
    let t1 = Instant::now();
    black_box(engine.eval(&scens));
    let cold_s = t1.elapsed().as_secs_f64();
    println!("{:>3} scenarios, parallel, cold cache        : {cold_s:>7.2}s", scens.len());

    let t2 = Instant::now();
    black_box(engine.eval(&scens));
    let warm_s = t2.elapsed().as_secs_f64();
    println!("{:>3} scenarios, parallel, warm cache        : {warm_s:>7.2}s", scens.len());
    let stats = engine.cache_stats();
    println!(
        "speedup vs sequential: {:.2}x cold, {:.2}x warm ({} threads; \
         cache {} hits / {} solves)\n",
        seq_s / cold_s,
        seq_s / warm_s,
        engine.threads(),
        stats.hits,
        stats.solves,
    );

    // --- experiments::run("all"): cold vs warm global engine -----------
    let t3 = Instant::now();
    let n_tables = canzona::experiments::run("all").unwrap().len();
    let all_cold_s = t3.elapsed().as_secs_f64();
    let t4 = Instant::now();
    black_box(canzona::experiments::run("all").unwrap().len());
    let all_warm_s = t4.elapsed().as_secs_f64();
    println!("run(\"all\") ({n_tables} tables): cold {all_cold_s:.2}s, warm {all_warm_s:.2}s\n");

    // --- single-scenario planning: cold solve vs cache hit -------------
    let s = Scenario::paper_default();
    let cold = bench("simulate_iteration 32B DP32 TP8 (cold plans)", 10, || {
        black_box(simulate_iteration(&s));
    });
    let one = SweepEngine::new(1);
    one.eval_one(&s); // warm the cache
    let hot = bench("simulate_iteration 32B DP32 TP8 (plan-cache hit)", 10, || {
        black_box(one.eval_one(&s));
    });
    println!(
        "\nplan-cache speedup: {:.2}x ({} cold vs {} warm)",
        cold.median_ns / hot.median_ns,
        fmt_ns(cold.median_ns),
        fmt_ns(hot.median_ns),
    );

    // --- allocation-free warm path (simulate_iteration_into) -----------
    let mut out = canzona::sim::Breakdown::default();
    canzona::sim::simulate_iteration_into(&s, one.cache(), &mut out);
    let (allocs, _) =
        canzona::util::alloc::count_allocations(|| {
            canzona::sim::simulate_iteration_into(&s, one.cache(), &mut out)
        });
    let zero_alloc = bench("simulate_iteration_into 32B DP32 TP8 (warm, reused out)", 10, || {
        canzona::sim::simulate_iteration_into(&s, one.cache(), &mut out);
        black_box(out.total_s);
    });
    println!(
        "warm allocation count: {allocs} (zero-alloc path, {} median)",
        fmt_ns(zero_alloc.median_ns),
    );

    // --- bounded vs unbounded cache under a DP=128 family slice --------
    let family = SweepGrid {
        models: vec![Qwen3Size::S8B, Qwen3Size::S32B],
        dp: vec![128],
        tp: vec![4, 8],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(512.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let fam_scens = family.scenarios();
    for (label, budget) in [("unbounded", 0usize), ("64 MB", 64 << 20), ("4 MB", 4 << 20)] {
        let engine = SweepEngine::with_budget(pool::default_threads(), budget);
        let t = Instant::now();
        black_box(engine.eval(&fam_scens));
        black_box(engine.eval(&fam_scens));
        let st = engine.cache_stats();
        println!(
            "DP=128 family x2 passes, cache {label:>9}: {:>6.2}s \
             ({} solves / {} evictions, peak {:.1} MB)",
            t.elapsed().as_secs_f64(),
            st.solves,
            st.evictions,
            st.peak_bytes as f64 / 1e6,
        );
    }

    // --- per-batch overhead: spawn-per-call vs persistent ---------------
    // 100 warm batches of 8 scenarios each, same L2-warm plan cache. The
    // delta is everything spawn-per-call costs a batch in practice: N
    // thread spawn/joins per call PLUS the cold per-thread state fresh
    // workers start with every time (SimScratch rebuilt, cache L1 empty
    // so reads serialize on the L2 mutex) — versus one injector push
    // onto long-lived workers whose scratches and L1s are already warm.
    // Paste the printed rows into CHANGES.md from a toolchain-equipped
    // run.
    println!("\n# Per-batch overhead (100 batches x 8 scenarios, warm cache)\n");
    let batch: Vec<Scenario> = grid.scenarios().into_iter().take(8).collect();
    let threads = pool::default_threads().min(8);
    let dispatch_cache = PlanCache::unbounded();
    let run_batch = |c: &PlanCache| {
        black_box(pool::parallel_map(&batch, threads, |s| simulate_iteration_cached(s, c)));
    };
    run_batch(&dispatch_cache); // warm plans + workers + scratches
    let t = Instant::now();
    for _ in 0..100 {
        black_box(pool::scoped_map(&batch, threads, |s| {
            simulate_iteration_cached(s, &dispatch_cache)
        }));
    }
    let scoped_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    for _ in 0..100 {
        run_batch(&dispatch_cache);
    }
    let persistent_s = t.elapsed().as_secs_f64();
    println!(
        "spawn-per-call (scoped, cold per-thread state) : {scoped_s:>7.3}s total, \
         {:>8.1} us/batch",
        scoped_s * 1e4,
    );
    println!(
        "persistent executor (warm scratches + L1s)     : {persistent_s:>7.3}s total, \
         {:>8.1} us/batch ({:.2}x less per-batch overhead, {threads} threads)",
        persistent_s * 1e4,
        scoped_s / persistent_s.max(1e-12),
    );

    // --- warm DP=128 read throughput: lock-free L1 vs single mutex ------
    // Every warm lookup in the mutex-only cache serializes N workers on
    // one lock; the L1 path takes no lock at all. Same scenarios, same
    // results (tests/cache_coherence.rs) — only the read path differs.
    // The 4-scenario family slice is cycled to 64 items per pass so 16
    // workers genuinely contend on the same hot plans.
    println!("\n# Warm DP=128 sweep: lock-free L1 vs mutex-only reads\n");
    let pressure: Vec<Scenario> =
        fam_scens.iter().cycle().take(64).cloned().collect();
    for threads in [1usize, 8, 16] {
        for (label, l1) in [("lock-free L1", true), ("mutex-only", false)] {
            let cache = PlanCache::with_options(0, l1);
            let warm_once =
                |c: &PlanCache| {
                    black_box(pool::parallel_map(&pressure, threads, |s| {
                        simulate_iteration_cached(s, c)
                    }))
                };
            warm_once(&cache); // cold pass: solve everything
            warm_once(&cache); // settle every worker's L1/scratch
            let t = Instant::now();
            const PASSES: usize = 5;
            for _ in 0..PASSES {
                warm_once(&cache);
            }
            let warm_s = t.elapsed().as_secs_f64();
            let per_pass = warm_s / PASSES as f64;
            let st = cache.stats();
            println!(
                "threads={threads:>2} {label:>13}: {per_pass:>7.4}s/pass \
                 ({:>7.0} scenarios/s; {} hits, {} via L1)",
                pressure.len() as f64 / per_pass,
                st.hits,
                st.l1_hits,
            );
        }
    }

    // --- bench_timeline: the event-driven pp sweep ----------------------
    // Paste the printed rows into CHANGES.md from a toolchain-equipped
    // run: cold (plans + tables solved) vs warm (pure timeline replay)
    // per pipeline depth, plus the single-scenario replay latency.
    println!("\n# Timeline engine (pp sweep, 1F1B, mb=8)\n");
    let pp_grid = SweepGrid {
        models: vec![Qwen3Size::S8B],
        dp: vec![8],
        tp: vec![4],
        pp: vec![1, 2, 4, 8],
        micro_batches: vec![8],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::NvLayerwise, DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(512.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let pp_scens = pp_grid.scenarios();
    let engine = SweepEngine::new(pool::default_threads());
    let t = Instant::now();
    black_box(engine.eval(&pp_scens));
    let cold_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    black_box(engine.eval(&pp_scens));
    let warm_s = t.elapsed().as_secs_f64();
    let st = engine.cache_stats();
    println!(
        "{:>3} pp-sweep scenarios: cold {cold_s:.3}s, warm {warm_s:.3}s \
         ({} solves, {} hits; stage canonicalization shares interior stages)",
        pp_scens.len(),
        st.solves,
        st.hits,
    );
    let deep = Scenario::new(Qwen3Size::S8B, 8, 4, 8, OptimKind::Muon, DpStrategy::LbAsc)
        .with_micro_batches(8);
    let one = SweepEngine::new(1);
    one.eval_one(&deep); // warm
    let replay = bench("timeline replay 8B DP8 TP4 PP8 mb8 (warm)", 10, || {
        black_box(one.eval_one(&deep));
    });
    println!("warm timeline replay: {} median", fmt_ns(replay.median_ns));

    // --- zero-allocation warm timeline path (reused scratch + out) ------
    // The warm steady state runs a lean Timeline over the per-thread
    // SimScratch: reused Breakdown + warm cache + warm scratch must
    // schedule every task without touching the heap.
    let mut out = canzona::sim::Breakdown::default();
    canzona::sim::simulate_iteration_into(&deep, one.cache(), &mut out);
    canzona::sim::simulate_iteration_into(&deep, one.cache(), &mut out);
    let tasks_before = one.cache_stats().timeline_tasks;
    let (tl_allocs, _) = canzona::util::alloc::count_allocations(|| {
        canzona::sim::simulate_iteration_into(&deep, one.cache(), &mut out)
    });
    let tasks_per_call = one.cache_stats().timeline_tasks - tasks_before;
    let warm_into = bench("timeline replay PP8 mb8 (warm, reused out + scratch)", 10, || {
        canzona::sim::simulate_iteration_into(&deep, one.cache(), &mut out);
        black_box(out.total_s);
    });
    println!(
        "warm timeline path: {tasks_per_call} tasks/call, {:.0} tasks/s, \
         {tl_allocs} allocs per warm call ({} median)",
        tasks_per_call as f64 / (warm_into.median_ns * 1e-9),
        fmt_ns(warm_into.median_ns),
    );
    let st = one.cache_stats();
    println!(
        "timeline counters: {} tasks total, {} scratch reuses, {} order-cache hits",
        st.timeline_tasks, st.scratch_reuses, st.order_hits,
    );

    // --- batched SoA evaluation: lanes/s vs the scalar loop -------------
    // One shared plan fingerprint, N lanes varying only continuous knobs
    // (bandwidth, C_max): the batch path hoists the stage table, shard
    // geometry and gradient volume once and streams the per-lane
    // schedule algebra through fixed-width chunks. The scalar loop
    // re-reads the same cached plans per call — bit-identical results
    // (tests/batch_differential.rs), so the delta is pure dispatch +
    // hoist overhead. Target: O(10M) lane-evals/s warm on one core.
    // Paste the printed rows into CHANGES.md from a toolchain-equipped
    // run.
    println!("\n# Batched SoA evaluation (shared fingerprint, warm cache)\n");
    {
        use canzona::sim::{
            simulate_batch_into, BreakdownBatch, LaneKnobs, ScenarioBatch,
        };
        let base = Scenario::new(Qwen3Size::S8B, 16, 4, 1, OptimKind::Muon, DpStrategy::LbAsc);
        const LANES: usize = 1024;
        let mut batch = ScenarioBatch::new(base.clone()).unwrap();
        let mut scalar_scens = Vec::with_capacity(LANES);
        for lane in 0..LANES {
            let mut k = LaneKnobs::from_scenario(&base);
            k.ib_bw *= 0.5 + lane as f64 / LANES as f64; // [0.5x, 1.5x)
            if lane % 4 == 0 {
                k.c_max_bytes = None;
            }
            batch.push(k).unwrap();
            let mut s = base.clone();
            s.hw.ib_bw = k.ib_bw;
            s.c_max_bytes = k.c_max_bytes;
            scalar_scens.push(s);
        }
        let cache = PlanCache::unbounded();
        let mut soa = BreakdownBatch::new();
        simulate_batch_into(&batch, &cache, &mut soa); // cold: solve plans
        simulate_batch_into(&batch, &cache, &mut soa); // settle capacity
        const PASSES: usize = 20;
        let t = Instant::now();
        for _ in 0..PASSES {
            simulate_batch_into(&batch, &cache, &mut soa);
        }
        black_box(soa.total_s[LANES - 1]);
        let batch_s = t.elapsed().as_secs_f64();
        let mut out = canzona::sim::Breakdown::default();
        canzona::sim::simulate_iteration_into(&scalar_scens[0], &cache, &mut out); // warm scratch
        let t = Instant::now();
        for _ in 0..PASSES {
            for s in &scalar_scens {
                canzona::sim::simulate_iteration_into(s, &cache, &mut out);
            }
        }
        black_box(out.total_s);
        let scalar_s = t.elapsed().as_secs_f64();
        let evals = (LANES * PASSES) as f64;
        println!(
            "scalar loop  ({LANES} lanes x {PASSES} passes): {scalar_s:>7.3}s \
             ({:>9.0} evals/s)",
            evals / scalar_s.max(1e-12),
        );
        println!(
            "batched SoA  ({LANES} lanes x {PASSES} passes): {batch_s:>7.3}s \
             ({:>9.0} evals/s, {:.2}x; {} batched evals counted)",
            evals / batch_s.max(1e-12),
            scalar_s / batch_s.max(1e-12),
            cache.stats().batched_evals,
        );
    }

    // --- batched timeline playback: schedule tape vs scalar replay ------
    // The timeline half of the batch tier: one cached tape per
    // (schedule, pp, mb, bucket-shape) key, N lanes replayed over SoA
    // duration columns. The scalar loop re-emits the full task graph
    // per call; the tape replays only the `free_at`/`ends` algebra —
    // bit-identical results (tests/batch_differential.rs). The cold
    // first call prices tape recording; its cost amortizes across every
    // later lane of the same shape. Paste the printed rows into
    // CHANGES.md from a toolchain-equipped run.
    println!("\n# Batched timeline playback (schedule tape, warm cache)\n");
    {
        use canzona::sim::{
            simulate_timeline_batch_into, BreakdownBatch, LaneKnobs, ScenarioBatch,
        };
        for pp in [2usize, 8] {
            let base =
                Scenario::new(Qwen3Size::S8B, 8, 4, pp, OptimKind::Muon, DpStrategy::LbAsc)
                    .with_micro_batches(8);
            const LANES: usize = 1024;
            let mut batch = ScenarioBatch::new(base.clone()).unwrap();
            let mut scalar_scens = Vec::with_capacity(LANES);
            for lane in 0..LANES {
                let mut k = LaneKnobs::from_scenario(&base);
                k.ib_bw *= 0.5 + lane as f64 / LANES as f64; // [0.5x, 1.5x)
                k.straggler = 1.0 + (lane % 8) as f64 * 0.05; // last-stage derate
                if lane % 4 == 0 {
                    k.c_max_bytes = None;
                }
                batch.push(k).unwrap();
                let mut s = base.clone();
                s.hw.ib_bw = k.ib_bw;
                s.straggler = k.straggler;
                s.c_max_bytes = k.c_max_bytes;
                scalar_scens.push(s);
            }
            let cache = PlanCache::unbounded();
            let mut soa = BreakdownBatch::new();
            let t = Instant::now();
            simulate_timeline_batch_into(&batch, &cache, &mut soa); // cold: plans + tape
            let tape_cold_s = t.elapsed().as_secs_f64();
            simulate_timeline_batch_into(&batch, &cache, &mut soa); // settle capacity
            const PASSES: usize = 20;
            let t = Instant::now();
            for _ in 0..PASSES {
                simulate_timeline_batch_into(&batch, &cache, &mut soa);
            }
            black_box(soa.total_s[LANES - 1]);
            let batch_s = t.elapsed().as_secs_f64();
            let mut out = canzona::sim::Breakdown::default();
            canzona::sim::simulate_iteration_into(&scalar_scens[0], &cache, &mut out);
            let t = Instant::now();
            for _ in 0..PASSES {
                for s in &scalar_scens {
                    canzona::sim::simulate_iteration_into(s, &cache, &mut out);
                }
            }
            black_box(out.total_s);
            let scalar_s = t.elapsed().as_secs_f64();
            let evals = (LANES * PASSES) as f64;
            println!(
                "pp={pp} scalar replay ({LANES} lanes x {PASSES} passes): {scalar_s:>7.3}s \
                 ({:>9.0} evals/s)",
                evals / scalar_s.max(1e-12),
            );
            println!(
                "pp={pp} schedule tape ({LANES} lanes x {PASSES} passes): {batch_s:>7.3}s \
                 ({:>9.0} evals/s, {:.2}x; {} timeline lanes counted)",
                evals / batch_s.max(1e-12),
                scalar_s / batch_s.max(1e-12),
                cache.stats().batched_timeline_evals,
            );
            println!(
                "pp={pp} tape-build amortization: cold first call {:.1} us vs \
                 {:.3} us/lane warm ({:.0} lanes to break even on one scalar eval)",
                tape_cold_s * 1e6,
                batch_s * 1e6 / evals,
                tape_cold_s / (scalar_s / evals).max(1e-12),
            );
        }
    }

    // --- branch-and-bound optimize: pruning ratio -----------------------
    // The search must beat exhaustive enumeration on evaluations, not
    // just match its winner (tests/optimize_differential.rs pins the
    // bit-identical-argmin contract; this quantifies the saving). Paste
    // the printed rows into CHANGES.md from a toolchain-equipped run.
    println!("\n# Branch-and-bound optimize vs exhaustive grid\n");
    let search_grid = SweepGrid {
        models: vec![Qwen3Size::S8B],
        dp: vec![8, 16, 32],
        tp: vec![2, 4, 8],
        pp: vec![1, 2],
        micro_batches: vec![1, 8],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon, OptimKind::Shampoo],
        strategies: vec![DpStrategy::Sc, DpStrategy::NvLayerwise, DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(512.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    for objective in [Objective::IterTime, Objective::OptimizerLatency, Objective::Memory] {
        let engine = SweepEngine::new(pool::default_threads());
        let t = Instant::now();
        black_box(engine.run_grid(&search_grid));
        let grid_s = t.elapsed().as_secs_f64();
        let engine = SweepEngine::new(pool::default_threads());
        let opts = OptimizeOptions { objective, ..OptimizeOptions::default() };
        let t = Instant::now();
        let r = optimize(&engine, &search_grid, &opts).unwrap();
        let search_s = t.elapsed().as_secs_f64();
        println!(
            "{:>17}: {:>3} of {:>3} leaves evaluated ({:>4.1}% pruned), \
             search {search_s:>6.3}s vs exhaustive {grid_s:>6.3}s ({:.2}x)",
            objective.label(),
            r.evaluated.len(),
            r.space,
            100.0 * r.pruned as f64 / r.space.max(1) as f64,
            grid_s / search_s.max(1e-12),
        );
    }

    // --- deep-pipeline optimize: the PR 9 timeline-arm bound ------------
    // Every leaf below is on the timeline arm; before the schedule-tape
    // PR the optimizer-latency bound claimed 0 here (degenerating that
    // search to exhaustive enumeration) and the iter-time bound lacked
    // its optimizer term, so the pruning ratios printed now are the
    // bound-tightening deltas. Paste the printed rows into CHANGES.md
    // from a toolchain-equipped run.
    println!("\n# Deep-pipeline optimize (pp grid, timeline-arm bounds)\n");
    let deep_grid = SweepGrid {
        pp: vec![2, 4, 8],
        micro_batches: vec![4, 8],
        schedules: vec![PipelineSchedule::OneFOneB, PipelineSchedule::GPipe],
        stragglers: vec![1.0, 1.3],
        ..search_grid.clone()
    };
    for objective in [Objective::IterTime, Objective::OptimizerLatency] {
        let engine = SweepEngine::new(pool::default_threads());
        let t = Instant::now();
        black_box(engine.run_grid(&deep_grid));
        let grid_s = t.elapsed().as_secs_f64();
        let engine = SweepEngine::new(pool::default_threads());
        let opts = OptimizeOptions { objective, ..OptimizeOptions::default() };
        let t = Instant::now();
        let r = optimize(&engine, &deep_grid, &opts).unwrap();
        let search_s = t.elapsed().as_secs_f64();
        println!(
            "{:>17}: {:>4} of {:>4} timeline leaves evaluated ({:>4.1}% pruned), \
             search {search_s:>6.3}s vs exhaustive {grid_s:>6.3}s ({:.2}x)",
            objective.label(),
            r.evaluated.len(),
            r.space,
            100.0 * r.pruned as f64 / r.space.max(1) as f64,
            grid_s / search_s.max(1e-12),
        );
    }

    // --- elastic fault layer: faulted vs clean evaluation ----------------
    // Heterogeneity / failure knobs route every lane to the scalar
    // timeline arm (faulted scenarios never batch — GroupKey carries the
    // fault state and ScenarioBatch refuses the base) and add the
    // cluster-profile + recovery arithmetic. The rows quantify that toll
    // against the otherwise-identical clean grid. Paste the printed rows
    // into CHANGES.md from a toolchain-equipped run.
    println!("\n# Elastic fault layer: faulted vs clean evaluation\n");
    {
        let clean = SweepGrid {
            models: vec![Qwen3Size::S8B],
            dp: vec![16, 32],
            tp: vec![4, 8],
            pp: vec![1, 2],
            micro_batches: vec![1, 8],
            schedules: vec![PipelineSchedule::OneFOneB],
            stragglers: vec![1.0],
            optims: vec![OptimKind::Muon],
            strategies: vec![DpStrategy::LbAsc, DpStrategy::MatrixFsdp],
            alphas: vec![1.0],
            c_max_mb: vec![Some(512.0)],
            heteros: vec![canzona::sim::HeteroSpec::None],
            fail_ranks: vec![None],
            mttfs: vec![None],
            ckpt_intervals: vec![1],
            metric: CostMetric::Numel,
            fault_seed: 0,
        };
        let faulted = SweepGrid {
            heteros: vec![
                canzona::sim::HeteroSpec::parse("slow:0.05:1.5+link:0.1:4").unwrap(),
            ],
            fail_ranks: vec![Some(canzona::sim::FailSpec { rank: 1, at: 0.5 })],
            mttfs: vec![Some(1800.0)],
            ckpt_intervals: vec![8],
            fault_seed: 7,
            ..clean.clone()
        };
        for (label, grid) in [("clean (batched)", &clean), ("faulted (scalar arm)", &faulted)] {
            let engine = SweepEngine::new(pool::default_threads());
            let scens = grid.scenarios();
            black_box(engine.eval(&scens)); // cold: solve plans + tables
            const PASSES: usize = 10;
            let t = Instant::now();
            for _ in 0..PASSES {
                black_box(engine.eval(&scens));
            }
            let warm_s = t.elapsed().as_secs_f64();
            println!(
                "{label:>22}: {:>3} scenarios, warm {:>8.5}s/pass ({:>9.0} evals/s)",
                scens.len(),
                warm_s / PASSES as f64,
                (scens.len() * PASSES) as f64 / warm_s.max(1e-12),
            );
        }
    }
}
