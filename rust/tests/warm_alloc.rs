//! The warm-path allocation contract: a cache-hit `simulate_iteration`
//! on the closed-form `pp = 1` fast path performs **zero heap
//! allocations**.
//!
//! The crate's global allocator (`util::alloc::CountingAllocator`)
//! counts allocations per thread; after two priming calls (first builds
//! the cached stage tables / plans, second sizes the reused
//! `Breakdown`'s vectors), a third `simulate_iteration_into` must not
//! touch the heap at all — every strategy, with and without fusion, and
//! at TP=1. Scenarios with `pp > 1`, `micro_batches > 1`, or a
//! straggler factor route through the event-driven timeline engine,
//! which builds a task trace and is *expected* to allocate — the last
//! test pins that boundary so the fast-path rule can't silently widen
//! or narrow.

use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{simulate_iteration_into, Breakdown, Scenario};
use canzona::sweep::PlanCache;
use canzona::util::alloc::count_allocations;

fn assert_warm_alloc_free(s: &Scenario, label: &str) {
    // Explicitly unbounded: a CANZONA_CACHE_BUDGET_MB override must not
    // be able to force evictions (and thus warm re-solves) here.
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    simulate_iteration_into(s, &cache, &mut out); // cold: builds tables
    simulate_iteration_into(s, &cache, &mut out); // warm: sizes capacity
    let before = out.total_s;
    let (allocs, _) = count_allocations(|| simulate_iteration_into(s, &cache, &mut out));
    assert_eq!(
        allocs, 0,
        "{label}: warm simulate_iteration performed {allocs} heap allocations",
    );
    assert_eq!(out.total_s.to_bits(), before.to_bits(), "{label}: warm result drifted");
    assert!(out.total_s > 0.0);
}

#[test]
fn warm_simulate_is_allocation_free_for_every_strategy() {
    for strategy in [
        DpStrategy::Sc,
        DpStrategy::NvLayerwise,
        DpStrategy::Asc,
        DpStrategy::LbAsc,
    ] {
        let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, strategy);
        assert_warm_alloc_free(&s, &format!("{strategy:?}"));
    }
}

#[test]
fn warm_simulate_is_allocation_free_no_fuse_and_flops_metric() {
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
        .with_c_max(None);
    assert_warm_alloc_free(&s, "LbAsc/no-fuse");
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Shampoo, DpStrategy::LbAsc)
        .with_metric(canzona::cost::optim::CostMetric::Flops);
    assert_warm_alloc_free(&s, "LbAsc/flops-metric");
}

#[test]
fn warm_simulate_is_allocation_free_at_tp1() {
    let mut s = Scenario::new(Qwen3Size::S1_7B, 8, 1, 1, OptimKind::Muon, DpStrategy::LbAsc);
    s.tp = 1;
    assert_warm_alloc_free(&s, "LbAsc/tp1");
}

#[test]
fn timeline_scenarios_are_outside_the_zero_alloc_contract() {
    // pp=2 routes through the event engine: it must still be warm-cache
    // deterministic, but it builds a task trace (allocates). This pins
    // the fast-path boundary: if the dispatch rule ever sent pp>1
    // through the closed form again, the differential suite would be
    // the only guard — here we assert the boundary itself.
    let mut s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 1, OptimKind::Muon, DpStrategy::LbAsc);
    s.pp = 2;
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    simulate_iteration_into(&s, &cache, &mut out); // cold
    simulate_iteration_into(&s, &cache, &mut out); // warm
    let before = out.total_s;
    let (allocs, _) = count_allocations(|| simulate_iteration_into(&s, &cache, &mut out));
    assert!(allocs > 0, "pp=2 should route through the (allocating) timeline engine");
    assert_eq!(out.total_s.to_bits(), before.to_bits(), "warm timeline result drifted");
}

#[test]
fn cold_path_still_allocates_sanity() {
    // The counter itself must be live in this binary: a cold run (fresh
    // cache) visibly allocates.
    let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    let (allocs, _) = count_allocations(|| simulate_iteration_into(&s, &cache, &mut out));
    assert!(allocs > 0, "cold path must register allocations");
}
