//! The warm-path allocation contract: a cache-hit `simulate_iteration`
//! performs **zero heap allocations** — on *both* dispatch arms.
//!
//! The crate's global allocator (`util::alloc::CountingAllocator`)
//! counts allocations per thread; after two priming calls (first builds
//! the cached stage tables / plans and grows the per-thread scratch,
//! second settles every reused buffer's capacity), a third
//! `simulate_iteration_into` must not touch the heap at all:
//!
//! * the closed-form `pp = 1` fast path — every strategy, with and
//!   without fusion, and at TP = 1;
//! * the event-driven timeline path — `pp ∈ {2, 4}`, `mb = 8`, both
//!   pipeline schedules, straggler ∈ {1.0, 1.5} (and straggler-forced
//!   timeline dispatch at `pp = 1`). The timeline arm stays heap-free
//!   because the lean `Timeline`, the flat pipeline-drive tables and
//!   the interned schedule orders all live in a reusable per-thread
//!   `SimScratch` (see `sim::iteration`'s module docs).

use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{
    simulate_batch_into, simulate_iteration_into, simulate_timeline_batch_into, Breakdown,
    BreakdownBatch, LaneKnobs, PipelineSchedule, Scenario, ScenarioBatch, BATCH_CHUNK,
};
use canzona::sweep::PlanCache;
use canzona::util::alloc::count_allocations;

fn assert_warm_alloc_free(s: &Scenario, label: &str) {
    // Explicitly unbounded: a CANZONA_CACHE_BUDGET_MB override must not
    // be able to force evictions (and thus warm re-solves) here.
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    simulate_iteration_into(s, &cache, &mut out); // cold: builds tables
    simulate_iteration_into(s, &cache, &mut out); // warm: sizes capacity
    let before = out.total_s;
    let solves = cache.stats().solves;
    let (allocs, _) = count_allocations(|| simulate_iteration_into(s, &cache, &mut out));
    assert_eq!(
        allocs, 0,
        "{label}: warm simulate_iteration performed {allocs} heap allocations",
    );
    assert_eq!(cache.stats().solves, solves, "{label}: warm call re-solved a plan");
    assert_eq!(out.total_s.to_bits(), before.to_bits(), "{label}: warm result drifted");
    assert!(out.total_s > 0.0);
}

#[test]
fn warm_simulate_is_allocation_free_for_every_strategy() {
    // The whole strategy zoo: the ladder plus the MatrixFSDP / DMuon /
    // Dion rivals — no strategy arm may allocate on the warm path.
    for strategy in DpStrategy::ALL {
        let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, strategy);
        assert_warm_alloc_free(&s, &format!("{strategy:?}"));
    }
}

#[test]
fn warm_simulate_is_allocation_free_no_fuse_and_flops_metric() {
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc)
        .with_c_max(None);
    assert_warm_alloc_free(&s, "LbAsc/no-fuse");
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Shampoo, DpStrategy::LbAsc)
        .with_metric(canzona::cost::optim::CostMetric::Flops);
    assert_warm_alloc_free(&s, "LbAsc/flops-metric");
}

#[test]
fn warm_simulate_is_allocation_free_at_tp1() {
    let mut s = Scenario::new(Qwen3Size::S1_7B, 8, 1, 1, OptimKind::Muon, DpStrategy::LbAsc);
    s.tp = 1;
    assert_warm_alloc_free(&s, "LbAsc/tp1");
}

#[test]
fn warm_timeline_is_allocation_free_across_the_pp_grid() {
    // The extended contract: warm steady-state on the event-driven
    // timeline path is zero-allocation for every cell of
    // pp ∈ {2, 4} × schedule ∈ {1f1b, gpipe} × straggler ∈ {1.0, 1.5}
    // at mb = 8.
    for pp in [2usize, 4] {
        for sched in [PipelineSchedule::OneFOneB, PipelineSchedule::GPipe] {
            for straggler in [1.0f64, 1.5] {
                let s = Scenario::new(
                    Qwen3Size::S1_7B, 4, 2, pp, OptimKind::Muon, DpStrategy::LbAsc,
                )
                .with_micro_batches(8)
                .with_schedule(sched)
                .with_straggler(straggler);
                assert_warm_alloc_free(
                    &s,
                    &format!("timeline pp{pp}/{}/x{straggler}", sched.label()),
                );
            }
        }
    }
}

#[test]
fn warm_timeline_is_allocation_free_for_other_strategies_and_straggler_pp1() {
    // The AR-path strategies exercise different emitter branches (no
    // parameter All-Gather gating), the rivals exercise the planless
    // stage-table arms, and straggler != 1.0 forces the timeline arm
    // even at pp = 1.
    for strategy in [
        DpStrategy::Sc,
        DpStrategy::NvLayerwise,
        DpStrategy::Asc,
        DpStrategy::MatrixFsdp,
        DpStrategy::DMuon,
        DpStrategy::Dion,
    ] {
        let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, strategy)
            .with_micro_batches(8);
        assert_warm_alloc_free(&s, &format!("timeline {strategy:?}"));
    }
    let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 1, OptimKind::Muon, DpStrategy::LbAsc)
        .with_straggler(1.5);
    assert_warm_alloc_free(&s, "timeline pp1/straggler");
}

#[test]
fn timeline_counters_report_through_the_cache() {
    // The scratch/order/task counters ride the cache handle: a pp>1
    // evaluation schedules tasks, and repeated evaluations on one
    // thread reuse the scratch and the interned schedule order.
    let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc)
        .with_micro_batches(4);
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    simulate_iteration_into(&s, &cache, &mut out);
    let first = cache.stats();
    assert!(first.timeline_tasks > 0, "pp=2 must schedule timeline tasks");
    simulate_iteration_into(&s, &cache, &mut out);
    simulate_iteration_into(&s, &cache, &mut out);
    let warm = cache.stats();
    assert_eq!(warm.timeline_tasks, 3 * first.timeline_tasks,
               "same scenario must schedule the same task count");
    assert!(warm.scratch_reuses >= 2, "warm calls must reuse the scratch");
    assert!(warm.order_hits >= 2, "warm calls must hit the order cache");
}

#[test]
fn warm_path_is_allocation_free_on_persistent_pool_workers() {
    // The contract must hold where the sweep actually runs: on the
    // persistent executor's workers. Each closure primes its own
    // thread's scratch/L1 (two calls), then proves the third call is
    // heap-free — covering both dispatch arms, with the plan-cache
    // reads going through the per-worker L1.
    let cache = PlanCache::unbounded();
    for pp in [1usize, 2] {
        let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, pp, OptimKind::Muon, DpStrategy::LbAsc)
            .with_micro_batches(if pp > 1 { 4 } else { 1 });
        let items: Vec<Scenario> = (0..16).map(|_| s.clone()).collect();
        let counts = canzona::util::pool::parallel_map(&items, 4, |sc| {
            let mut out = Breakdown::default();
            simulate_iteration_into(sc, &cache, &mut out); // cold for this thread
            simulate_iteration_into(sc, &cache, &mut out); // settles capacity
            let (allocs, _) =
                canzona::util::alloc::count_allocations(|| {
                    simulate_iteration_into(sc, &cache, &mut out)
                });
            allocs
        });
        assert!(
            counts.iter().all(|&n| n == 0),
            "pp={pp}: warm calls on pool workers allocated: {counts:?}",
        );
    }
}

#[test]
fn warm_batch_evaluation_is_allocation_free() {
    // The batched SoA path shares the scalar warm-path contract: after
    // two priming calls (first builds the cached tables / plans and
    // grows the per-thread batch scratch, second settles the SoA
    // columns' capacity), a third `simulate_batch_into` on the same
    // batch shape must not touch the heap — including a ragged tail
    // that leaves the last fixed-width chunk partially filled.
    let cache = PlanCache::unbounded();
    let base = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let mut batch = ScenarioBatch::new(base.clone()).expect("closed-form base");
    for lane in 0..BATCH_CHUNK + 3 {
        let mut k = LaneKnobs::from_scenario(&base);
        k.ib_bw *= 1.0 + lane as f64 * 0.125; // distinct lanes, same fingerprint
        k.c_max_bytes = if lane % 2 == 0 { k.c_max_bytes } else { None };
        batch.push(k).expect("valid lane");
    }
    let mut out = BreakdownBatch::new();
    simulate_batch_into(&batch, &cache, &mut out); // cold: builds tables
    simulate_batch_into(&batch, &cache, &mut out); // warm: sizes capacity
    let before = out.total_s[0];
    let evals = cache.stats().batched_evals;
    let (allocs, _) = count_allocations(|| simulate_batch_into(&batch, &cache, &mut out));
    assert_eq!(
        allocs, 0,
        "warm simulate_batch_into performed {allocs} heap allocations",
    );
    assert_eq!(out.len(), batch.len());
    assert_eq!(out.total_s[0].to_bits(), before.to_bits(), "warm batch result drifted");
    assert_eq!(
        cache.stats().batched_evals,
        evals + batch.len() as u64,
        "batched_evals must count every lane of the warm call",
    );
}

#[test]
fn warm_batched_timeline_is_allocation_free_on_persistent_pool_workers() {
    // The schedule-tape tier's warm contract, proven where the sweep
    // actually runs: each persistent worker primes its own thread's
    // tape cache / SoA scratch (two calls), then a third replay of the
    // same batch shape — ragged tail, straggling lanes, No-Fuse lanes
    // included — must not touch the heap.
    let cache = PlanCache::unbounded();
    let base = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc)
        .with_micro_batches(4);
    let mut batch = ScenarioBatch::new(base.clone()).expect("timeline base accepted");
    for lane in 0..BATCH_CHUNK + 3 {
        let mut k = LaneKnobs::from_scenario(&base);
        k.ib_bw *= 1.0 + lane as f64 * 0.125; // distinct lanes, same fingerprint
        k.straggler = 1.0 + lane as f64 * 0.05;
        k.c_max_bytes = if lane % 2 == 0 { k.c_max_bytes } else { None };
        batch.push(k).expect("valid lane");
    }
    let evals = cache.stats().batched_timeline_evals;
    let jobs: Vec<usize> = (0..8).collect();
    let counts = canzona::util::pool::parallel_map(&jobs, 4, |_| {
        let mut out = BreakdownBatch::new();
        simulate_timeline_batch_into(&batch, &cache, &mut out); // cold for this thread
        simulate_timeline_batch_into(&batch, &cache, &mut out); // settles capacity
        let before = out.total_s[0];
        let (allocs, _) = canzona::util::alloc::count_allocations(|| {
            simulate_timeline_batch_into(&batch, &cache, &mut out)
        });
        assert_eq!(out.len(), batch.len());
        assert_eq!(out.total_s[0].to_bits(), before.to_bits(), "warm replay drifted");
        assert!(out.total_s[0] > 0.0);
        allocs
    });
    assert!(
        counts.iter().all(|&n| n == 0),
        "warm batched timeline replay on pool workers allocated: {counts:?}",
    );
    assert_eq!(
        cache.stats().batched_timeline_evals,
        evals + (3 * jobs.len() * batch.len()) as u64,
        "batched_timeline_evals must count every lane of every call",
    );
}

#[test]
fn cold_path_still_allocates_sanity() {
    // The counter itself must be live in this binary: a cold run (fresh
    // cache) visibly allocates.
    let s = Scenario::new(Qwen3Size::S1_7B, 4, 2, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    let (allocs, _) = count_allocations(|| simulate_iteration_into(&s, &cache, &mut out));
    assert!(allocs > 0, "cold path must register allocations");
}
