//! Lifecycle contracts of the persistent executor (`util::pool`):
//!
//! * a panic in a job propagates to the submitting caller **with its
//!   original payload**, and the executor is not poisoned — the next
//!   job runs clean on the same workers;
//! * many tiny batches back-to-back cause **no thread-count growth**
//!   (workers are spawned once per high-water helper count, never per
//!   batch) and per-worker `SimScratch` state visibly survives across
//!   batches (`scratch_reuses` keeps climbing);
//! * the persistent executor's output is byte-identical to the scoped
//!   spawn-per-call reference implementation and to a serial run.
//!
//! Every test here requests at most 8 threads, so concurrently-running
//! tests in this binary can never grow the pool past the count the
//! stress test records.

use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::Scenario;
use canzona::sweep::SweepEngine;
use canzona::util::pool;

#[test]
fn panic_in_a_job_propagates_and_leaves_the_executor_clean() {
    let items: Vec<u32> = (0..100).collect();
    let caught = std::panic::catch_unwind(|| {
        pool::parallel_map(&items, 4, |&x| {
            if x == 42 {
                panic!("scenario {x} exploded");
            }
            x * 2
        })
    });
    let payload = caught.expect_err("the job's panic must reach the caller");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(
        msg.contains("scenario 42 exploded"),
        "panic payload must survive propagation, got {msg:?}",
    );

    // Not poisoned: the very next job on the same executor runs clean,
    // repeatedly.
    for round in 0..3 {
        let out = pool::parallel_map(&items, 4, |&x| x + round);
        assert_eq!(out, items.iter().map(|x| x + round).collect::<Vec<_>>());
    }
}

#[test]
fn tiny_batches_do_not_grow_the_pool() {
    // Prewarm to this file's maximum width, then hammer the executor
    // with small batches: the worker count must not move.
    let warm: Vec<u64> = (0..64).collect();
    pool::parallel_map(&warm, 8, |&x| x);
    let workers = pool::live_workers();
    assert!(workers >= 7, "threads=8 needs >= 7 helpers, got {workers}");

    let tiny: Vec<u64> = (0..8).collect();
    for i in 0..200u64 {
        let out = pool::parallel_map(&tiny, 4, |&x| x.wrapping_mul(i + 1));
        assert_eq!(out.len(), 8);
    }
    assert_eq!(
        pool::live_workers(),
        workers,
        "200 tiny batches must reuse the persistent workers, not spawn",
    );
}

#[test]
fn scratch_reuse_climbs_across_batches() {
    // pp = 2 scenarios route through the timeline engine, whose
    // per-thread SimScratch reports reuse through the engine's cache.
    // With persistent workers the scratches warmed by batch k are still
    // warm for batch k+1, so the counter keeps climbing batch after
    // batch — the cross-batch reuse the persistent executor exists for.
    let engine = SweepEngine::with_budget(4, 0);
    let batch: Vec<Scenario> = (0..16)
        .map(|_| {
            Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc)
                .with_micro_batches(4)
        })
        .collect();

    engine.eval(&batch);
    let after_one = engine.cache_stats().scratch_reuses;
    engine.eval(&batch);
    let after_two = engine.cache_stats().scratch_reuses;
    engine.eval(&batch);
    let after_three = engine.cache_stats().scratch_reuses;

    assert!(
        after_two > after_one,
        "batch 2 must reuse batch 1's worker scratches ({after_one} -> {after_two})",
    );
    assert!(
        after_three > after_two,
        "batch 3 must keep reusing ({after_two} -> {after_three})",
    );
    // At most `threads` playbacks per batch can be first-touches (one
    // per participating thread); everything else must be a reuse.
    assert!(
        after_three - after_two >= (batch.len() - 4) as u64,
        "almost every batch-3 playback should reuse a warm scratch \
         ({after_two} -> {after_three})",
    );
}

#[test]
fn persistent_matches_scoped_reference_and_serial() {
    // The executor rewrite must be invisible in the output: persistent,
    // scoped spawn-per-call, and serial runs all merge byte-identically.
    let items: Vec<u64> = (0..500).map(|i| i * 37 % 211).collect();
    let f = |&x: &u64| x.wrapping_mul(0x9E37_79B9).rotate_left(7) ^ 0x5DEE_CE66;
    let serial: Vec<u64> = items.iter().map(f).collect();
    for threads in [2, 4, 8] {
        assert_eq!(pool::parallel_map(&items, threads, f), serial, "{threads} threads");
        assert_eq!(pool::scoped_map(&items, threads, f), serial, "{threads} threads scoped");
    }
}

#[test]
fn panic_mid_sweep_leaves_engine_usable() {
    // A panicking closure routed through the same executor an engine
    // uses must not corrupt later engine evals.
    let items: Vec<u32> = (0..32).collect();
    let _ = std::panic::catch_unwind(|| {
        pool::parallel_map(&items, 4, |&x| {
            if x % 7 == 3 {
                panic!("boom");
            }
            x
        })
    });
    let engine = SweepEngine::with_budget(4, 0);
    let scens: Vec<Scenario> = (0..8)
        .map(|_| Scenario::new(Qwen3Size::S1_7B, 4, 2, 1, OptimKind::Muon, DpStrategy::LbAsc))
        .collect();
    let a = engine.eval(&scens);
    let b = engine.eval(&scens);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.total_s.to_bits(), y.total_s.to_bits());
        assert!(x.total_s > 0.0);
    }
}
