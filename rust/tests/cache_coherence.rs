//! Coherence of the plan cache's two-level read path: reads served
//! through the lock-free per-thread L1 must be indistinguishable —
//! value-wise and accounting-wise — from reads that always take the
//! shared L2 mutex, across randomized eviction schedules.
//!
//! * every artifact an L1-enabled cache returns is byte-identical to a
//!   direct solve (evictions between reads included: the epoch bump
//!   invalidates the L1 and the deterministic solver recomputes the
//!   same bytes);
//! * an L1-enabled cache and a mutex-only cache driven by the same op
//!   sequence agree **bit-for-bit** on hits, solves, evictions,
//!   resident bytes and membership after every op (the batched recency
//!   touches flush before every insert, so single-threaded eviction
//!   order is exactly the always-locked order);
//! * a multi-threaded engine sweep under eviction pressure renders
//!   byte-identical tables with the L1 on and off.

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::{Atomicity, DpPlan, DpStrategy};
use canzona::sweep::{render_table, DpKey, PlanCache, SweepEngine, SweepGrid, TpKey};
use canzona::util::rng::Rng;

fn dp_key(stage: usize) -> DpKey {
    DpKey {
        model: Qwen3Size::S1_7B,
        stage,
        pp: 1,
        dp: 8,
        tp: 2,
        strategy: DpStrategy::LbAsc,
        optim: None,
        metric: CostMetric::Numel,
        alpha_bits: 1.0f64.to_bits(),
        bucket_elems: 40_000_000,
    }
}

fn tp_key(rank: usize) -> TpKey {
    TpKey {
        dp_key: dp_key(0),
        rank,
        c_max_bits: Some(512e6f64.to_bits()),
        optim: OptimKind::Muon,
    }
}

/// Deterministic synthetic plan; size varies with `i` so eviction
/// schedules differ per key.
fn dp_plan(i: usize) -> DpPlan {
    let ranks = 2 + i % 5;
    DpPlan {
        ranks,
        cuts: vec![(0..=ranks).map(|r| r * (13 + i)).collect()],
        atomicity: Atomicity::None,
    }
}

fn tp_plan(i: usize) -> canzona::schedule::microgroup::TpPlan {
    let tasks: Vec<canzona::schedule::microgroup::TpTask> = (0..(2 + i % 4))
        .map(|id| canzona::schedule::microgroup::TpTask {
            id,
            name: format!("t{id}"),
            cost: 1.0 + (id + i) as f64,
            comm_bytes: 2.0,
            flops: 10.0,
            state_bytes: 4.0,
        })
        .collect();
    canzona::schedule::microgroup::build_micro_groups(tasks, 2, 1e9)
}

#[test]
fn l1_reads_are_byte_identical_to_direct_solves_under_eviction() {
    // Randomized budgets small enough to evict constantly: whatever mix
    // of L1 hits, L2 hits and re-solves a read lands on, the bytes must
    // match a from-scratch solve.
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xC0FE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let budget = 400 + rng.index(2000);
        let cache = PlanCache::with_budget(budget);
        for step in 0..400 {
            let i = rng.index(8);
            if rng.index(2) == 0 {
                let got = cache.dp_plan(&dp_key(i), || dp_plan(i));
                assert_eq!(
                    format!("{got:?}"),
                    format!("{:?}", dp_plan(i)),
                    "seed {seed} step {step}: dp plan {i} diverged from a direct solve",
                );
            } else {
                let got = cache.tp_plan(&tp_key(i), || tp_plan(i));
                assert_eq!(
                    format!("{:?}", got.group_cost),
                    format!("{:?}", tp_plan(i).group_cost),
                    "seed {seed} step {step}: tp plan {i} diverged from a direct solve",
                );
            }
            let s = cache.stats();
            assert!(
                s.budget_bytes == 0 || s.resident_bytes <= s.budget_bytes,
                "seed {seed} step {step}: budget violated {s:?}",
            );
        }
    }
}

#[test]
fn l1_and_mutex_only_paths_agree_bit_for_bit() {
    // The shadow equivalence at the accounting level: same single-thread
    // op sequence, one cache reading through the L1, one always locking.
    // Hits/solves/evictions/resident bytes and per-key membership must
    // match after every op — the L1 is a pure read-path optimization.
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xAB1E ^ seed.wrapping_mul(0x2545F4914F6CDD1D));
        let budget = 400 + rng.index(2000);
        let with_l1 = PlanCache::with_options(budget, true);
        let mutex_only = PlanCache::with_options(budget, false);
        for step in 0..300 {
            let i = rng.index(8);
            if rng.index(2) == 0 {
                with_l1.dp_plan(&dp_key(i), || dp_plan(i));
                mutex_only.dp_plan(&dp_key(i), || dp_plan(i));
            } else {
                with_l1.tp_plan(&tp_key(i), || tp_plan(i));
                mutex_only.tp_plan(&tp_key(i), || tp_plan(i));
            }
            let a = with_l1.stats();
            let b = mutex_only.stats();
            assert_eq!(
                (a.hits, a.solves, a.evictions, a.resident_bytes, a.peak_bytes),
                (b.hits, b.solves, b.evictions, b.resident_bytes, b.peak_bytes),
                "seed {seed} step {step}: read paths diverged",
            );
            for k in 0..8 {
                assert_eq!(
                    with_l1.contains_dp(&dp_key(k)),
                    mutex_only.contains_dp(&dp_key(k)),
                    "seed {seed} step {step}: dp membership diverged at key {k}",
                );
                assert_eq!(
                    with_l1.contains_tp(&tp_key(k)),
                    mutex_only.contains_tp(&tp_key(k)),
                    "seed {seed} step {step}: tp membership diverged at key {k}",
                );
            }
        }
        assert!(
            with_l1.stats().l1_hits > 0,
            "seed {seed}: the L1 path was never exercised",
        );
    }
}

#[test]
fn retiring_participants_release_stale_l1_pins() {
    // A thread's L1 holds Arc clones of what it read. If the owner
    // cache dies (or evicts) while the thread is idle, the pool's
    // participant-retire hook must release the stale L1 instead of
    // pinning the artifacts until some future cache access. The caller
    // participates in every parallel_map job and its retire hook runs
    // before the call returns, so the orphaned-cache case is exactly
    // observable on this thread:
    let weak = {
        let cache = PlanCache::unbounded();
        let a = cache.dp_plan(&dp_key(0), || dp_plan(0)); // in L2 + our L1
        let w = std::sync::Arc::downgrade(&a);
        drop(a);
        w
        // `cache` (the L2) drops here; only this thread's L1 pins it now.
    };
    assert!(
        weak.upgrade().is_some(),
        "precondition: the thread L1 should still hold the artifact",
    );
    // A trivial pool round-trip: the caller's retire hook finds the
    // epoch handle dead (owner cache dropped) and clears the L1.
    let items = [0u8, 1];
    canzona::util::pool::parallel_map(&items, 2, |&x| x);
    assert!(
        weak.upgrade().is_none(),
        "orphaned artifact still pinned by an idle participant's L1",
    );

    // Positive control: a live cache with no evictions keeps its L1
    // across retirement — the next read is still served lock-free.
    let cache = PlanCache::unbounded();
    cache.dp_plan(&dp_key(0), || dp_plan(0));
    canzona::util::pool::parallel_map(&items, 2, |&x| x);
    let l1_hits = cache.stats().l1_hits;
    cache.dp_plan(&dp_key(0), || panic!("hit expected"));
    assert_eq!(
        cache.stats().l1_hits,
        l1_hits + 1,
        "a warm, un-evicted L1 must survive participant retirement",
    );
}

#[test]
fn sweep_under_eviction_pressure_matches_with_l1_on_and_off() {
    // End to end through the engine and real solvers, multi-threaded,
    // with a budget tiny enough to force evictions: the rendered tables
    // (and a warm second pass) must be byte-identical either way.
    let grid = SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![64],
        tp: vec![2, 4],
        pp: vec![1, 2],
        micro_batches: vec![1, 4],
        schedules: vec![canzona::sim::PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let budget = 96 * 1024;
    let l1_engine = SweepEngine::with_cache(4, PlanCache::with_options(budget, true));
    let mutex_engine = SweepEngine::with_cache(4, PlanCache::with_options(budget, false));
    let (scens_a, res_a) = l1_engine.run_grid(&grid);
    let (scens_b, res_b) = mutex_engine.run_grid(&grid);
    assert_eq!(
        render_table(&scens_a, &res_a).render(),
        render_table(&scens_b, &res_b).render(),
        "L1 read path changed sweep results",
    );
    // Warm second pass under continuing pressure.
    let res_a2 = l1_engine.eval(&scens_a);
    let res_b2 = mutex_engine.eval(&scens_b);
    assert_eq!(
        render_table(&scens_a, &res_a2).render(),
        render_table(&scens_b, &res_b2).render(),
        "warm L1 reads changed sweep results",
    );
    let s = l1_engine.cache_stats();
    assert!(s.evictions > 0, "the pressure grid must actually evict: {s:?}");
}
