//! Property pins for the rival sharding strategies (MatrixFSDP, DMuon,
//! Dion) — the invariants that make each rival's cost model *mean*
//! something, beyond the differential oracles' cross-path agreement:
//!
//! * MatrixFSDP's ZeRO-3 row sharding conserves optimizer state exactly:
//!   per-rank bytes sum to the unsharded TP-local census, for every
//!   optimizer and TP degree.
//! * DMuon's LPT tensor assignment respects the greedy makespan bound
//!   (avg + largest item) and gathers every matrix tensor exactly once.
//! * Dion's low-rank state is monotone in the rank fraction and never
//!   exceeds the full-rank (frac = 1) configuration.
//! * The paper's headline ordering survives the zoo: LB-ASC beats
//!   MatrixFSDP on optimizer latency at the 256-GPU Qwen3-32B point.
//! * The user-facing docs (README, docs/CLI.md) list every strategy
//!   token the CLI parses.

mod common;

use canzona::cost::optim::{
    dion_state_bytes, OptimCost, OptimKind, DION_RANK_FRACTION,
};
use canzona::model::{qwen3, tp_split, Qwen3Size};
use canzona::partition::rivals::{lpt_owners, zero3_rows};
use canzona::partition::DpStrategy;
use canzona::sim::{simulate_iteration_into, Breakdown, Scenario};
use canzona::sweep::PlanCache;
use common::close;

fn simulate(s: &Scenario) -> Breakdown {
    let cache = PlanCache::unbounded();
    let mut out = Breakdown::default();
    simulate_iteration_into(s, &cache, &mut out);
    out
}

/// The unsharded TP-local optimizer-state census: matrix shards under
/// the matrix optimizer's model, everything else AdamW (8 bytes/elem) —
/// the same routing `sim::iteration`'s stage tables use.
fn census_state_bytes(size: Qwen3Size, tp: usize, optim: OptimKind) -> f64 {
    let cost = OptimCost::new(optim);
    tp_split(&qwen3(size), tp)
        .iter()
        .map(|sh| {
            if sh.param.is_matrix_opt() {
                cost.state_bytes(&sh.shard_shape)
            } else {
                8.0 * sh.shard_numel as f64
            }
        })
        .sum()
}

#[test]
fn matrix_fsdp_state_conservation_is_exact() {
    // ZeRO-3 row prorating must neither lose nor duplicate state: the
    // per-DP-rank state loads sum to the unsharded census for every
    // optimizer (matrix and element-wise alike) and TP degree.
    for tp in [1usize, 4] {
        for optim in
            [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap, OptimKind::AdamW]
        {
            let s = Scenario::new(
                Qwen3Size::S1_7B, 8, tp, 1, optim, DpStrategy::MatrixFsdp,
            );
            let b = simulate(&s);
            assert_eq!(b.dp_loads_state.len(), s.dp);
            let sharded: f64 = b.dp_loads_state.iter().sum();
            let unsharded = census_state_bytes(Qwen3Size::S1_7B, tp, optim);
            assert!(
                close(sharded, unsharded),
                "tp={tp} {optim:?}: sharded state {sharded:.6e} != census {unsharded:.6e}",
            );
            // And every rank holds strictly less than the whole census.
            for (d, st) in b.dp_loads_state.iter().enumerate() {
                assert!(*st > 0.0 && *st < unsharded, "rank {d}: {st}");
            }
        }
    }
}

#[test]
fn zero3_rows_tile_exactly_for_awkward_shapes() {
    // Integer tiling with no gaps/overlap even when dp doesn't divide
    // rows — the substrate of the conservation property above.
    for (rows, dp) in [(5usize, 4usize), (1, 8), (7, 3), (4096, 32), (9, 9)] {
        let total: usize = (0..dp).map(|d| zero3_rows(rows, dp, d)).sum();
        assert_eq!(total, rows, "rows={rows} dp={dp}");
        // Prefix ranks own the (joint-)largest blocks.
        let first = zero3_rows(rows, dp, 0);
        for d in 0..dp {
            assert!(zero3_rows(rows, dp, d) <= first, "rows={rows} dp={dp} d={d}");
        }
    }
}

#[test]
fn dmuon_lpt_load_respects_the_greedy_makespan_bound() {
    // LPT over full-shape update FLOPs: the pacing rank may exceed the
    // mean only by less than one largest tensor (the classic greedy
    // bound), and every matrix tensor is gathered by exactly one owner.
    let dp = 8usize;
    let cost = OptimCost::new(OptimKind::Muon);
    for size in [Qwen3Size::S1_7B, Qwen3Size::S4B] {
        for tp in [1usize, 4] {
            let flops: Vec<f64> = tp_split(&qwen3(size), tp)
                .iter()
                .filter(|sh| sh.param.is_matrix_opt())
                .map(|sh| cost.flops(&sh.param.shape))
                .collect();
            let owners = lpt_owners(&flops, dp);
            assert_eq!(owners.len(), flops.len());
            assert!(owners.iter().all(|&d| d < dp));
            let mut loads = vec![0.0f64; dp];
            for (k, &d) in owners.iter().enumerate() {
                loads[d] += flops[k];
            }
            let total: f64 = flops.iter().sum();
            let largest = flops.iter().cloned().fold(0.0, f64::max);
            let max_load = loads.iter().cloned().fold(0.0, f64::max);
            assert!(
                max_load <= total / dp as f64 + largest + 1e-6,
                "{size:?}/tp{tp}: LPT makespan {max_load:.3e} breaks avg+max bound",
            );
            // The simulated DP flops loads agree with the local replay.
            let s = Scenario::new(size, dp, tp, 1, OptimKind::Muon, DpStrategy::DMuon);
            let b = simulate(&s);
            let sim_max = b.dp_loads_flops.iter().cloned().fold(0.0, f64::max);
            assert!(
                close(sim_max, max_load),
                "{size:?}/tp{tp}: simulated pacing load {sim_max:.6e} != LPT {max_load:.6e}",
            );
        }
    }
}

#[test]
fn dion_state_is_monotone_in_rank_fraction_and_below_full_rank() {
    // For every matrix shape in the census: state grows with the rank
    // fraction and the default low-rank point stays at or below the
    // frac = 1 full-rank configuration — the memory story that justifies
    // Dion in the first place.
    let dp = 8usize;
    for sh in tp_split(&qwen3(Qwen3Size::S4B), 4) {
        if !sh.param.is_matrix_opt() {
            continue;
        }
        let (m, n) = (sh.shard_shape.rows() as f64, sh.shard_shape.cols() as f64);
        let full = dion_state_bytes(m, n, 1.0, dp);
        let mut prev = 0.0;
        for frac in [0.01, 0.1, DION_RANK_FRACTION, 0.5, 0.75, 1.0] {
            let st = dion_state_bytes(m, n, frac, dp);
            assert!(st >= prev, "{}: state not monotone at frac {frac}", sh.param.name);
            assert!(st <= full + 1e-9, "{}: frac {frac} above full rank", sh.param.name);
            prev = st;
        }
    }
}

#[test]
fn lb_asc_beats_matrix_fsdp_at_the_papers_256_gpu_point() {
    // The headline direction pin: at the paper's main-results
    // configuration (Qwen3-32B, DP=32 x TP=8, Muon), the ladder's
    // LB-ASC optimizer step must beat MatrixFSDP's redundant
    // preconditioner recomputation by a wide margin — and SC, which
    // replicates everything, must trail both.
    let lb = simulate(&Scenario::paper_default());
    let fsdp = simulate(&Scenario::new(
        Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, DpStrategy::MatrixFsdp,
    ));
    let sc = simulate(&Scenario::new(
        Qwen3Size::S32B, 32, 8, 1, OptimKind::Muon, DpStrategy::Sc,
    ));
    assert!(
        lb.optimizer_s * 2.0 < fsdp.optimizer_s,
        "LB-ASC {:.4e}s must be at least 2x faster than MatrixFSDP {:.4e}s",
        lb.optimizer_s,
        fsdp.optimizer_s,
    );
    assert!(
        fsdp.optimizer_s < sc.optimizer_s,
        "MatrixFSDP {:.4e}s must still beat fully-replicated SC {:.4e}s",
        fsdp.optimizer_s,
        sc.optimizer_s,
    );
}

#[test]
fn docs_list_every_cli_strategy_token() {
    // README and docs/CLI.md must document the whole zoo: each CLI
    // token parses, the tokens cover DpStrategy::ALL exactly, and both
    // documents mention every token.
    let tokens =
        ["sc", "nv-layerwise", "asc", "lb-asc", "matrix-fsdp", "dmuon", "dion"];
    let mut parsed: Vec<DpStrategy> = tokens
        .iter()
        .map(|t| DpStrategy::parse(t).unwrap_or_else(|| panic!("token {t} must parse")))
        .collect();
    parsed.sort_by_key(|s| s.ordinal());
    parsed.dedup();
    assert_eq!(parsed.len(), DpStrategy::ALL.len(), "tokens must cover the zoo");

    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
    for doc in ["README.md", "docs/CLI.md"] {
        let text = std::fs::read_to_string(format!("{root}/{doc}"))
            .unwrap_or_else(|e| panic!("{doc}: {e}"))
            .to_ascii_lowercase();
        for t in tokens {
            assert!(text.contains(t), "{doc} does not mention strategy token {t:?}");
        }
    }
}
