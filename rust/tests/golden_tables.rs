//! Golden-table regression tests: the rendered Markdown for `fig3a`,
//! `fig4`, `fig_pp`, `fig_rivals` and `planning` is pinned under
//! `tests/goldens/` so refactors cannot silently drift the paper's
//! numbers.
//!
//! * Missing golden files are bootstrapped from the current output on
//!   first run (and the test passes with a notice) — the repo's build
//!   environment has no way to pre-generate them. Set `REQUIRE_GOLDENS=1`
//!   to turn a missing snapshot into a failure instead (flip it on in CI
//!   once the bootstrapped files are committed, so the gate is real).
//! * `UPDATE_GOLDENS=1 cargo test` refreshes every snapshot after an
//!   intentional model change.
//! * Wall-clock cells (the `planning` table's "N.N ms") are masked to
//!   `<time>` and whitespace-collapsed before comparison; everything
//!   else is byte-compared.

use std::fs;
use std::path::PathBuf;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("goldens")
}

/// Mask wall-clock measurements ("12.3 ms" → "<time> ms") and collapse
/// space runs on masked lines, so only deterministic bytes remain.
fn mask_timings(rendered: &str) -> String {
    let mut out = String::with_capacity(rendered.len());
    for line in rendered.lines() {
        if let Some(pos) = line.find(" ms") {
            let bytes = line.as_bytes();
            let mut start = pos;
            while start > 0 && matches!(bytes[start - 1], b'0'..=b'9' | b'.') {
                start -= 1;
            }
            let masked = format!("{}<time>{}", &line[..start], &line[pos..]);
            let mut collapsed = String::with_capacity(masked.len());
            let mut prev_space = false;
            for c in masked.chars() {
                if c == ' ' {
                    if !prev_space {
                        collapsed.push(c);
                    }
                    prev_space = true;
                } else {
                    collapsed.push(c);
                    prev_space = false;
                }
            }
            out.push_str(&collapsed);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

fn check_golden(id: &str) {
    let rendered: String = canzona::experiments::run(id)
        .unwrap()
        .iter()
        .map(|t| t.render())
        .collect();
    let actual = mask_timings(&rendered);

    let dir = goldens_dir();
    fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}.golden.md", id.replace('-', "_")));

    let env_on = |name: &str| std::env::var(name).map(|v| v == "1").unwrap_or(false);
    let update = env_on("UPDATE_GOLDENS");
    if update || !path.exists() {
        assert!(
            update || !env_on("REQUIRE_GOLDENS"),
            "golden {path:?} is missing and REQUIRE_GOLDENS=1; generate it \
             with `cargo test -q golden` and commit the snapshot",
        );
        fs::write(&path, &actual).unwrap();
        eprintln!(
            "{} golden {path:?}",
            if update { "updated" } else { "bootstrapped" },
        );
        return;
    }
    let expected = fs::read_to_string(&path).unwrap();
    assert_eq!(
        actual, expected,
        "\n`{id}` drifted from {path:?}.\nIf the change is intentional, \
         refresh with `UPDATE_GOLDENS=1 cargo test -q golden`.",
    );
}

#[test]
fn golden_fig3a() {
    check_golden("fig3a");
}

#[test]
fn golden_fig4() {
    check_golden("fig4");
}

#[test]
fn golden_planning() {
    check_golden("planning");
}

#[test]
fn golden_fig_pp() {
    // The timeline engine's pp sweep: every cell is simulated (not
    // wall-clock) time, so the snapshot is fully deterministic.
    check_golden("fig_pp");
}

#[test]
fn golden_fig_rivals() {
    // The strategy-zoo head-to-head (ladder vs MatrixFSDP / DMuon /
    // Dion) on both dispatch arms: every cell is simulated time or a
    // simulated load, so the snapshot is fully deterministic.
    check_golden("fig_rivals");
}

#[test]
fn mask_is_stable_across_magnitudes() {
    let a = mask_timings("| Qwen3-1.7B | 9.8 ms   |\n");
    let b = mask_timings("| Qwen3-1.7B | 123.4 ms |\n");
    assert_eq!(a, b, "masked timings must not depend on the measured value");
    assert!(a.contains("<time> ms"));
    // Deterministic cells are left untouched.
    let t = "| SC | 0.877s | 12.24x |\n";
    assert_eq!(mask_timings(t), t);
}
