//! Edge-case and failure-injection tests across module boundaries:
//! degenerate censuses, single-rank groups, extreme α/C_max values,
//! malformed manifests, and hostile JSON.

use canzona::buffer::FlatBuffer;
use canzona::cost::comm::{CollectiveKind, CommModel};
use canzona::cost::hardware::{Hardware, LinkKind};
use canzona::cost::optim::{CostMetric, OptimCost, OptimKind};
use canzona::model::qwen3::{qwen3, Qwen3Size};
use canzona::model::shapes::{Param, ParamKind, TensorShape};
use canzona::partition::{alpha_balanced, equal_chunk, naive_atomic, naive_atomic_per_bucket};
use canzona::schedule::microgroup::{build_micro_groups, TpTask};
use canzona::sim::{simulate_iteration, Scenario};
use canzona::partition::DpStrategy;
use canzona::util::json::Value;

fn single_param_census() -> Vec<Param> {
    vec![Param::new("lonely", TensorShape::matrix(64, 64), ParamKind::Matrix, Some(0))]
}

#[test]
fn one_param_one_rank() {
    let fb = FlatBuffer::build(&single_param_census(), 1000);
    for plan in [
        alpha_balanced(&fb, 1, 1.0, true, |p| p.numel() as f64),
        naive_atomic(&fb, 1),
        naive_atomic_per_bucket(&fb, 1),
        equal_chunk(&fb, 1),
    ] {
        plan.validate(&fb).unwrap();
        assert_eq!(plan.rank_loads(&fb, |p| p.numel() as f64), vec![4096.0]);
    }
}

#[test]
fn one_param_many_ranks() {
    // A single atomic matrix across 8 ranks: exactly one rank owns it.
    let fb = FlatBuffer::build(&single_param_census(), 1000);
    let plan = alpha_balanced(&fb, 8, 1.0, true, |p| p.numel() as f64);
    plan.validate(&fb).unwrap();
    let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
    assert_eq!(loads.iter().filter(|&&l| l > 0.0).count(), 1);
    assert_eq!(loads.iter().sum::<f64>(), 4096.0);
}

#[test]
fn more_ranks_than_params() {
    let census: Vec<Param> = (0..3)
        .map(|i| Param::new(&format!("p{i}"), TensorShape::matrix(8, 8),
                            ParamKind::Matrix, Some(i)))
        .collect();
    let fb = FlatBuffer::build(&census, usize::MAX);
    let plan = alpha_balanced(&fb, 16, 1.0, false, |p| p.numel() as f64);
    plan.validate(&fb).unwrap();
    let loads = plan.rank_loads(&fb, |p| p.numel() as f64);
    assert_eq!(loads.iter().filter(|&&l| l > 0.0).count(), 3);
}

#[test]
fn alpha_extremes_valid_on_family() {
    let census = qwen3(Qwen3Size::S1_7B);
    let fb = FlatBuffer::build(&census, 40_000_000);
    for alpha in [0.0, 1e-9, 1.0 - 1e-9, 1.0] {
        alpha_balanced(&fb, 32, alpha, true, |p| p.numel() as f64)
            .validate(&fb)
            .unwrap();
    }
}

#[test]
#[should_panic(expected = "alpha out of range")]
fn alpha_above_one_rejected() {
    let fb = FlatBuffer::build(&single_param_census(), 1000);
    alpha_balanced(&fb, 2, 1.5, true, |p| p.numel() as f64);
}

#[test]
fn zero_cost_tasks_schedule() {
    let tasks: Vec<TpTask> = (0..10)
        .map(|id| TpTask {
            id,
            name: format!("z{id}"),
            cost: 0.0,
            comm_bytes: 0.0,
            flops: 0.0,
            state_bytes: 0.0,
        })
        .collect();
    let plan = build_micro_groups(tasks, 4, 1.0);
    assert!(plan.is_complete());
}

#[test]
fn c_max_exactly_largest_task() {
    let tasks: Vec<TpTask> = [10.0, 10.0, 10.0]
        .iter()
        .enumerate()
        .map(|(id, &c)| TpTask {
            id,
            name: format!("t{id}"),
            cost: c,
            comm_bytes: c,
            flops: c,
            state_bytes: c,
        })
        .collect();
    // cap == task cost: each rank may hold exactly one task per group.
    let plan = build_micro_groups(tasks, 2, 10.0);
    assert!(plan.is_complete());
    for g in &plan.groups {
        assert!(g.max_load <= 10.0 + 1e-12);
    }
}

#[test]
fn comm_model_degenerate_sizes() {
    let m = CommModel::new(Hardware::h800());
    // Zero-byte collective still pays the latency floor, nothing more.
    let t0 = m.collective(CollectiveKind::AllReduce, 0.0, 8, LinkKind::InterNode);
    assert!(t0 > 0.0 && t0 < 1e-3, "{t0}");
    assert_eq!(m.collective_v(CollectiveKind::ReduceScatter, &[], LinkKind::InterNode), 0.0);
    assert_eq!(m.volume(CollectiveKind::Broadcast, 100.0, 1), 0.0);
}

#[test]
fn optimizer_cost_tiny_shapes() {
    for kind in [OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap, OptimKind::AdamW] {
        let c = OptimCost::new(kind);
        for shape in [TensorShape::matrix(1, 1), TensorShape::vector(1)] {
            assert!(c.flops(&shape) >= 0.0);
            assert!(c.state_bytes(&shape) > 0.0);
            assert!(c.cost(&shape, CostMetric::Numel) == shape.numel() as f64);
        }
    }
}

#[test]
fn simulator_extreme_grids() {
    // 1x1x1 "cluster" and very wide DP both complete.
    for (dp, tp, pp) in [(1, 1, 1), (256, 1, 1), (1, 8, 1), (2, 2, 8)] {
        let s = Scenario::new(Qwen3Size::S1_7B, dp, tp, pp, OptimKind::Muon,
                              DpStrategy::LbAsc);
        let b = simulate_iteration(&s);
        assert!(b.total_s.is_finite() && b.total_s > 0.0, "dp{dp} tp{tp} pp{pp}");
    }
}

#[test]
fn json_hostile_inputs() {
    for bad in [
        "", "{", "}", "[", "\"", "{\"a\"}", "{\"a\":}", "[1 2]",
        "tru", "1e", "-", "{\"a\":1,}", "\"\\q\"", "\"\\u12\"",
    ] {
        assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
    }
    // Deeply-nested but valid input parses.
    let deep = format!("{}1{}", "[".repeat(100), "]".repeat(100));
    assert!(Value::parse(&deep).is_ok());
}

#[test]
fn json_number_precision() {
    let v = Value::parse("1e308").unwrap();
    assert_eq!(v.as_f64().unwrap(), 1e308);
    let v = Value::parse("-0.5").unwrap();
    assert_eq!(v.as_f64().unwrap(), -0.5);
    assert!(Value::parse("123456789012345").unwrap().as_usize().is_ok());
    assert!(Value::parse("-1").unwrap().as_usize().is_err());
    assert!(Value::parse("1.5").unwrap().as_usize().is_err());
}

#[test]
fn buffer_bucket_size_one() {
    // bucket_size=1 => one bucket per parameter.
    let census = qwen3(Qwen3Size::S1_7B);
    let fb = FlatBuffer::build(&census, 1);
    assert_eq!(fb.buckets.len(), census.len());
    let plan = alpha_balanced(&fb, 8, 1.0, true, |p| p.numel() as f64);
    plan.validate(&fb).unwrap();
}

#[test]
fn strategy_and_optimizer_parsers_roundtrip() {
    for s in ["sc", "asc", "lb-asc", "nv-layerwise"] {
        assert!(DpStrategy::parse(s).is_some(), "{s}");
    }
    for o in ["muon", "shampoo", "soap", "adamw"] {
        assert!(OptimKind::parse(o).is_some(), "{o}");
    }
}
